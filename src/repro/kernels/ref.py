"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references (tests assert_allclose pallas-interpret
vs these) AND the lowering path used on non-TPU backends (the CPU dry-run
lowers these; XLA counts identical matmul FLOPs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.pytree import safe_weight_sum

NEG_INF = -1e30


# --------------------------------------------------------------------------
# attention (GQA, causal, optional sliding window)
# --------------------------------------------------------------------------
def _attention_dense(qg, kf, vf, qpos, kpos, causal, window):
    """qg: (B,H,Sq,D); kf/vf: (B,H,Skv,D). Full score matrix."""
    scores = jnp.einsum("bhqd,bhsd->bhqs", qg, kf)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqs,bhsd->bhqd", probs, vf)


_DENSE_LIMIT = 2048        # max seq for the single-shot score matrix; above
                           # this the flash-equivalent streaming paths run, so
                           # the dry-run's HBM-traffic model matches the TPU
                           # Pallas kernel (K/V streamed per query tile)
_Q_CHUNK = 512             # query tile of the chunked paths


def attention(
    q: jnp.ndarray,           # (B, Sq, H, D)
    k: jnp.ndarray,           # (B, Skv, KV, D)
    v: jnp.ndarray,           # (B, Skv, KV, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,        # absolute position of q[0] (prefill chunks / decode)
    scale: float | None = None,
) -> jnp.ndarray:
    """Oracle attention.  Three lowering paths, all numerically identical:

    - dense:  S <= 4096 — one score matrix (the literal definition);
    - banded: sliding window < Skv — per query tile only the
      [tile_start - window, tile_end) key band is touched (linear cost);
    - flash-style: long full attention — online-softmax scan over KV chunks
      inside a lax.map over query tiles (O(S * chunk) memory).
    """
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    dv = v.shape[-1]          # MLA: value head dim may differ from qk dim
    groups = h // kv
    scale = scale if scale is not None else d ** -0.5

    # GQA via K/V broadcast to H heads (NOT by grouping Q into (KV, G):
    # that reshape breaks GSPMD head-sharding when KV < mesh model size)
    qg = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)   # (B,H,Sq,D)
    kf = jnp.repeat(k.astype(jnp.float32), groups, axis=2).transpose(0, 2, 1, 3)
    vf = jnp.repeat(v.astype(jnp.float32), groups, axis=2).transpose(0, 2, 1, 3)

    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)

    if max(sq, skv) <= _DENSE_LIMIT:
        out = _attention_dense(qg, kf, vf, qpos, kpos, causal, window)
        return out.transpose(0, 2, 1, 3).astype(q.dtype)

    qc = min(_Q_CHUNK, sq)
    n_tiles = sq // qc
    assert sq % qc == 0, f"Sq={sq} not divisible by query tile {qc}"

    if window is not None and window < skv:
        band = window + qc  # static key-band width per tile

        def tile(i):
            q_i = jax.lax.dynamic_slice_in_dim(qg, i * qc, qc, axis=2)
            lo = jnp.clip(i * qc + q_offset - window + 1, 0, skv - band)
            k_i = jax.lax.dynamic_slice_in_dim(kf, lo, band, axis=2)
            v_i = jax.lax.dynamic_slice_in_dim(vf, lo, band, axis=2)
            qp = jnp.arange(qc) + i * qc + q_offset
            kp = jnp.arange(band) + lo
            return _attention_dense(q_i, k_i, v_i, qp, kp, causal, window)

        out = jax.lax.map(tile, jnp.arange(n_tiles))  # (T,B,H,qc,Dv)
        out = out.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, dv)
        return out.astype(q.dtype)

    # flash-style online softmax over KV chunks
    kc = min(1024, skv)
    assert skv % kc == 0
    n_kv = skv // kc
    kfc = kf.reshape(b, h, n_kv, kc, d).transpose(2, 0, 1, 3, 4)
    vfc = vf.reshape(b, h, n_kv, kc, dv).transpose(2, 0, 1, 3, 4)

    def tile(i):
        q_i = jax.lax.dynamic_slice_in_dim(qg, i * qc, qc, axis=2)
        qp = jnp.arange(qc) + i * qc + q_offset

        def kv_step(carry, xs):
            m, l, acc = carry
            j, k_j, v_j = xs
            kp = jnp.arange(kc) + j * kc
            s = jnp.einsum("bhqd,bhsd->bhqs", q_i, k_j)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bhqs,bhsd->bhqd", p, v_j)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, h, qc), NEG_INF),
            jnp.zeros((b, h, qc)),
            jnp.zeros((b, h, qc, dv)),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (jnp.arange(n_kv), kfc, vfc))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(tile, jnp.arange(n_tiles))   # (T,B,H,qc,Dv)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,            # (B, H, D) single new token
    k_cache: jnp.ndarray,      # (B, S, KV, D)
    v_cache: jnp.ndarray,      # (B, S, KV, D)
    *,
    kv_valid: jnp.ndarray,     # (B, S) bool — which cache slots attend
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    groups = h // kv
    scale = scale if scale is not None else d ** -0.5

    # q is tiny: group it (B,KV,G,D); the cache is NEVER copied/expanded —
    # fp32-repeat of a 32k cache costs ~100 GB/device at decode_32k scale.
    qg = ((q.astype(jnp.float32) * scale).astype(k_cache.dtype)
          .reshape(b, kv, groups, d))
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    scores = jnp.where(kv_valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", probs.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# Mamba selective scan
# --------------------------------------------------------------------------
def selective_scan(
    x: jnp.ndarray,    # (B, S, Di)      input sequence
    dt: jnp.ndarray,   # (B, S, Di)      softplus'd step sizes
    A: jnp.ndarray,    # (Di, N)         negative-real state matrix
    Bm: jnp.ndarray,   # (B, S, N)       input->state projection
    Cm: jnp.ndarray,   # (B, S, N)       state->output projection
    D: jnp.ndarray,    # (Di,)           skip
    *,
    init_state: jnp.ndarray | None = None,  # (B, Di, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """y_t = C_t h_t + D x_t,  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t.

    Chunked formulation: lax.scan over sequence chunks carrying the (B,Di,N)
    state, associative scan *within* each chunk.  Materializing full
    (B,S,Di,N) dA/dBx tensors (the textbook parallel form) costs S*N times
    the residual — ~68 GB/layer for Jamba — while the Pallas kernel streams
    the state through VMEM; this oracle matches the kernel's traffic shape.
    """
    bsz, s, di = x.shape
    n = A.shape[-1]
    chunk = min(64, s)
    if s % chunk != 0:
        chunk = s
    n_chunks = s // chunk

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    def chunk_body(h0, xs):
        xc, dtc, bc, cc = xs              # (B, chunk, ...)
        dtf = dtc.astype(jnp.float32)
        dA = jnp.exp(dtf[..., None] * A[None, None])   # (B,c,Di,N)
        dBx = dtf[..., None] * bc[:, :, None, :].astype(jnp.float32) * (
            xc.astype(jnp.float32)[..., None]
        )
        first = dA[:, 0] * h0 + dBx[:, 0]
        dBx = dBx.at[:, 0].set(first)
        dA = dA.at[:, 0].set(jnp.ones_like(dA[:, 0]))
        _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        y = jnp.einsum("bsn,bsdn->bsd", cc.astype(jnp.float32), h)
        y = y + D[None, None].astype(jnp.float32) * xc.astype(jnp.float32)
        return h[:, -1], y.astype(x.dtype)

    def to_chunks(t):
        return t.reshape(bsz, n_chunks, chunk, t.shape[-1]).transpose(1, 0, 2, 3)

    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, di, n), jnp.float32)
    )
    hT, ys = jax.lax.scan(
        jax.checkpoint(chunk_body), h0,
        (to_chunks(x), to_chunks(dt), to_chunks(Bm), to_chunks(Cm)),
    )
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    return y, hT


def selective_scan_step(
    x: jnp.ndarray,    # (B, Di)
    dt: jnp.ndarray,   # (B, Di)
    A: jnp.ndarray,    # (Di, N)
    Bm: jnp.ndarray,   # (B, N)
    Cm: jnp.ndarray,   # (B, N)
    D: jnp.ndarray,    # (Di,)
    state: jnp.ndarray,  # (B, Di, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent step (decode path)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf[..., None] * A[None])
    new_state = dA * state.astype(jnp.float32) + (
        dtf[..., None] * Bm[:, None, :].astype(jnp.float32) * xf[..., None]
    )
    y = jnp.einsum("bn,bdn->bd", Cm.astype(jnp.float32), new_state)
    y = y + D[None].astype(jnp.float32) * xf
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------
# FedAvg weighted aggregation (the server hotspot)
# --------------------------------------------------------------------------
def fedavg_reduce(updates: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(C, N) x (C,) -> (N,): sum_c w_c * u_c / sum_c w_c, fp32 accumulate."""
    wf = weights.astype(jnp.float32)
    acc = jnp.einsum("c,cn->n", wf, updates.astype(jnp.float32))
    return (acc / safe_weight_sum(wf)).astype(updates.dtype)


def topk_scatter_reduce(
    idx: jnp.ndarray,      # (C, k) int32 sparse positions
    val: jnp.ndarray,      # (C, k) fp sparse values
    weights: jnp.ndarray,  # (C,) aggregation weights
    n_params: int,
) -> jnp.ndarray:
    """O(C·k) oracle for the scatter-accumulate kernel: one XLA scatter-add
    of every client's weighted payload into a zero (N,) accumulator — the
    dense (C, N) per-client matrix is never built.  Duplicate indices within
    a client accumulate; weights follow ``safe_weight_sum`` semantics;
    out-of-range indices (corrupt wire) are dropped — masked explicitly, so
    a negative index cannot wrap numpy-style into a valid coordinate."""
    c, k = idx.shape
    wf = weights.astype(jnp.float32)
    if k == 0 or c == 0:
        return jnp.zeros((n_params,), jnp.float32)
    valid = (idx >= 0) & (idx < n_params)
    safe_idx = jnp.where(valid, idx, 0)
    contrib = jnp.where(valid, val.astype(jnp.float32), 0.0) * wf[:, None]
    acc = (
        jnp.zeros((n_params,), jnp.float32)
        .at[safe_idx.reshape(-1)]
        .add(contrib.reshape(-1))
    )
    return acc / safe_weight_sum(wf)


# --------------------------------------------------------------------------
# int8 block quantization (update compression codec)
# --------------------------------------------------------------------------
def quantize_int8(x: jnp.ndarray, block: int = 256) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (N,) fp -> (values int8 (N,), scales fp32 (N/block,)). N % block == 0."""
    xf = x.astype(jnp.float32).reshape(-1, block)
    scale = jnp.max(jnp.abs(xf), axis=1) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xf / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    qf = q.reshape(-1, block).astype(jnp.float32)
    return (qf * scale[:, None]).reshape(-1)


def collective_pack(x: jnp.ndarray, scales: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    """Compressed-collective pack oracle: quantize one device's partial sum
    against a SHARED (pre-pmax'd) per-block scale.  Unlike ``quantize_int8``
    the scale is an input, not derived from ``x`` — scale choice is a
    collective decision, so every reducing device rounds against the same
    grid and the int8-valued payloads sum exactly.  int32 container: the
    psum accumulator dtype (values fit int8; |q| <= 127)."""
    xf = x.astype(jnp.float32).reshape(-1, block)
    sf = scales.astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / sf[:, None]), -127, 127)
    return q.reshape(-1).astype(jnp.int32)


def collective_unpack(q: jnp.ndarray, scales: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    """Fused post-psum dequant oracle: int32 payload (one device's pack or
    the psum of many) * shared block scales -> fp32."""
    qf = q.reshape(-1, block).astype(jnp.float32)
    return (qf * scales.astype(jnp.float32)[:, None]).reshape(-1)


def dequant_reduce(
    q: jnp.ndarray,        # (C, N) int8 wire payload
    scales: jnp.ndarray,   # (C, N/block) fp32 block scales
    weights: jnp.ndarray,  # (C,) aggregation weights
    block: int = 256,
) -> jnp.ndarray:
    """Fused-kernel oracle: dequantize every client row, weighted mean."""
    c, n = q.shape
    x = q.astype(jnp.float32).reshape(c, n // block, block) * (
        scales.astype(jnp.float32)[:, :, None]
    )
    wf = weights.astype(jnp.float32)
    acc = jnp.einsum("c,cn->n", wf, x.reshape(c, n))
    return acc / safe_weight_sum(wf)

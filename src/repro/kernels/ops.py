"""Public kernel entry points with backend dispatch.

Models call these; on TPU they route to the Pallas kernels, elsewhere to the
pure-jnp oracles in ref.py (which is also what the CPU dry-run lowers).
``set_impl`` lets tests force either path (the ``REPRO_KERNEL_IMPL`` env
var sets the same switch at import, which is how CI forces the Pallas
bodies through interpret mode on its CPU runners), and ``interpret=True``
runs the Pallas kernel bodies on CPU for the per-kernel allclose tests.
"""
from __future__ import annotations

import os

from functools import partial

import jax
import jax.numpy as jnp

from . import ref

_IMPL = os.environ.get("REPRO_KERNEL_IMPL", "auto").strip()
if _IMPL not in ("auto", "pallas", "reference"):
    # fail loud: a typo here would silently turn the CI pallas-interpret job
    # into a ref.py run that tests zero kernel bodies
    raise ValueError(
        f"REPRO_KERNEL_IMPL={_IMPL!r}: expected auto | pallas | reference"
    )


def set_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("auto", "pallas", "reference")
    _IMPL = impl


def _use_pallas() -> bool:
    if _IMPL == "pallas":
        return True
    if _IMPL == "reference":
        return False
    return jax.default_backend() == "tpu"


# ---------------- attention ----------------
def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0, interpret=False):
    if _use_pallas() or interpret:
        from .flash_attention import flash_attention as fa

        b, sq, h, d = q.shape
        # kernel needs MXU-aligned tiles; fall back for tiny/ragged shapes
        if sq % 128 == 0 and k.shape[1] % 128 == 0 and d % 8 == 0:
            return fa(
                q, k, v, causal=causal, window=window, q_offset=q_offset,
                interpret=interpret or jax.default_backend() != "tpu",
            )
    return ref.attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, *, kv_valid, interpret=False):
    if _use_pallas() or interpret:
        from .decode_attention import decode_attention as da

        b, s, kv, d = k_cache.shape
        if s % 128 == 0 and d % 8 == 0:
            return da(
                q, k_cache, v_cache, kv_valid=kv_valid,
                interpret=interpret or jax.default_backend() != "tpu",
            )
    return ref.decode_attention(q, k_cache, v_cache, kv_valid=kv_valid)


# ---------------- mamba scan ----------------
def selective_scan(x, dt, A, B, C, D, *, init_state=None, interpret=False):
    if _use_pallas() or interpret:
        from .selective_scan import selective_scan as ss

        if x.shape[1] % 128 == 0:
            return ss(
                x, dt, A, B, C, D, init_state=init_state,
                interpret=interpret or jax.default_backend() != "tpu",
            )
    return ref.selective_scan(x, dt, A, B, C, D, init_state=init_state)


selective_scan_step = ref.selective_scan_step  # trivially small; no kernel


# ---------------- FL aggregation ----------------
def _denormalize(out, weights):
    """Undo the reduce kernels' internal safe_weight_sum normalization,
    turning the weighted mean back into the weighted SUM — the group-partial
    form the mixed-codec engine combines under ONE fleet-wide denominator.
    Exact for the all-zero-weight case (0 * 1 == 0 on both forms)."""
    from repro.utils.pytree import safe_weight_sum

    return out * safe_weight_sum(weights.astype(jnp.float32)).astype(out.dtype)


def fedavg_reduce(updates, weights, *, interpret=False, normalize=True):
    if _use_pallas() or interpret:
        from .fedavg_reduce import fedavg_reduce as fr

        # the kernel pads N up to a lane-aligned tile itself: no shape gate
        out = fr(
            updates, weights,
            interpret=interpret or jax.default_backend() != "tpu",
        )
    else:
        out = ref.fedavg_reduce(updates, weights)
    return out if normalize else _denormalize(out, weights)


def dequant_reduce(q, scales, weights, block: int = 256, *, interpret=False,
                   normalize=True):
    """Fused server-side decode: int8 payload (C,N) + scales -> (N,) mean."""
    if _use_pallas() or interpret:
        from .dequant_reduce import dequant_reduce as dr

        # the encoder pads to a block multiple; the kernel tile-pads beyond
        if q.shape[-1] % block == 0:
            out = dr(
                q, scales, weights, block=block,
                interpret=interpret or jax.default_backend() != "tpu",
            )
            return out if normalize else _denormalize(out, weights)
    out = ref.dequant_reduce(q, scales, weights, block=block)
    return out if normalize else _denormalize(out, weights)


# count of sparse-path dispatches (trace-time): benchmarks/compression_bench
# --smoke asserts this moves when TopK aggregates, so the scatter path cannot
# silently regress to densify-then-reduce
_TOPK_SPARSE_CALLS = 0
# count of dispatches that took the VMEM-resident Pallas branch (vs the XLA
# scatter-add oracle).  Segmented codecs call this reduce once per segment,
# so the `n_params <= MAX_N_PARAMS` gate below sees seg.size — a model whose
# TOTAL size is over budget still takes the Pallas path for every in-budget
# segment; tests pin that per-segment dispatch moves this counter where the
# monolithic flat vector would not.
_TOPK_PALLAS_CALLS = 0


def topk_sparse_calls() -> int:
    return _TOPK_SPARSE_CALLS


def topk_pallas_calls() -> int:
    return _TOPK_PALLAS_CALLS


def topk_scatter_reduce(idx, val, weights, n_params: int, *, interpret=False,
                        normalize=True):
    """Sparse TopK aggregation: (C,k) idx/val + (C,) weights -> (N,) mean.

    O(C·k) on every branch — the Pallas kernel keeps the (N,) accumulator
    VMEM-resident (so it only runs when N fits); above that, the XLA
    scatter-add oracle.  Neither materializes a dense (C, N) matrix.
    ``n_params`` is whatever span the caller reduces — the whole flat
    update, or one segment of a ``SegmentMap``-structured one — so the
    VMEM gate is per-call, i.e. per segment for segmented codecs.
    """
    global _TOPK_SPARSE_CALLS, _TOPK_PALLAS_CALLS
    _TOPK_SPARSE_CALLS += 1
    if _use_pallas() or interpret:
        # the kernel file owns its VMEM budget; the dispatch gate is derived
        # from it (fedlint audits that the two stay consistent)
        from .scatter_reduce import MAX_N_PARAMS, topk_scatter_reduce as sr

        if n_params <= MAX_N_PARAMS:
            _TOPK_PALLAS_CALLS += 1
            out = sr(
                idx, val, weights, n_params,
                interpret=interpret or jax.default_backend() != "tpu",
            )
            return out if normalize else _denormalize(out, weights)
    out = ref.topk_scatter_reduce(idx, val, weights, n_params)
    return out if normalize else _denormalize(out, weights)


# ---------------- int8 codec ----------------
def quantize_int8(x, block: int = 256, *, interpret=False):
    if _use_pallas() or interpret:
        from .quantize import quantize_int8 as qz

        if x.shape[-1] % max(block, 1024) == 0:
            return qz(x, block=block, interpret=interpret or jax.default_backend() != "tpu")
    return ref.quantize_int8(x, block=block)


def dequantize_int8(q, scale, block: int = 256, *, interpret=False):
    if _use_pallas() or interpret:
        from .quantize import dequantize_int8 as dq

        if q.shape[-1] % max(block, 1024) == 0:
            return dq(q, scale, block=block, interpret=interpret or jax.default_backend() != "tpu")
    return ref.dequantize_int8(q, scale, block=block)


# ---------------- compressed collective (mesh psum wire) ----------------
def collective_pack(x, scales, block: int = 256, *, interpret=False):
    """Quantize one device's partial weighted sum against a SHARED per-block
    scale (pre-pmax'd across the reducing devices) -> int32 psum payload
    with every value in [-127, 127] (one int8 byte on the wire)."""
    if _use_pallas() or interpret:
        from .collective_quant import collective_pack as cp

        if x.shape[-1] % max(block, 1024) == 0:
            return cp(x, scales, block=block,
                      interpret=interpret or jax.default_backend() != "tpu")
    return ref.collective_pack(x, scales, block=block)


def collective_unpack(q, scales, block: int = 256, *, interpret=False):
    """Fused post-psum dequant: int32 summed payload + shared scales -> fp32."""
    if _use_pallas() or interpret:
        from .collective_quant import collective_unpack as cu

        if q.shape[-1] % max(block, 1024) == 0:
            return cu(q, scales, block=block,
                      interpret=interpret or jax.default_backend() != "tpu")
    return ref.collective_unpack(q, scales, block=block)

"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel has: <name>.py (pl.pallas_call + BlockSpec), an entry in ops.py
(backend-dispatching jit wrapper) and an oracle in ref.py (pure jnp).  On
this CPU container kernels are validated with interpret=True.
"""
from . import ops, ref

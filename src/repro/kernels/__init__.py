"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel has: <name>.py (pl.pallas_call + BlockSpec), an entry in ops.py
(backend-dispatching jit wrapper) and an oracle in ref.py (pure jnp).  On
this CPU container kernels are validated with interpret=True.

Submodules load lazily (PEP 562): importing ``repro.kernels`` must not pull
in jax — fedlint's import-scan gate (and pytest collection on machines
without any accelerator backend) depends on module import staying inert.
"""
from __future__ import annotations

import importlib

_SUBMODULES = (
    "decode_attention", "dequant_reduce", "fedavg_reduce",
    "flash_attention", "ops", "quantize", "ref", "scatter_reduce",
    "selective_scan",
)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))

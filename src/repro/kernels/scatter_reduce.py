"""Fused TopK scatter-accumulate weighted reduce (Pallas TPU) — the
server-side aggregation of sparse (idx, val) uplinks in O(C·k).

Input is the TopK wire payload of every client: idx (C, k) int32 positions
and val (C, k) fp32 magnitudes, plus the (C,) aggregation weights.  The
densify baseline scatters every client into a dense (C, N) fp32 matrix and
then runs the weighted reduce over it — O(C·N) time AND memory, defeating
the whole point of shipping k << N entries.  This kernel never builds that
matrix: grid = (C,), the (N,) fp32 output accumulator stays resident in
VMEM across all C grid steps (same out-block index every step), and each
step scatters one client's k weighted values into it:

    out[idx[c, j]] += w_c * val[c, j]        for j < k

HBM traffic is the C·k·8-byte payload plus one (N,) result write — the
wire itself is the roofline.  The inner scatter is a fori_loop of k
single-element read-modify-writes against VMEM; that serializes k
lane-granular ops per client, which is the price of arbitrary indices on a
vector unit, but VMEM latency is ~2 orders below HBM and k << N, so the
loop stays far under the dense path's C·N·4-byte HBM cost.

Contract (mirrors ``ref.topk_scatter_reduce``):
- duplicate indices within a client ACCUMULATE (scatter-add, not set);
- weights are auto-normalized with ``safe_weight_sum`` semantics: an
  all-zero weight vector yields a zero average, never NaNs;
- k == 0 (a payload with no entries) yields the zero vector;
- out-of-range indices (negative or >= N — a corrupt/hostile wire
  payload) are DROPPED, identically on kernel and oracle: both sanitize
  before scattering, so neither raw-VMEM writes (here) nor numpy-style
  negative wrapping (XLA scatter) can leak into the aggregate;
- N needs no alignment: the output is lane-padded internally and the pad
  is sliced off (in-range indices never touch the pad).

Fallback: the (N,) accumulator must fit in VMEM, so ``ops`` dispatches to
the XLA scatter-add oracle above ``MAX_N_PARAMS`` (derived from this
file's declared ``VMEM_BUDGET_ELEMS``) — still O(C·k), just not fused.  The only remaining densify path is ``TopKCodec.decode_batch``,
which exists for callers that *want* the dense per-client matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.pytree import safe_weight_sum

# Static VMEM ceiling, audited by fedlint (pallas-vmem-budget): the
# resident footprint of every pallas_call in this file — double-buffered
# pipelined blocks, grid-invariant blocks, scratch — must stay under it.
# Units are fp32-equivalent elements (4 bytes each): 3M elems = 12 MB of
# the ~16 MB/core VMEM.
VMEM_BUDGET_ELEMS = 3 * (1 << 20)

# Worst-case runtime dims the budget is audited against (and that the
# dispatch gate below enforces for n_params).
K_MAX = 1 << 15       # TopK payload width (k = frac * N; 0.01 * 3M < 32768)
C_MAX = 1 << 12       # cohort size of the (1, C) weight row
# Largest dense accumulator the budget admits beside the payload blocks,
# with 2x headroom on the payload: the ops dispatch falls back to the XLA
# scatter-add oracle above this.
MAX_N_PARAMS = (VMEM_BUDGET_ELEMS - 8 * K_MAX - 2 * C_MAX) // 128 * 128
VMEM_ELEMS = MAX_N_PARAMS  # back-compat alias for older callers

VMEM_ASSUMES = {"n_params": MAX_N_PARAMS, "k": K_MAX, "c": C_MAX}


def _scatter_reduce_kernel(idx_ref, val_ref, w_ref, o_ref, *, k: int):
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[0, c]

    def body(j, carry):
        i = idx_ref[0, j]
        o_ref[pl.ds(i, 1)] = o_ref[pl.ds(i, 1)] + (w * val_ref[0, j]).reshape(1)
        return carry

    jax.lax.fori_loop(0, k, body, 0)


@functools.partial(jax.jit, static_argnames=("n_params", "interpret"))
def topk_scatter_reduce(idx, val, weights, n_params: int, *, interpret: bool = False):
    """(C,k) int32 x (C,k) fp x (C,) -> (N,) fp32 weighted mean of the
    scattered sparse updates (weights auto-normalized)."""
    c, k = idx.shape
    assert val.shape == (c, k), (val.shape, idx.shape)
    if k == 0 or c == 0:
        return jnp.zeros((n_params,), jnp.float32)

    # sanitize the wire: out-of-range indices contribute nothing (idx -> 0
    # with val -> 0), so the unchecked VMEM store below cannot be steered
    # outside the accumulator by a corrupt payload
    idx = idx.astype(jnp.int32)
    valid = (idx >= 0) & (idx < n_params)
    idx = jnp.where(valid, idx, 0)
    val = jnp.where(valid, val.astype(jnp.float32), 0.0)

    pad = (-n_params) % 128  # lane-aligned accumulator; idx < N stays clear
    np_ = n_params + pad
    wf = weights.astype(jnp.float32)
    wn = (wf / safe_weight_sum(wf)).reshape(1, c)

    out = pl.pallas_call(
        functools.partial(_scatter_reduce_kernel, k=k),
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((np_,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(idx, val, wn)
    return out[:n_params] if pad else out

"""int8 collective pack/unpack (Pallas TPU) for the compressed mesh psum.

The mesh round's hierarchical psum moves each device's *partial weighted
sum* across the interconnect.  ``CompressedPsum`` (core/compression.py)
shrinks that wire: every device quantizes its partial sum against a
block-max scale that is **shared across the reducing devices** (a cheap
``lax.pmax`` of per-256-block absmax runs before the psum), so the int8
payloads are exactly summable in the integer domain — the int32 psum
loses nothing, ``unpack(sum_d pack(x_d))`` equals
``sum_d unpack(pack(x_d))`` up to ONE final fp32 rounding per element
(instead of a requantization per hop) — and one fused dequant after the
last hop recovers the fp32 sum.

Unlike ``quantize.py`` (the uplink codec, which derives its scale from its
own input), both kernels here take the scale as an INPUT: scale choice is
a collective decision, not a local one.  ``pack`` writes the quantized
values into an int32 container — the psum accumulator dtype; the values
themselves fit int8 (|q| <= 127, the wire carries one byte per element),
and the int32 sum cannot overflow below a 2**31/127 ~= 16.9M-device fan-in.

Grid = (N/bn,); each step packs/unpacks a bn tile (bn % 256 == 0) in one
HBM pass, same streaming shape as the uplink quantizer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256

# Static VMEM ceiling audited by fedlint (pallas-vmem-budget), in
# fp32-equivalent elements (int32 tiles cost the same): 128K elems = 512 KB
# — thin streaming kernels, far below the ~16 MB/core.
VMEM_BUDGET_ELEMS = 1 << 17
VMEM_ASSUMES = {"n": 1 << 22}


def _pack_kernel(x_ref, s_ref, q_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32).reshape(-1, block)
    s = s_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x / s[:, None]), -127, 127)
    q_ref[...] = q.reshape(-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "bn", "interpret"))
def collective_pack(x, scales, *, block: int = BLOCK, bn: int = 8192,
                    interpret: bool = False):
    """x: (N,) fp32, scales: (N/block,) fp32 (shared, pre-pmax'd) ->
    q int32 (N,) with every value in [-127, 127].  N % block == 0."""
    n = x.shape[0]
    bn = min(bn, n)
    assert n % block == 0 and bn % block == 0
    kernel = functools.partial(_pack_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn // block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(x, scales)


def _unpack_kernel(q_ref, s_ref, x_ref, *, block: int):
    q = q_ref[...].astype(jnp.float32).reshape(-1, block)
    s = s_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s[:, None]).reshape(-1)


@functools.partial(jax.jit, static_argnames=("block", "bn", "interpret"))
def collective_unpack(q, scales, *, block: int = BLOCK, bn: int = 8192,
                      interpret: bool = False):
    """q: (N,) int32 (one device's pack, or the psum of many), scales as in
    ``collective_pack`` -> fp32 (N,): the fused post-psum dequant."""
    n = q.shape[0]
    bn = min(bn, n)
    assert n % block == 0 and bn % block == 0
    kernel = functools.partial(_unpack_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn // block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(q, scales)

"""Fused dequantize + FedAvg weighted reduce (Pallas TPU) — the server-side
decode hotspot of the compressed-wire round path.

Input is the int8 wire payload of every client: q (C, N) int8 values and
per-256-block fp32 scales (C, N/block).  The unfused reduce materializes
the dequantized fp32 (C, N) matrix in HBM (4x the int8 payload) and then
reads it back for the weighted reduce — three HBM passes over C x N.  This
kernel makes ONE pass: each grid step loads a (C, bn) int8 tile plus its
scales, dequantizes in VMEM, and contracts against the normalized weight
vector on the MXU (1xC @ Cxbn, fp32 accumulate).  HBM traffic of the
reduce is the int8 payload + scales + the (N,) result — the bandwidth
roofline for this op.  (The error-feedback residual in core/rounds.py
still dequantizes the payload separately, once per round.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.pytree import safe_weight_sum

BLOCK = 256

# Static VMEM ceiling audited by fedlint (pallas-vmem-budget), in
# fp32-equivalent elements (the int8 tile is costed at fp32 — the kernel
# dequantizes it in VMEM anyway): 3M elems = 12 MB of ~16 MB/core.
VMEM_BUDGET_ELEMS = 3 * (1 << 20)
# Worst-case audited dims; the bn clamp below enforces the budget at
# runtime for any cohort up to this C.
VMEM_ASSUMES = {"c": 1024, "n": 1 << 22}


def _dequant_reduce_kernel(q_ref, s_ref, w_ref, o_ref, *, block: int):
    q = q_ref[...].astype(jnp.float32)              # (C, bn)
    s = s_ref[...].astype(jnp.float32)              # (C, bn/block)
    w = w_ref[...].astype(jnp.float32)              # (1, C) normalized weights
    c, bn = q.shape
    x = (q.reshape(c, bn // block, block) * s[:, :, None]).reshape(c, bn)
    acc = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (1, bn)
    o_ref[...] = acc[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "bn", "interpret"))
def dequant_reduce(
    q, scales, weights, *, block: int = BLOCK, bn: int = 8192, interpret: bool = False
):
    """(C,N) int8 x (C,N/block) fp32 x (C,) -> (N,) fp32 weighted mean.

    N % block == 0 (the encoder pads).  N is further padded up to a multiple
    of the tile width bn with zero blocks (zero scale -> zero contribution)
    and the pad is sliced off the result.  Weights are auto-normalized.
    """
    c, n = q.shape
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    assert scales.shape == (c, n // block), scales.shape
    bn = min(bn, n)
    bn = max(block, (bn // block) * block)
    # large-cohort clamp: double-buffered (C, bn) payload + (C, bn/block)
    # scales + the (1, C) weight row + the (bn,) output tile must fit the
    # budget: 2*C*bn + 2*C*bn/block + C + 2*bn <= VMEM_BUDGET_ELEMS
    bn = max(block, min(
        bn, (VMEM_BUDGET_ELEMS - c) // (2 * c + 2 * c // block + 2)
    ) // block * block)
    pad = (-n) % bn
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // block)))
    np_ = n + pad
    wf = weights.astype(jnp.float32)
    wn = (wf / safe_weight_sum(wf)).reshape(1, c)

    out = pl.pallas_call(
        functools.partial(_dequant_reduce_kernel, block=block),
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((c, bn), lambda i: (0, i)),
            pl.BlockSpec((c, bn // block), lambda i: (0, i)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(q, scales, wn)
    return out[:n] if pad else out

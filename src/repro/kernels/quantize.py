"""int8 block quantization codec (Pallas TPU) for FL update compression.

Symmetric per-256-block scaling.  Grid = (N/bn,); each step quantizes a bn
tile (bn % 256 == 0): reshape to (bn/256, 256), rowwise absmax -> scale,
round/clamp to int8.  Dequantize reverses it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256

# Static VMEM ceiling audited by fedlint (pallas-vmem-budget), in
# fp32-equivalent elements (int8 tiles costed at fp32): 128K elems = 512 KB
# — these are thin streaming kernels, far below the ~16 MB/core.
VMEM_BUDGET_ELEMS = 1 << 17
VMEM_ASSUMES = {"n": 1 << 22}


def _quant_kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)                  # (bn,)
    xb = x.reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127)
    q_ref[...] = q.reshape(-1).astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block", "bn", "interpret"))
def quantize_int8(x, *, block: int = BLOCK, bn: int = 8192, interpret: bool = False):
    """x: (N,) -> (q int8 (N,), scales fp32 (N/block,)). N % block == 0."""
    n = x.shape[0]
    bn = min(bn, n)
    assert n % block == 0 and bn % block == 0
    kernel = functools.partial(_quant_kernel, block=block)
    q, s = pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn // block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int8),
            jax.ShapeDtypeStruct((n // block,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def _dequant_kernel(q_ref, s_ref, x_ref, *, block: int):
    q = q_ref[...].astype(jnp.float32).reshape(-1, block)
    s = s_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s[:, None]).reshape(-1).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "bn", "interpret"))
def dequantize_int8(q, scales, *, block: int = BLOCK, bn: int = 8192, interpret: bool = False):
    n = q.shape[0]
    bn = min(bn, n)
    kernel = functools.partial(_dequant_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn // block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(q, scales)

"""Flash attention (Pallas TPU): causal + sliding-window + GQA.

Online-softmax blockwise attention.  Grid = (B*KV*G, Sq/bq, Skv/bk) with the
KV dimension minor-most so the (m, l, acc) VMEM scratch carries across KV
blocks for one query tile; the output tile is written on the last KV block.

Tiles default to 128x128 (MXU-aligned); the head dim stays whole in VMEM.
VMEM footprint per step ~ bq*D + bk*D + bq*bk + bq*(D+2) floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# Static VMEM ceiling audited by fedlint (pallas-vmem-budget), in fp32
# elements: 512K elems = 2 MB — double-buffered q/k/v/o tiles + the
# (m, l, acc) online-softmax scratch at the worst-case head dim below.
VMEM_BUDGET_ELEMS = 1 << 19
VMEM_ASSUMES = {"d": 256, "sq": 1 << 14, "skv": 1 << 14}


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int | None,
    q_offset: int, bq: int, bk: int, n_kv: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    iq = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q, k, v, *, causal: bool = True, window: int | None = None,
    q_offset: int = 0, bq: int = 128, bk: int = 128, interpret: bool = False,
):
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D) -> (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    g = h // kv
    scale = d ** -0.5
    bq = min(bq, sq)
    bk = min(bk, skv)
    n_q, n_kv = sq // bq, skv // bk

    # (B,Sq,H,D) -> (B*KV*G, Sq, D); (B,Skv,KV,D) -> (B*KV, Skv, D)
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, skv, d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, n_kv=n_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)

"""FedAvg weighted aggregation (Pallas TPU) — the server's compute hotspot.

updates: (C, N) flat client updates, weights: (C,).  Grid = (N/bn,): each
step loads a (C, bn) tile and contracts against the weight vector on the MXU
(1xC @ Cxbn), fp32 accumulate — one pass over the C x N payload at HBM
bandwidth, which is the roofline for this op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.pytree import safe_weight_sum

# Static VMEM ceiling audited by fedlint (pallas-vmem-budget), in
# fp32-equivalent elements: 3M elems = 12 MB of the ~16 MB/core VMEM.
VMEM_BUDGET_ELEMS = 3 * (1 << 20)
# Worst-case dims the audit pins: the cohort height of the (C, bn) tile
# and the flat update length.  The bn clamp below keeps any C <= this
# inside the budget at runtime.
VMEM_ASSUMES = {"c": 1024, "n": 1 << 22}


def _reduce_kernel(u_ref, w_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)          # (C, bn)
    w = w_ref[...].astype(jnp.float32)          # (1, C) normalized weights
    acc = jax.lax.dot_general(
        w, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (1, bn)
    o_ref[...] = acc[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def fedavg_reduce(updates, weights, *, bn: int = 8192, interpret: bool = False):
    """(C,N) x (C,) -> (N,) weighted mean (weights auto-normalized).

    N is padded up to a multiple of the tile width bn (ceil-division grid)
    so tail elements are reduced too, and the pad is sliced off the result.
    """
    c, n = updates.shape
    bn = max(128, min(bn, n) // 128 * 128)  # lane-aligned tile width
    # shrink the tile for large cohorts so the double-buffered (C, bn)
    # update tiles + the (1, C) weight row + the (bn,) output stay inside
    # the declared VMEM budget: 2*C*bn + 2*bn + C <= VMEM_BUDGET_ELEMS
    bn = max(128, min(bn, (VMEM_BUDGET_ELEMS - c) // (2 * (c + 1))) // 128 * 128)
    pad = (-n) % bn
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    np_ = n + pad
    wf = weights.astype(jnp.float32)
    wn = (wf / safe_weight_sum(wf)).reshape(1, c)

    out = pl.pallas_call(
        _reduce_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((c, bn), lambda i: (0, i)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), updates.dtype),
        interpret=interpret,
    )(updates, wn)
    return out[:n] if pad else out

"""Single-token decode attention (Pallas TPU): one query against a KV cache.

Grid = (B*KV, S/bk) — KV-length minor so the per-(batch, kv-head) online
softmax state for the G grouped query heads carries in VMEM scratch.
Validity of cache slots (ring buffers, unfilled tails) comes in as an int32
mask rather than positions, so the same kernel serves linear and ring caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# Static VMEM ceiling audited by fedlint (pallas-vmem-budget), in fp32
# elements: 256K elems = 1 MB — q/o hold G grouped heads, k/v stream in
# bk-wide cache tiles, softmax state in scratch.
VMEM_BUDGET_ELEMS = 1 << 18
VMEM_ASSUMES = {"d": 256, "g": 16, "s": 1 << 14}


def _decode_kernel(
    q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, n_kv_blocks: int,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale   # (G, D)
    k = k_ref[0].astype(jnp.float32)           # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    valid = valid_ref[0] != 0                  # (bk,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, bk)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k_cache, v_cache, *, kv_valid, bk: int = 128, interpret: bool = False):
    """q: (B,H,D); caches: (B,S,KV,D); kv_valid: (B,S) bool -> (B,H,D)."""
    b, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    g = h // kv
    scale = d ** -0.5
    bk = min(bk, s)
    n_kv = s // bk

    qr = q.reshape(b * kv, g, d)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    validr = jnp.broadcast_to(
        kv_valid[:, None, :].astype(jnp.int32), (b, kv, s)
    ).reshape(b * kv, s)

    kernel = functools.partial(_decode_kernel, scale=scale, n_kv_blocks=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(b * kv, n_kv),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bk_, ik: (bk_, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda bk_, ik: (bk_, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bk_, ik: (bk_, ik, 0)),
            pl.BlockSpec((1, bk), lambda bk_, ik: (bk_, ik)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bk_, ik: (bk_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, validr)
    return out.reshape(b, h, d)

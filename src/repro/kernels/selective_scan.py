"""Mamba selective scan (Pallas TPU).

Grid = (B, Di/bd, S/chunk) with the sequence-chunk dim minor-most: the SSM
state h (bd, N) lives in VMEM scratch and carries across chunks; within a
chunk the recurrence h = dA*h + dB*x steps sequentially (N and bd are the
vector lanes — each step is a (bd, N) elementwise FMA, which is VPU work;
the chunk dim amortizes HBM<->VMEM traffic of x/dt/B/C tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Static VMEM ceiling audited by fedlint (pallas-vmem-budget), in fp32
# elements: 1M elems = 4 MB — chunked x/dt/B/C tiles, the (bd, N) state
# scratch, and the double-buffered carry blocks at the dims below.
VMEM_BUDGET_ELEMS = 1 << 20
VMEM_ASSUMES = {"n": 64, "s": 1 << 13, "di": 1 << 12}


def _scan_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
    y_ref, hout_ref, h_ref,
    *, chunk: int, n_chunks: int, use_h0: bool,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        if use_h0:
            h_ref[...] = h0_ref[0].astype(jnp.float32)
        else:
            h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)          # (bd, N)
    D = d_ref[...].astype(jnp.float32)          # (1, bd)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)       # (bd,)
        dt_t = dt_ref[0, t].astype(jnp.float32)     # (bd,)
        b_t = b_ref[0, t].astype(jnp.float32)       # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)       # (N,)
        dA = jnp.exp(dt_t[:, None] * A)             # (bd, N)
        h = dA * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=1) + D[0] * x_t
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ic == n_chunks - 1)
    def _done():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def selective_scan(
    x, dt, A, B, C, D, *, init_state=None, bd: int = 512, chunk: int = 128,
    interpret: bool = False,
):
    """x, dt: (B,S,Di); A: (Di,N); B,C: (B,S,N); D: (Di,) ->
    (y (B,S,Di), final_state (B,Di,N))."""
    bsz, s, di = x.shape
    n = A.shape[-1]
    bd = min(bd, di)
    chunk = min(chunk, s)
    n_chunks = s // chunk
    nd = di // bd

    use_h0 = init_state is not None
    h0 = (
        init_state.astype(jnp.float32)
        if use_h0
        else jnp.zeros((bsz, di, n), jnp.float32)
    )
    D2 = D.reshape(1, di)

    kernel = functools.partial(
        _scan_kernel, chunk=chunk, n_chunks=n_chunks, use_h0=use_h0
    )
    y, hout = pl.pallas_call(
        kernel,
        grid=(bsz, nd, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda ib, idd, ic: (ib, ic, idd)),
            pl.BlockSpec((1, chunk, bd), lambda ib, idd, ic: (ib, ic, idd)),
            pl.BlockSpec((bd, n), lambda ib, idd, ic: (idd, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, idd, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, idd, ic: (ib, ic, 0)),
            pl.BlockSpec((1, bd), lambda ib, idd, ic: (0, idd)),
            pl.BlockSpec((1, bd, n), lambda ib, idd, ic: (ib, idd, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda ib, idd, ic: (ib, ic, idd)),
            pl.BlockSpec((1, bd, n), lambda ib, idd, ic: (ib, idd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), x.dtype),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D2, h0)
    return y, hout

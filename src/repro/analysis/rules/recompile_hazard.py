"""Rule 3: recompile-hazard — jit signatures that retrace or fail to cache.

For every jitted function (``@jax.jit``, ``@functools.partial(jax.jit,
static_argnames=...)``, or ``f = jax.jit(g, ...)`` where ``g`` resolves):

- ``unknown-static``: ``static_argnames`` names a parameter the function
  does not have (silently ignored by jax -> the arg retraces every call).
- ``unhashable-static``: a static parameter's default is a dict/list/set
  literal — jit hashes static args, so the first call raises.
- ``py-scalar-arg``: a call site passes a Python scalar literal to a
  NON-static parameter.  Weak-typed scalars bake into the trace and every
  distinct value recompiles.
- ``container-arg``: a call site passes a dict/list literal to a
  non-static parameter whose values are scalar literals (a pytree of
  baked-in constants — same retrace-per-value hazard, spelled bigger).
- ``varying-shape``: two call sites construct the same non-static
  parameter with different literal shapes (``jnp.zeros((8,))`` vs
  ``jnp.zeros((16,))``) — each shape is a separate compile; fine when
  intended, a silent compile-storm when not.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from ..core import Finding, FunctionInfo, Project, attr_chain, iter_calls

NAME = "recompile-hazard"
SHAPE_CTORS = {"zeros", "ones", "full", "empty"}


@dataclass
class JitInfo:
    fn: FunctionInfo
    static: set[str]
    line: int
    # param name -> shape tuple -> first line seen (for varying-shape)
    shapes: dict[str, dict[tuple, int]] = field(default_factory=dict)


def _is_jax_jit(node: ast.AST, mod) -> bool:
    chain = attr_chain(node)
    if chain and chain[-1] == "jit":
        if chain[0] in mod.jax_aliases or chain == ["jit"]:
            return True
        if mod.from_imports.get(chain[0], ("", ""))[0] == "jax":
            return True
    return False


def _static_names(call: ast.Call) -> set[str]:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            if kw.arg == "static_argnums":
                return set()  # positional statics: out of scope
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                return {
                    e.value for e in v.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)
                }
    return set()


def _partial_jit(call: ast.Call, mod) -> set[str] | None:
    """functools.partial(jax.jit, static_argnames=...) -> static set."""
    chain = attr_chain(call.func)
    if not chain:
        return None
    is_partial = chain[-1] == "partial" and (
        len(chain) == 1 or chain[0] in ("functools",)
        or mod.from_imports.get(chain[0], ("", ""))[0] == "functools"
    )
    if is_partial and call.args and _is_jax_jit(call.args[0], mod):
        return _static_names(call)
    return None


def _params(fnode) -> list[str]:
    a = fnode.args
    return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


def _collect_jitted(project: Project) -> dict[FunctionInfo, JitInfo]:
    jitted: dict[FunctionInfo, JitInfo] = {}
    for mod in project.modules.values():
        for fn in mod.functions.values():
            node = fn.node
            for dec in getattr(node, "decorator_list", []):
                static = None
                if _is_jax_jit(dec, mod):
                    static = set()
                elif isinstance(dec, ast.Call):
                    if _is_jax_jit(dec.func, mod):
                        static = _static_names(dec)
                    else:
                        static = _partial_jit(dec, mod)
                if static is not None:
                    jitted[fn] = JitInfo(fn, static, dec.lineno)
        # assignment form: f = jax.jit(g, static_argnames=...)
        for owner in mod.functions.values():
            for call in iter_calls(owner.node):
                if not (isinstance(call.func, (ast.Attribute, ast.Name))
                        and _is_jax_jit(call.func, mod)):
                    continue
                if call.args and isinstance(call.args[0], ast.Name):
                    targets = project.resolve_call(
                        owner, ast.Call(func=call.args[0], args=[], keywords=[])
                    )
                    for t in targets:
                        jitted.setdefault(
                            t, JitInfo(t, _static_names(call), call.lineno)
                        )
    return jitted


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    jitted = _collect_jitted(project)

    for fn, info in jitted.items():
        params = set(_params(fn.node))
        for s in sorted(info.static - params):
            findings.append(Finding(
                NAME, fn.module.path, info.line, fn.qualname,
                "unknown-static",
                f"static_argnames names {s!r} but {fn.name}() has no such "
                "parameter — jax ignores it and the arg retraces",
            ))
        a = fn.node.args
        named = a.posonlyargs + a.args + a.kwonlyargs
        defaults = dict(zip(
            [p.arg for p in a.posonlyargs + a.args][-len(a.defaults):]
            if a.defaults else [], a.defaults,
        ))
        defaults.update({
            p.arg: d for p, d in zip(a.kwonlyargs, a.kw_defaults) if d
        })
        for p in named:
            if p.arg in info.static and isinstance(
                defaults.get(p.arg), (ast.Dict, ast.List, ast.Set)
            ):
                findings.append(Finding(
                    NAME, fn.module.path, fn.node.lineno, fn.qualname,
                    "unhashable-static",
                    f"static parameter {p.arg!r} defaults to an unhashable "
                    "container — jit hashes static args; this raises on "
                    "first call",
                ))

    # call-site checks
    for mod in project.modules.values():
        for caller in mod.functions.values():
            for call in iter_calls(caller.node):
                for target in project.resolve_call(caller, call):
                    info = jitted.get(target)
                    if info is None:
                        continue
                    findings.extend(
                        _check_site(mod, caller, call, target, info)
                    )

    # varying-shape: aggregated across sites per (fn, param)
    for fn, info in jitted.items():
        for pname, shapes in info.shapes.items():
            if len(shapes) > 1:
                desc = ", ".join(
                    f"{s} (line {ln})" for s, ln in sorted(shapes.items())
                )
                findings.append(Finding(
                    NAME, fn.module.path, min(shapes.values()), fn.qualname,
                    "varying-shape",
                    f"non-static parameter {pname!r} receives arrays of "
                    f"different literal shapes: {desc} — each shape is a "
                    "separate XLA compile",
                ))
    return findings


def _literal_shape(node: ast.AST) -> tuple | None:
    if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if chain and chain[-1] in SHAPE_CTORS and node.args:
            shp = node.args[0]
            if isinstance(shp, ast.Tuple) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in shp.elts
            ):
                return tuple(e.value for e in shp.elts)
    return None


def _check_site(mod, caller, call, target, info: JitInfo):
    a = target.node.args
    pos_params = [p.arg for p in a.posonlyargs + a.args]
    bound: list[tuple[str, ast.AST]] = []
    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred) or i >= len(pos_params):
            break
        bound.append((pos_params[i], arg))
    bound.extend((kw.arg, kw.value) for kw in call.keywords if kw.arg)
    for pname, val in bound:
        if pname in info.static:
            continue
        if isinstance(val, ast.Constant) and isinstance(
            val.value, (int, float, bool)
        ):
            yield Finding(
                NAME, mod.path, call.lineno, caller.qualname,
                "py-scalar-arg",
                f"Python scalar {val.value!r} passed to non-static "
                f"parameter {pname!r} of jitted {target.name}() — it bakes "
                "into the trace; every distinct value recompiles (make it "
                "static or pass an array)",
            )
        elif isinstance(val, (ast.Dict, ast.List)) and any(
            isinstance(e, ast.Constant) and isinstance(e.value, (int, float))
            for e in (val.values if isinstance(val, ast.Dict) else val.elts)
        ):
            yield Finding(
                NAME, mod.path, call.lineno, caller.qualname,
                "container-arg",
                f"literal container of Python scalars passed to non-static "
                f"parameter {pname!r} of jitted {target.name}() — a pytree "
                "of baked-in constants retraces per value",
            )
        shp = _literal_shape(val)
        if shp is not None:
            info.shapes.setdefault(pname, {}).setdefault(shp, call.lineno)

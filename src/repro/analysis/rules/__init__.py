"""fedlint rule registry."""
from __future__ import annotations

from . import (
    jit_host_sync,
    mask_nan,
    pallas_vmem,
    recompile_hazard,
    rng_discipline,
    wire_accounting,
)

ALL_RULES = (
    jit_host_sync,
    rng_discipline,
    recompile_hazard,
    pallas_vmem,
    mask_nan,
    wire_accounting,
)

RULES_BY_NAME = {r.NAME: r for r in ALL_RULES}

"""Rule 6: wire-accounting — codecs that change the wire must re-cost it.

The paper's entire argument runs through measured communication cost, so a
codec whose ``encode``/``decode`` changes the wire format while inheriting
the parent's ``wire_bytes`` silently mis-costs every experiment.

A class is a codec when its (transitive, name-resolved) base chain contains
a class that itself defines ``wire_bytes`` or ``_wire_bytes_scalar``.  If
such a subclass overrides ``encode``/``decode``/``encode_batch``/
``decode_batch`` but defines neither ``wire_bytes`` nor
``_wire_bytes_scalar``, it is flagged.

The segmented surface carries the same contract per segment: a codec that
overrides ``encode_segment``/``decode_segment`` changes what one segment's
wire carries, and the base ``segment_wire_bytes`` (which bills the *flat*
format at ``seg.size``) silently mis-costs it — so such an override must
restate ``segment_wire_bytes`` (flat ``wire_bytes`` does not discharge
this: the segmented billing path never calls it).

The *collective* surface carries it too: a class whose methods psum an
ENCODED payload (any ``...psum(...)`` call next to a pack/encode-family
call in the same class) changes what crosses the mesh links per hop, and
the fp32 default accounting silently mis-bills it — such a class must
define ``collective_bytes`` stating its per-device per-hop wire size
(``compression.CompressedPsum`` is the canonical example).  Plain fp32
psums (no encode in the class) are the billed default and are not flagged.
"""
from __future__ import annotations

from ..core import Finding, Project, attr_chain, iter_calls

NAME = "wire-accounting"
WIRE_METHODS = ("wire_bytes", "_wire_bytes_scalar")
CODEC_METHODS = ("encode", "decode", "encode_batch", "decode_batch")
SEGMENT_WIRE_METHODS = ("segment_wire_bytes",)
SEGMENT_CODEC_METHODS = ("encode_segment", "decode_segment")
COLLECTIVE_WIRE_METHODS = ("collective_bytes",)
# pack/encode-family callees that put an encoded payload on the wire
COLLECTIVE_PACK_CALLS = (
    "collective_pack", "encode", "encode_segment", "encode_batch",
)


def _class_index(project: Project):
    idx = {}
    for mod in project.modules.values():
        for cls in mod.classes.values():
            idx.setdefault(cls.name, []).append((mod, cls))
    return idx


def _defines_wire(cls) -> bool:
    return any(m in cls.methods for m in WIRE_METHODS)


def _ancestry_defines_wire(cls, idx, seen=None) -> bool:
    """Any base (transitively, resolved by name project-wide) defines the
    wire-accounting methods?"""
    seen = seen or set()
    for base in cls.bases:
        if base in seen:
            continue
        seen.add(base)
        for _, bcls in idx.get(base, []):
            if _defines_wire(bcls) or _ancestry_defines_wire(
                bcls, idx, seen
            ):
                return True
    return False


def _class_call_names(cls) -> set[str]:
    """Last attr-chain component of every call made by the class's own
    methods (``jax.lax.psum`` -> "psum", ``ops.collective_pack`` ->
    "collective_pack", bare ``encode(...)`` -> "encode")."""
    names = set()
    for fn in cls.methods.values():
        for call in iter_calls(fn.node):
            chain = attr_chain(call.func)
            if chain:
                names.add(chain[-1])
    return names


def _check_collective(mod, cls) -> Finding | None:
    """A class that psums an encoded payload must restate collective_bytes."""
    calls = _class_call_names(cls)
    if "psum" not in calls:
        return None
    packs = sorted(calls & set(COLLECTIVE_PACK_CALLS))
    if not packs or any(m in cls.methods for m in COLLECTIVE_WIRE_METHODS):
        return None
    return Finding(
        NAME, mod.path, cls.node.lineno, cls.name,
        "collective-bytes-not-stated",
        f"{cls.name} psums an encoded payload ({'/'.join(packs)}) but "
        "does not define collective_bytes — the cost model will bill the "
        "fp32 collective for wire the class compressed; state the "
        "per-device per-hop byte size",
    )


def check(project: Project) -> list[Finding]:
    findings = []
    idx = _class_index(project)
    for mod in project.modules.values():
        for cls in mod.classes.values():
            coll = _check_collective(mod, cls)
            if coll is not None:
                findings.append(coll)
            if not _ancestry_defines_wire(cls, idx):
                continue
            overridden = [m for m in CODEC_METHODS if m in cls.methods]
            if overridden and not _defines_wire(cls):
                findings.append(Finding(
                    NAME, mod.path, cls.node.lineno, cls.name,
                    "wire-bytes-not-overridden",
                    f"codec {cls.name} overrides "
                    f"{'/'.join(overridden)} but inherits wire_bytes — "
                    "the cost model will bill the parent's wire format; "
                    "override wire_bytes or _wire_bytes_scalar",
                ))
            seg_overridden = [
                m for m in SEGMENT_CODEC_METHODS if m in cls.methods
            ]
            if seg_overridden and not any(
                m in cls.methods for m in SEGMENT_WIRE_METHODS
            ):
                findings.append(Finding(
                    NAME, mod.path, cls.node.lineno, cls.name,
                    "segment-wire-bytes-not-overridden",
                    f"codec {cls.name} overrides "
                    f"{'/'.join(seg_overridden)} but inherits "
                    "segment_wire_bytes — the segmented billing path will "
                    "cost the parent's per-segment wire format; override "
                    "segment_wire_bytes",
                ))
    return findings

"""Rule 6: wire-accounting — codecs that change the wire must re-cost it.

The paper's entire argument runs through measured communication cost, so a
codec whose ``encode``/``decode`` changes the wire format while inheriting
the parent's ``wire_bytes`` silently mis-costs every experiment.

A class is a codec when its (transitive, name-resolved) base chain contains
a class that itself defines ``wire_bytes`` or ``_wire_bytes_scalar``.  If
such a subclass overrides ``encode``/``decode``/``encode_batch``/
``decode_batch`` but defines neither ``wire_bytes`` nor
``_wire_bytes_scalar``, it is flagged.

The segmented surface carries the same contract per segment: a codec that
overrides ``encode_segment``/``decode_segment`` changes what one segment's
wire carries, and the base ``segment_wire_bytes`` (which bills the *flat*
format at ``seg.size``) silently mis-costs it — so such an override must
restate ``segment_wire_bytes`` (flat ``wire_bytes`` does not discharge
this: the segmented billing path never calls it).
"""
from __future__ import annotations

from ..core import Finding, Project

NAME = "wire-accounting"
WIRE_METHODS = ("wire_bytes", "_wire_bytes_scalar")
CODEC_METHODS = ("encode", "decode", "encode_batch", "decode_batch")
SEGMENT_WIRE_METHODS = ("segment_wire_bytes",)
SEGMENT_CODEC_METHODS = ("encode_segment", "decode_segment")


def _class_index(project: Project):
    idx = {}
    for mod in project.modules.values():
        for cls in mod.classes.values():
            idx.setdefault(cls.name, []).append((mod, cls))
    return idx


def _defines_wire(cls) -> bool:
    return any(m in cls.methods for m in WIRE_METHODS)


def _ancestry_defines_wire(cls, idx, seen=None) -> bool:
    """Any base (transitively, resolved by name project-wide) defines the
    wire-accounting methods?"""
    seen = seen or set()
    for base in cls.bases:
        if base in seen:
            continue
        seen.add(base)
        for _, bcls in idx.get(base, []):
            if _defines_wire(bcls) or _ancestry_defines_wire(
                bcls, idx, seen
            ):
                return True
    return False


def check(project: Project) -> list[Finding]:
    findings = []
    idx = _class_index(project)
    for mod in project.modules.values():
        for cls in mod.classes.values():
            if not _ancestry_defines_wire(cls, idx):
                continue
            overridden = [m for m in CODEC_METHODS if m in cls.methods]
            if overridden and not _defines_wire(cls):
                findings.append(Finding(
                    NAME, mod.path, cls.node.lineno, cls.name,
                    "wire-bytes-not-overridden",
                    f"codec {cls.name} overrides "
                    f"{'/'.join(overridden)} but inherits wire_bytes — "
                    "the cost model will bill the parent's wire format; "
                    "override wire_bytes or _wire_bytes_scalar",
                ))
            seg_overridden = [
                m for m in SEGMENT_CODEC_METHODS if m in cls.methods
            ]
            if seg_overridden and not any(
                m in cls.methods for m in SEGMENT_WIRE_METHODS
            ):
                findings.append(Finding(
                    NAME, mod.path, cls.node.lineno, cls.name,
                    "segment-wire-bytes-not-overridden",
                    f"codec {cls.name} overrides "
                    f"{'/'.join(seg_overridden)} but inherits "
                    "segment_wire_bytes — the segmented billing path will "
                    "cost the parent's per-segment wire format; override "
                    "segment_wire_bytes",
                ))
    return findings

"""Rule 2: rng-discipline — seeds compose as tuples, keys split before reuse.

Three defect classes:

- ``additive-seed``: ``default_rng(seed * 1000 + rnd)`` (or any seed
  expression arithmetically combining >= 2 variables).  Affine maps
  collide: seed k+1 round r replays seed k round r+1000, silently
  correlating "independent" runs.  numpy accepts sequences — spell it
  ``default_rng((seed, rnd))``.  PR 5 review round 3 fixed exactly this;
  the rule makes the fix permanent.
- ``round-only-seed``: ``default_rng(rnd)`` — a stream derived from the
  round index alone ignores the experiment seed entirely, so every seed
  produces the same data.
- ``key-reuse``: the same ``jax.random`` key (name or constant-index
  subscript like ``ks[0]``) fed to two sinks without an interleaving
  ``split`` / reassignment — the second sink replays the first's stream.
"""
from __future__ import annotations

import ast

from ..core import Finding, Project, attr_chain, own_nodes

NAME = "rng-discipline"
SEED_SINKS_ARG0 = {"default_rng", "PRNGKey", "key"}
SEED_KWARGS = {"seed"}
# non-sinks: constructors take seeds (not keys), split/fold_in derive
SPLITTERS = {"split", "fold_in", "clone", "key_data", "wrap_key_data",
             "key", "PRNGKey"}


def _var_leaves(node: ast.AST) -> set[str]:
    """Distinct variable leaves of an expression; dotted attrs count once."""
    out: set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute):
            chain = attr_chain(n)
            if chain:
                out.add(".".join(chain))
        elif isinstance(n, ast.Name):
            out.add(n.id)
    # a.b contributes both "a.b" and "a" via the inner Name; keep the dotted
    pruned = {v for v in out if not any(
        w != v and w.startswith(v + ".") for w in out
    )}
    return pruned


def _seed_exprs(call: ast.Call) -> list[ast.AST]:
    """Seed-position expressions of a call, if it is a seeding call."""
    fn = call.func
    if isinstance(fn, ast.Attribute):
        name = fn.attr
        # bare ``.key`` is too common a name; require a *.random.key chain
        if name == "key":
            chain = attr_chain(fn)
            if not chain or "random" not in chain[:-1]:
                name = None
    elif isinstance(fn, ast.Name) and fn.id in ("default_rng", "PRNGKey"):
        name = fn.id
    else:
        name = None
    out = []
    if name in SEED_SINKS_ARG0 and call.args:
        out.append(call.args[0])
    if name == "fold_in" and len(call.args) >= 2:
        out.append(call.args[1])
    out.extend(
        kw.value for kw in call.keywords if kw.arg in SEED_KWARGS
    )
    return out


def _is_roundish(v: str) -> bool:
    leaf = v.split(".")[-1].lower()
    return leaf in ("rnd", "r", "round", "round_idx", "round_id") \
        or "rnd" in leaf or "round" in leaf


def _is_seedish(v: str) -> bool:
    return "seed" in v.split(".")[-1].lower()


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules.values():
        for fn in mod.functions.values():
            findings.extend(_check_seeding(mod, fn))
            findings.extend(_check_key_reuse(mod, fn))
    return findings


def _check_seeding(mod, fn):
    for node in own_nodes(fn.node):
        if not isinstance(node, ast.Call):
            continue
        for expr in _seed_exprs(node):
            if isinstance(expr, ast.BinOp):
                leaves = _var_leaves(expr)
                if len(leaves) >= 2:
                    yield Finding(
                        NAME, mod.path, node.lineno, fn.qualname,
                        "additive-seed",
                        "seed combines variables arithmetically ("
                        + ", ".join(sorted(leaves))
                        + "); affine seed maps collide across (seed, round) "
                        "pairs — pass the tuple itself, e.g. "
                        "default_rng((seed, rnd))",
                    )
                    continue
            leaves = _var_leaves(expr)
            if leaves and all(_is_roundish(v) for v in leaves) \
                    and not any(_is_seedish(v) for v in leaves):
                yield Finding(
                    NAME, mod.path, node.lineno, fn.qualname,
                    "round-only-seed",
                    "stream seeded from the round index alone ("
                    + ", ".join(sorted(leaves))
                    + ") — every experiment seed replays identical data; "
                    "seed with (experiment_seed, rnd)",
                )


def _key_id(node: ast.AST) -> str | None:
    """Identity of a key expression: bare name or name[const-int]."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name) \
            and isinstance(node.slice, ast.Constant) \
            and isinstance(node.slice.value, int):
        return f"{node.value.id}[{node.slice.value}]"
    return None


def _check_key_reuse(mod, fn):
    jax_roots = mod.jax_aliases
    if not jax_roots:
        return
    # line-ordered stream of events touching jax.random keys
    uses: dict[str, list[int]] = {}
    kills: dict[str, list[int]] = {}
    for node in own_nodes(fn.node):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                kid = _key_id(t)
                if kid:
                    kills.setdefault(kid, []).append(node.lineno)
                    # overwriting ks also retires every ks[i]
                    if isinstance(t, ast.Name):
                        kills.setdefault(t.id + "[", []).append(node.lineno)
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func)
        if not chain or chain[0] not in jax_roots or "random" not in chain:
            continue
        sink = chain[-1] not in SPLITTERS
        for arg in node.args[:1]:  # the key is always the first argument
            kid = _key_id(arg)
            if kid is None:
                continue
            if sink:
                uses.setdefault(kid, []).append(node.lineno)
            else:
                kills.setdefault(kid, []).append(node.lineno)
    for kid, lines in uses.items():
        if len(lines) < 2:
            continue
        lines = sorted(lines)
        killed = sorted(
            kills.get(kid, [])
            + (kills.get(kid.split("[")[0] + "[", []) if "[" in kid else [])
        )
        for a, b in zip(lines, lines[1:]):
            if not any(a < k <= b for k in killed):
                yield Finding(
                    NAME, mod.path, b, fn.qualname, "key-reuse",
                    f"key {kid!r} consumed by two jax.random sinks "
                    f"(lines {a} and {b}) without an interleaving split — "
                    "the second sink replays the first's stream",
                )
                break

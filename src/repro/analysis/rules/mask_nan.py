"""Rule 5: mask-nan-safety — reductions in mask-carrying paths use where=.

When a function carries a client-participation mask (a parameter or local
whose name looks like ``mask`` / ``mask_c`` / ``mf``), the unselected lanes
hold garbage (NaN-poisoned losses of clients that never ran).  A bare
``jnp.mean / sum / max / min`` over metric arrays then leaks that garbage
into the aggregate — the PR 5 NaN-poisoning class.

A reduction in such a function is flagged unless one of:

- it passes ``where=``;
- its argument contains ``jnp.where(...)`` (already sanitized inline);
- its argument references a *sanitized* local (assigned from an expression
  containing ``jnp.where`` or another sanitized name — sanitization
  propagates through arithmetic);
- its argument references the mask itself (mask arithmetic like
  ``jnp.sum(w * mf)`` is the guard, not the leak);
- it sits in the ``mask is None`` arm of an ``if`` (the unmasked path).

Pytree-leaf masks (``trainable_mask`` — which leaves train, not which
clients exist) do not make a function mask-carrying.
"""
from __future__ import annotations

import ast
import re

from ..core import Finding, Project, attr_chain, own_nodes

NAME = "mask-nan-safety"
REDUCTIONS = {"mean", "sum", "max", "min", "average"}
MASK_RE = re.compile(r"^(mask|mf)(_\w+)?$|_mask$")
EXEMPT_RE = re.compile(r"trainable|tree|leaf")


def _mask_names(fnode) -> set[str]:
    names = {
        a.arg for a in (
            fnode.args.posonlyargs + fnode.args.args + fnode.args.kwonlyargs
        )
    }
    for node in own_nodes(fnode):
        if isinstance(node, ast.Assign):
            names.update(
                t.id for t in node.targets if isinstance(t, ast.Name)
            )
    return {
        n for n in names if MASK_RE.search(n) and not EXEMPT_RE.search(n)
    }


def _none_zones(fnode, masks: set[str]) -> list[tuple[int, int]]:
    """Line spans of the unmasked arms: `if m is None:` body / the orelse
    of `if m is not None:`."""
    zones = []
    for node in own_nodes(fnode):
        if not isinstance(node, ast.If):
            continue
        t = node.test
        if isinstance(t, ast.Compare) and len(t.ops) == 1 \
                and isinstance(t.left, ast.Name) and t.left.id in masks \
                and isinstance(t.comparators[0], ast.Constant) \
                and t.comparators[0].value is None:
            arm = node.body if isinstance(t.ops[0], ast.Is) else node.orelse
            if arm:
                end = max(
                    getattr(s, "end_lineno", s.lineno) for s in arm
                )
                zones.append((arm[0].lineno, end))
    return zones


def _mentions(node: ast.AST, names: set[str]) -> bool:
    return any(
        isinstance(n, ast.Name) and n.id in names for n in ast.walk(node)
    )


def _has_jnp_where(node: ast.AST, jnp: set[str]) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            chain = attr_chain(n.func)
            if chain and chain[0] in jnp and chain[-1] == "where":
                return True
    return False


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules.values():
        jnp = mod.jnp_aliases
        if not jnp:
            continue
        for fn in mod.functions.values():
            masks = _mask_names(fn.node)
            if not masks:
                continue
            zones = _none_zones(fn.node, masks)
            sanitized: set[str] = set()
            # single line-ordered pass: propagate sanitization, flag leaks
            events = sorted(
                (
                    (n.lineno, i, n)
                    for i, n in enumerate(own_nodes(fn.node))
                    if isinstance(n, (ast.Assign, ast.Call))
                ),
                key=lambda t: t[:2],
            )
            for line, _, node in events:
                if isinstance(node, ast.Assign):
                    if _has_jnp_where(node.value, jnp) \
                            or _mentions(node.value, sanitized | masks):
                        sanitized.update(
                            t.id for t in node.targets
                            if isinstance(t, ast.Name)
                        )
                    continue
                chain = attr_chain(node.func)
                if not (chain and chain[0] in jnp
                        and chain[-1] in REDUCTIONS):
                    continue
                if any(kw.arg == "where" for kw in node.keywords):
                    continue
                if any(lo <= line <= hi for lo, hi in zones):
                    continue
                if not node.args:
                    continue
                arg = node.args[0]
                if _has_jnp_where(arg, jnp) \
                        or _mentions(arg, sanitized | masks):
                    continue
                findings.append(Finding(
                    NAME, mod.path, line, fn.qualname,
                    f"unmasked-{chain[-1]}",
                    f"jnp.{chain[-1]}() over {ast.unparse(arg)!r} in a "
                    f"mask-carrying path (masks: {', '.join(sorted(masks))})"
                    " without where= — unselected lanes poison the result",
                ))
    return findings

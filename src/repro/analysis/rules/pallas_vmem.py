"""Rule 4: pallas-vmem-budget — every kernel declares and meets a VMEM ceiling.

Each Pallas kernel file must carry a module constant ``VMEM_BUDGET_ELEMS``
(fp32-equivalent elements; 1 elem = 4 bytes, so ``1 << 20`` = 4 MB of the
~16 MB/core TPU VMEM).  The rule statically evaluates every
``pl.pallas_call``'s resident footprint:

    sum over BlockSpecs of  buffering_factor x prod(block_shape)
    + sum over scratch_shapes of prod(shape)

where buffering_factor is 2 for pipelined blocks (index_map depends on the
grid position — Pallas double-buffers those) and 1 for grid-invariant
blocks (e.g. an accumulator with ``lambda i: (0,)``) and scratch.  All
elements are costed at 4 bytes: kernels upcast to fp32 in VMEM anyway, so
int8 tiles are deliberately over-counted rather than under.

Shapes come from a tiny const-evaluator over the dispatch function's body
(module constants, parameter defaults, straight-line assignments).  Runtime
dims the evaluator cannot see (C, N, head dim, ...) must be pinned by a
module-level ``VMEM_ASSUMES = {"c": 1024, ...}`` dict — the kernel author's
declared worst case, which this rule then audits the budget against.

Findings:
- ``missing-budget``: a pallas_call module without VMEM_BUDGET_ELEMS.
- ``vmem-over-budget``: footprint under VMEM_ASSUMES exceeds the budget.
- ``unresolved-block-shape``: a block dim neither evaluates nor appears in
  VMEM_ASSUMES — the ceiling is unauditable, which is itself the defect.
- ``no-oracle-fallback``: a kernel module none of whose importers also
  reference the ``ref`` oracle module — no CPU/edge-case escape hatch.
"""
from __future__ import annotations

import ast

from ..core import Finding, Project, attr_chain, const_eval

NAME = "pallas-vmem-budget"
BUDGET_NAMES = ("VMEM_BUDGET_ELEMS", "VMEM_BUDGET_BYTES")


def _pallas_aliases(mod) -> set[str]:
    out = {
        local for local, d in mod.module_aliases.items()
        if d in ("jax.experimental.pallas", "pallas")
    }
    out |= {
        local for local, (d, n) in mod.from_imports.items()
        if n == "pallas" or (d, n) == ("jax.experimental", "pallas")
    }
    return out


def _vmem_scratch_aliases(mod) -> set[str]:
    return {
        local for local, d in mod.module_aliases.items()
        if d.endswith("pallas.tpu")
    } | {
        local for local, (d, n) in mod.from_imports.items()
        if n == "tpu" and "pallas" in d
    }


def _assumes(mod) -> dict[str, int]:
    for stmt in mod.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and stmt.targets[0].id == "VMEM_ASSUMES" \
                and isinstance(stmt.value, ast.Dict):
            out = {}
            for k, v in zip(stmt.value.keys, stmt.value.values):
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    val = const_eval(v, mod.consts)
                    if val is not None:
                        out[k.value] = val
            return out
    return {}


def _budget_elems(mod) -> int | None:
    if "VMEM_BUDGET_ELEMS" in mod.consts:
        return int(mod.consts["VMEM_BUDGET_ELEMS"])
    if "VMEM_BUDGET_BYTES" in mod.consts:
        return int(mod.consts["VMEM_BUDGET_BYTES"]) // 4
    return None


def _fn_env(fn, mod, assumes: dict[str, int], stop_line: int) -> dict:
    """Constant environment at stop_line: module consts, param defaults,
    then straight-line assignments (ASSUMES pins what won't evaluate)."""
    env: dict[str, object] = dict(mod.consts)
    env.update(assumes)
    a = fn.node.args
    pos = a.posonlyargs + a.args
    for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        v = const_eval(d, env)
        if v is not None:
            env[p.arg] = v
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            v = const_eval(d, env)
            if v is not None:
                env[p.arg] = v

    def walk(stmts):
        for stmt in stmts:
            if stmt.lineno >= stop_line:
                return
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                targets = (
                    stmt.targets if isinstance(stmt, ast.Assign)
                    else [stmt.target]
                )
                value = stmt.value
                names = []
                for t in targets:
                    if isinstance(t, ast.Name):
                        names.append(t.id)
                    elif isinstance(t, ast.Tuple):
                        names.extend(
                            e.id for e in t.elts if isinstance(e, ast.Name)
                        )
                v = const_eval(value, env) if value is not None else None
                if v is not None and len(names) == 1:
                    env[names[0]] = v
                else:
                    for n in names:
                        if n in assumes:
                            env[n] = assumes[n]
                        else:
                            env.pop(n, None)
            for attr in ("body", "orelse", "finalbody"):
                walk(getattr(stmt, attr, []) or [])

    walk(fn.node.body)
    return env


def _block_elems(shape_val) -> int | None:
    if isinstance(shape_val, tuple):
        n = 1
        for d in shape_val:
            if not isinstance(d, int):
                return None
            n *= d
        return n
    if isinstance(shape_val, int):
        return shape_val
    return None


def _index_map_factor(spec_call: ast.Call) -> int:
    """2 when the block pipelines across the grid (double-buffered)."""
    index_map = spec_call.args[1] if len(spec_call.args) > 1 else None
    for kw in spec_call.keywords:
        if kw.arg == "index_map":
            index_map = kw.value
    if index_map is None or not isinstance(index_map, ast.Lambda):
        return 2
    params = {p.arg for p in index_map.args.args}
    used = {
        n.id for n in ast.walk(index_map.body) if isinstance(n, ast.Name)
    }
    return 2 if params & used else 1


def _iter_specs(node):
    """Flatten in_specs/out_specs values into BlockSpec call nodes."""
    if isinstance(node, (ast.List, ast.Tuple)):
        for e in node.elts:
            yield from _iter_specs(e)
    elif isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if chain and chain[-1] == "BlockSpec":
            yield node


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    kernel_mods = []
    for mod in project.modules.values():
        pl_aliases = _pallas_aliases(mod)
        if not pl_aliases:
            continue
        calls = []
        for fn in mod.functions.values():
            for node in ast.walk(fn.node):
                if isinstance(node, ast.Call):
                    chain = attr_chain(node.func)
                    if chain and chain[-1] == "pallas_call" \
                            and chain[0] in pl_aliases:
                        calls.append((fn, node))
        if not calls:
            continue
        kernel_mods.append(mod)
        budget = _budget_elems(mod)
        if budget is None:
            findings.append(Finding(
                NAME, mod.path, 1, "<module>", "missing-budget",
                "pallas_call module declares no VMEM_BUDGET_ELEMS — every "
                "kernel file must carry an explicit VMEM ceiling (plus "
                "VMEM_ASSUMES pinning its worst-case runtime dims)",
            ))
            continue
        assumes = _assumes(mod)
        for fn, call in calls:
            findings.extend(
                _check_call(mod, fn, call, budget, assumes)
            )
    findings.extend(_check_fallback(project, kernel_mods))
    return findings


def _check_call(mod, fn, call, budget, assumes):
    env = _fn_env(fn, mod, assumes, call.lineno)
    scratch_aliases = _vmem_scratch_aliases(mod)
    total = 0
    parts = []
    unresolved = []
    specs = []
    for kw in call.keywords:
        if kw.arg in ("in_specs", "out_specs"):
            specs.extend(_iter_specs(kw.value))
        elif kw.arg == "scratch_shapes" and isinstance(
            kw.value, (ast.List, ast.Tuple)
        ):
            for e in kw.value.elts:
                if isinstance(e, ast.Call):
                    chain = attr_chain(e.func)
                    if chain and chain[0] in scratch_aliases and e.args:
                        v = _block_elems(const_eval(e.args[0], env))
                        if v is None:
                            unresolved.append(
                                ast.unparse(e.args[0])
                            )
                        else:
                            total += v
                            parts.append(f"scratch {v}")
    for spec in specs:
        if not spec.args:
            unresolved.append("BlockSpec()")
            continue
        v = _block_elems(const_eval(spec.args[0], env))
        if v is None:
            unresolved.append(ast.unparse(spec.args[0]))
            continue
        factor = _index_map_factor(spec)
        total += factor * v
        parts.append(f"{factor}x{v}")
    if unresolved:
        yield Finding(
            NAME, mod.path, call.lineno, fn.qualname,
            "unresolved-block-shape",
            "block dims not statically evaluable and not pinned by "
            "VMEM_ASSUMES: " + "; ".join(sorted(set(unresolved))),
        )
        return
    if total > budget:
        yield Finding(
            NAME, mod.path, call.lineno, fn.qualname, "vmem-over-budget",
            f"resident VMEM footprint {total} elems "
            f"({total * 4 / 2**20:.1f} MB) exceeds VMEM_BUDGET_ELEMS="
            f"{budget} under VMEM_ASSUMES={assumes} "
            f"[blocks: {', '.join(parts)}]",
        )


def _relative_base(mod, node: ast.ImportFrom) -> str:
    if node.level:
        pkg = mod.dotted.split(".")
        pkg = pkg[: max(0, len(pkg) - node.level)]
        return ".".join(pkg + ([node.module] if node.module else []))
    return node.module or ""


def _check_fallback(project, kernel_mods):
    """Every kernel module needs an importer that also calls the oracle."""
    kernel_dotted = {m.dotted: m for m in kernel_mods}
    covered: set[str] = set()
    importers: dict[str, list] = {}
    for mod in project.modules.values():
        if mod.dotted in kernel_dotted:
            continue
        has_ref = any(
            d == "ref" or d.endswith(".ref")
            for d in mod.module_aliases.values()
        ) or any(
            (f"{d}.{n}" if d else n) == "ref"
            or (f"{d}.{n}" if d else n).endswith(".ref")
            for d, n in mod.from_imports.values()
        )
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ImportFrom):
                continue
            base = _relative_base(mod, node)
            hits = [base] + [f"{base}.{a.name}" for a in node.names]
            for h in hits:
                if h in kernel_dotted:
                    importers.setdefault(h, []).append(mod)
                    if has_ref:
                        covered.add(h)
    out = []
    for dotted, mod in sorted(kernel_dotted.items()):
        if dotted not in covered and importers.get(dotted):
            out.append(Finding(
                NAME, mod.path, 1, "<module>", "no-oracle-fallback",
                f"kernel module {dotted} is dispatched from "
                f"{', '.join(m.dotted for m in importers[dotted])} without "
                "any reference to the ref oracle — no CPU / over-budget "
                "escape hatch",
            ))
    return out

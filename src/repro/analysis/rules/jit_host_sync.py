"""Rule 1: jit-host-sync — no host synchronization inside traced bodies.

Builds the reachability graph rooted at every ``make_round_step`` /
``make_client_update`` (the functions whose returned closures are jitted)
and flags, in any reachable function:

- ``.item()`` / ``.block_until_ready()``   (forces a device sync)
- ``float(...)`` / ``int(...)``            (concretizes a tracer)
- ``np.*`` calls                           (host numpy inside the trace)
- ``print(...)``                           (traces once, then lies)

numpy on trace-time-static data (shapes, codec assignments) is legitimate;
those few functions are suppressed via the baseline with a reason, which is
the point — the exemption is recorded, not folklore.

Also hosts the module-scope import-scan: calls at module import time that
touch the device (``jax.devices()``, ``jax.device_put``, any ``jnp.*`` /
``jax.random.*`` call) break ``pytest`` collection on machines without the
backend, so they are flagged as ``module-scope-device-call``.
"""
from __future__ import annotations

import ast

from ..core import Finding, Project, attr_chain, own_nodes

NAME = "jit-host-sync"
ROOTS = ("make_round_step", "make_client_update", "make_multi_round_step")
SYNC_ATTRS = {"item": "item", "block_until_ready": "block-until-ready"}
DEVICE_CALLS = {
    "devices", "local_devices", "device_count", "local_device_count",
    "default_backend", "device_put", "device_get",
}


def _np_root(node: ast.AST, np_aliases: set[str]) -> str | None:
    chain = attr_chain(node)
    if chain and chain[0] in np_aliases and len(chain) > 1:
        return ".".join(chain)
    return None


def _contains_np_call(node: ast.AST, np_aliases: set[str]) -> bool:
    return any(
        isinstance(n, ast.Call) and _np_root(n.func, np_aliases)
        for n in ast.walk(node)
    )


def check(project: Project) -> list[Finding]:
    findings = list(_import_scan(project))
    reachable = project.reachable_from(ROOTS)
    for fn in sorted(reachable, key=lambda f: (f.module.path, f.qualname)):
        mod = fn.module
        np_aliases = mod.numpy_aliases
        np_calls: list[tuple[int, str]] = []
        for node in own_nodes(fn.node):
            if not isinstance(node, ast.Call):
                continue
            root = _np_root(node.func, np_aliases)
            if root:
                np_calls.append((node.lineno, root))
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in SYNC_ATTRS:
                findings.append(Finding(
                    NAME, mod.path, node.lineno, fn.qualname,
                    SYNC_ATTRS[node.func.attr],
                    f".{node.func.attr}() in a traced body forces a "
                    "host-device sync",
                ))
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in ("float", "int") and node.args:
                # int(np.prod(...)) is the static-shape idiom: fold it into
                # the per-function np finding instead of double-reporting
                if not _contains_np_call(node.args[0], np_aliases):
                    findings.append(Finding(
                        NAME, mod.path, node.lineno, fn.qualname, "py-cast",
                        f"{node.func.id}() on a traced value concretizes "
                        "it (host sync + constant-folds into the trace)",
                    ))
            elif isinstance(node.func, ast.Name) and node.func.id == "print":
                findings.append(Finding(
                    NAME, mod.path, node.lineno, fn.qualname, "print",
                    "print() in a traced body runs at trace time only",
                ))
        if np_calls:
            first = min(ln for ln, _ in np_calls)
            names = sorted({n for _, n in np_calls})
            findings.append(Finding(
                NAME, mod.path, first, fn.qualname, "np-call",
                "host numpy inside a jit-reachable function: "
                + ", ".join(names)
                + " (fine on trace-time-static data — baseline it with the "
                "reason; otherwise use jnp)",
            ))
    return findings


def _import_scan(project: Project):
    """Module-scope statements must not touch the device."""
    for mod in project.modules.values():
        jax_roots = mod.jax_aliases
        jnp_roots = mod.jnp_aliases
        if not jax_roots and not jnp_roots:
            continue
        for stmt in mod.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                chain = attr_chain(node.func)
                if not chain or len(chain) < 2:
                    continue
                bad = (
                    (chain[0] in jax_roots and chain[-1] in DEVICE_CALLS)
                    or (chain[0] in jnp_roots)
                    or (chain[0] in jax_roots and len(chain) >= 3
                        and chain[1] in ("numpy", "random"))
                )
                if bad:
                    yield Finding(
                        NAME, mod.path, node.lineno, "<module>",
                        "module-scope-device-call",
                        f"{'.'.join(chain)}() at import time initializes the "
                        "backend; pytest collection on backend-less machines "
                        "dies here — defer it into a function",
                    )

"""fedlint core: AST project model shared by every rule.

Stdlib-only by design — the analyzer must run (and fail CI) on machines
where jax itself cannot import, so nothing here touches the runtime.

The model is deliberately heuristic where Python is dynamic:

- calls through bare names resolve lexically (enclosing-function closures,
  then module scope, then ``from x import y`` aliases);
- ``mod.fn(...)`` resolves precisely when ``mod`` is an imported project
  module;
- ``obj.meth(...)`` resolves to every project *method* of that name (class
  dispatch is dynamic, so we over-approximate project-wide) and to nested
  closure functions of that name in modules the caller imports (closures
  travel inside objects like ``Optimizer(init, update)``, but only between
  modules that can see each other).

Findings carry a line-independent key ``rule:path:func:code`` so the
baseline survives unrelated edits to the same file.
"""
from __future__ import annotations

import ast
import json
from dataclasses import dataclass, field
from pathlib import Path

# ---------------------------------------------------------------------------
# findings + baseline


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    func: str          # lexical qualname within the module, or "<module>"
    code: str          # stable short tag for the defect class
    message: str

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.func}:{self.code}"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule, "path": self.path, "line": self.line,
            "func": self.func, "code": self.code, "message": self.message,
            "key": self.key,
        }


def load_baseline(path: str | Path) -> dict[str, str]:
    """baseline JSON -> {finding key: reason}. Missing file -> empty."""
    p = Path(path)
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    out: dict[str, str] = {}
    for entry in data.get("suppressions", []):
        out[entry["key"]] = entry.get("reason", "")
    return out


def split_baseline(
    findings: list[Finding], baseline: dict[str, str]
) -> tuple[list[Finding], list[Finding], list[str]]:
    """-> (active, suppressed, stale_baseline_keys)."""
    active, suppressed = [], []
    hit: set[str] = set()
    for f in findings:
        if f.key in baseline:
            suppressed.append(f)
            hit.add(f.key)
        else:
            active.append(f)
    stale = sorted(set(baseline) - hit)
    return active, suppressed, stale


# ---------------------------------------------------------------------------
# AST helpers


def attr_chain(node: ast.AST) -> list[str] | None:
    """``a.b.c`` -> ["a", "b", "c"]; None if the chain has non-name parts."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def own_nodes(fnode: ast.AST):
    """Walk a function's own body, not descending into nested def bodies
    (nested defs are separate FunctionInfos).  Lambdas stay with the owner."""
    stack = list(ast.iter_child_nodes(fnode))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


def iter_calls(fnode: ast.AST):
    for node in own_nodes(fnode):
        if isinstance(node, ast.Call):
            yield node


def const_eval(node: ast.AST, env: dict[str, object]):
    """Tiny static evaluator over ints/tuples: Name, Constant, +,-,*,//,%,
    <<,>>, unary -, min/max, tuple literals.  None when unresolvable."""
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, (int, float)) else None
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.Tuple):
        vals = [const_eval(e, env) for e in node.elts]
        return None if any(v is None for v in vals) else tuple(vals)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_eval(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        a, b = const_eval(node.left, env), const_eval(node.right, env)
        if a is None or b is None or isinstance(a, tuple) or isinstance(b, tuple):
            return None
        try:
            if isinstance(node.op, ast.Add):
                return a + b
            if isinstance(node.op, ast.Sub):
                return a - b
            if isinstance(node.op, ast.Mult):
                return a * b
            if isinstance(node.op, ast.FloorDiv):
                return a // b
            if isinstance(node.op, ast.Mod):
                return a % b
            if isinstance(node.op, ast.LShift):
                return a << b
            if isinstance(node.op, ast.RShift):
                return a >> b
            if isinstance(node.op, ast.Pow):
                return a ** b
        except (ZeroDivisionError, TypeError, ValueError):
            return None
        return None
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("min", "max") and not node.keywords:
        vals = [const_eval(a, env) for a in node.args]
        if any(v is None or isinstance(v, tuple) for v in vals) or not vals:
            return None
        return (min if node.func.id == "min" else max)(vals)
    return None


# ---------------------------------------------------------------------------
# project model


@dataclass
class FunctionInfo:
    module: "ModuleInfo"
    name: str
    qualname: str
    node: ast.AST
    parent_class: str | None = None

    def __hash__(self):
        return hash((self.module.path, self.qualname))

    def __eq__(self, other):
        return (
            isinstance(other, FunctionInfo)
            and self.module.path == other.module.path
            and self.qualname == other.qualname
        )

    def __repr__(self):
        return f"<fn {self.module.dotted}:{self.qualname}>"


@dataclass
class ClassInfo:
    name: str
    node: ast.ClassDef
    bases: list[str] = field(default_factory=list)
    methods: dict[str, FunctionInfo] = field(default_factory=dict)


@dataclass
class ModuleInfo:
    path: str
    dotted: str
    tree: ast.Module
    functions: dict[str, FunctionInfo] = field(default_factory=dict)
    classes: dict[str, ClassInfo] = field(default_factory=dict)
    # local name -> dotted module path (import x.y as z / from pkg import mod)
    module_aliases: dict[str, str] = field(default_factory=dict)
    # local name -> (dotted module, original name)   (from mod import name)
    from_imports: dict[str, tuple[str, str]] = field(default_factory=dict)
    consts: dict[str, object] = field(default_factory=dict)

    def _roots(self, dotted_prefix: str) -> set[str]:
        out = {
            local for local, d in self.module_aliases.items()
            if d == dotted_prefix or d.startswith(dotted_prefix + ".")
        }
        out |= {
            local for local, (d, _) in self.from_imports.items()
            if d == dotted_prefix or d.startswith(dotted_prefix + ".")
        }
        return out

    @property
    def numpy_aliases(self) -> set[str]:
        return {
            local for local, d in self.module_aliases.items()
            if d == "numpy" or d.startswith("numpy.")
        }

    @property
    def jnp_aliases(self) -> set[str]:
        return {
            local for local, d in self.module_aliases.items()
            if d == "jax.numpy"
        }

    @property
    def jax_aliases(self) -> set[str]:
        return {
            local for local, d in self.module_aliases.items() if d == "jax"
        }


def _module_dotted(path: Path) -> str:
    parts = list(path.with_suffix("").parts)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    for anchor in ("src",):
        if anchor in parts:
            parts = parts[parts.index(anchor) + 1:]
            break
    else:
        if "repro" in parts:
            parts = parts[parts.index("repro"):]
    return ".".join(parts) if parts else path.stem


class _Indexer(ast.NodeVisitor):
    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.stack: list[str] = []
        self.class_stack: list[str] = []

    def _register_function(self, node):
        # several siblings may share a name (e.g. one nested `round_step`
        # per execution mode) — dedupe so none of their bodies is lost
        base = node.name
        qual = ".".join(self.stack + [base])
        k = 2
        while qual in self.mod.functions:
            base = f"{node.name}#{k}"
            qual = ".".join(self.stack + [base])
            k += 1
        info = FunctionInfo(
            module=self.mod, name=node.name, qualname=qual, node=node,
            parent_class=self.class_stack[-1] if self.class_stack and
            len(self.stack) and self.stack[-1] == self.class_stack[-1] else None,
        )
        self.mod.functions[qual] = info
        if info.parent_class:
            self.mod.classes[info.parent_class].methods[node.name] = info
        self.stack.append(base)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _register_function
    visit_AsyncFunctionDef = _register_function

    def visit_ClassDef(self, node: ast.ClassDef):
        bases = []
        for b in node.bases:
            chain = attr_chain(b)
            if chain:
                bases.append(chain[-1])
        self.mod.classes[node.name] = ClassInfo(node.name, node, bases)
        self.stack.append(node.name)
        self.class_stack.append(node.name)
        self.generic_visit(node)
        self.class_stack.pop()
        self.stack.pop()

    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            local = alias.asname or alias.name.split(".")[0]
            dotted = alias.name if alias.asname else alias.name.split(".")[0]
            self.mod.module_aliases[local] = dotted

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.level:  # relative: resolve against this module's package
            pkg_parts = self.mod.dotted.split(".")
            # drop the module leaf, then (level - 1) more packages
            pkg_parts = pkg_parts[: max(0, len(pkg_parts) - node.level)]
            base = ".".join(pkg_parts + ([node.module] if node.module else []))
        else:
            base = node.module or ""
        for alias in node.names:
            if alias.name == "*":
                continue
            local = alias.asname or alias.name
            self.mod.from_imports[local] = (base, alias.name)


class Project:
    def __init__(self, paths: list[str | Path]):
        self.modules: dict[str, ModuleInfo] = {}       # path -> info
        self.by_dotted: dict[str, ModuleInfo] = {}
        self.methods_by_name: dict[str, list[FunctionInfo]] = {}
        self.nested_by_name: dict[str, list[FunctionInfo]] = {}
        self.errors: list[str] = []
        for p in self._expand(paths):
            self._load(p)
        self._index()

    @staticmethod
    def _expand(paths) -> list[Path]:
        out: list[Path] = []
        for p in paths:
            p = Path(p)
            if p.is_dir():
                out.extend(sorted(p.rglob("*.py")))
            elif p.suffix == ".py":
                out.append(p)
        return out

    @staticmethod
    def _rel(path: Path) -> str:
        try:
            return path.resolve().relative_to(Path.cwd().resolve()).as_posix()
        except ValueError:
            return path.as_posix()

    def _load(self, path: Path):
        rel = self._rel(path)
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError as e:  # pragma: no cover - scanned trees parse
            self.errors.append(f"{rel}: {e}")
            return
        mod = ModuleInfo(path=rel, dotted=_module_dotted(Path(rel)), tree=tree)
        _Indexer(mod).visit(tree)
        for stmt in tree.body:
            targets = []
            if isinstance(stmt, ast.Assign):
                targets = [
                    t.id for t in stmt.targets if isinstance(t, ast.Name)
                ]
                value = stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
                    and isinstance(stmt.target, ast.Name):
                targets, value = [stmt.target.id], stmt.value
            else:
                continue
            v = const_eval(value, mod.consts)
            if v is not None:
                for t in targets:
                    mod.consts[t] = v
        self.modules[rel] = mod
        self.by_dotted[mod.dotted] = mod

    def _index(self):
        for mod in self.modules.values():
            for fn in mod.functions.values():
                if fn.parent_class:
                    self.methods_by_name.setdefault(fn.name, []).append(fn)
                elif "." in fn.qualname:
                    self.nested_by_name.setdefault(fn.name, []).append(fn)

    # -- resolution --------------------------------------------------------

    def _visible_modules(self, mod: ModuleInfo) -> set[str]:
        """Paths of project modules this module imports (plus itself)."""
        vis = {mod.path}
        for dotted in mod.module_aliases.values():
            m = self.by_dotted.get(dotted)
            if m:
                vis.add(m.path)
        for dotted, name in mod.from_imports.values():
            for cand in (f"{dotted}.{name}", dotted):
                m = self.by_dotted.get(cand)
                if m:
                    vis.add(m.path)
        return vis

    def aliased_module(self, mod: ModuleInfo, local: str) -> ModuleInfo | None:
        """Project module a local name refers to (import x / from pkg
        import mod), else None."""
        if local in mod.module_aliases:
            return self.by_dotted.get(mod.module_aliases[local])
        if local in mod.from_imports:
            dotted, orig = mod.from_imports[local]
            return self.by_dotted.get(f"{dotted}.{orig}" if dotted else orig)
        return None

    def module_level_function(self, dotted: str, name: str) -> FunctionInfo | None:
        m = self.by_dotted.get(dotted)
        if m is None:
            return None
        fn = m.functions.get(name)
        if fn is not None and "." not in fn.qualname:
            return fn
        return None

    def resolve_call(self, caller: FunctionInfo, call: ast.Call) -> list[FunctionInfo]:
        mod = caller.module
        callee = call.func
        out: list[FunctionInfo] = []
        if isinstance(callee, ast.Name):
            n = callee.id
            parts = caller.qualname.split(".")
            for i in range(len(parts), -1, -1):
                qual = ".".join(parts[:i] + [n])
                if qual in mod.functions:
                    return [mod.functions[qual]]
            if n in mod.from_imports:
                dotted, orig = mod.from_imports[n]
                fn = self.module_level_function(dotted, orig)
                if fn:
                    return [fn]
            return []
        if isinstance(callee, ast.Attribute):
            chain = attr_chain(callee)
            target = chain and self.aliased_module(mod, chain[0])
            if target:
                if len(chain) == 2:
                    fn = target.functions.get(chain[1])
                    if fn and "." not in fn.qualname:
                        return [fn]
                return []
            # dynamic attribute dispatch: project methods of this name
            # anywhere, closures of this name in modules the caller imports
            name = callee.attr
            out.extend(self.methods_by_name.get(name, []))
            vis = self._visible_modules(mod)
            out.extend(
                f for f in self.nested_by_name.get(name, [])
                if f.module.path in vis
            )
        return out

    # -- reachability ------------------------------------------------------

    def lexical_children(self, fn: FunctionInfo) -> list[FunctionInfo]:
        prefix = fn.qualname + "."
        return [
            f for f in fn.module.functions.values()
            if f.qualname.startswith(prefix)
        ]

    def reachable_from(self, root_names: tuple[str, ...]) -> set[FunctionInfo]:
        roots = [
            fn for mod in self.modules.values()
            for fn in mod.functions.values()
            if fn.name in root_names and "." not in fn.qualname
        ]
        seen: set[FunctionInfo] = set()
        frontier = list(roots)
        while frontier:
            fn = frontier.pop()
            if fn in seen:
                continue
            seen.add(fn)
            frontier.extend(self.lexical_children(fn))
            for call in iter_calls(fn.node):
                frontier.extend(self.resolve_call(fn, call))
        return seen

"""fedlint CLI.

Exit codes: 0 clean (all findings baselined), 1 active findings,
2 stale baseline entries under --check-baseline, 3 usage/parse errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import run
from .core import load_baseline, split_baseline
from .rules import RULES_BY_NAME


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="fedlint: static contracts of the FL round engine",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to scan (default: src/repro)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this path")
    ap.add_argument("--baseline", default="fedlint_baseline.json",
                    help="suppression file (default: ./fedlint_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report raw findings, ignore the baseline")
    ap.add_argument("--check-baseline", action="store_true",
                    help="also fail on stale (unmatched) baseline entries")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file with "
                         "TODO reasons and exit 0")
    ap.add_argument("--rule", action="append", default=None,
                    choices=sorted(RULES_BY_NAME),
                    help="run only these rules (repeatable)")
    args = ap.parse_args(argv)

    paths = args.paths or ["src/repro"]
    missing = [p for p in paths if not Path(p).exists()]
    if missing:
        print(f"fedlint: no such path: {', '.join(missing)}",
              file=sys.stderr)
        return 3

    rules = [RULES_BY_NAME[r] for r in args.rule] if args.rule else None
    findings = run(paths, rules)

    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    active, suppressed, stale = split_baseline(findings, baseline)

    if args.write_baseline:
        payload = {
            "comment": "fedlint suppressions — every entry needs a reason",
            "suppressions": [
                {"key": f.key, "reason": baseline.get(f.key, "TODO"),
                 "message": f.message}
                for f in findings
            ],
        }
        Path(args.baseline).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"fedlint: wrote {len(findings)} entries to {args.baseline}")
        return 0

    report = {
        "paths": paths,
        "counts": {
            "active": len(active), "suppressed": len(suppressed),
            "stale_baseline": len(stale),
        },
        "findings": [f.to_dict() for f in active],
        "suppressed": [
            dict(f.to_dict(), reason=baseline[f.key]) for f in suppressed
        ],
        "stale_baseline": stale,
    }
    if args.out:
        Path(args.out).write_text(json.dumps(report, indent=2) + "\n")

    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        for f in active:
            print(f"{f.path}:{f.line}: [{f.rule}/{f.code}] {f.func}: "
                  f"{f.message}")
        if suppressed:
            print(f"# {len(suppressed)} finding(s) suppressed by "
                  f"{args.baseline}")
        for key in stale:
            print(f"# stale baseline entry (no longer fires): {key}")
        status = "clean" if not active else f"{len(active)} finding(s)"
        print(f"fedlint: {status} "
              f"({len(findings)} raw, {len(suppressed)} baselined)")

    if active:
        return 1
    if args.check_baseline and stale:
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""fedlint — JAX/Pallas-aware static analysis for the FL round engine.

Stdlib-only (never imports jax): it must run where the runtime cannot.

    python -m repro.analysis src/repro            # text report, exit != 0
    python -m repro.analysis --format=json --out fedlint.json src/repro
    python -m repro.analysis --check-baseline src/repro

See README.md in this package for the rule catalogue.
"""
from __future__ import annotations

from .core import Finding, Project, load_baseline, split_baseline
from .rules import ALL_RULES, RULES_BY_NAME


def run(paths, rules=None) -> list[Finding]:
    """Analyze paths with the given rules (default: all). Sorted output."""
    project = Project(paths)
    findings: list[Finding] = []
    for rule in rules or ALL_RULES:
        findings.extend(rule.check(project))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.code))
    return findings


__all__ = [
    "ALL_RULES", "Finding", "Project", "RULES_BY_NAME",
    "load_baseline", "run", "split_baseline",
]

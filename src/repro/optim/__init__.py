from .base import Optimizer, Schedule, chain_clip_by_global_norm, constant_schedule
from .sgd import sgd
from .adam import adam, adamw, yogi

__all__ = [
    "Optimizer",
    "Schedule",
    "chain_clip_by_global_norm",
    "constant_schedule",
    "sgd",
    "adam",
    "adamw",
    "yogi",
]

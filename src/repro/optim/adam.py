"""Adam / AdamW — used as *server* optimizers (FedAdam/FedYogi) and available
as a client optimizer for small models."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer, constant_schedule


def adam(
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    schedule = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, params, state, step):
        step = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = schedule(step)
        bc1 = 1.0 - b1 ** step
        bc2 = 1.0 - b2 ** step

        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(state_dtype), state["m"], grads
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(state_dtype)),
            state["v"],
            grads,
        )

        def step_fn(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(state_dtype)
            return (p.astype(state_dtype) - lr_t * upd).astype(p.dtype)

        new_params = jax.tree.map(step_fn, params, m, v)
        return new_params, {"m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def yogi(lr, b1: float = 0.9, b2: float = 0.99, eps: float = 1e-3) -> Optimizer:
    """Yogi second-moment update (additive, sign-controlled) — FedYogi server opt."""
    schedule = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.full(p.shape, 1e-6, jnp.float32), params),
        }

    def update(grads, params, state, step):
        lr_t = schedule(step)
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )

        def v_fn(v_, g):
            g2 = jnp.square(g.astype(jnp.float32))
            return v_ - (1 - b2) * jnp.sign(v_ - g2) * g2

        v = jax.tree.map(v_fn, state["v"], grads)
        new_params = jax.tree.map(
            lambda p, m_, v_: (
                p.astype(jnp.float32) - lr_t * m_ / (jnp.sqrt(v_) + eps)
            ).astype(p.dtype),
            params,
            m,
            v,
        )
        return new_params, {"m": m, "v": v}

    return Optimizer(init, update)

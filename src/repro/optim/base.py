"""Minimal optax-style optimizer core (optax is not installed offline).

An ``Optimizer`` is an (init, update) pair over pytrees.  ``update`` returns
(new_params, new_state) directly — FL clients apply updates in-graph inside
``lax.scan`` so the fused form avoids an extra tree_map.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, Any], tuple[PyTree, PyTree]]
    # update(grads, params, state, step) -> (new_params, new_state)


def chain_clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""

    def update(grads, params, state, step):
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        return opt.update(grads, params, state, step)

    return Optimizer(opt.init, update)


@dataclass(frozen=True)
class Schedule:
    """Piecewise schedule: linear warmup then cosine decay to `final_frac`."""

    base_lr: float
    warmup_steps: int = 0
    decay_steps: int = 0
    final_frac: float = 0.1

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(1, self.warmup_steps))
        if self.decay_steps:
            prog = jnp.clip(
                (step - self.warmup_steps) / jnp.maximum(1, self.decay_steps), 0.0, 1.0
            )
            cos = self.final_frac + (1 - self.final_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * prog)
            )
        else:
            cos = 1.0
        return self.base_lr * warm * cos


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)

"""SGD (+momentum, +weight decay) — the paper's on-device client optimizer.

Plain SGD keeps per-client optimizer state tiny (zero for momentum=0), which
is what makes client-parallel FL of multi-billion-parameter models feasible:
memory = params + grads only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer, constant_schedule


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    schedule = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, params, state, step):
        lr_t = schedule(step)

        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params
            )
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: p - (lr_t * g.astype(jnp.float32)).astype(p.dtype),
                params,
                grads,
            )
            return new_params, state

        new_state = jax.tree.map(
            lambda m, g: momentum * m + g.astype(m.dtype), state, grads
        )
        step_dir = (
            jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype), new_state, grads)
            if nesterov
            else new_state
        )
        new_params = jax.tree.map(
            lambda p, d: p - (lr_t * d.astype(jnp.float32)).astype(p.dtype),
            params,
            step_dir,
        )
        return new_params, new_state

    return Optimizer(init, update)

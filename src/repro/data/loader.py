"""Shard-aware batching: assemble per-round global batches for the jitted
FL round step.

In client-parallel mode the round step consumes a *stacked* batch
``{k: (C, steps, per_client_batch, ...)}`` — client axis first (sharded over
`data`), then the local-step axis consumed by ``lax.scan``.
"""
from __future__ import annotations

import numpy as np

from .federated import ClientDataset


def stack_client_batches(
    clients: list[ClientDataset],
    *,
    steps: int,
    batch_size: int,
) -> dict[str, np.ndarray]:
    """Draw `steps` mini-batches from each client and stack to (C, steps, B, ...)."""
    per_client = []
    for c in clients:
        bs = [c.next_batch(batch_size) for _ in range(steps)]
        per_client.append({k: np.stack([b[k] for b in bs]) for k in bs[0]})
    return {
        k: np.stack([pc[k] for pc in per_client]) for k in per_client[0]
    }


def lm_round_batch(
    *,
    n_clients: int,
    steps: int,
    batch_size: int,
    seq_len: int,
    vocab_size: int,
    seed,  # int or (experiment_seed, rnd) tuple — default_rng takes both
) -> dict[str, np.ndarray]:
    """Synthetic LM round batch (C, steps, B, seq) for the LLM-FL example."""
    from .synthetic import make_lm_tokens

    rng_seed = seed
    toks = make_lm_tokens(
        n_tokens=n_clients * steps * batch_size * (seq_len + 1),
        vocab_size=vocab_size,
        seed=rng_seed,
    ).reshape(n_clients, steps, batch_size, seq_len + 1)
    return {
        "tokens": toks[..., :-1].copy(),
        "labels": toks[..., 1:].copy(),
    }

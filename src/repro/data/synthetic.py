"""Synthetic data sources.

CIFAR-10 / Office-31 are not available offline (DESIGN.md §7.4); we generate
*structured* synthetic data whose difficulty scales smoothly so the paper's
qualitative trends (accuracy vs E, vs C) reproduce:

- classification: Gaussian-mixture "images" — one mixture center per class,
  per-sample noise, optional per-client covariate shift (for non-IID splits).
- LM: a deterministic "k-gram chain" token stream — next token is a noisy
  function of the previous k tokens, so real learning signal exists.
- features: precomputed frontend embeddings for the base/head split.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ClassificationData:
    x: np.ndarray  # (N, ...) float32
    y: np.ndarray  # (N,) int32

    def __len__(self) -> int:
        return len(self.y)


def make_classification(
    *,
    n: int,
    num_classes: int,
    shape: tuple[int, ...],
    noise: float = 1.0,
    seed: int = 0,
    class_sep: float = 2.0,
) -> ClassificationData:
    """Gaussian mixture with one center per class in flattened pixel space."""
    rng = np.random.default_rng(seed)
    dim = int(np.prod(shape))
    centers = rng.normal(0.0, class_sep / np.sqrt(dim), size=(num_classes, dim))
    y = rng.integers(0, num_classes, size=n).astype(np.int32)
    x = centers[y] + rng.normal(0.0, noise / np.sqrt(dim), size=(n, dim))
    return ClassificationData(
        x=x.reshape((n, *shape)).astype(np.float32), y=y
    )


def make_features(
    *, n: int, num_classes: int, feature_dim: int, noise: float = 0.6, seed: int = 0
) -> ClassificationData:
    """Frozen-base features for the head model (paper §4.1 Android workload)."""
    return make_classification(
        n=n, num_classes=num_classes, shape=(feature_dim,), noise=noise, seed=seed
    )


def make_lm_tokens(
    *, n_tokens: int, vocab_size: int, order: int = 2, noise: float = 0.1, seed: int = 0
) -> np.ndarray:
    """k-gram chain: t_i = f(t_{i-1..i-k}) with prob 1-noise, uniform otherwise.

    f is a fixed random hash so a model with context >= order can reach low
    loss; pure-noise tokens bound the attainable loss from below.
    """
    rng = np.random.default_rng(seed)
    a = rng.integers(1, vocab_size, size=order).astype(np.int64)
    toks = np.empty(n_tokens, dtype=np.int64)
    toks[:order] = rng.integers(0, vocab_size, size=order)
    rnd = rng.random(n_tokens)
    jumps = rng.integers(0, vocab_size, size=n_tokens)
    for i in range(order, n_tokens):
        nxt = int((toks[i - order : i] * a).sum() % vocab_size)
        toks[i] = jumps[i] if rnd[i] < noise else nxt
    return toks.astype(np.int32)


def make_lm_batches(
    *,
    n_batches: int,
    batch: int,
    seq_len: int,
    vocab_size: int,
    seed: int = 0,
) -> list[dict[str, np.ndarray]]:
    """Pre-materialized LM batches: {tokens, labels} with next-token labels."""
    stream = make_lm_tokens(
        n_tokens=n_batches * batch * (seq_len + 1), vocab_size=vocab_size, seed=seed
    )
    out = []
    per = batch * (seq_len + 1)
    for b in range(n_batches):
        chunk = stream[b * per : (b + 1) * per].reshape(batch, seq_len + 1)
        out.append({"tokens": chunk[:, :-1].copy(), "labels": chunk[:, 1:].copy()})
    return out

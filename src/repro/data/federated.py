"""Federated dataset partitioning.

The paper's clients hold *their own* (non-identically-distributed) data; the
standard simulation device is a Dirichlet(alpha) label split (alpha -> inf is
IID, alpha -> 0 gives one-class clients).  Each client also gets an optional
covariate shift so even IID-label splits are not trivially identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .synthetic import ClassificationData


@dataclass
class ClientDataset:
    """One client's local shard + iteration state."""

    client_id: int
    x: np.ndarray
    y: np.ndarray
    _order: np.ndarray = field(init=False, repr=False)
    _pos: int = field(default=0, repr=False)
    _epoch_rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        self._epoch_rng = np.random.default_rng(1000 + self.client_id)
        self._order = self._epoch_rng.permutation(len(self.y))

    def __len__(self) -> int:
        return len(self.y)

    def num_examples(self) -> int:
        return len(self.y)

    def next_batch(self, batch_size: int) -> dict[str, np.ndarray]:
        """Cyclic mini-batch sampler with per-epoch reshuffle."""
        idx = np.empty(batch_size, dtype=np.int64)
        filled = 0
        while filled < batch_size:
            take = min(batch_size - filled, len(self._order) - self._pos)
            idx[filled : filled + take] = self._order[self._pos : self._pos + take]
            filled += take
            self._pos += take
            if self._pos >= len(self._order):
                self._order = self._epoch_rng.permutation(len(self.y))
                self._pos = 0
        return {"x": self.x[idx], "y": self.y[idx]}

    def steps_per_epoch(self, batch_size: int) -> int:
        return max(1, len(self.y) // batch_size)


def dirichlet_partition(
    data: ClassificationData,
    *,
    n_clients: int,
    alpha: float = 0.5,
    seed: int = 0,
    min_per_client: int = 8,
) -> list[ClientDataset]:
    """Label-Dirichlet split of a classification dataset into client shards."""
    rng = np.random.default_rng(seed)
    num_classes = int(data.y.max()) + 1
    by_class = [np.flatnonzero(data.y == c) for c in range(num_classes)]
    for idxs in by_class:
        rng.shuffle(idxs)

    client_indices: list[list[int]] = [[] for _ in range(n_clients)]
    for c, idxs in enumerate(by_class):
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idxs)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idxs, cuts)):
            client_indices[cid].extend(part.tolist())

    # guarantee a floor so every client can form a batch
    all_idx = rng.permutation(len(data.y))
    floor_iter = iter(all_idx.tolist())
    for cid in range(n_clients):
        while len(client_indices[cid]) < min_per_client:
            client_indices[cid].append(next(floor_iter))

    out = []
    for cid in range(n_clients):
        sel = np.asarray(client_indices[cid], dtype=np.int64)
        rng.shuffle(sel)
        out.append(ClientDataset(client_id=cid, x=data.x[sel], y=data.y[sel]))
    return out


def iid_partition(
    data: ClassificationData, *, n_clients: int, seed: int = 0
) -> list[ClientDataset]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(data.y))
    shards = np.array_split(order, n_clients)
    return [
        ClientDataset(client_id=cid, x=data.x[s], y=data.y[s])
        for cid, s in enumerate(shards)
    ]


def partition_stats(clients: list[ClientDataset]) -> dict:
    sizes = np.array([len(c) for c in clients])
    num_classes = int(max(c.y.max() for c in clients)) + 1
    label_hists = np.stack(
        [np.bincount(c.y, minlength=num_classes) for c in clients]
    )
    p = label_hists / np.maximum(1, label_hists.sum(axis=1, keepdims=True))
    # mean per-client label entropy (nats): low = very non-IID
    with np.errstate(divide="ignore", invalid="ignore"):
        ent = -np.sum(np.where(p > 0, p * np.log(np.maximum(p, 1e-12)), 0.0), axis=1)
    return {
        "n_clients": len(clients),
        "sizes_min": int(sizes.min()),
        "sizes_max": int(sizes.max()),
        "sizes_mean": float(sizes.mean()),
        "mean_label_entropy": float(ent.mean()),
    }

"""Architecture + run configuration system.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting a
``CONFIG: ArchConfig``.  The registry maps ``--arch <id>`` to it.  Configs are
plain frozen dataclasses: hashable (usable as jit static args) and entirely
derivable from the published model cards cited in each file.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio", "cnn", "head"]
LayerKind = Literal["attn", "mamba", "slstm", "mlstm"]


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared_experts: int = 0          # DeepSeekMoE fine-grained shared experts
    d_expert: int = 0                  # per-expert FFN hidden dim (0 -> use d_ff)
    layer_period: int = 1              # MoE every `period` layers ...
    layer_offset: int = 0              # ... starting at this layer index
    router_aux_coef: float = 0.01      # load-balance loss weight
    router_z_coef: float = 1e-3


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                   # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class LayerSpec:
    kind: LayerKind
    moe: bool = False


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    # attention flavour
    sliding_window: Optional[int] = None   # tokens; None = full attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mla: Optional[MLAConfig] = None
    # mixture-of-experts
    moe: Optional[MoEConfig] = None
    # state-space / recurrent
    ssm: Optional[SSMConfig] = None
    attn_layer_period: int = 1         # hybrid: attention every Nth layer...
    attn_layer_offset: int = 0         # ...at this offset; others are `alt_kind`
    alt_kind: LayerKind = "mamba"
    xlstm_slstm_every: int = 0         # xLSTM: sLSTM every Nth block (rest mLSTM)
    # embeddings / head
    tie_embeddings: bool = True
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["silu", "gelu"] = "silu"
    # modality frontend stub: non-text archs consume precomputed embeddings
    modality: Literal["text", "vision_stub", "audio_stub"] = "text"
    frontend_tokens: int = 0           # prefix embedding tokens (vlm patches)
    frontend_dim: int = 0              # raw frontend embedding width (0 -> d_model)
    # FL / distribution behaviour
    execution_mode: Literal["parallel", "sequential", "fsdp"] = "parallel"
    microbatches: int = 1              # grad-accumulation slices per local step
    remat: bool = True
    scan_layers: bool = True
    dtype: str = "bfloat16"
    # long-context: archs whose reference model is full-attention run
    # long_500k only under this sliding-window-variant flag (see DESIGN.md §5)
    long_context_window: int = 4096
    source: str = ""                   # citation bracket from the assignment

    # ---------------- derived ----------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_plan(self) -> tuple[LayerSpec, ...]:
        """Per-layer (kind, moe?) plan for the whole stack."""
        plan = []
        for i in range(self.n_layers):
            if self.family in ("ssm",) and self.xlstm_slstm_every:
                kind: LayerKind = (
                    "slstm" if i % self.xlstm_slstm_every == 0 else "mlstm"
                )
            elif self.attn_layer_period > 1:
                kind = (
                    "attn"
                    if i % self.attn_layer_period == self.attn_layer_offset
                    else self.alt_kind
                )
            elif self.family == "ssm":
                kind = self.alt_kind
            else:
                kind = "attn"
            is_moe = False
            if self.moe is not None:
                is_moe = i % self.moe.layer_period == self.moe.layer_offset
            plan.append(LayerSpec(kind=kind, moe=is_moe))
        return tuple(plan)

    @property
    def uniform_plan(self) -> bool:
        """True when every layer is identical -> stack scans over one block."""
        plan = self.layer_plan()
        return all(p == plan[0] for p in plan)

    @property
    def plan_period(self) -> int:
        """Smallest repeating period of the layer plan (for scan-over-period)."""
        plan = self.layer_plan()
        for p in range(1, self.n_layers + 1):
            if self.n_layers % p == 0 and all(
                plan[i] == plan[i % p] for i in range(self.n_layers)
            ):
                return p
        return self.n_layers

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode path exists (natively or via SWA variant)."""
        return True  # every arch here gets SWA ring-cache or recurrent state

    def reduced(self, *, n_layers: int = 2, d_model: int = 128) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests (spec: <=512 d_model,
        2 layers, <=4 experts)."""
        head_dim = 32
        n_heads = max(2, min(4, d_model // head_dim))
        n_kv = 1 if self.n_kv_heads < self.n_heads else n_heads
        kw: dict = dict(
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=d_model * 2,
            vocab_size=min(self.vocab_size, 512),
            frontend_tokens=min(self.frontend_tokens, 16),
            execution_mode="parallel",
            scan_layers=False,
            remat=False,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            long_context_window=64,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                d_expert=d_model if self.moe.d_expert else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=8)
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
                qk_rope_head_dim=16, v_head_dim=32,
            )
        # keep hybrid structure visible even at 2 layers
        if self.attn_layer_period > 1:
            kw["attn_layer_period"] = 2
            kw["attn_layer_offset"] = min(self.attn_layer_offset, 1)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES: dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


# ---------------- registry ----------------
_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_ARCH_MODULES = (
    "mixtral_8x7b",
    "jamba_1_5_large_398b",
    "xlstm_1_3b",
    "stablelm_3b",
    "granite_8b",
    "paligemma_3b",
    "qwen3_0_6b",
    "minicpm3_4b",
    "musicgen_medium",
    "deepseek_moe_16b",
    "resnet18_cifar10",
    "mobilenet_head_office31",
)

_loaded = False


def _ensure_loaded() -> None:
    global _loaded
    if _loaded:
        return
    import importlib

    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _loaded = True

"""MobileNetV2-base + 2-layer head on Office-31 — the paper's Android workload (§4.1, Table 2b).

The frozen MobileNetV2 base is a feature extractor producing 1280-d features
(the paper freezes it and ports it via TFLite); faithful to that design, the
base here is a fixed random-projection feature stub and FL trains only the
2-layer DNN head — exactly the paper's Model-Personalization split.
"""
from dataclasses import dataclass

from repro.configs.base import ArchConfig, register


@dataclass(frozen=True)
class HeadConfig:
    name: str = "mobilenet-head-office31"
    feature_dim: int = 1280     # MobileNetV2 penultimate features
    hidden_dim: int = 256       # 2-layer DNN head (paper §5)
    num_classes: int = 31       # Office-31

    def reduced(self) -> "HeadConfig":
        return HeadConfig(name=self.name + "-reduced", feature_dim=64, hidden_dim=32)


HEAD_CONFIG = HeadConfig()

CONFIG = register(
    ArchConfig(
        name="mobilenet-head-office31",
        family="head",
        n_layers=2,
        d_model=256,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=31,
        source="[paper §4.1/§5: MobileNetV2 base + 2-layer head, Office-31]",
    )
)

"""ResNet-18 on CIFAR-10 — the paper's own Jetson-TX2 workload (§5, Tables 2a/3).

Not part of the assigned transformer pool; used by the paper-validation
benchmarks (benchmarks/table2a.py, table3.py) and the FL examples.
"""
from dataclasses import dataclass, field

from repro.configs.base import ArchConfig, register


@dataclass(frozen=True)
class CNNConfig:
    name: str = "resnet18-cifar10"
    stage_sizes: tuple[int, ...] = (2, 2, 2, 2)
    stage_widths: tuple[int, ...] = (64, 128, 256, 512)
    num_classes: int = 10
    image_size: int = 32
    channels: int = 3
    norm: str = "groupnorm"  # BatchNorm is pathological under FedAvg; see DESIGN.md

    def reduced(self) -> "CNNConfig":
        return CNNConfig(
            name=self.name + "-reduced",
            stage_sizes=(1, 1),
            stage_widths=(16, 32),
            num_classes=self.num_classes,
            image_size=self.image_size,
        )


CNN_CONFIG = CNNConfig()

# registry stub so `--arch resnet18-cifar10` resolves; transformer fields unused.
CONFIG = register(
    ArchConfig(
        name="resnet18-cifar10",
        family="cnn",
        n_layers=18,
        d_model=512,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=10,
        source="[paper §5: ResNet-18 / CIFAR-10 on Jetson TX2]",
    )
)

"""xLSTM 1.3B [arXiv:2405.04517].

48L d_model=2048 4H d_ff=0 (xLSTM blocks carry their own up-projection; no
separate FFN) vocab=50304.  xLSTM[7:1]: one sLSTM block per 8 blocks, the
rest mLSTM (matrix-memory, fully parallelizable).  Recurrent state makes
long_500k decode native.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        head_dim=512,
        d_ff=0,
        vocab_size=50304,
        xlstm_slstm_every=8,   # blocks 0,8,16,... are sLSTM; rest mLSTM
        alt_kind="mlstm",
        ssm=SSMConfig(),       # unused by xLSTM blocks but keeps family tooling uniform
        tie_embeddings=False,
        execution_mode="fsdp",
        source="[arXiv:2405.04517]",
    )
)

"""StableLM 3B [hf:stabilityai/stablelm-2-1_6b family, 3B config].

32L d_model=2560 32H (MHA: kv=32) d_ff=6912 vocab=50304.  LayerNorm + rotary
(partial in the reference; full here), SiLU-gated MLP.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab_size=50304,
        norm="layernorm",
        tie_embeddings=False,
        execution_mode="fsdp",
        source="[hf:stabilityai/stablelm-2-1_6b]",
    )
)

"""Jamba 1.5 Large (398B) [arXiv:2403.19887 / Jamba-1.5 report].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.  Hybrid Mamba +
attention with a 1:7 attention:mamba interleave (one attention layer per
8-layer period) and MoE (16 experts, top-2) on every other layer.

398B total params: client-sequential FL (one client occupies the whole mesh;
experts sharded over `data`, tensor-parallel over `model`).  Long-context
decode is native (Mamba recurrent state + few attention layers w/ window).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        moe=MoEConfig(n_experts=16, top_k=2, layer_period=2, layer_offset=1),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        attn_layer_period=8,   # 1 attention : 7 mamba
        attn_layer_offset=4,
        alt_kind="mamba",
        tie_embeddings=False,
        execution_mode="sequential",
        microbatches=16,   # 398B: activation memory / 8 via grad accumulation
        source="[arXiv:2403.19887]",
    )
)

"""DeepSeekMoE 16B [arXiv:2401.06066].

28L d_model=2048 16H (MHA kv=16) d_ff=1408 (per-expert) vocab=102400.
Fine-grained MoE: 2 shared + 64 routed experts, top-6 routing.  (The
reference model keeps layer 0 dense; per the assignment spec we make every
layer MoE — noted in DESIGN.md.)

Pure-FSDP FL (execution_mode="fsdp"): a 16.4B fine-grained MoE per-client
replica exceeds a v5e chip at the assigned train_4k batch, and 2D TP+FSDP
keeps 16 sequences of dispatch buffers per chip; ZeRO-sharding weights over
all 256 chips with batch 256 -> 1 sequence/chip is the memory-optimal
regime (per-layer weight all-gathers show up in the collective term).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        moe=MoEConfig(
            n_experts=64,
            top_k=6,
            n_shared_experts=2,
            d_expert=1408,
        ),
        tie_embeddings=False,
        execution_mode="fsdp",
        source="[arXiv:2401.06066]",
    )
)

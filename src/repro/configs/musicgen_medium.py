"""MusicGen Medium [arXiv:2306.05284] — decoder backbone.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 (EnCodec codebook).
Decoder-only transformer over EnCodec audio tokens.  The EnCodec tokenizer /
conditioning encoder is the frozen modality frontend: ``input_specs()``
supplies a 64-token conditioning-embedding prefix (T5-style) + codec token
ids; FL trains the decoder.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        norm="layernorm",
        act="gelu",
        tie_embeddings=False,
        modality="audio_stub",
        frontend_tokens=64,
        frontend_dim=768,
        execution_mode="fsdp",
        source="[arXiv:2306.05284]",
    )
)

"""Granite 8B Code [arXiv:2405.04324].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.  Llama-style
architecture (RMSNorm, SwiGLU, RoPE).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        rope_theta=10_000_000.0,
        tie_embeddings=False,
        execution_mode="fsdp",
        source="[arXiv:2405.04324]",
    )
)

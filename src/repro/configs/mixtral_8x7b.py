"""Mixtral 8x7B [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, 8 experts top-2,
sliding-window attention (4096).  46.7B total / ~12.9B active params.

Execution mode: client-sequential — per-client replicas of a 47B model do not
fit client-parallel on a v5e-256; the full mesh trains one client at a time
(expert-parallel over `data`, tensor-parallel over `model`).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        sliding_window=4096,
        rope_theta=1e6,
        moe=MoEConfig(n_experts=8, top_k=2),
        tie_embeddings=False,
        execution_mode="fsdp",
        source="[arXiv:2401.04088]",
    )
)

"""PaliGemma 3B [arXiv:2407.07726] — Gemma-2B language backbone.

18L d_model=2048 8H (GQA kv=1: MQA) d_ff=16384 vocab=257216.  The SigLIP
vision tower + projector is the frozen *Base Model* in the paper's §4.1
head/base split: ``input_specs()`` supplies 256 precomputed patch embeddings
(224px / 14px patches = 16x16) prepended to the token stream; FL trains the
language decoder (the head).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        act="gelu",
        tie_embeddings=True,
        modality="vision_stub",
        frontend_tokens=256,
        frontend_dim=1152,
        execution_mode="fsdp",  # 257k-vocab CE + patch frontend: per-client replica too fat
        source="[arXiv:2407.07726]",
    )
)

"""Checkpointing: pytree <-> .npz round-trip + FL server state.

No orbax offline; we serialize with numpy's npz using flattened key paths,
restoring dtypes/shapes exactly.  Good enough for CPU-scale tests and for the
protocol's "serialized parameters" wire-format tests.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_META = "__repro_meta__"


def _to_numpy(leaf) -> np.ndarray:
    arr = jax.device_get(leaf)
    if hasattr(arr, "dtype") and arr.dtype.name == "bfloat16":
        return np.asarray(arr.view(np.uint16))  # npz-safe carrier
    return np.asarray(arr)


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): _to_numpy(leaf) for path, leaf in flat}


def save_pytree(path: str, tree: PyTree, *, extra_meta: dict | None = None) -> None:
    flat = _flatten(tree)
    meta = {"keys": list(flat.keys()), "extra": extra_meta or {}}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **{_META: np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)}, **flat)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    with np.load(path) as zf:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kpath, leaf in flat:
            key = jax.tree_util.keystr(kpath)
            if key not in zf:
                raise KeyError(f"checkpoint missing {key}")
            arr = zf[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != expected {leaf.shape}"
                )
            if jnp.dtype(leaf.dtype).name == "bfloat16":
                leaves.append(jnp.asarray(arr.view(np.uint16)).view(jnp.bfloat16))
            else:
                leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves
        )


def load_meta(path: str) -> dict:
    with np.load(path) as zf:
        raw = bytes(zf[_META].tobytes())
    return json.loads(raw.decode())

"""Serving driver: prefill + batched decode for any architecture (reduced on
CPU; the production shapes are exercised via launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.frontend_tokens:
        fd = cfg.frontend_dim or cfg.d_model
        batch["frontend"] = rng.normal(
            size=(args.batch, cfg.frontend_tokens, fd)
        ).astype(np.float32)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, args.context))
    decode = jax.jit(lambda p, b, c: model.decode_step(p, b, c, args.context))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    for _ in range(args.tokens - 1):
        logits, cache = decode(params, {"tokens": tok}, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} generated {gen.shape} tokens in {dt:.2f}s")
    for row in gen[: min(2, args.batch)]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()

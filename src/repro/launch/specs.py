"""Dry-run plumbing: ShapeDtypeStruct stand-ins + shardings for every
(architecture x input-shape x mesh) combination.

``build_case`` returns everything ``jax.jit(...).lower()`` needs — the step
function, abstract arguments, and in/out shardings — without allocating a
single real array.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape, get_config
from repro.core import FedAvg, RoundSpec, make_round_step
from repro.models import build_model
from repro.models.sharding import ShardRules, serve_rules, train_rules
from repro.optim import sgd
from repro.utils.pytree import tree_size

PyTree = Any


def _sds(tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct,)),
    )


def abstract_params(model) -> PyTree:
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


@dataclass
class Case:
    """One lowered dry-run case."""

    arch: str
    shape: str
    fn: Callable
    args: tuple            # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    model_flops: float
    meta: dict
    donate_argnums: tuple = ()


def active_param_count(cfg: ArchConfig, params_abs: PyTree) -> float:
    """N_active for MODEL_FLOPS: routed experts count at top_k/n_experts."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params_abs)
    total = 0.0
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        n = float(np.prod(leaf.shape))
        if cfg.moe is not None and "ffn" in key and "shared" not in key and (
            "w_gate" in key or "w_up" in key or "w_down" in key
        ):
            n *= cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return total


def token_batch_specs(cfg: ArchConfig, shape: InputShape, *, clients: int, steps: int):
    """Abstract FL-round batch: leaves (C, steps, B, ...)."""
    per_client = shape.global_batch // clients
    assert per_client >= 1, f"batch {shape.global_batch} < clients {clients}"
    s_tokens = shape.seq_len - cfg.frontend_tokens
    batch = {
        "tokens": jax.ShapeDtypeStruct((clients, steps, per_client, s_tokens), jnp.int32),
        "labels": jax.ShapeDtypeStruct((clients, steps, per_client, s_tokens), jnp.int32),
    }
    if cfg.frontend_tokens:
        fd = cfg.frontend_dim or cfg.d_model
        batch["frontend"] = jax.ShapeDtypeStruct(
            (clients, steps, per_client, cfg.frontend_tokens, fd), jnp.bfloat16
        )
    return batch


def build_train_case(arch_name: str, shape: InputShape, mesh, *, multi_pod: bool,
                     ce_chunk: int = 512) -> Case:
    from repro.models import transformer as tfm

    cfg = get_config(arch_name)
    model = build_model(cfg, ce_chunk=ce_chunk)
    rules = train_rules(mesh, multi_pod, cfg.execution_mode)
    params_abs = abstract_params(model)
    param_spec = model.param_specs(rules)

    from repro.models.layers import moe as moe_lib

    # sequence-parallel residual saves (see transformer.CARRY_SHARDING).
    # Parallel mode leaves the carry to GSPMD: inside shard_map the client's
    # batch already bounds the save; constraints there measurably backfired
    # (EXPERIMENTS.md §Perf log).
    if cfg.execution_mode == "parallel":
        tfm.CARRY_SHARDING = None
        moe_lib.BATCH_SHARDING = None
        moe_lib.FF_SHARDING = None
        moe_lib.MODEL_LAST_SHARDING = None
    else:
        # Pin ONLY the batch dim of the layer-scan carry.  Without it GSPMD
        # replicates the carry across the batch axes (fsdp: 36x309GB saves
        # for granite).  Pinning S over `model` as well was tried and
        # refuted - per-layer fp32 all-gathers of the residual cost more
        # than the saves they shard (EXPERIMENTS.md §Perf).
        tfm.CARRY_SHARDING = NamedSharding(mesh, P(rules.batch_axes, None, None))
        moe_lib.BATCH_SHARDING = NamedSharding(mesh, P(rules.batch_axes))
        moe_lib.FF_SHARDING = NamedSharding(
            mesh, P(rules.batch_axes, None, None, rules.model_axis)
        )
        moe_lib.MODEL_LAST_SHARDING = NamedSharding(
            mesh, P(rules.batch_axes, None, None, rules.model_axis)
        )

    if cfg.execution_mode == "parallel":
        clients = rules.size(rules.client_axes)
        batch_axes = rules.client_axes
    else:
        clients = 1
        batch_axes = rules.batch_axes

    steps = 1  # one local step + aggregation is the canonical lowered round
    batch = token_batch_specs(cfg, shape, clients=clients, steps=steps)
    if cfg.execution_mode == "parallel":
        batch_spec = jax.tree.map(lambda x: P(batch_axes), batch)
    else:
        batch_spec = jax.tree.map(lambda x: P(None, None, batch_axes), batch)

    strategy = FedAvg()
    spec = RoundSpec(max_steps=steps, execution_mode=cfg.execution_mode,
                     microbatches=cfg.microbatches)
    round_step = make_round_step(
        model.loss_fn, sgd(0.05), strategy, spec,
        mesh=mesh if cfg.execution_mode == "parallel" else None,
        client_axes=rules.client_axes,
        param_shardings=(
            _named(mesh, param_spec) if cfg.execution_mode != "parallel" else None
        ),
    )

    # codec-owned client state (empty for the default NullCodec): abstract,
    # threaded through the uniform round_step signature
    client_state = jax.eval_shape(
        lambda: spec.codec.init_client_state(clients, tree_size(params_abs))
    )

    args = (
        params_abs,
        (),  # FedAvg server state
        client_state,
        batch,
        jax.ShapeDtypeStruct((clients,), jnp.float32),
        jax.ShapeDtypeStruct((clients,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    state_sharding = jax.tree.map(
        lambda x: NamedSharding(mesh, P()), client_state
    ) if jax.tree.leaves(client_state) else None
    in_shardings = (
        _named(mesh, param_spec),
        None,
        state_sharding,
        _named(mesh, batch_spec),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P()),
    )
    out_shardings = (
        _named(mesh, param_spec),
        None,
        state_sharding,
        None,
    )

    n_active = active_param_count(cfg, params_abs)
    model_flops = 6.0 * n_active * shape.global_batch * shape.seq_len
    return Case(
        arch=arch_name, shape=shape.name, fn=round_step, args=args,
        in_shardings=in_shardings, out_shardings=out_shardings,
        model_flops=model_flops,
        meta={"clients": clients, "mode": cfg.execution_mode,
              "n_active_params": n_active},
    )


def serve_batch_specs(cfg: ArchConfig, shape: InputShape):
    if shape.kind == "prefill":
        s_tokens = shape.seq_len - cfg.frontend_tokens
        batch = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, s_tokens), jnp.int32)}
        if cfg.frontend_tokens:
            fd = cfg.frontend_dim or cfg.d_model
            batch["frontend"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.frontend_tokens, fd), jnp.bfloat16
            )
        return batch
    return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}


def build_serve_case(arch_name: str, shape: InputShape, mesh, *, multi_pod: bool) -> Case:
    from repro.models import transformer as tfm
    from repro.models.layers import moe as moe_lib

    cfg = get_config(arch_name)
    model = build_model(cfg)
    rules = serve_rules(mesh, multi_pod)
    params_abs = abstract_params(model)
    param_spec = model.param_specs(rules)
    tfm.CARRY_SHARDING = (
        NamedSharding(mesh, P(rules.batch_axes, "model", None))
        if shape.kind == "prefill"
        else None  # decode carries are (B,1,d): tiny
    )
    moe_lib.BATCH_SHARDING = NamedSharding(mesh, P(rules.batch_axes))
    moe_lib.FF_SHARDING = NamedSharding(
        mesh, P(rules.batch_axes, None, None, "model")
    )
    moe_lib.MODEL_LAST_SHARDING = NamedSharding(
        mesh, P(rules.batch_axes, None, None, "model")
    )
    batch = serve_batch_specs(cfg, shape)
    batch_spec = jax.tree.map(
        lambda x: rules.spec(
            rules.batch_axes, *([None] * (len(x.shape) - 1)), dim_sizes=x.shape
        ),
        batch,
    )

    n_active = active_param_count(cfg, params_abs)

    if shape.kind == "prefill":
        fn = partial(model.prefill, ctx=shape.seq_len)
        cache_spec = model.cache_specs(rules, shape.global_batch, shape.seq_len)
        args = (params_abs, batch)
        in_shardings = (_named(mesh, param_spec), _named(mesh, batch_spec))
        out_shardings = (NamedSharding(mesh, P()), _named(mesh, cache_spec))
        model_flops = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        cache_spec = model.cache_specs(rules, shape.global_batch, shape.seq_len)
        fn = partial(model.decode_step, ctx=shape.seq_len)
        args = (params_abs, batch, cache_abs)
        donate = (2,)  # donate the KV cache: in-place update, not 2x copies
        in_shardings = (
            _named(mesh, param_spec),
            _named(mesh, batch_spec),
            _named(mesh, cache_spec),
        )
        out_shardings = (NamedSharding(mesh, P()), _named(mesh, cache_spec))
        model_flops = 2.0 * n_active * shape.global_batch  # one token per seq

    return Case(
        arch=arch_name, shape=shape.name, fn=fn, args=args,
        in_shardings=in_shardings, out_shardings=out_shardings,
        model_flops=model_flops,
        meta={"mode": "serve", "kind": shape.kind},
        donate_argnums=(2,) if shape.kind == "decode" else (),
    )


def build_case(arch_name: str, shape: InputShape, mesh, *, multi_pod: bool) -> Case:
    if shape.kind == "train":
        return build_train_case(arch_name, shape, mesh, multi_pod=multi_pod)
    return build_serve_case(arch_name, shape, mesh, multi_pod=multi_pod)

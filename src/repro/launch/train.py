"""FL training driver — the end-to-end example entry point.

Runs real federated training at CPU scale (reduced configs) or assembles the
pod-scale jitted round step for any assigned architecture:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \\
      --clients 4 --rounds 5 --epochs 2 --strategy fedavg

The reduced path exercises the identical code the dry-run lowers for the
production mesh: model -> loss -> make_round_step -> strategy aggregation.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import (
    Int8Codec, NullCodec, RoundSpec, STRATEGIES, TopKCodec, make_round_step,
)
from repro.core.cost_model import AWS_DEVICE_FARM, PROFILES, CostModel
from repro.data.loader import lm_round_batch
from repro.models import build_model
from repro.optim import sgd
from repro.utils.logging import MetricsLogger
from repro.utils.pytree import tree_bytes, tree_size


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--epochs", type=int, default=1, help="local epochs E")
    ap.add_argument("--steps-per-epoch", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--strategy", default="fedavg", choices=sorted(STRATEGIES))
    ap.add_argument("--tau-steps", type=int, default=0,
                    help="cutoff step budget per round (0 = no cutoff)")
    ap.add_argument("--codec", default="fp32", choices=("fp32", "int8", "topk"),
                    help="uplink wire codec for the compressed round path")
    ap.add_argument("--scan", action="store_true",
                    help="compile the whole run into one lax.scan "
                         "(Server.run_scanned) instead of the per-round loop")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    logger = MetricsLogger("train")

    key = jax.random.key(args.seed)
    params = model.init(key)
    logger.log("init", arch=cfg.name, params=tree_size(params),
               bytes_mb=tree_bytes(params) / 1e6)

    strategy = STRATEGIES[args.strategy]()
    steps = args.epochs * args.steps_per_epoch
    codec = {"fp32": NullCodec(), "int8": Int8Codec(),
             "topk": TopKCodec(frac=0.01)}[args.codec]
    spec = RoundSpec(max_steps=steps, execution_mode="parallel", codec=codec)

    cost = CostModel(
        profiles=[PROFILES[AWS_DEVICE_FARM[i % len(AWS_DEVICE_FARM)]]
                  for i in range(args.clients)],
        update_bytes=tree_bytes(params),
    )

    weights = jnp.ones((args.clients,), jnp.float32)
    budget = args.tau_steps if args.tau_steps > 0 else steps
    budgets = jnp.full((args.clients,), budget, jnp.int32)

    def round_batch(rnd: int):
        batch = lm_round_batch(
            n_clients=args.clients, steps=steps, batch_size=args.batch,
            seq_len=args.seq, vocab_size=cfg.vocab_size,
            # tuple seeding (never seed*K+rnd arithmetic): affine seed maps
            # collide across (seed, round) pairs, correlating "independent"
            # runs — enforced by fedlint's rng-discipline rule
            seed=(args.seed, rnd),
        )
        if cfg.frontend_tokens:
            fd = cfg.frontend_dim or cfg.d_model
            rng = np.random.default_rng((args.seed, rnd))
            batch["frontend"] = rng.normal(
                size=(args.clients, steps, args.batch, cfg.frontend_tokens, fd)
            ).astype(np.float32)
        return batch

    if args.scan:
        # rounds-as-scan: the SAME per-round batches, stacked (R, C, ...),
        # one compiled program for the whole run, History decoded at the end
        from repro.core import Server

        stacked = jax.tree.map(
            lambda *xs: np.stack(xs),
            *[round_batch(r) for r in range(1, args.rounds + 1)],
        )
        srv = Server(strategy=strategy, clients=[], cost_model=cost)
        srv.logger.quiet = True
        _, hist, _ = srv.run_scanned(
            params, args.rounds, loss_fn=model.loss_fn, opt=sgd(args.lr),
            spec=spec, batches=stacked, weights=weights, step_budgets=budgets,
        )
        for rec in hist.rounds:
            logger.log(
                "round", rnd=rec.rnd, loss=rec.train_loss, steps=rec.steps,
                wall_s=rec.wall_time_s, energy_kj=rec.energy_j / 1e3,
            )
        print(f"final loss: {hist.rounds[-1].train_loss:.4f}")
        return

    round_step = jax.jit(make_round_step(model.loss_fn, sgd(args.lr),
                                         strategy, spec))
    server_state = strategy.init_state(params)
    client_state = codec.init_client_state(args.clients, tree_size(params))
    uplink = codec.wire_bytes([tree_size(params)] * args.clients)

    for rnd in range(1, args.rounds + 1):
        batch = round_batch(rnd)
        params, server_state, client_state, metrics = round_step(
            params, server_state, client_state, batch, weights, budgets, rnd
        )
        costs = cost.round_costs(
            [int(budgets[i]) for i in range(args.clients)], uplink_bytes=uplink
        )
        logger.log(
            "round", rnd=rnd,
            loss=float(metrics["client_loss_mean"]),
            steps=int(metrics["steps_total"]),
            wall_s=cost.round_wall_time(costs),
            energy_kj=cost.round_energy(costs) / 1e3,
        )

    print(f"final loss: {float(metrics['client_loss_mean']):.4f}")


if __name__ == "__main__":
    main()

"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import and then calls these.

Single pod:  (16, 16)      axes ("data", "model")     = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax

from repro.models.sharding import shard_map_compat  # noqa: F401  (re-export:
# launch-side drivers build their shard_maps through the same ONE version
# shim core.rounds uses)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(*, data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_info(mesh) -> dict:
    return {
        "axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": mesh.devices.size,
    }


def collective_tiers(mesh, client_axes) -> tuple:
    """``CostModel.mesh_tiers`` for a concrete mesh: the client axes the
    round step psums over, outer->inner, with their sizes —
    ``(("pod", 2), ("data", 16))`` on the multi-pod production mesh.  The
    one place the cost model's tier layout is derived from a mesh, so byte
    accounting cannot drift from the mesh actually launched."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    missing = [a for a in client_axes if a not in sizes]
    if missing:
        raise ValueError(
            f"client axes {missing} not on mesh axes {tuple(sizes)}"
        )
    return tuple((a, int(sizes[a])) for a in client_axes)

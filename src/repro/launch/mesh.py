"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import and then calls these.

Single pod:  (16, 16)      axes ("data", "model")     = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(*, data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_info(mesh) -> dict:
    return {
        "axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": mesh.devices.size,
    }

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, compiles, and fits — and extract the roofline terms.

The first two executable lines below MUST precede any jax import: jax locks
the device count on first initialization.  512 host devices back both the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import INPUT_SHAPES, list_configs
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.launch.specs import build_case
from repro.utils import roofline as rl

ASSIGNED_ARCHS = (
    "mixtral-8x7b",
    "jamba-1.5-large-398b",
    "xlstm-1..3b".replace("..", "."),
    "stablelm-3b",
    "granite-8b",
    "paligemma-3b",
    "qwen3-0.6b",
    "minicpm3-4b",
    "musicgen-medium",
    "deepseek-moe-16b",
)
SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def run_case(arch: str, shape_name: str, *, multi_pod: bool, verbose: bool = True) -> dict:
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size

    t0 = time.time()
    case = build_case(arch, shape, mesh, multi_pod=multi_pod)
    with mesh:
        lowered = jax.jit(
            case.fn,
            in_shardings=case.in_shardings,
            out_shardings=case.out_shardings,
            donate_argnums=getattr(case, "donate_argnums", ()),
        ).lower(*case.args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()

    report = rl.analyze(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        cost_analysis=cost, hlo_text=hlo, model_flops=case.model_flops,
    )

    per_dev_bytes = getattr(mem, "argument_size_in_bytes", 0) + getattr(
        mem, "output_size_in_bytes", 0
    ) + getattr(mem, "temp_size_in_bytes", 0)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "ok": True,
        "compile_s": round(t_compile, 1),
        "per_device_bytes": int(per_dev_bytes),
        "per_device_gb": round(per_dev_bytes / 2**30, 3),
        "hlo_flops": report.hlo_flops,
        "hlo_bytes": report.hlo_bytes,
        "collective_bytes": report.collective_bytes,
        "model_flops": report.model_flops,
        "compute_ms": round(report.compute_s * 1e3, 3),
        "memory_ms": round(report.memory_s * 1e3, 3),
        "collective_ms": round(report.collective_s * 1e3, 3),
        "dominant": report.dominant,
        "useful_flops_frac": round(report.useful_flops_frac, 3),
        "collective_breakdown": report.collective_breakdown,
        "meta": case.meta,
    }
    if verbose:
        print(
            f"[dryrun] {arch:>22} {shape_name:<12} {mesh_name:<8} OK "
            f"compile={t_compile:5.1f}s mem/dev={result['per_device_gb']:.2f}GB "
            f"compute={result['compute_ms']}ms memory={result['memory_ms']}ms "
            f"collective={result['collective_ms']}ms dominant={report.dominant} "
            f"useful={report.useful_flops_frac:.2f}"
        )
        print(f"  memory_analysis: {mem}")
        print("  " + report.collective_breakdown.replace("\n", "\n  "))
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPE_ORDER))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None, help="append results to this JSON file")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else (args.arch,)
    shapes = SHAPE_ORDER if (args.all or not args.shape) else (args.shape,)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    def _save(results):
        if not args.json:
            return
        existing = []
        if os.path.exists(args.json):
            with open(args.json) as f:
                existing = json.load(f)
        key = lambda r: (r["arch"], r["shape"], r["mesh"])
        keep = [r for r in existing if key(r) not in {key(r2) for r2 in results}]
        with open(args.json, "w") as f:
            json.dump(keep + results, f, indent=1)

    results, failed = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key3 = (arch, shape, "2x16x16" if mp else "16x16")
                if args.json and os.path.exists(args.json):
                    with open(args.json) as f:
                        done = {(r["arch"], r["shape"], r["mesh"]) for r in json.load(f) if r.get("ok")}
                    if key3 in done and args.skip_done:
                        continue
                try:
                    results.append(
                        run_case(arch, shape, multi_pod=mp, verbose=not args.quiet)
                    )
                    _save(results)  # incremental: survive crashes
                except Exception as e:  # a failure here is a sharding bug
                    failed.append((arch, shape, mp, repr(e)))
                    traceback.print_exc()
                    print(f"[dryrun] {arch} {shape} multi_pod={mp} FAILED: {e}")

    print(f"\n[dryrun] {len(results)} OK, {len(failed)} failed")
    for f_ in failed:
        print("  FAILED:", f_)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())

"""Pytree utilities shared across the framework.

The FL engine treats model parameters as arbitrary pytrees; everything here is
pure-functional and jit-compatible unless noted.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def safe_weight_sum(wf):
    """Denominator for weighted means: an all-zero weight vector (every
    sampled client reported zero examples) must yield a zero average, not
    NaNs that poison the global params.  Shared by every reduce path —
    kernels, reference oracles, codecs, and the round engine."""
    wsum = jnp.sum(wf)
    return jnp.where(wsum == 0.0, 1.0, wsum)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(a, x: PyTree, y: PyTree) -> PyTree:
    """a * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_lerp(a: PyTree, b: PyTree, t) -> PyTree:
    """(1 - t) * a + t * b, leafwise (t may be a traced scalar)."""
    return jax.tree.map(lambda ai, bi: ai + t * (bi - ai), a, b)


def tree_dot(a: PyTree, b: PyTree):
    parts = jax.tree.leaves(
        jax.tree.map(lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    )
    return jnp.sum(jnp.stack(parts))


def tree_sq_norm(tree: PyTree):
    return tree_dot(tree, tree)


def tree_norm(tree: PyTree):
    return jnp.sqrt(tree_sq_norm(tree))


def tree_size(tree: PyTree) -> int:
    """Total number of scalar elements (static)."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_bytes(tree: PyTree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_where(mask, a: PyTree, b: PyTree) -> PyTree:
    """Select a (mask true) or b leafwise; mask is a scalar/broadcastable bool."""
    return jax.tree.map(lambda x, y: jnp.where(mask, x, y), a, b)


def tree_stack(trees: list[PyTree]) -> PyTree:
    """Stack a list of identically-structured pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: PyTree, n: int) -> list[PyTree]:
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_flatten_to_vector(tree: PyTree) -> jnp.ndarray:
    """Concatenate every leaf (flattened, fp32) into one 1-D vector.

    Used by the aggregation/compression paths that operate on flat updates.
    """
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([x.astype(jnp.float32).reshape(-1) for x in leaves])


def tree_unflatten_from_vector(vec: jnp.ndarray, like: PyTree) -> PyTree:
    """Inverse of :func:`tree_flatten_to_vector` against a template pytree."""
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for leaf in leaves:
        # shapes are static: math.prod keeps host numpy out of the traced body
        n = math.prod(leaf.shape)
        out.append(vec[off : off + n].reshape(leaf.shape).astype(leaf.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def tree_map_with_path_filter(
    fn: Callable, tree: PyTree, predicate: Callable[[tuple, Any], bool]
) -> PyTree:
    """Apply fn to leaves where predicate(path, leaf) holds; identity otherwise."""

    def _apply(path, leaf):
        return fn(leaf) if predicate(path, leaf) else leaf

    return jax.tree_util.tree_map_with_path(_apply, tree)


def tree_paths(tree: PyTree) -> list[str]:
    """Human-readable path strings for every leaf (for masks / logging)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in flat]


def tree_mask_like(tree: PyTree, predicate: Callable[[str], bool]) -> PyTree:
    """Boolean mask pytree: True where predicate(path_string)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    vals = [bool(predicate(jax.tree_util.keystr(p))) for p, _ in flat]
    return jax.tree.unflatten(treedef, vals)


def tree_partition_apply(update_fn, params: PyTree, mask: PyTree) -> PyTree:
    """Apply update_fn only to leaves where mask (a bool pytree) is True.

    This realizes the paper's frozen-base/trainable-head split (§4.1): the FL
    client updates head leaves and passes base leaves through untouched.
    """
    return jax.tree.map(
        lambda p, m: update_fn(p) if m else p, params, mask
    )

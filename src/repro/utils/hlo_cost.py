"""HLO-text cost analyzer — the dry-run 'profiler'.

``compiled.cost_analysis()`` counts each computation ONCE, so anything inside
a ``while`` loop (every ``lax.scan``: the layer stack, local-step loop, CE
chunking) is undercounted by its trip count.  This analyzer parses the
partitioned, scheduled HLO text, builds per-computation symbol tables
(operands are referenced by name in scheduled HLO), extracts scan trip
counts from loop conditions, and accumulates through the call graph:

- dot FLOPs        2 * numel(out) * prod(contracted dims) — the MXU work;
- HBM bytes        operand + result bytes of every materializing top-level
                   instruction (post-fusion boundaries = HBM traffic model);
- collective bytes by op kind, with loop multipliers.

All quantities are PER-DEVICE (the text is the partitioned module).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]{1,12})\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(
    r"^(?:\((?:[^()]|\([^()]*\))*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\("
)

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id",
    "get-dimension-size", "iota", "copy-start", "copy-done",
}


def _shapes_of(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _nbytes(shapes: list[tuple[str, list[int]]]) -> float:
    total = 0.0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class _Instr:
    name: str
    opcode: str
    result_shapes: list          # [(dtype, dims)]
    operands: list[str]          # referenced instruction names
    attrs: str                   # full body text


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)  # name -> result_shapes
    max_const: float = 1.0


def _split_result_and_rest(body: str) -> tuple[str, str]:
    """body starts with the result shape (maybe a tuple); split it off."""
    if body.startswith("("):
        depth = 0
        for i, ch in enumerate(body):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                return body[: i + 1], body[i + 1 :]
    m = re.match(r"[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?", body)
    if m:
        return m.group(0), body[m.end():]
    return "", body


def _operand_names(rest: str) -> list[str]:
    """Names inside the first top-level parenthesized operand list."""
    start = rest.find("(")
    if start < 0:
        return []
    depth, end = 0, len(rest)
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            end = i
            break
    return re.findall(r"%([\w\.\-]+)", rest[start:end])


def parse_module(hlo_text: str):
    comps: dict[str, _Comp] = {}
    entry_name = None
    cur: _Comp | None = None

    for raw in hlo_text.splitlines():
        line = raw.strip()
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", line)
            if m:
                cur = _Comp(name=m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry_name = cur.name
            continue
        if cur is None:
            continue
        if line == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, body = mi.group(1), mi.group(2)
        res_text, rest = _split_result_and_rest(body)
        mo = re.match(r"\s*([\w\-]+)\(", rest)
        opcode = mo.group(1) if mo else ""
        shapes = _shapes_of(res_text)
        instr = _Instr(
            name=name, opcode=opcode, result_shapes=shapes,
            operands=_operand_names(rest), attrs=rest,
        )
        cur.instrs.append(instr)
        cur.symbols[name] = shapes
        for c in re.findall(r"\bconstant\((\d+)\)", body):
            cur.max_const = max(cur.max_const, float(c))

    return comps, entry_name


@dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=lambda: defaultdict(float))
    coll_count: dict = field(default_factory=lambda: defaultdict(float))

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    def coll_summary(self) -> str:
        total = self.collective_bytes
        lines = [f"collective traffic (per-device): {total/1e9:.3f} GB"]
        for op in sorted(self.coll_bytes, key=self.coll_bytes.get, reverse=True):
            lines.append(
                f"  {op:<22} x{int(self.coll_count[op]):<6} {self.coll_bytes[op]/1e9:.3f} GB"
            )
        return "\n".join(lines)

    def add(self, other: "ModuleCost", mult: float = 1.0, bytes_too: bool = True):
        self.flops += mult * other.flops
        if bytes_too:
            self.bytes += mult * other.bytes
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += mult * v
            self.coll_count[k] += mult * other.coll_count[k]


def _dot_flops(instr: _Instr, symbols: dict) -> float:
    numel_out = 1
    for _, dims in instr.result_shapes:
        for d in dims:
            numel_out *= d
    lhs_shapes = symbols.get(instr.operands[0]) if instr.operands else None
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    if m and m.group(1) and lhs_shapes:
        lhs_dims = lhs_shapes[0][1]
        for ax in m.group(1).split(","):
            ax = int(ax)
            if ax < len(lhs_dims):
                contract *= lhs_dims[ax]
    return 2.0 * numel_out * contract


def analyze_hlo(hlo_text: str) -> ModuleCost:
    comps, entry_name = parse_module(hlo_text)
    memo: dict[str, ModuleCost] = {}

    def cost_of(comp_name: str, depth: int = 0) -> ModuleCost:
        if comp_name in memo:
            return memo[comp_name]
        mc = ModuleCost()
        comp = comps.get(comp_name)
        if comp is None or depth > 128:
            return mc
        memo[comp_name] = mc

        for instr in comp.instrs:
            op = instr.opcode
            if op == "dot":
                mc.flops += _dot_flops(instr, comp.symbols)

            coll = None
            for kind in COLLECTIVE_KINDS:
                if op == kind or op == kind + "-start":
                    coll = kind
                    break
            if op.endswith("-done"):
                continue
            if coll:
                nb = _nbytes(instr.result_shapes)
                mc.coll_bytes[coll] += nb
                mc.coll_count[coll] += 1
                mc.bytes += nb
                continue

            if op == "while":
                mw = re.search(
                    r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", instr.attrs
                )
                if mw:
                    cond, body = mw.group(1), mw.group(2)
                    trip = max(1.0, comps[cond].max_const if cond in comps else 1.0)
                    mc.add(cost_of(body, depth + 1), mult=trip)
                continue

            if op in ("fusion",):
                mcall = re.search(r"calls=%?([\w\.\-]+)", instr.attrs)
                if mcall:
                    mc.add(cost_of(mcall.group(1), depth + 1), bytes_too=False)
            elif op in ("call", "custom-call", "map", "reduce", "reduce-window",
                        "sort", "scatter", "select-and-scatter"):
                for mcall in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", instr.attrs):
                    mc.add(cost_of(mcall.group(1), depth + 1), bytes_too=False)
            elif op == "conditional":
                branches = re.findall(
                    r"(?:true_computation|false_computation)=%?([\w\.\-]+)", instr.attrs
                )
                mb = re.search(r"branch_computations=\{([^}]*)\}", instr.attrs)
                if mb:
                    branches += re.findall(r"%?([\w\.\-]+)", mb.group(1))
                for brname in branches:
                    mc.add(cost_of(brname, depth + 1))

            if op in _SKIP_BYTES_OPS or not op:
                continue
            # HBM traffic model: result + operand bytes of materializing instrs
            res_b = _nbytes(instr.result_shapes)
            opnd_b = [
                _nbytes(comp.symbols.get(opnd, [])) for opnd in instr.operands
            ]
            lname = instr.name.lower()
            if op == "dynamic-update-slice" or (
                op == "fusion" and "dynamic-update-slice" in lname
            ):
                # in-place slice update: traffic = 2 x update region, not the
                # whole buffer (XLA fuses DUS in place)
                nb = 2.0 * sum(b for b in opnd_b if b < res_b)
            elif op == "dynamic-slice" or (
                op == "fusion" and "dynamic-slice" in lname
            ):
                nb = 2.0 * res_b
            else:
                nb = res_b + sum(opnd_b)
            mc.bytes += nb

        return mc

    return cost_of(entry_name or "")

"""Roofline-term derivation from a compiled (dry-run) executable.

TPU v5e constants (per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: ~50 GB/s/link

Terms (seconds, per step).  ``compiled.cost_analysis()`` and the partitioned
HLO text both describe the PER-DEVICE program (calibrated against an 8192^3
matmul on the 256-chip mesh: reported flops = global/chips), so:

  compute    = HLO_FLOPs_per_dev / PEAK_FLOPS
  memory     = HLO_bytes_per_dev / HBM_BW
  collective = collective_bytes_per_dev / ICI_BW
  useful_FLOP_frac = MODEL_FLOPS / (HLO_FLOPs_per_dev * chips)
"""
from __future__ import annotations

from dataclasses import dataclass, asdict

from .hlo import CollectiveStats, parse_collectives

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9       # bytes/s per chip
ICI_BW = 50e9        # bytes/s per link per chip


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float  # 6*N*D (dense) / 6*N_active*D (MoE); 0 for serve steps
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_flops_frac: float = 0.0
    collective_breakdown: str = ""

    def finalize(self) -> "RooflineReport":
        # hlo_flops / hlo_bytes / collective_bytes are per-device quantities
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.dominant = max(terms, key=terms.get)
        self.useful_flops_frac = (
            self.model_flops / (self.hlo_flops * self.chips) if self.hlo_flops else 0.0
        )
        return self

    def row(self) -> str:
        return (
            f"| {self.arch} | {self.shape} | {self.mesh} | "
            f"{self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} | "
            f"{self.collective_s*1e3:.2f} | {self.dominant} | "
            f"{self.useful_flops_frac:.2f} |"
        )

    def to_dict(self) -> dict:
        return asdict(self)


def analyze(
    *,
    arch: str,
    shape: str,
    mesh: str,
    chips: int,
    cost_analysis: dict,
    hlo_text: str,
    model_flops: float,
) -> RooflineReport:
    """Derive roofline terms from the compiled per-device HLO.

    Primary source: utils.hlo_cost.analyze_hlo (resolves scan trip counts,
    which cost_analysis() does not).  cost_analysis values are kept for
    cross-checking on loop-free programs.
    """
    from .hlo_cost import analyze_hlo

    mc = analyze_hlo(hlo_text)
    rep = RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh,
        chips=chips,
        hlo_flops=mc.flops,
        hlo_bytes=mc.bytes,
        collective_bytes=mc.collective_bytes,
        model_flops=model_flops,
        collective_breakdown=mc.coll_summary(),
    )
    return rep.finalize()


TABLE_HEADER = (
    "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
    "| dominant | useful-FLOP frac |\n"
    "|---|---|---|---|---|---|---|---|"
)

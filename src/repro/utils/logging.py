"""Minimal structured logging for the FL server and launchers."""
from __future__ import annotations

import json
import sys
import time
from dataclasses import dataclass, field
from typing import Any


@dataclass
class MetricsLogger:
    """Collects per-round metrics; prints compact lines and can dump JSON."""

    name: str = "repro"
    stream: Any = field(default_factory=lambda: sys.stderr)
    history: list[dict] = field(default_factory=list)
    t0: float = field(default_factory=time.time)
    quiet: bool = False

    def log(self, event: str, **kv) -> None:
        rec = {"t": round(time.time() - self.t0, 3), "event": event, **kv}
        self.history.append(rec)
        if not self.quiet:
            kvs = " ".join(
                f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                for k, v in kv.items()
            )
            print(f"[{self.name}] {event} {kvs}", file=self.stream)

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.history, f, indent=1, default=str)

    def series(self, event: str, key: str) -> list:
        return [r[key] for r in self.history if r["event"] == event and key in r]

"""HLO-text analysis: collective-traffic extraction for the roofline report.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse the (stable)HLO text and sum operand sizes of every
communication op.  This is the "profiler" of the CPU-only dry-run environment.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

# op name -> traffic multiplier heuristic. For a ring algorithm an all-gather
# of output size S moves ~S*(n-1)/n per link; we report *operand/result bytes*
# (the canonical "collective bytes" that roofline term divides by link bw) and
# leave algorithmic factors to the analysis text.
COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
)

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g. "bf16[256,4096,512]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    """Aggregate collective traffic of one compiled executable."""

    bytes_by_op: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    count_by_op: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    instances: list[tuple[str, int, str]] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    def summary(self) -> str:
        lines = [f"collective traffic: {self.total_bytes/1e9:.3f} GB total"]
        for op in sorted(self.bytes_by_op, key=self.bytes_by_op.get, reverse=True):
            lines.append(
                f"  {op:<22} x{self.count_by_op[op]:<4} {self.bytes_by_op[op]/1e9:.3f} GB"
            )
        return "\n".join(lines)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective instruction in HLO text.

    We parse the *result* shape on the lhs of `= <shape> op-name(...)` lines;
    for fusion-wrapped collectives XLA keeps the collective op visible at the
    module level, so a line scan is sufficient in practice.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+([a-z\-]+)", stripped)
        if not m:
            continue
        opname = m.group(2)
        matched = None
        for coll in COLLECTIVE_OPS:
            if opname == coll or opname.startswith(coll + "-start") or opname == coll + "-done":
                matched = coll
                break
        if matched is None:
            continue
        if opname.endswith("-done"):
            continue  # counted at -start
        nbytes = shape_bytes(m.group(1))
        stats.bytes_by_op[matched] += nbytes
        stats.count_by_op[matched] += 1
        stats.instances.append((matched, nbytes, stripped[:160]))
    return stats


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"=\s*[^=]*\b{re.escape(opname)}\(", hlo_text))

"""Version-tolerant access to XLA's ``compiled.cost_analysis()``.

Older JAX returns a single properties dict; newer JAX returns a list with
one dict per partition (and some backends return ``None``).  Everything in
this repo that compares the HLO-text analyzer against XLA's own counters
goes through :func:`xla_cost_dict` so both shapes work.
"""
from __future__ import annotations

from typing import Any, Mapping


def xla_cost_dict(compiled: Any) -> dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` to one flat ``{metric: value}``.

    Accepts a compiled executable (anything with ``cost_analysis()``), an
    already-extracted dict, or the list-of-dicts shape.  Multi-partition
    lists are summed per key — cost properties are additive across
    partitions of one module.
    """
    props = compiled.cost_analysis() if hasattr(compiled, "cost_analysis") else compiled
    if props is None:
        return {}
    if isinstance(props, Mapping):
        return {str(k): float(v) for k, v in props.items()}
    # list/tuple of per-partition dicts (newer JAX)
    out: dict[str, float] = {}
    for part in props:
        if part is None:
            continue
        for k, v in part.items():
            out[str(k)] = out.get(str(k), 0.0) + float(v)
    return out

"""Million-client population layer: packed device fleet, resident-only state.

The paper's fleets are six devices; its thesis — quantified system costs
should shape FL algorithm design — is about fleets of millions (PAPERS.md:
mobile-edge survey 1909.11875, IoT panorama 2002.10610).  This module makes
that scale representable without making anything per-client:

- ``Population``: N device profiles stored **struct-of-arrays** — one small
  integer profile code per device plus per-*class* columns (step time,
  power, link speeds).  ~1 byte/device instead of a python object/device;
  every per-device quantity is a vectorized ``column[codes[ids]]`` gather
  over just the ids in hand, O(cohort) regardless of N.
- ``CohortState``: the codec error-feedback residual store.  Only the
  *sampled* cohort's rows are ever resident — as a dense ``(C, n_params)``
  array for flat codecs, or as a tuple of per-segment ``(C, seg.size)``
  blocks for segmented codecs (``gather`` on dispatch, ``scatter`` on
  report); everything else lives in a hashed (python dict) LRU spill store
  bounded by ``capacity`` rows.
- ``LazyClientPool``: a sequence-like client collection that materializes
  ``Client`` objects on demand (LRU-bounded), spilling/rehydrating their
  error-feedback carry through a ``CohortState`` so ``Server.run`` never
  holds N python clients.

The resident-state contract
---------------------------

Codec client state is resident **only while sampled**.  ``gather(ids)``
densifies the cohort's rows for one jitted ``round_step`` (missing rows are
zeros); ``scatter(ids, state)`` returns them to the spill store.  The round
engine is unchanged shape-wise: it sees exactly the pytree the codec's
``init_client_state`` describes — one dense ``(C, n_params)`` buffer for a
flat codec, or per-segment ``(C, seg.size)`` blocks (``()`` for stateless
segments) for a codec carrying a ``SegmentMap`` — with row order matching
the cohort id order, and the participation mask / codec contracts apply
verbatim (rounds.py).  Spilled rows are stored *leafwise* for segmented
codecs: a multi-B fsdp model never needs one monolithic (n_params,)
buffer per client anywhere in the store.

Eviction semantics: the spill store holds at most ``capacity`` rows; beyond
that the least-recently-sampled client's row is dropped and **eviction
resets the residual to zero** — the next time that client is sampled it
gathers a zero row, exactly the state of a client that never compressed
anything.  Error feedback stays correct under this reset (the residual is
an *optimization* that telescopes past compression error; zeroing it only
forgets error already accounted as such), so the eviction test pins that a
post-eviction round is bitwise the round of a fresh-residual client.
``MixedCodec`` is rejected: its per-client codec assignment is static along
the client axis, which cannot follow a dynamically sampled cohort.

Python-path twin: ``JaxClient`` owns its residual between ``fit`` calls, so
``LazyClientPool`` spills it (``Client.export_state``) into the same store
on eviction and rehydrates (``import_state``) on re-materialization — the
same eviction-resets-residual contract, now bounding live *clients* too.
Keep ``capacity`` above cohort size + in-flight arrivals: evicting a client
with an undelivered fit spills its optimistically-committed residual, so a
later scheduler drop can no longer roll it back.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import numpy as np

from .compression import MixedCodec
from .cost_model import AWS_DEVICE_FARM, PROFILES, DeviceProfile, link_time_s

PyTree = Any

# the packed per-class columns, in DeviceProfile field order
_COLUMNS = (
    "step_time_s", "active_power_w", "idle_power_w", "uplink_mbps",
    "downlink_mbps",
)


@dataclass(frozen=True)
class Population:
    """N devices as profile codes + per-class columns (struct-of-arrays).

    ``profile_codes`` is ``(N,)`` small-uint indices into ``table`` — the
    only O(N) storage (~1 byte/device).  All hardware numbers live in
    ``(P,)`` per-class column arrays, so any per-device quantity for a set
    of ids is one ``column[codes[ids]]`` gather: O(len(ids)), never O(N).
    """

    profile_codes: np.ndarray
    table: tuple[DeviceProfile, ...]

    def __post_init__(self):
        assert self.table, "a population needs at least one device class"
        codes = np.ascontiguousarray(self.profile_codes)
        assert codes.ndim == 1 and codes.size > 0
        assert int(codes.max()) < len(self.table), "profile code out of range"
        object.__setattr__(self, "profile_codes", codes)
        for name in _COLUMNS:
            col = np.asarray([getattr(p, name) for p in self.table], np.float64)
            object.__setattr__(self, f"{name}_table", col)

    # ------------------------------------------------------------ builders
    @classmethod
    def from_profiles(cls, profiles: Sequence[DeviceProfile]) -> "Population":
        """Pack an explicit per-device profile list (the legacy fleet shape):
        ``pop.profile(i)`` is ``profiles[i]``, deduplicated into classes."""
        table: dict[DeviceProfile, int] = {}
        codes = np.empty(len(profiles), np.int64)
        for i, p in enumerate(profiles):
            codes[i] = table.setdefault(p, len(table))
        dtype = np.min_scalar_type(max(0, len(table) - 1))
        return cls(profile_codes=codes.astype(dtype), table=tuple(table))

    @classmethod
    def synthetic(
        cls,
        n: int,
        mix: dict[str, float] | Sequence[str] | None = None,
        seed: int = 0,
    ) -> "Population":
        """An N-device fleet drawn from a device-class mix.

        ``mix`` maps profile names (``PROFILES``) to sampling weights, or
        lists names for a uniform mix; default is the paper's AWS Device
        Farm classes (Table 1), uniform.  O(N) once, here — everything
        downstream is O(cohort).
        """
        if mix is None:
            mix = AWS_DEVICE_FARM
        if not isinstance(mix, dict):
            mix = {name: 1.0 for name in mix}
        table = tuple(PROFILES[name] for name in mix)
        w = np.asarray(list(mix.values()), np.float64)
        rng = np.random.default_rng(seed)
        dtype = np.min_scalar_type(len(table) - 1)
        codes = rng.choice(len(table), size=n, p=w / w.sum()).astype(dtype)
        return cls(profile_codes=codes, table=table)

    # ------------------------------------------------------------- queries
    def __len__(self) -> int:
        return int(self.profile_codes.shape[0])

    @property
    def n_profiles(self) -> int:
        return len(self.table)

    @property
    def nbytes(self) -> int:
        """Host bytes of the packed representation (the flat-memory claim)."""
        cols = sum(getattr(self, f"{c}_table").nbytes for c in _COLUMNS)
        return int(self.profile_codes.nbytes) + cols

    def profile(self, client_id: int) -> DeviceProfile:
        """One device's class — P distinct objects exist, never N."""
        return self.table[int(self.profile_codes[client_id])]

    def column(self, name: str, ids) -> np.ndarray:
        """Vectorized per-device column gather for ``ids`` (O(len(ids)))."""
        return getattr(self, f"{name}_table")[self.profile_codes[ids]]

    def expected_round_s(
        self, ids, *, steps: int, up_bytes: float, down_bytes: float
    ) -> np.ndarray:
        """Predicted compute+comm round time per id, vectorized over the
        candidate pool (``link_time_s`` is the shared link-time owner)."""
        ids = np.asarray(ids)
        codes = self.profile_codes[ids]
        comm = link_time_s(
            up_bytes, down_bytes,
            self.uplink_mbps_table[codes], self.downlink_mbps_table[codes],
        )
        return steps * self.step_time_s_table[codes] + comm


class CohortState:
    """Resident-only-when-sampled codec client state (see module docstring).

    ``gather(ids)`` -> dense cohort state for the jitted engine (``()`` for
    stateless codecs; a ``(C, n_params)`` buffer for flat codecs; a tuple
    of per-segment ``(C, seg.size)`` blocks for segmented codecs), zeros
    where a client was never seen *or was evicted*; ``scatter(ids, state)``
    writes the engine's updated rows back into the LRU spill store.
    ``get_row``/``put_row`` are the single-row surface ``LazyClientPool``
    spills python-path clients through — for a segmented codec a row is a
    tuple of per-segment fp32 vectors (``()`` entries for stateless
    segments), never one monolithic (n_params,) buffer.
    """

    def __init__(self, codec, n_params: int, *, capacity: int = 4096,
                 shardings=None):
        if isinstance(codec, MixedCodec):
            raise TypeError(
                "MixedCodec assigns codecs to static client-axis slots; a "
                "population cohort is resampled every round, so per-client "
                "codec choice must come from BandwidthCodecPolicy instead"
            )
        assert capacity >= 1
        self.codec = codec
        self.n_params = int(n_params)
        self.capacity = int(capacity)
        # mesh layout for the gathered cohort blocks (fsdp archs): one
        # NamedSharding for the flat (C, n_params) block, or a tuple with
        # one per segment (models.sharding.client_state_shardings) — gather
        # device_puts each stateful block to it, so the dense cohort state
        # lands sharded (param dim split, per-device bytes ~1/n_dev) and is
        # never materialized replicated.  Placement only: values bitwise
        # what the unsharded gather returns.
        self.shardings = shardings
        self.stateless = (
            codec is None or not codec.carries_client_state(self.n_params)
        )
        self.segments = getattr(codec, "segments", None)
        if self.segments is not None:
            assert self.segments.n_params == self.n_params, (
                f"codec segment map covers {self.segments.n_params} params, "
                f"store built for {self.n_params}"
            )
            self._seg_stateful = tuple(
                codec.segment_stateful(seg) for seg in self.segments
            )
        self._rows: OrderedDict[int, Any] = OrderedDict()
        self.evictions = 0

    def _pack_row(self, row):
        """Normalize a row to the spill representation: one (n_params,)
        fp32 vector for flat codecs; a tuple of per-segment vectors
        (leafwise, ``()`` for stateless segments) for segmented codecs —
        a flat vector is accepted and split for convenience."""
        if self.segments is None:
            return np.asarray(row, np.float32).reshape(self.n_params)
        segs = self.segments
        if isinstance(row, (tuple, list)):
            assert len(row) == len(segs), (
                f"segmented row has {len(row)} entries, map has {len(segs)}"
            )
            return tuple(
                np.asarray(r, np.float32).reshape(seg.size) if sf else ()
                for r, seg, sf in zip(row, segs, self._seg_stateful)
            )
        flat = np.asarray(row, np.float32).reshape(self.n_params)
        return tuple(
            flat[seg.offset : seg.offset + seg.size].copy() if sf else ()
            for seg, sf in zip(segs, self._seg_stateful)
        )

    # ------------------------------------------------------- row-level API
    def get_row(self, client_id: int):
        row = self._rows.get(int(client_id))
        if row is not None:
            self._rows.move_to_end(int(client_id))
        return row

    def put_row(self, client_id: int, row) -> None:
        self._rows[int(client_id)] = self._pack_row(row)
        self._rows.move_to_end(int(client_id))
        while len(self._rows) > self.capacity:
            self._rows.popitem(last=False)  # eviction == residual reset to 0
            self.evictions += 1

    # ------------------------------------------------- cohort (engine) API
    def gather(self, cohort_ids):
        """Round-local dense cohort state, row i belongs to cohort_ids[i].

        The returned pytree matches ``codec.init_client_state(C, n_params)``
        structurally, so the jitted engine is oblivious to the store."""
        if self.stateless:
            return ()
        import jax.numpy as jnp

        if self.segments is None:
            out = np.zeros((len(cohort_ids), self.n_params), np.float32)
            for i, cid in enumerate(cohort_ids):
                row = self.get_row(cid)
                if row is not None:
                    out[i] = row
            if self.shardings is not None:
                import jax

                sh = (
                    self.shardings[0]
                    if isinstance(self.shardings, (tuple, list))
                    else self.shardings
                )
                return jax.device_put(out, sh)
            return jnp.asarray(out)

        cols = [
            np.zeros((len(cohort_ids), seg.size), np.float32) if sf else None
            for seg, sf in zip(self.segments, self._seg_stateful)
        ]
        for i, cid in enumerate(cohort_ids):
            row = self.get_row(cid)
            if row is not None:
                for col, r in zip(cols, row):
                    if col is not None:
                        col[i] = r
        if self.shardings is not None:
            import jax

            assert len(self.shardings) == len(cols), (
                f"{len(self.shardings)} shardings for {len(cols)} segments"
            )
            return tuple(
                jax.device_put(col, sh) if col is not None else ()
                for col, sh in zip(cols, self.shardings)
            )
        return tuple(
            jnp.asarray(col) if col is not None else () for col in cols
        )

    def scatter(self, cohort_ids, state) -> None:
        """Return the engine's updated rows to the spill store (same order
        as the ``gather`` that produced them)."""
        if self.stateless:
            return
        if self.segments is None:
            rows = np.asarray(state, np.float32)
            assert rows.shape == (len(cohort_ids), self.n_params), (
                f"scatter shape {rows.shape} != ({len(cohort_ids)}, {self.n_params})"
            )
            for cid, row in zip(cohort_ids, rows):
                self.put_row(cid, row)
            return
        state = tuple(state)
        assert len(state) == len(self.segments), (
            f"segmented scatter has {len(state)} entries, map has "
            f"{len(self.segments)}"
        )
        cols = []
        for st, seg, sf in zip(state, self.segments, self._seg_stateful):
            if not sf:
                cols.append(None)
                continue
            arr = np.asarray(st, np.float32)
            assert arr.shape == (len(cohort_ids), seg.size), (
                f"segment {seg.name!r} scatter shape {arr.shape} != "
                f"({len(cohort_ids)}, {seg.size})"
            )
            cols.append(arr)
        for i, cid in enumerate(cohort_ids):
            self.put_row(
                cid, tuple(() if col is None else col[i] for col in cols)
            )

    # ---------------------------------------------------------- accounting
    def __len__(self) -> int:
        return len(self._rows)

    @property
    def nbytes(self) -> int:
        return sum(
            sum(x.nbytes for x in r if not isinstance(x, tuple))
            if isinstance(r, tuple) else r.nbytes
            for r in self._rows.values()
        )

    def reset(self) -> None:
        self._rows.clear()
        self.evictions = 0


class LazyClientPool:
    """Sequence-like client collection over a ``Population``.

    ``pool[cid]`` materializes a ``Client`` via ``factory(cid)`` on first
    access and keeps at most ``capacity`` live objects (LRU).  With a
    ``state_store`` (``CohortState``), an evicted client's error-feedback
    carry is spilled (``Client.export_state``) and rehydrated on the next
    materialization — beyond the store's own capacity the residual resets
    to zero, the module-level eviction contract.
    """

    def __init__(
        self,
        population: Population,
        factory: Callable[[int], Any],
        *,
        capacity: int = 256,
        state_store: CohortState | None = None,
    ):
        assert capacity >= 1
        self.population = population
        self.factory = factory
        self.capacity = int(capacity)
        self.state_store = state_store
        self._live: OrderedDict[int, Any] = OrderedDict()
        self.materializations = 0

    def __len__(self) -> int:
        return len(self.population)

    def __getitem__(self, client_id: int):
        cid = int(client_id)
        client = self._live.get(cid)
        if client is None:
            client = self.factory(cid)
            self.materializations += 1
            if self.state_store is not None:
                row = self.state_store.get_row(cid)
                if row is not None:
                    client.import_state(row)
            self._live[cid] = client
        self._live.move_to_end(cid)
        while len(self._live) > self.capacity:
            old_cid, old = self._live.popitem(last=False)
            if self.state_store is not None:
                row = old.export_state()
                if row is not None:
                    self.state_store.put_row(old_cid, row)
        return client

    @property
    def live(self) -> int:
        return len(self._live)

    def reset_state(self) -> None:
        """Fresh trajectory: drop live clients and any spilled carry
        (``Server.run``'s population-mode twin of per-client reset)."""
        self._live.clear()
        self.materializations = 0
        if self.state_store is not None:
            self.state_store.reset()

"""FL engine — the paper's contribution as a composable JAX module."""
from .protocol import (
    FitIns, FitRes, EvaluateIns, EvaluateRes, Parameters,
    pytree_to_parameters, parameters_to_pytree,
)
from .client import Client, JaxClient
from .server import Server, History, RoundRecord, make_cost_model_for
from .cost_model import CostModel, DeviceProfile, PROFILES, AWS_DEVICE_FARM
from .rounds import RoundSpec, make_round_step, make_client_update, init_residuals
from .compression import Int8Codec, TopKCodec, NullCodec, compress_update, decompress_update
from .strategy import (
    Strategy, FedAvg, FedProx, FedTau, FedOpt, FedAdam, FedYogi, FedAvgM,
    STRATEGIES, tau_from_reference_processor,
)

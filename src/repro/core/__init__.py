"""FL engine — the paper's contribution as a composable JAX module."""
from .protocol import (
    FitIns, FitRes, EvaluateIns, EvaluateRes, Parameters, CompressedParameters,
    ClientProperties, pytree_to_parameters, parameters_to_pytree,
    compress_to_wire, wire_to_pytree,
)
from .client import Client, JaxClient
from .server import Server, History, RoundRecord, make_cost_model_for
from .cost_model import (
    CostModel, DeviceProfile, PROFILES, AWS_DEVICE_FARM, AvailabilityTrace,
    ClientCost,
)
from .scheduler import (
    VirtualClock, Arrival, RoundOutcome, RoundPolicy, SyncAll, Deadline,
    BufferedAsync,
)
from .rounds import (
    RoundSpec, cohort_dispatch_mask, init_collective_residual,
    make_client_update, make_multi_round_step, make_round_step,
)
from .compression import (
    UpdateCodec, Int8Codec, TopKCodec, NullCodec, MixedCodec, LoRACodec,
    Segment, SegmentMap, StructuredUpdate, CompressedPsum,
    BandwidthCodecPolicy, compress_update, decompress_update,
)
from .population import CohortState, LazyClientPool, Population
from .strategy import (
    Strategy, FedAvg, FedProx, FedTau, FedBuffStrategy, FedOpt, FedAdam,
    FedYogi, FedAvgM, STRATEGIES, tau_from_reference_processor,
    CostAwareSampling, CostAwareFedAvg,
)

"""Update compression codecs (beyond paper).

The paper measures communication as a first-class system cost; these codecs
shrink the client->server payload that the cost model charges for:

- int8 block quantization (8x over fp32 wire, ~4x over bf16), via the
  Pallas quantize kernel;
- top-k sparsification with error feedback (classic gradient compression).

Codecs operate on the *delta* (client params - global params), which is
small-magnitude and quantizes well.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.utils.pytree import (
    tree_flatten_to_vector,
    tree_sub,
    tree_unflatten_from_vector,
)

PyTree = Any


@dataclass(frozen=True)
class Int8Codec:
    block: int = 256

    def wire_bytes(self, n_params: int) -> int:
        return n_params + 4 * (n_params // self.block)  # int8 + fp32 scales

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        pad = (-n) % self.block
        padded = jnp.pad(delta_vec, (0, pad))
        q, scale = ops.quantize_int8(padded, block=self.block)
        return {"q": q, "scale": scale, "n": n}

    def decode(self, enc) -> jnp.ndarray:
        vec = ops.dequantize_int8(enc["q"], enc["scale"], block=self.block)
        return vec[: enc["n"]]


@dataclass(frozen=True)
class TopKCodec:
    """Keep the k largest-|.| entries; the residual feeds back next round."""

    frac: float = 0.01

    def wire_bytes(self, n_params: int) -> int:
        k = max(1, int(n_params * self.frac))
        return k * 8  # int32 index + fp32 value

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        k = max(1, int(n * self.frac))
        vals, idx = jax.lax.top_k(jnp.abs(delta_vec), k)
        return {"idx": idx, "val": delta_vec[idx], "n": n}

    def decode(self, enc) -> jnp.ndarray:
        return jnp.zeros((enc["n"],), enc["val"].dtype).at[enc["idx"]].set(enc["val"])


def compress_update(
    codec, new_params: PyTree, global_params: PyTree
) -> tuple[Any, PyTree]:
    """-> (wire_payload, residual_vec) for error feedback."""
    delta = tree_flatten_to_vector(tree_sub(new_params, global_params))
    enc = codec.encode(delta)
    residual = delta - codec.decode(enc)
    return enc, residual


def decompress_update(codec, enc, global_params: PyTree) -> PyTree:
    delta = codec.decode(enc)
    flat_global = tree_flatten_to_vector(global_params)
    return tree_unflatten_from_vector(flat_global + delta, global_params)

"""Update compression codecs — the compressed-wire round path's wire format.

The paper measures communication as a first-class system cost; these codecs
shrink the client->server payload that the cost model charges for:

- ``Int8Codec``: int8 block quantization (~4x over fp32 wire), via the
  Pallas quantize kernel; decoded server-side through the fused
  dequantize+weighted-reduce kernel (one HBM pass over the int8 payload).
- ``TopKCodec``: top-k sparsification with error feedback (classic gradient
  compression).
- ``NullCodec``: identity fp32 wire — the uncompressed baseline with the
  same interface, so the round engine has one code path.

Codecs operate on the *delta* (client params - global params), which is
small-magnitude and quantizes well.  Two surfaces:

- 1-D ``encode`` / ``decode`` on a single flat delta vector (the python-side
  Server/Client path and unit tests);
- batched ``encode_batch`` / ``decode_batch`` / ``reduce`` on a (C, N) delta
  matrix — jit-/vmap-free row-block layout used inside the jitted round
  step (core/rounds.py).  ``reduce`` consumes the *encoded* payload directly
  so the Int8 weighted-mean itself never materializes the fp32 (C, N)
  matrix (the round step still dequantizes once per round to compute the
  error-feedback residual).

``wire_bytes(n)`` is the per-client uplink charge the CostModel uses in
place of raw ``tree_bytes`` (core/server.py, core/cost_model.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.utils.pytree import (
    tree_flatten_to_vector,
    tree_sub,
    tree_unflatten_from_vector,
)

PyTree = Any


@dataclass(frozen=True)
class NullCodec:
    """Identity codec: full-precision fp32 wire (the uncompressed baseline)."""

    def wire_bytes(self, n_params: int) -> int:
        return 4 * n_params

    def encode(self, delta_vec: jnp.ndarray):
        return {"delta": delta_vec.astype(jnp.float32), "n": delta_vec.shape[0]}

    def decode(self, enc) -> jnp.ndarray:
        return enc["delta"]

    def encode_batch(self, deltas: jnp.ndarray):
        return {"delta": deltas.astype(jnp.float32), "n": deltas.shape[1]}

    def decode_batch(self, enc) -> jnp.ndarray:
        return enc["delta"]

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        return ops.fedavg_reduce(enc["delta"], weights, interpret=interpret)


@dataclass(frozen=True)
class Int8Codec:
    block: int = 256

    def _n_scales(self, n_params: int) -> int:
        return -(-n_params // self.block)  # ceil: encode pads to a block multiple

    def wire_bytes(self, n_params: int) -> int:
        # int8 payload (pad blocks need not cross the wire: the receiver
        # re-pads from n) + one fp32 scale per ceil(n/block) block
        return n_params + 4 * self._n_scales(n_params)

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        pad = (-n) % self.block
        padded = jnp.pad(delta_vec, (0, pad))
        q, scale = ops.quantize_int8(padded, block=self.block)
        return {"q": q, "scale": scale, "n": n}

    def decode(self, enc) -> jnp.ndarray:
        vec = ops.dequantize_int8(enc["q"], enc["scale"], block=self.block)
        return vec[: enc["n"]]

    # ---- batched (C, N) wire path used inside the jitted round step ----
    def encode_batch(self, deltas: jnp.ndarray):
        """(C, N) -> q (C, Np) int8 + scales (C, Np/block); Np = padded N.

        Rows are padded to a block multiple, so flattening (C, Np) keeps
        every quantization block inside one client row and the 1-D Pallas
        kernel applies unchanged.
        """
        c, n = deltas.shape
        pad = (-n) % self.block
        padded = jnp.pad(deltas, ((0, 0), (0, pad)))
        np_ = n + pad
        q, scale = ops.quantize_int8(padded.reshape(-1), block=self.block)
        return {
            "q": q.reshape(c, np_),
            "scale": scale.reshape(c, np_ // self.block),
            "n": n,
        }

    def decode_batch(self, enc) -> jnp.ndarray:
        c = enc["q"].shape[0]
        vec = ops.dequantize_int8(
            enc["q"].reshape(-1), enc["scale"].reshape(-1), block=self.block
        )
        return vec.reshape(c, -1)[:, : enc["n"]]

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        """Weighted-mean decode straight off the int8 payload (fused kernel)."""
        avg = ops.dequant_reduce(
            enc["q"], enc["scale"], weights, block=self.block, interpret=interpret
        )
        return avg[: enc["n"]]


@dataclass(frozen=True)
class TopKCodec:
    """Keep the k largest-|.| entries; the residual feeds back next round."""

    frac: float = 0.01

    def k_of(self, n_params: int) -> int:
        return max(1, int(n_params * self.frac))

    def wire_bytes(self, n_params: int) -> int:
        return self.k_of(n_params) * 8  # int32 index + fp32 value

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        _, idx = jax.lax.top_k(jnp.abs(delta_vec), self.k_of(n))
        return {"idx": idx, "val": delta_vec[idx], "n": n}

    def decode(self, enc) -> jnp.ndarray:
        return jnp.zeros((enc["n"],), enc["val"].dtype).at[enc["idx"]].set(enc["val"])

    def encode_batch(self, deltas: jnp.ndarray):
        n = deltas.shape[1]
        _, idx = jax.lax.top_k(jnp.abs(deltas), self.k_of(n))  # (C, k)
        return {"idx": idx, "val": jnp.take_along_axis(deltas, idx, axis=1), "n": n}

    def decode_batch(self, enc) -> jnp.ndarray:
        c = enc["idx"].shape[0]
        rows = jnp.arange(c)[:, None]
        return (
            jnp.zeros((c, enc["n"]), enc["val"].dtype)
            .at[rows, enc["idx"]]
            .set(enc["val"])
        )

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        # sparse payload: densify per client, then the weighted-reduce kernel
        return ops.fedavg_reduce(self.decode_batch(enc), weights, interpret=interpret)


def compress_update(
    codec, new_params: PyTree, global_params: PyTree
) -> tuple[Any, PyTree]:
    """-> (wire_payload, residual_vec) for error feedback."""
    delta = tree_flatten_to_vector(tree_sub(new_params, global_params))
    enc = codec.encode(delta)
    residual = delta - codec.decode(enc)
    return enc, residual


def decompress_update(codec, enc, global_params: PyTree) -> PyTree:
    delta = codec.decode(enc)
    flat_global = tree_flatten_to_vector(global_params)
    return tree_unflatten_from_vector(flat_global + delta, global_params)

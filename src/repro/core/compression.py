"""Update compression codecs — first-class citizens of every execution path.

The paper measures communication as a first-class system cost; these codecs
shrink the client->server payload that the cost model charges for:

- ``Int8Codec``: int8 block quantization (~4x over fp32 wire), via the
  Pallas quantize kernel; decoded server-side through the fused
  dequantize+weighted-reduce kernel (one HBM pass over the int8 payload).
- ``TopKCodec``: top-k sparsification with error feedback (classic gradient
  compression).  Server-side aggregation is O(C·k): the (idx, val) payloads
  feed the scatter-accumulate kernel directly (see the O(C·k) reduce
  contract below) — the dense (C, n_params) delta matrix is never built.
- ``LoRACodec``: low-rank factor wire for matrix-shaped segments — the
  structure-aware codec that makes LLM-scale federated fine-tuning fit the
  paper's smartphone uplink numbers (see the LoRA wire format below).
- ``NullCodec``: identity fp32 wire — the uncompressed baseline with the
  same interface, and the *default* codec of ``RoundSpec``, so the round
  engine has exactly one code path.

The segmented wire contract (``SegmentMap`` / ``StructuredUpdate``)
-------------------------------------------------------------------

Historically every codec operated on ONE flat ``(n_params,)`` fp32 vector.
That representation is now the degenerate case of a *leafwise-segmented*
wire:

- A ``SegmentMap`` is a static tuple of ``Segment(name, shape, offset)``
  records covering ``[0, n_params)`` contiguously — usually one segment per
  model leaf (``SegmentMap.from_tree``), with ``SegmentMap.flat(n)`` as the
  single-segment legacy layout.  It is frozen/hashable python data, so a
  codec carrying one stays a valid jit-static closure constant.
- ``codec.with_segments(segmap)`` returns a segmented copy.  With
  ``segments=None`` (the default) every codec runs the EXACT pre-segment
  flat code path; with a map set, the codec surface becomes per-segment:

  * ``init_client_state`` returns a *tuple* of per-segment state entries
    (``(C, seg.size)`` fp32 residual rows for stateful segments, ``()``
    for stateless ones) instead of one ``(C, n_params)`` buffer — the
    population layer spills/rehydrates these rows leafwise.
  * ``encode``/``decode`` happen per segment (``encode_segment`` /
    ``decode_segment``); the full-update payload is a ``StructuredUpdate``
    — the segment map plus one codec payload per segment.
  * ``transmit_tree`` works leaf-by-leaf when the map matches the delta
    tree (a sharded/fsdp model is never flattened into one replicated
    vector); when it does not match, the flat vector is sliced per
    segment.
  * ``wire_bytes`` is the sum of ``segment_wire_bytes(seg)`` — wire
    accounting composes per segment, and a codec that changes a segment's
    wire (LoRA) restates exactly that segment's cost.
  * ``aggregate_batch`` reduces column-blocks per segment through the same
    kernels as before — and because each block is ``seg.size`` wide, the
    VMEM-budget dispatch in ``kernels/ops.py`` is consulted *per segment*:
    a model whose total ``n_params`` exceeds ``scatter_reduce.MAX_N_PARAMS``
    can still take the Pallas scatter path segment-by-segment.

  Bitwise parity: a single-segment map (``SegmentMap.flat``) produces
  bit-identical results to the legacy flat path for Null/Int8/TopK on all
  three execution modes — the per-segment driver degenerates to the flat
  code applied to the whole-vector slice (pinned in
  ``tests/test_structured_update.py``).

The LoRA wire format (``LoRACodec``)
------------------------------------

Per segment, the wire is either the low-rank factorization or the wrapped
fallback codec:

- **Matrix segments** (``len(seg.shape) >= 2``, folded to
  ``(prod(shape[:-1]), shape[-1])``, and strictly cheaper than dense at the
  effective rank ``r = min(rank, m, n)``): the delta block ``X`` ships as
  PowerSGD-style factors ``A (m, r)`` (orthonormalized ``X @ q``) and
  ``B (r, n) = A.T @ X``, each encoded by ``factor_codec`` (e.g. Int8 on
  the factors) — ``segment_wire_bytes = factor_codec.wire_bytes(m*r) +
  factor_codec.wire_bytes(r*n)``.  The random projection ``q`` is derived
  from ``(seed, seg.offset)`` only, so server and clients agree on it
  without it ever crossing the wire.  The reconstruction ``A @ B`` is what
  the server decodes; the factorization error feeds back through the
  per-segment residual rows, so it telescopes across rounds exactly like
  TopK's untransmitted coordinates.
- **Non-matrix segments** (biases, norm scales, or matrices too small to
  win): delegate wholesale to ``fallback`` (default Int8) — encode, state,
  and wire accounting.

The O(C·k) TopK reduce contract
-------------------------------

- **Payload layout**: per client, ``idx`` (k,) int32 positions and ``val``
  (k,) fp32 values, 8k wire bytes.  The encoder is deterministic (equal
  magnitudes tie-break toward the lower index via a stable sort) and emits
  indices in canonical ascending order, so a given delta yields
  bit-identical wire bytes under jit and eager alike.
- **Duplicate-index semantics**: our encoder emits distinct indices, but
  every consumer (``decode``, ``decode_batch``, ``reduce``, the Pallas
  kernel and its oracle) treats duplicates as scatter-ADD — a foreign
  payload with repeated indices means the same thing on every path.
- **Reduce paths**: ``aggregate_batch`` (jit-parallel engine) scatter-
  reduces the encoded payload and updates the error-feedback state by
  zeroing the transmitted coordinates — O(C·k), no dense decode;
  ``transmit_tree`` (mesh shard_map / sequential scan) decodes one
  client's (n_params,) vector at a time, never a (C, n_params) matrix;
  ``Strategy.aggregate_fit`` scatter-reduces serialized wire payloads when
  the whole fleet shipped TopK.  Under a segment map every bound holds
  per segment with k = k_of(seg.size).
- **When densify still applies**: ``decode_batch`` exists for callers that
  explicitly want the dense per-client matrix — nothing on any reduce path
  calls it.  The fused kernel additionally requires the (n_params,)
  accumulator to fit VMEM; above ``scatter_reduce.MAX_N_PARAMS`` (derived
  from the kernel file's declared ``VMEM_BUDGET_ELEMS``) the dispatch
  falls back to the XLA scatter-add oracle, which is still O(C·k).

Mixed-batch group semantics (``MixedCodec``)
--------------------------------------------

A heterogeneous fleet (some clients on TopK, some Int8, some fp32) runs
inside ONE jitted ``round_step`` through ``MixedCodec``: a codec *bank*
plus a static per-client group assignment (e.g. derived once from
``BandwidthCodecPolicy`` over the fleet's ``DeviceProfile``s).  The
contract extends the O(C·k) reduce contract group-wise:

- **Trace-time partition**: the assignment is static python data, so the
  client axis is partitioned into per-codec groups when the round step is
  traced — every group is a fixed, shape-static slice of the batch, and
  each group's encode + reduce runs on its own kernel path (TopK group →
  scatter-accumulate, Int8 group → fused dequant+reduce, Null group →
  ``fedavg_reduce`` on the flat surface / the leafwise mean on the pytree
  surface).  The TopK group is still O(C_g·k): its payload is never
  densified (``decode_batch`` stays off every mixed path too).
- **One denominator**: each group contributes its *partial weighted sum*
  (the group mean scaled back by the group's weight mass); the groups'
  partials combine into one mean with a single ``safe_weight_sum``
  denominator over the whole fleet, so the result equals a flat weighted
  mean of the per-client decoded deltas up to fp rounding (the partials
  are recovered as group-mean x weight mass) — an all-zero-weight group
  contributes exactly zero, never NaNs.
- **Per-group state**: ``init_client_state`` returns a *tuple* pytree, one
  entry per bank codec — residual rows only for the groups whose codec
  carries error feedback ((C_g, n_params) fp32 flat, or the per-segment
  tuple for a segmented group codec), ``()`` for Null groups — carried
  opaquely through the uniform ``round_step`` signature on the
  vmap-parallel and sequential paths alike.
- **Segment maps thread through group construction**: bank codecs may be
  segmented (``MixedCodec.with_segments`` maps the whole bank) — a LoRA
  group and an Int8 group coexist in one fleet.  Codecs carrying
  *different* explicit maps are rejected at build time (the client axis
  shares one model, so there is exactly one valid leaf layout).
- **Per-group wire accounting**: ``wire_bytes`` returns one uplink size
  per client (the codec its group ships, segmented codecs included),
  which is what ``CostModel.round_costs`` charges a mixed fleet.
- The mesh shard_map path is NOT supported for ``MixedCodec`` (an SPMD
  program cannot run a different wire format per device);
  ``make_round_step`` rejects the combination at build time.

Codecs operate on the *delta* (client params - global params), which is
small-magnitude and quantizes well.  The ``UpdateCodec`` base class defines
the full surface the engine and protocol layer program against:

- ``init_client_state(n_clients, n_params)`` — the codec-owned per-client
  state pytree carried across rounds by ``round_step``.  Error-feedback
  codecs return fp32 residual rows ((C, n_params) flat, or a per-segment
  tuple under a segment map); ``NullCodec`` returns an empty pytree (no
  state is allocated for the uncompressed wire).
- ``aggregate_batch(deltas, weights, state)`` — the batched (C, N) path
  used inside the jitted parallel round step: fold the residual in, encode,
  reduce straight off the *encoded* payload (for Int8 the fused
  dequant+reduce kernel never materializes the fp32 (C, N) matrix), and
  return the new residual state.
- ``transmit_tree(delta_tree, state_row)`` — the per-client path used
  inside the mesh ``shard_map`` manual region and the sequential scan:
  what the server would decode from this one client's uplink, plus the
  client's next state row.  ``NullCodec`` overrides it to the identity so
  sharded models never round-trip through a flat replicated vector.
- ``wire_payload(enc)`` / ``from_wire(payload)`` — the exact arrays that
  cross the wire (Int8 trims encoder padding; the receiver re-pads), used
  by the protocol layer's ``CompressedParameters`` serialization.  Under a
  segment map the per-segment hooks ``segment_wire_payload`` /
  ``segment_from_wire`` serialize each ``StructuredUpdate`` payload; the
  protocol layer namespaces the fields ``s{i}.<key>``.
- ``wire_bytes(n)`` — the per-client uplink charge; accepts an int or a
  vector of per-client sizes so ``CostModel.round_costs`` can account for
  a heterogeneous fleet where every client ships a different payload.
"""
from __future__ import annotations

import dataclasses
import math
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.utils.pytree import (
    safe_weight_sum,
    tree_flatten_to_vector,
    tree_sub,
    tree_unflatten_from_vector,
)

PyTree = Any


# ---------------- segment map: the static leaf layout of an update ----------------
@dataclass(frozen=True)
class Segment:
    """One contiguous span of the flat update: a leaf's shape at an offset.

    Static python data (hashable): codecs carry segments as jit-closure
    constants, so every field is a plain int/str/tuple.
    """

    name: str
    shape: tuple
    offset: int

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(d) for d in self.shape))
        object.__setattr__(self, "offset", int(self.offset))

    @property
    def size(self) -> int:
        return math.prod(self.shape) if self.shape else 1

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def matrix_shape(self) -> tuple:
        """The 2-D view structured codecs factorize: leading axes fold into
        rows — (..., m, n) -> (prod(leading) * m, n).  A stacked-expert MoE
        leaf (E, d_in, d_out) is E matrices sharing the output basis, which
        is exactly the fold a low-rank factorization wants."""
        assert self.ndim >= 2, f"segment {self.name!r} has no matrix view"
        return (math.prod(self.shape[:-1]), int(self.shape[-1]))


@dataclass(frozen=True)
class SegmentMap:
    """A static, contiguous tuple of ``Segment``s covering [0, n_params).

    ``flat(n)`` is the single-segment legacy layout; ``from_tree`` builds
    one segment per model leaf in ``tree_flatten`` order (the same order
    ``tree_flatten_to_vector`` concatenates), so offsets line up with the
    flat vector bitwise.
    """

    segments: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "segments", tuple(self.segments))
        off = 0
        for seg in self.segments:
            assert seg.offset == off, (
                f"segment {seg.name!r} at offset {seg.offset}, expected {off}"
                " — segments must tile the flat vector contiguously"
            )
            off += seg.size

    @classmethod
    def flat(cls, n_params: int) -> "SegmentMap":
        return cls((Segment("flat", (n_params,), 0),))

    @classmethod
    def from_tree(cls, tree: PyTree) -> "SegmentMap":
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        segs, off = [], 0
        for path, leaf in flat:
            seg = Segment(jax.tree_util.keystr(path) or "leaf", tuple(leaf.shape), off)
            segs.append(seg)
            off += seg.size
        return cls(tuple(segs))

    @property
    def n_params(self) -> int:
        return sum(s.size for s in self.segments)

    def __iter__(self):
        return iter(self.segments)

    def __len__(self) -> int:
        return len(self.segments)

    def __getitem__(self, i):
        return self.segments[i]

    def matches_leaves(self, leaves) -> bool:
        """Do these pytree leaves line up 1:1 with the segments (same count,
        same shapes, tree_flatten order)?  When true, segmented codecs work
        leaf-by-leaf and never build the flat (n_params,) vector."""
        return len(leaves) == len(self.segments) and all(
            tuple(leaf.shape) == seg.shape
            for leaf, seg in zip(leaves, self.segments)
        )

    def split(self, vec: jnp.ndarray):
        """Slice a flat (n_params,) vector into per-segment vectors."""
        return [vec[s.offset : s.offset + s.size] for s in self.segments]


@dataclass(frozen=True, eq=False)
class StructuredUpdate:
    """A segmented wire payload: one codec payload per segment.

    Registered as a pytree (segments are static aux data), so it crosses
    jit boundaries and ``jax.tree`` transforms transparently.
    """

    segments: SegmentMap
    payloads: tuple


jax.tree_util.register_pytree_node(
    StructuredUpdate,
    lambda su: (su.payloads, su.segments),
    lambda segs, payloads: StructuredUpdate(segs, tuple(payloads)),
)


class UpdateCodec:
    """Base codec: error-feedback residual state + flat-vector wire.

    Subclasses implement the wire format (``encode``/``decode`` and their
    batched variants, ``reduce``, ``_wire_bytes_scalar``); the state and
    transport machinery below is shared.  ``NullCodec`` overrides the state
    hooks to be stateless/identity.

    With ``segments`` set (see the module docstring's segmented wire
    contract) the public surface dispatches per segment through the
    ``*_segment`` hooks; their defaults apply the flat wire format to each
    segment's slice, so Null/Int8/TopK are segment-ready without further
    overrides and a single flat segment reproduces the legacy path bitwise.
    """

    # dataclass subclasses redeclare this as a field; plain access must work
    segments: SegmentMap | None = None

    def with_segments(self, segments: SegmentMap) -> "UpdateCodec":
        """A copy of this codec bound to a static segment map."""
        if dataclasses.is_dataclass(self):
            return dataclasses.replace(self, segments=segments)
        raise TypeError(f"{type(self).__name__} cannot carry a segment map")

    def segment_map(self, n_params: int | None = None) -> SegmentMap:
        if self.segments is not None:
            if n_params is not None:
                assert self.segments.n_params == n_params, (
                    f"{type(self).__name__} segment map covers "
                    f"{self.segments.n_params} params, caller has {n_params}"
                )
            return self.segments
        assert n_params is not None, "flat codec needs n_params for a map"
        return SegmentMap.flat(n_params)

    # ---- per-client state (carried by round_step across rounds) ----
    def init_client_state(self, n_clients: int, n_params: int) -> PyTree:
        """Zero error-feedback state: one flat fp32 residual per client, or
        (under a segment map) a tuple of per-segment residual rows."""
        if self.segments is not None:
            self.segment_map(n_params)
            return tuple(
                self.init_segment_state(n_clients, seg) for seg in self.segments
            )
        return self._init_flat_state(n_clients, n_params)

    def _init_flat_state(self, n_clients: int, n_params: int) -> PyTree:
        return jnp.zeros((n_clients, n_params), jnp.float32)

    def init_segment_state(self, n_clients: int, seg: Segment) -> PyTree:
        return self._init_flat_state(n_clients, seg.size)

    def segment_stateful(self, seg: Segment) -> bool:
        return bool(jax.tree_util.tree_leaves(self.init_segment_state(1, seg)))

    def carries_client_state(self, n_params: int = 1) -> bool:
        """Whether this codec owns round-to-round per-client state.

        The population layer's ``CohortState`` consults this: a stateless
        codec gathers ``()`` and spills nothing, a stateful one gathers a
        dense residual row per sampled client.  Probes a one-client state
        rather than trusting subclasses to remember a flag.
        """
        if self.segments is not None:
            n_params = self.segments.n_params
        return bool(jax.tree_util.tree_leaves(
            self.init_client_state(1, n_params)
        ))

    # ---- batched (C, N) surface: the jitted parallel round step ----
    def aggregate_updates(
        self, client_params: PyTree, global_params: PyTree,
        weights: jnp.ndarray, state,
    ):
        """Full aggregation of vmapped client params -> (avg params, state).

        Default (flat): flatten per-client deltas to the (C, n_params) wire
        layout and aggregate off the encoded payload (``aggregate_batch``).
        ``NullCodec`` overrides this leafwise so the uncompressed engine
        never materializes the flat fp32 matrix.

        Segmented: when the map matches the model leaves, each leaf's
        (C, seg.size) delta block aggregates independently — the full
        (C, n_params) concat is never built; otherwise the flat matrix is
        sliced per segment (bitwise-equal column spans).
        """
        if self.segments is None:
            flat_global = tree_flatten_to_vector(global_params)
            deltas = jax.vmap(
                lambda p: tree_flatten_to_vector(p) - flat_global
            )(client_params)
            avg_delta, new_state = self.aggregate_batch(deltas, weights, state)
            return (
                tree_unflatten_from_vector(flat_global + avg_delta, global_params),
                new_state,
            )

        segs = self.segment_map()
        leaves_g, treedef = jax.tree_util.tree_flatten(global_params)
        new_state = list(state)
        if segs.matches_leaves(leaves_g):
            leaves_c = jax.tree_util.tree_flatten(client_params)[0]
            new_leaves = []
            for i, (seg, lc, lg) in enumerate(zip(segs, leaves_c, leaves_g)):
                c = lc.shape[0]
                block = (
                    lc.astype(jnp.float32).reshape(c, -1)
                    - lg.astype(jnp.float32).reshape(-1)
                )
                mean_i, new_state[i] = self.aggregate_segment_batch(
                    block, weights, state[i], seg
                )
                new_leaves.append(
                    (lg.astype(jnp.float32) + mean_i.reshape(lg.shape)).astype(lg.dtype)
                )
            return jax.tree_util.tree_unflatten(treedef, new_leaves), tuple(new_state)

        flat_global = tree_flatten_to_vector(global_params)
        self.segment_map(flat_global.shape[0])
        deltas = jax.vmap(
            lambda p: tree_flatten_to_vector(p) - flat_global
        )(client_params)
        avg_delta, new_state = self.aggregate_batch(deltas, weights, state)
        return (
            tree_unflatten_from_vector(flat_global + avg_delta, global_params),
            new_state,
        )

    def aggregate_batch(self, deltas: jnp.ndarray, weights: jnp.ndarray, state):
        """(C, N) deltas + state -> (weighted-mean decoded delta (N,), new state).

        Error feedback in, encode, reduce off the encoded payload; what was
        not transmitted becomes the next residual, so the compression error
        telescopes across rounds instead of accumulating.  Under a segment
        map, each segment's column block reduces independently through
        ``aggregate_segment_batch`` (the per-segment sizes are what the
        kernel dispatch's VMEM budget sees).
        """
        if self.segments is None:
            return self._aggregate_batch_flat(deltas, weights, state)
        segs = self.segment_map(deltas.shape[1])
        parts, new_state = [], list(state)
        for i, seg in enumerate(segs):
            part, new_state[i] = self.aggregate_segment_batch(
                deltas[:, seg.offset : seg.offset + seg.size], weights, state[i], seg
            )
            parts.append(part)
        return jnp.concatenate(parts), tuple(new_state)

    def _aggregate_batch_flat(self, deltas, weights, state):
        eff = deltas + state
        enc = self.encode_batch(eff)
        new_state = eff - self.decode_batch(enc)
        return self.reduce(enc, weights), new_state

    def aggregate_segment_batch(self, deltas, weights, state, seg: Segment):
        """One segment's (C, seg.size) block -> (mean (seg.size,), new state).

        Default: the flat wire format applied to the block — which is why a
        single flat segment is bitwise the legacy path.
        """
        return self._aggregate_batch_flat(deltas, weights, state)

    # ---- per-client surface: mesh shard_map region / sequential scan ----
    def transmit_tree(self, delta_tree: PyTree, state_row):
        """One client's uplink: -> (decoded delta tree, new state row).

        The returned tree contains exactly the information that survives the
        wire (encode -> decode); the caller aggregates it, so only codec-
        representable values ever cross the slow inter-pod links.  Under a
        segment map matching the tree, each leaf transmits on its own — a
        sharded model never round-trips through one replicated flat vector.
        """
        if self.segments is None:
            vec = tree_flatten_to_vector(delta_tree)
            seg = Segment("flat", (vec.shape[0],), 0)
            dec, new_row = self.transmit_segment(vec, state_row, seg)
            return tree_unflatten_from_vector(dec, delta_tree), new_row

        segs = self.segment_map()
        leaves, treedef = jax.tree_util.tree_flatten(delta_tree)
        if segs.matches_leaves(leaves):
            decs, rows = [], []
            for leaf, row, seg in zip(leaves, state_row, segs):
                dec, new_row = self.transmit_segment(
                    leaf.astype(jnp.float32).reshape(-1), row, seg
                )
                decs.append(dec.reshape(leaf.shape).astype(leaf.dtype))
                rows.append(new_row)
            return jax.tree_util.tree_unflatten(treedef, decs), tuple(rows)

        vec = tree_flatten_to_vector(delta_tree)
        self.segment_map(vec.shape[0])
        decs, rows = [], []
        for part, row, seg in zip(segs.split(vec), state_row, segs):
            dec, new_row = self.transmit_segment(part, row, seg)
            decs.append(dec.reshape(-1))
            rows.append(new_row)
        return (
            tree_unflatten_from_vector(jnp.concatenate(decs), delta_tree),
            tuple(rows),
        )

    def transmit_segment(self, vec: jnp.ndarray, state_row, seg: Segment):
        """One client's uplink for ONE segment: (vec (seg.size,), row) ->
        (decoded (seg.size,), new row).  ``state_row`` is ``()`` for a
        stateless segment."""
        stateful = not isinstance(state_row, tuple)
        eff = vec + state_row if stateful else vec
        enc = self.encode_segment(eff, seg)
        dec = self.decode_segment(enc, seg)
        return dec, (eff - dec if stateful else ())

    # ---- per-segment wire hooks (defaults: the flat format per slice) ----
    def encode_segment(self, vec: jnp.ndarray, seg: Segment):
        return self.encode(vec)

    def decode_segment(self, enc, seg: Segment) -> jnp.ndarray:
        return self.decode(enc)

    def encode_structured(self, delta_vec: jnp.ndarray) -> StructuredUpdate:
        """Flat (n_params,) delta -> per-segment payloads (protocol path)."""
        segs = self.segment_map(int(delta_vec.shape[0]))
        return StructuredUpdate(
            segs,
            tuple(
                self.encode_segment(part, seg)
                for part, seg in zip(segs.split(delta_vec), segs)
            ),
        )

    def decode_structured(self, su: StructuredUpdate) -> jnp.ndarray:
        """Dense (n_params,) fp32 decode of a ``StructuredUpdate``."""
        return jnp.concatenate([
            self.decode_segment(p, seg).reshape(-1).astype(jnp.float32)
            for seg, p in zip(su.segments, su.payloads)
        ])

    # ---- wire serialization hooks (protocol.CompressedParameters) ----
    def wire_payload(self, enc) -> dict:
        """The exact fields that cross the wire (arrays + python scalars)."""
        return dict(enc)

    def from_wire(self, payload: dict) -> dict:
        """Rebuild the decodable payload from ``wire_payload`` fields."""
        return dict(payload)

    def segment_wire_payload(self, payload, seg: Segment) -> dict:
        """Wire fields for ONE segment's payload (protocol layer namespaces
        them ``s{i}.<key>``)."""
        return self.wire_payload(payload)

    def segment_from_wire(self, fields: dict, seg: Segment):
        return self.from_wire(fields)

    # ---- uplink accounting ----
    def _wire_bytes_scalar(self, n_params: int) -> int:
        raise NotImplementedError

    def segment_wire_bytes(self, seg: Segment) -> int:
        """Uplink bytes for ONE segment (the flat format on its slice by
        default; structure-aware codecs restate this per segment)."""
        return self._wire_bytes_scalar(seg.size)

    def wire_bytes(self, n_params):
        """Uplink bytes for an ``n_params``-sized update.

        Accepts an int (homogeneous fleet) or a sequence of per-client sizes
        (heterogeneous accounting) and returns an int or list respectively.
        Under a segment map the scalar is the sum of per-segment wire sizes.
        """
        if self.segments is not None:
            total = sum(self.segment_wire_bytes(seg) for seg in self.segments)
            if isinstance(n_params, (list, tuple, np.ndarray)):
                ns = np.asarray(n_params).reshape(-1)
                for n in ns:
                    self.segment_map(int(n))
                return [total] * len(ns)
            self.segment_map(int(n_params))
            return total
        if isinstance(n_params, (list, tuple, np.ndarray)):
            return [self._wire_bytes_scalar(int(n)) for n in np.asarray(n_params).reshape(-1)]
        return self._wire_bytes_scalar(int(n_params))


@dataclass(frozen=True)
class NullCodec(UpdateCodec):
    """Identity codec: full-precision fp32 wire (the uncompressed baseline).

    Stateless: ``init_client_state`` is empty, ``transmit_tree`` is the
    identity on the delta pytree (no flatten — sharded sequential/fsdp
    models keep their layout), and ``aggregate_batch`` is exactly the fused
    weighted reduce of the uncompressed engine.
    """

    segments: Any = None

    def _wire_bytes_scalar(self, n_params: int) -> int:
        return 4 * n_params

    def _init_flat_state(self, n_clients: int, n_params: int) -> PyTree:
        return ()

    def aggregate_updates(self, client_params, global_params, weights, state):
        """Leafwise fp32 weighted mean — the fp32 wire loses nothing, so the
        uncompressed path never flattens the model into one (C, N) matrix
        (same reasoning as the identity ``transmit_tree``)."""
        wf = weights.astype(jnp.float32)
        wsum = safe_weight_sum(wf)

        def leaf_mean(xs, g):
            wshape = (xs.shape[0],) + (1,) * (xs.ndim - 1)
            gf = g.astype(jnp.float32)
            acc = jnp.sum(
                (xs.astype(jnp.float32) - gf) * wf.reshape(wshape), axis=0
            )
            return (gf + acc / wsum).astype(g.dtype)

        # state passes through unchanged (() flat; a tuple of ()s segmented)
        # so the scan carry keeps one stable structure across rounds
        return jax.tree.map(leaf_mean, client_params, global_params), state

    def _aggregate_batch_flat(self, deltas, weights, state):
        return self.reduce(self.encode_batch(deltas), weights), state

    def transmit_tree(self, delta_tree, state_row):
        return delta_tree, state_row

    def encode(self, delta_vec: jnp.ndarray):
        return {"delta": delta_vec.astype(jnp.float32), "n": delta_vec.shape[0]}

    def decode(self, enc) -> jnp.ndarray:
        return enc["delta"]

    def encode_batch(self, deltas: jnp.ndarray):
        return {"delta": deltas.astype(jnp.float32), "n": deltas.shape[1]}

    def decode_batch(self, enc) -> jnp.ndarray:
        return enc["delta"]

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        return ops.fedavg_reduce(enc["delta"], weights, interpret=interpret)


@dataclass(frozen=True)
class Int8Codec(UpdateCodec):
    block: int = 256
    segments: Any = None

    def _n_scales(self, n_params: int) -> int:
        return -(-n_params // self.block)  # ceil: encode pads to a block multiple

    def _wire_bytes_scalar(self, n_params: int) -> int:
        # int8 payload (pad blocks need not cross the wire: the receiver
        # re-pads from n) + one fp32 scale per ceil(n/block) block
        return n_params + 4 * self._n_scales(n_params)

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        pad = (-n) % self.block
        padded = jnp.pad(delta_vec, (0, pad))
        q, scale = ops.quantize_int8(padded, block=self.block)
        return {"q": q, "scale": scale, "n": n}

    def decode(self, enc) -> jnp.ndarray:
        vec = ops.dequantize_int8(enc["q"], enc["scale"], block=self.block)
        return vec[: enc["n"]]

    def wire_payload(self, enc) -> dict:
        # pad int8s never cross the wire: trim to n, the receiver re-pads
        return {"q": enc["q"][: enc["n"]], "scale": enc["scale"], "n": enc["n"]}

    def from_wire(self, payload: dict) -> dict:
        n = payload["n"]
        q = jnp.asarray(payload["q"])
        return {
            "q": jnp.pad(q, (0, (-n) % self.block)),
            "scale": jnp.asarray(payload["scale"]),
            "n": n,
        }

    # ---- batched (C, N) wire path used inside the jitted round step ----
    def encode_batch(self, deltas: jnp.ndarray):
        """(C, N) -> q (C, Np) int8 + scales (C, Np/block); Np = padded N.

        Rows are padded to a block multiple, so flattening (C, Np) keeps
        every quantization block inside one client row and the 1-D Pallas
        kernel applies unchanged.
        """
        c, n = deltas.shape
        pad = (-n) % self.block
        padded = jnp.pad(deltas, ((0, 0), (0, pad)))
        np_ = n + pad
        q, scale = ops.quantize_int8(padded.reshape(-1), block=self.block)
        return {
            "q": q.reshape(c, np_),
            "scale": scale.reshape(c, np_ // self.block),
            "n": n,
        }

    def decode_batch(self, enc) -> jnp.ndarray:
        c = enc["q"].shape[0]
        vec = ops.dequantize_int8(
            enc["q"].reshape(-1), enc["scale"].reshape(-1), block=self.block
        )
        return vec.reshape(c, -1)[:, : enc["n"]]

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        """Weighted-mean decode straight off the int8 payload (fused kernel)."""
        avg = ops.dequant_reduce(
            enc["q"], enc["scale"], weights, block=self.block, interpret=interpret
        )
        return avg[: enc["n"]]


@dataclass(frozen=True)
class TopKCodec(UpdateCodec):
    """Keep the k largest-|.| entries; the residual feeds back next round.

    Wire contract (load-bearing for the O(C·k) reduce):

    - selection is DETERMINISTIC: magnitudes tie-break toward the lower
      index via a stable sort (raw ``lax.top_k`` tie order is lowering-
      dependent), so a given delta produces bit-identical payloads under
      jit and eager alike;
    - payload indices are canonically sorted ascending — reproducible wire
      bytes, and the scatter kernel walks VMEM monotonically;
    - this encoder emits distinct indices, but every consumer treats
      duplicate indices as ACCUMULATE (scatter-add), so foreign payloads
      mean the same thing on all paths;
    - ``reduce`` consumes (idx, val) directly through the scatter-
      accumulate kernel — O(C·k) time and memory, no dense (C, N) matrix;
      ``decode_batch`` remains the explicit densify fallback for callers
      that want the per-client dense matrix (nothing on the reduce or
      error-feedback path does).
    - under a segment map each segment keeps its own k = k_of(seg.size)
      coordinates, and the scatter kernel's VMEM-budget dispatch sees
      seg.size — not the whole model — per reduce call.
    """

    frac: float = 0.01
    segments: Any = None

    def k_of(self, n_params: int) -> int:
        # math.floor, not int(): n_params is static, but this method is
        # jit-reachable and a py-cast here would read as tracer concretization
        return max(1, math.floor(n_params * self.frac))

    def _wire_bytes_scalar(self, n_params: int) -> int:
        return self.k_of(n_params) * 8  # int32 index + fp32 value

    @staticmethod
    def _topk_idx(mags: jnp.ndarray, k: int) -> jnp.ndarray:
        """Deterministic top-k positions along the last axis: stable sort by
        descending magnitude (ties keep ascending index order), then the
        selected k re-sorted to the canonical ascending-index wire order."""
        iota = jax.lax.broadcasted_iota(jnp.int32, mags.shape, mags.ndim - 1)
        _, idx = jax.lax.sort(
            (-mags.astype(jnp.float32), iota),
            dimension=-1, num_keys=1, is_stable=True,
        )
        return jnp.sort(idx[..., :k], axis=-1)

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        idx = self._topk_idx(jnp.abs(delta_vec), self.k_of(n))
        return {"idx": idx, "val": delta_vec[idx], "n": n}

    def decode(self, enc) -> jnp.ndarray:
        # scatter-ADD: duplicate indices accumulate (kernel semantics)
        return jnp.zeros((enc["n"],), enc["val"].dtype).at[enc["idx"]].add(enc["val"])

    def encode_batch(self, deltas: jnp.ndarray):
        n = deltas.shape[1]
        idx = self._topk_idx(jnp.abs(deltas), self.k_of(n))  # (C, k)
        return {"idx": idx, "val": jnp.take_along_axis(deltas, idx, axis=1), "n": n}

    def decode_batch(self, enc) -> jnp.ndarray:
        """Densify fallback: the dense (C, n) matrix for callers that want
        it — the reduce and error-feedback paths never call this."""
        c = enc["idx"].shape[0]
        rows = jnp.arange(c)[:, None]
        return (
            jnp.zeros((c, enc["n"]), enc["val"].dtype)
            .at[rows, enc["idx"]]
            .add(enc["val"])
        )

    def _aggregate_batch_flat(self, deltas: jnp.ndarray, weights: jnp.ndarray, state):
        """O(C·k) end to end: encode, scatter-reduce straight off the
        payload, and zero the transmitted coordinates out of the error-
        feedback state — TopK transmits exact values, so
        ``eff - decode(enc) == eff`` zeroed at idx; no dense decode."""
        eff = deltas + state
        enc = self.encode_batch(eff)
        rows = jnp.arange(eff.shape[0])[:, None]
        new_state = eff.at[rows, enc["idx"]].set(0.0)
        return self.reduce(enc, weights), new_state

    def transmit_segment(self, vec: jnp.ndarray, state_row, seg: Segment):
        """Per-client path (mesh shard_map / sequential scan): the decode
        stays per-client (seg.size,) — never (C, N) — and the next state
        row zeroes the transmitted coordinates in O(k)."""
        eff = vec + state_row
        enc = self.encode_segment(eff, seg)
        new_row = eff.at[enc["idx"]].set(0.0)
        return self.decode_segment(enc, seg), new_row

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        # sparse scatter-accumulate straight off the (idx, val) payload
        return ops.topk_scatter_reduce(
            enc["idx"], enc["val"], weights, enc["n"], interpret=interpret
        )


@dataclass(frozen=True)
class LoRACodec(UpdateCodec):
    """Low-rank factor wire for matrix segments; fallback codec elsewhere.

    The wire format is documented in the module docstring ("The LoRA wire
    format").  Config:

    - ``rank``: the rank budget; each matrix segment uses the effective
      rank ``min(rank, m, n)`` of its folded ``matrix_shape``.
    - ``factor_codec``: the codec applied to each factor's flat vector on
      the wire (``Int8Codec`` composes int8 quantization on the factors;
      ``NullCodec`` ships fp32 factors).
    - ``fallback``: the codec that owns non-matrix segments wholesale —
      encode, per-segment state, and wire accounting all delegate.
    - ``power_iters``: subspace iterations of the PowerSGD-style
      factorization (1 = project, orthonormalize, project back).
    - ``seed``: the deterministic projection seed; the per-segment key is
      ``fold_in(key(seed), seg.offset)``, shared by every client and the
      server, so the random basis never crosses the wire.

    This codec is segment-structured by construction: build it with a
    ``SegmentMap`` (``LoRACodec(...).with_segments(SegmentMap.from_tree(params))``).
    The flat-vector surface raises — there is no meaningful rank structure
    in one anonymous flat vector.
    """

    rank: int = 8
    factor_codec: UpdateCodec = NullCodec()
    fallback: UpdateCodec = Int8Codec()
    power_iters: int = 1
    seed: int = 0
    segments: Any = None

    def __post_init__(self):
        assert self.rank >= 1, f"rank must be >= 1, got {self.rank}"
        assert self.power_iters >= 1
        assert self.factor_codec.segments is None, "factor_codec is flat-per-factor"
        assert self.fallback.segments is None, "fallback inherits LoRA's segments"

    # ---- which segments get the low-rank wire ----
    def _eff_rank(self, seg: Segment) -> int:
        m, n = seg.matrix_shape
        return min(self.rank, m, n)

    def _use_lora(self, seg: Segment) -> bool:
        """Low-rank wins when the segment has a matrix view and the factor
        wire is strictly smaller than the dense fallback wire."""
        if seg.ndim < 2:
            return False
        m, n = seg.matrix_shape
        r = min(self.rank, m, n)
        return (
            self.factor_codec._wire_bytes_scalar(m * r)
            + self.factor_codec._wire_bytes_scalar(r * n)
            < self.fallback.segment_wire_bytes(seg)
        )

    def _seg_key(self, seg: Segment):
        return jax.random.fold_in(jax.random.key(self.seed), seg.offset)

    # ---- the factorization (PowerSGD-style, deterministic basis) ----
    def _factorize(self, x: jnp.ndarray, key):
        """(m, n) fp32 -> A (m, r) orthonormal, B (r, n) = A.T @ x."""
        m, n = x.shape
        r = min(self.rank, m, n)
        q = jax.random.normal(key, (n, r), jnp.float32)
        p = x @ q
        for _ in range(self.power_iters - 1):
            p = jnp.linalg.qr(p)[0]
            p = x @ (x.T @ p)
        a = jnp.linalg.qr(p)[0]
        return a, a.T @ x

    # ---- per-segment wire ----
    def encode_segment(self, vec: jnp.ndarray, seg: Segment):
        if not self._use_lora(seg):
            return self.fallback.encode_segment(vec, seg)
        m, n = seg.matrix_shape
        a, b = self._factorize(
            vec.reshape(m, n).astype(jnp.float32), self._seg_key(seg)
        )
        return {
            "a": self.factor_codec.encode(a.reshape(-1)),
            "b": self.factor_codec.encode(b.reshape(-1)),
        }

    def decode_segment(self, enc, seg: Segment) -> jnp.ndarray:
        if not self._use_lora(seg):
            return self.fallback.decode_segment(enc, seg)
        m, n = seg.matrix_shape
        r = self._eff_rank(seg)
        a = self.factor_codec.decode(enc["a"]).reshape(m, r)
        b = self.factor_codec.decode(enc["b"]).reshape(r, n)
        return (a @ b).reshape(-1)

    # ---- per-segment state: residual rows on lora segments, fallback's otherwise ----
    def init_segment_state(self, n_clients: int, seg: Segment) -> PyTree:
        if self._use_lora(seg):
            return jnp.zeros((n_clients, seg.size), jnp.float32)
        return self.fallback.init_segment_state(n_clients, seg)

    # ---- batched aggregation: factorize per client, reduce reconstructions ----
    def aggregate_segment_batch(self, deltas, weights, state, seg: Segment):
        if not self._use_lora(seg):
            return self.fallback.aggregate_segment_batch(deltas, weights, state, seg)
        c = deltas.shape[0]
        m, n = seg.matrix_shape
        r = self._eff_rank(seg)
        eff = deltas.astype(jnp.float32) + state
        x = eff.reshape(c, m, n)
        key = self._seg_key(seg)  # one shared basis: clients and server agree
        a, b = jax.vmap(lambda xi: self._factorize(xi, key))(x)
        # factor wire round-trip (what the server can actually see)
        fa = self.factor_codec.decode_batch(
            self.factor_codec.encode_batch(a.reshape(c, m * r))
        ).reshape(c, m, r)
        fb = self.factor_codec.decode_batch(
            self.factor_codec.encode_batch(b.reshape(c, r * n))
        ).reshape(c, r, n)
        dec = jnp.einsum("cmr,crn->cmn", fa, fb)
        wf = weights.astype(jnp.float32)
        mean = jnp.einsum("c,cmn->mn", wf, dec) / safe_weight_sum(wf)
        return mean.reshape(-1), eff - dec.reshape(c, -1)

    # ---- per-segment serialization: factor payloads namespaced a./b. ----
    def segment_wire_payload(self, payload, seg: Segment) -> dict:
        if not self._use_lora(seg):
            return self.fallback.segment_wire_payload(payload, seg)
        out = {}
        for fk in ("a", "b"):
            for k, v in self.factor_codec.wire_payload(payload[fk]).items():
                out[f"{fk}.{k}"] = v
        return out

    def segment_from_wire(self, fields: dict, seg: Segment):
        if not self._use_lora(seg):
            return self.fallback.segment_from_wire(fields, seg)
        def sub(prefix):
            return self.factor_codec.from_wire({
                k[len(prefix):]: v for k, v in fields.items() if k.startswith(prefix)
            })
        return {"a": sub("a."), "b": sub("b.")}

    # ---- wire accounting: restated per segment (factors, not dense) ----
    def segment_wire_bytes(self, seg: Segment) -> int:
        if not self._use_lora(seg):
            return self.fallback.segment_wire_bytes(seg)
        m, n = seg.matrix_shape
        r = self._eff_rank(seg)
        return (
            self.factor_codec._wire_bytes_scalar(m * r)
            + self.factor_codec._wire_bytes_scalar(r * n)
        )

    # ---- the flat-vector surface is meaningless for a structured codec ----
    def _no_flat_surface(self, name: str):
        raise TypeError(
            f"LoRACodec.{name}: the low-rank wire needs matrix shapes — build "
            "the codec with a SegmentMap (with_segments(SegmentMap.from_tree(params)))"
        )

    def _wire_bytes_scalar(self, n_params: int) -> int:
        self._no_flat_surface("wire_bytes")

    def _init_flat_state(self, n_clients: int, n_params: int):
        self._no_flat_surface("init_client_state")

    def encode(self, delta_vec):
        self._no_flat_surface("encode")

    def decode(self, enc):
        self._no_flat_surface("decode")

    def encode_batch(self, deltas):
        self._no_flat_surface("encode_batch")

    def decode_batch(self, enc):
        self._no_flat_surface("decode_batch")

    def reduce(self, enc, weights, *, interpret: bool = False):
        self._no_flat_surface("reduce")


@dataclass(frozen=True)
class MixedCodec(UpdateCodec):
    """Shape-static per-client codec bank — mixed fleets in ONE jitted round.

    ``codecs`` is the bank (one entry per group); ``assignment`` maps each
    client to a bank index and is *static python data*, so the round step
    partitions the client axis into per-codec groups at trace time (see the
    module docstring's mixed-batch group semantics).  Build one from the
    fleet's measured hardware with ``MixedCodec.from_policy``.

    The batched aggregation surfaces (``aggregate_updates`` /
    ``aggregate_batch``) gather each group's rows with static indices, run
    the group codec's own encode + reduce kernel path, and combine the
    groups' partial weighted sums under a single ``safe_weight_sum``
    denominator.  The per-client surfaces (``encode`` / ``transmit_tree``)
    are deliberately absent: a single client belongs to exactly one group,
    so callers must dispatch through ``groups()`` (the sequential round
    engine does).

    Segment maps thread through group construction: bank codecs may carry
    segment maps (``with_segments`` maps the whole bank), and each group's
    state/encode/reduce then runs that codec's segmented path — a LoRA
    group and an Int8 group coexist in one fleet.  Conflicting explicit
    maps are rejected at build time.

    Population mode is out of scope by construction: the static
    ``assignment`` binds codecs to client-axis *slots*, while a population
    round resamples which client occupies each slot every round —
    ``CohortState`` and the population ``Server`` both reject a MixedCodec
    (per-device codec choice there goes through ``BandwidthCodecPolicy``).
    """

    codecs: tuple = ()
    assignment: tuple = ()

    def __post_init__(self):
        assert self.codecs, "MixedCodec needs a non-empty codec bank"
        assert all(
            0 <= int(g) < len(self.codecs) for g in self.assignment
        ), f"assignment {self.assignment} out of range for {len(self.codecs)} codecs"
        # tuples, not lists: the codec is a static field of RoundSpec and a
        # jit-closure constant, so it must stay hashable
        object.__setattr__(self, "codecs", tuple(self.codecs))
        object.__setattr__(
            self, "assignment", tuple(int(g) for g in self.assignment)
        )
        maps = {c.segments for c in self.codecs if c.segments is not None}
        if len(maps) > 1:
            raise ValueError(
                "MixedCodec bank codecs carry conflicting segment maps — the "
                "client axis shares one model, so every segmented group must "
                "use the same leaf layout (use MixedCodec.with_segments)"
            )

    def with_segments(self, segments: SegmentMap) -> "MixedCodec":
        """Thread one segment map through every group codec in the bank."""
        return dataclasses.replace(
            self, codecs=tuple(c.with_segments(segments) for c in self.codecs)
        )

    @classmethod
    def from_policy(cls, policy, fleet) -> "MixedCodec":
        """Static group assignment from per-device facts.

        ``fleet``: one ``ClientProperties`` / ``DeviceProfile`` (anything
        with ``.uplink_mbps``) per client, in client order; ``policy``: a
        ``BandwidthCodecPolicy``-shaped object.  Equal codecs dedupe into
        one bank entry (frozen dataclasses compare by config)."""
        bank: list = []
        assignment = []
        for props in fleet:
            codec = policy.codec_for(props)
            if codec not in bank:
                bank.append(codec)
            assignment.append(bank.index(codec))
        return cls(codecs=tuple(bank), assignment=tuple(assignment))

    @property
    def n_clients(self) -> int:
        return len(self.assignment)

    def groups(self):
        """-> [(bank_index, codec, client-index list)] for every NON-EMPTY
        group, in bank order.  The index lists are static python data (the
        assignment is a trace-time constant): under jit they become constant
        gathers, so every group is shape-static."""
        return [
            (g, codec, idx)
            for g, codec in enumerate(self.codecs)
            if (idx := [i for i, a in enumerate(self.assignment) if a == g])
        ]

    # ---- per-client state: one entry per bank codec ----
    def init_client_state(self, n_clients: int, n_params: int) -> PyTree:
        assert n_clients == self.n_clients, (
            f"MixedCodec assigns {self.n_clients} clients, got {n_clients}"
        )
        assign = np.asarray(self.assignment, np.int64)
        return tuple(
            codec.init_client_state(int((assign == g).sum()), n_params)
            for g, codec in enumerate(self.codecs)
        )

    # ---- batched pytree surface: the vmap-parallel round step ----
    def aggregate_updates(self, client_params, global_params, weights, state):
        """Group-wise aggregation of vmapped client params.

        Each group's rows are gathered with static indices and aggregated by
        the group's own codec (TopK never densifies its payload, Null never
        flattens the model, a segmented group runs its per-segment path);
        the group means are scaled back to partial weighted sums and
        combined under one fleet-wide denominator."""
        assert weights.shape[0] == self.n_clients, (
            f"batch carries {weights.shape[0]} clients, MixedCodec assigns "
            f"{self.n_clients}"  # a static gather would silently clamp
        )
        wf = weights.astype(jnp.float32)
        wsum = safe_weight_sum(wf)
        total = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), global_params
        )
        new_states = list(state)
        for g, codec, idx in self.groups():
            ia = jnp.asarray(idx)  # static rows -> constant gather under jit
            params_g = jax.tree.map(lambda x: x[ia], client_params)
            avg_g, new_states[g] = codec.aggregate_updates(
                params_g, global_params, wf[ia], state[g]
            )
            wsum_g = jnp.sum(wf[ia])  # group mean * mass = partial sum
            total = jax.tree.map(
                lambda t, a, gp: t
                + (a.astype(jnp.float32) - gp.astype(jnp.float32)) * wsum_g,
                total, avg_g, global_params,
            )
        new_global = jax.tree.map(
            lambda gp, t: (gp.astype(jnp.float32) + t / wsum).astype(gp.dtype),
            global_params, total,
        )
        return new_global, tuple(new_states)

    # ---- batched flat surface ----
    def aggregate_batch(self, deltas: jnp.ndarray, weights: jnp.ndarray, state):
        assert deltas.shape[0] == self.n_clients, (
            f"batch carries {deltas.shape[0]} clients, MixedCodec assigns "
            f"{self.n_clients}"  # a static gather would silently clamp
        )
        wf = weights.astype(jnp.float32)
        total = jnp.zeros((deltas.shape[1],), jnp.float32)
        new_states = list(state)
        for g, codec, idx in self.groups():
            ia = jnp.asarray(idx)
            mean_g, new_states[g] = codec.aggregate_batch(
                deltas[ia], wf[ia], state[g]
            )
            total = total + mean_g.astype(jnp.float32) * jnp.sum(wf[ia])
        return total / safe_weight_sum(wf), tuple(new_states)

    # ---- per-group wire accounting ----
    def wire_bytes(self, n_params):
        """One uplink size per client (its group's codec), in client order.

        Accepts an int (every client ships an ``n_params``-sized update) or
        a per-client vector of sizes; always returns a per-client list —
        a mixed fleet has no single scalar wire size.  Dispatches through
        each group codec's own ``wire_bytes`` so segmented group codecs
        (LoRA) account their structured wire correctly."""
        ns = np.asarray(n_params).reshape(-1)
        if ns.size == 1:
            ns = np.full(self.n_clients, int(ns[0]))
        assert len(ns) == self.n_clients, (
            f"per-client size vector ({len(ns)}) != clients ({self.n_clients})"
        )
        return [
            self.codecs[g].wire_bytes(int(n))
            for g, n in zip(self.assignment, ns)
        ]

    def _wire_bytes_scalar(self, n_params: int) -> int:
        raise TypeError("MixedCodec has no scalar wire size; use wire_bytes")

    def _no_per_client_surface(self, name: str):
        raise TypeError(
            f"MixedCodec.{name}: per-client codec surfaces are group-owned; "
            "dispatch through groups()"
        )

    def encode(self, delta_vec):
        self._no_per_client_surface("encode")

    def decode(self, enc):
        self._no_per_client_surface("decode")

    def encode_batch(self, deltas):
        self._no_per_client_surface("encode_batch")

    def decode_batch(self, enc):
        self._no_per_client_surface("decode_batch")

    def reduce(self, enc, weights, *, interpret: bool = False):
        self._no_per_client_surface("reduce")

    def transmit_tree(self, delta_tree, state_row):
        self._no_per_client_surface("transmit_tree")


@dataclass(frozen=True)
class BandwidthCodecPolicy:
    """Per-device codec selection from the client's measured uplink.

    The Strategy consults this in ``configure_fit`` (the paper's system-cost
    quantification driving an algorithmic decision): slow phone-class
    uplinks get TopK sparsification, mid-tier edge boards get Int8, and
    datacenter-class backbone links ship the full-precision wire.
    """

    topk_below_mbps: float = 30.0       # Pixel-class cellular uplinks
    null_above_mbps: float = 100_000.0  # TPU-class datacenter backbone
    topk: TopKCodec = TopKCodec(frac=0.01)
    int8: Int8Codec = Int8Codec()
    null: NullCodec = NullCodec()

    def codec_for(self, properties) -> UpdateCodec:
        """properties: protocol.ClientProperties (or any .uplink_mbps owner)."""
        if properties.uplink_mbps >= self.null_above_mbps:
            return self.null
        if properties.uplink_mbps < self.topk_below_mbps:
            return self.topk
        return self.int8


# ---------------- compressed collective: the mesh psum wire ----------------
@dataclass(frozen=True)
class CompressedPsum:
    """int8 wire-compressed hierarchical psum for the mesh round path.

    The mesh round's cross-device reduce moves each device's *partial
    weighted sum*; this class is the wire format of that collective —
    the analogue, one layer down, of what ``Int8Codec`` is to the client
    uplink.  Per (segment-shaped) operand:

    1. fold in the per-device error-feedback residual: ``eff = wx + r``;
    2. per-256-block absmax of ``eff``, then ``lax.pmax`` over the client
       axes — a tiny fp32 sidecar (4 bytes per block) that makes the scale
       a COLLECTIVE decision: every device rounds against the same grid,
       so quantization commutes with the sum;
    3. ``kernels.ops.collective_pack``: quantize to int8-valued payloads
       in an int32 container (the accumulator dtype; |q| <= 127, so the
       int32 psum provably cannot overflow below a 2**31/127 ~= 16.9M
       fan-in — any real mesh);
    4. hierarchical ``lax.psum`` of the int payload (pod-inner ordering,
       same hop structure as the fp32 path);
    5. one fused ``collective_unpack`` after the last hop recovers the
       fp32 sum; the weight denominator psums alongside as a 4-byte fp32
       sidecar (the caller's existing ``wsum`` reduce).

    The residual ``eff - unpack(pack(eff))`` stays on the device that
    produced it, so the quantized psum telescopes across rounds exactly
    like the uplink codecs' error feedback.
    """

    block: int = 256

    def shared_scales(self, eff: jnp.ndarray, axes) -> jnp.ndarray:
        """Per-block scales agreed across the reducing devices: pmax of the
        local per-block absmax over every client axis, /127, zero -> 1."""
        absmax = jnp.max(jnp.abs(eff).reshape(-1, self.block), axis=1)
        for ax in reversed(tuple(axes)):
            absmax = jax.lax.pmax(absmax, ax)
        return jnp.where(absmax == 0.0, 1.0, absmax / 127.0)

    def psum(self, wx: jnp.ndarray, residual: jnp.ndarray, axes):
        """One operand's compressed hierarchical psum, inside shard_map.

        ``wx``: (n,) fp32 — this device's partial weighted sum.
        ``residual``: (n,) fp32 — this device's error-feedback carry
        (pass zeros when already folded, or a masked row: the caller owns
        participation semantics).

        Returns ``(total, new_residual)``: the fp32 sum of every device's
        quantized ``wx + residual`` and this device's next residual.
        """
        n = wx.shape[0]
        pad = (-n) % self.block
        eff = wx + residual
        effp = jnp.pad(eff, (0, pad)) if pad else eff
        scales = self.shared_scales(effp, axes)
        q = ops.collective_pack(effp, scales, block=self.block)
        # local dequant: what THIS device's payload contributes to the sum;
        # the gap is next round's residual (error feedback telescopes)
        sent = ops.collective_unpack(q, scales, block=self.block)[:n]
        for ax in reversed(tuple(axes)):
            q = jax.lax.psum(q, ax)
        total = ops.collective_unpack(q, scales, block=self.block)[:n]
        return total, eff - sent

    # ---- collective wire accounting (audited by fedlint) ----
    def collective_bytes(self, n: int) -> int:
        """Physical bytes ONE device moves across ONE hop for an n-element
        operand: int8 payload (1 B/elem) + the fp32 per-block scale sidecar
        (rides the pmax) + the 4-byte fp32 weight denominator.  The int32
        container is accumulator dtype, not wire format — the wire carries
        one byte per element.  ``CostModel.collective_bytes`` multiplies
        this by the mesh's hop/tier structure."""
        return int(n) + 4 * math.ceil(int(n) / self.block) + 4


def fp32_collective_bytes(n: int) -> int:
    """The uncompressed counterpart of ``CompressedPsum.collective_bytes``:
    fp32 payload + the same 4-byte weight-denominator sidecar per hop."""
    return 4 * int(n) + 4


@contextmanager
def ban_topk_densify():
    """Guard for the O(C·k) reduce contract: within the block, ANY call to
    ``TopKCodec.decode_batch`` (the explicit densify fallback) raises.
    Tests and the compression benchmark wrap aggregation paths in this to
    prove the sparse scatter reduce never regresses to densify-then-reduce.
    """
    def _boom(self, enc):
        raise AssertionError(
            "TopKCodec.decode_batch called on the aggregation path — the "
            "O(C·k) scatter reduce has regressed to densify"
        )

    orig = TopKCodec.decode_batch
    TopKCodec.decode_batch = _boom
    try:
        yield
    finally:
        TopKCodec.decode_batch = orig


def _init_residual_rows(codec, segs: SegmentMap):
    return tuple(
        jnp.zeros((seg.size,), jnp.float32) if codec.segment_stateful(seg) else ()
        for seg in segs
    )


def compress_update(
    codec, new_params: PyTree, global_params: PyTree, residual=None
) -> tuple[Any, PyTree]:
    """-> (wire_payload, new_residual) for error feedback.

    ``residual`` is the client's carried error-feedback state (folded into
    the delta before encoding); None means no carried state.  Flat codecs
    take/return one (n_params,) vector; segmented codecs take/return a
    tuple of per-segment rows and emit a ``StructuredUpdate``.
    """
    if codec.segments is not None:
        segs = codec.segments
        delta_tree = tree_sub(new_params, global_params)
        leaves, _ = jax.tree_util.tree_flatten(delta_tree)
        if segs.matches_leaves(leaves):
            vecs = [leaf.astype(jnp.float32).reshape(-1) for leaf in leaves]
        else:
            flat = tree_flatten_to_vector(delta_tree)
            codec.segment_map(int(flat.shape[0]))
            vecs = segs.split(flat)
        if residual is None:
            residual = _init_residual_rows(codec, segs)
        encs, new_res = [], []
        for vec, res, seg in zip(vecs, residual, segs):
            stateful = not isinstance(res, tuple)
            eff = vec + res if stateful else vec
            enc = codec.encode_segment(eff, seg)
            encs.append(enc)
            new_res.append(eff - codec.decode_segment(enc, seg) if stateful else ())
        return StructuredUpdate(segs, tuple(encs)), tuple(new_res)

    delta = tree_flatten_to_vector(tree_sub(new_params, global_params))
    if residual is not None:
        delta = delta + residual
    enc = codec.encode(delta)
    new_residual = delta - codec.decode(enc)
    return enc, new_residual


def decompress_update(codec, enc, global_params: PyTree) -> PyTree:
    if isinstance(enc, StructuredUpdate):
        delta = codec.decode_structured(enc)
    else:
        delta = codec.decode(enc)
    flat_global = tree_flatten_to_vector(global_params)
    return tree_unflatten_from_vector(flat_global + delta, global_params)

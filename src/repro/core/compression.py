"""Update compression codecs — first-class citizens of every execution path.

The paper measures communication as a first-class system cost; these codecs
shrink the client->server payload that the cost model charges for:

- ``Int8Codec``: int8 block quantization (~4x over fp32 wire), via the
  Pallas quantize kernel; decoded server-side through the fused
  dequantize+weighted-reduce kernel (one HBM pass over the int8 payload).
- ``TopKCodec``: top-k sparsification with error feedback (classic gradient
  compression).  Server-side aggregation is O(C·k): the (idx, val) payloads
  feed the scatter-accumulate kernel directly (see the O(C·k) reduce
  contract below) — the dense (C, n_params) delta matrix is never built.
- ``NullCodec``: identity fp32 wire — the uncompressed baseline with the
  same interface, and the *default* codec of ``RoundSpec``, so the round
  engine has exactly one code path.

The O(C·k) TopK reduce contract
-------------------------------

- **Payload layout**: per client, ``idx`` (k,) int32 positions and ``val``
  (k,) fp32 values, 8k wire bytes.  The encoder is deterministic (equal
  magnitudes tie-break toward the lower index via a stable sort) and emits
  indices in canonical ascending order, so a given delta yields
  bit-identical wire bytes under jit and eager alike.
- **Duplicate-index semantics**: our encoder emits distinct indices, but
  every consumer (``decode``, ``decode_batch``, ``reduce``, the Pallas
  kernel and its oracle) treats duplicates as scatter-ADD — a foreign
  payload with repeated indices means the same thing on every path.
- **Reduce paths**: ``aggregate_batch`` (jit-parallel engine) scatter-
  reduces the encoded payload and updates the error-feedback state by
  zeroing the transmitted coordinates — O(C·k), no dense decode;
  ``transmit_tree`` (mesh shard_map / sequential scan) decodes one
  client's (n_params,) vector at a time, never a (C, n_params) matrix;
  ``Strategy.aggregate_fit`` scatter-reduces serialized wire payloads when
  the whole fleet shipped TopK.
- **When densify still applies**: ``decode_batch`` exists for callers that
  explicitly want the dense per-client matrix — nothing on any reduce path
  calls it.  The fused kernel additionally requires the (n_params,)
  accumulator to fit VMEM; above ``scatter_reduce.MAX_N_PARAMS`` (derived
  from the kernel file's declared ``VMEM_BUDGET_ELEMS``) the dispatch
  falls back to the XLA scatter-add oracle, which is still O(C·k).

Mixed-batch group semantics (``MixedCodec``)
--------------------------------------------

A heterogeneous fleet (some clients on TopK, some Int8, some fp32) runs
inside ONE jitted ``round_step`` through ``MixedCodec``: a codec *bank*
plus a static per-client group assignment (e.g. derived once from
``BandwidthCodecPolicy`` over the fleet's ``DeviceProfile``s).  The
contract extends the O(C·k) reduce contract group-wise:

- **Trace-time partition**: the assignment is static python data, so the
  client axis is partitioned into per-codec groups when the round step is
  traced — every group is a fixed, shape-static slice of the batch, and
  each group's encode + reduce runs on its own kernel path (TopK group →
  scatter-accumulate, Int8 group → fused dequant+reduce, Null group →
  ``fedavg_reduce`` on the flat surface / the leafwise mean on the pytree
  surface).  The TopK group is still O(C_g·k): its payload is never
  densified (``decode_batch`` stays off every mixed path too).
- **One denominator**: each group contributes its *partial weighted sum*
  (the group mean scaled back by the group's weight mass); the groups'
  partials combine into one mean with a single ``safe_weight_sum``
  denominator over the whole fleet, so the result equals a flat weighted
  mean of the per-client decoded deltas up to fp rounding (the partials
  are recovered as group-mean x weight mass) — an all-zero-weight group
  contributes exactly zero, never NaNs.
- **Per-group state**: ``init_client_state`` returns a *tuple* pytree, one
  entry per bank codec — residual rows only for the groups whose codec
  carries error feedback ((C_g, n_params) fp32), ``()`` for Null groups —
  carried opaquely through the uniform ``round_step`` signature on the
  vmap-parallel and sequential paths alike.
- **Per-group wire accounting**: ``wire_bytes`` returns one uplink size
  per client (the codec its group ships), which is what
  ``CostModel.round_costs`` charges a mixed fleet.
- The mesh shard_map path is NOT supported for ``MixedCodec`` (an SPMD
  program cannot run a different wire format per device);
  ``make_round_step`` rejects the combination at build time.

Codecs operate on the *delta* (client params - global params), which is
small-magnitude and quantizes well.  The ``UpdateCodec`` base class defines
the full surface the engine and protocol layer program against:

- ``init_client_state(n_clients, n_params)`` — the codec-owned per-client
  state pytree carried across rounds by ``round_step``.  Error-feedback
  codecs return a (C, n_params) fp32 residual buffer; ``NullCodec`` returns
  an empty pytree (no state is allocated for the uncompressed wire).
- ``aggregate_batch(deltas, weights, state)`` — the batched (C, N) path
  used inside the jitted parallel round step: fold the residual in, encode,
  reduce straight off the *encoded* payload (for Int8 the fused
  dequant+reduce kernel never materializes the fp32 (C, N) matrix), and
  return the new residual state.
- ``transmit_tree(delta_tree, state_row)`` — the per-client path used
  inside the mesh ``shard_map`` manual region and the sequential scan:
  what the server would decode from this one client's uplink, plus the
  client's next state row.  ``NullCodec`` overrides it to the identity so
  sharded models never round-trip through a flat replicated vector.
- ``wire_payload(enc)`` / ``from_wire(payload)`` — the exact arrays that
  cross the wire (Int8 trims encoder padding; the receiver re-pads), used
  by the protocol layer's ``CompressedParameters`` serialization.
- ``wire_bytes(n)`` — the per-client uplink charge; accepts an int or a
  vector of per-client sizes so ``CostModel.round_costs`` can account for
  a heterogeneous fleet where every client ships a different payload.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.utils.pytree import (
    safe_weight_sum,
    tree_flatten_to_vector,
    tree_sub,
    tree_unflatten_from_vector,
)

PyTree = Any


class UpdateCodec:
    """Base codec: error-feedback residual state + flat-vector wire.

    Subclasses implement the wire format (``encode``/``decode`` and their
    batched variants, ``reduce``, ``_wire_bytes_scalar``); the state and
    transport machinery below is shared.  ``NullCodec`` overrides the state
    hooks to be stateless/identity.
    """

    # ---- per-client state (carried by round_step across rounds) ----
    def init_client_state(self, n_clients: int, n_params: int) -> PyTree:
        """Zero error-feedback state: one flat fp32 residual per client."""
        return jnp.zeros((n_clients, n_params), jnp.float32)

    def carries_client_state(self, n_params: int = 1) -> bool:
        """Whether this codec owns round-to-round per-client state.

        The population layer's ``CohortState`` consults this: a stateless
        codec gathers ``()`` and spills nothing, a stateful one gathers a
        dense residual row per sampled client.  Probes a one-client state
        rather than trusting subclasses to remember a flag.
        """
        return bool(jax.tree_util.tree_leaves(
            self.init_client_state(1, n_params)
        ))

    # ---- batched (C, N) surface: the jitted parallel round step ----
    def aggregate_updates(
        self, client_params: PyTree, global_params: PyTree,
        weights: jnp.ndarray, state,
    ):
        """Full aggregation of vmapped client params -> (avg params, state).

        Default: flatten per-client deltas to the (C, n_params) wire layout
        and aggregate off the encoded payload (``aggregate_batch``).
        ``NullCodec`` overrides this leafwise so the uncompressed engine
        never materializes the flat fp32 matrix.
        """
        flat_global = tree_flatten_to_vector(global_params)
        deltas = jax.vmap(
            lambda p: tree_flatten_to_vector(p) - flat_global
        )(client_params)
        avg_delta, new_state = self.aggregate_batch(deltas, weights, state)
        return (
            tree_unflatten_from_vector(flat_global + avg_delta, global_params),
            new_state,
        )

    def aggregate_batch(self, deltas: jnp.ndarray, weights: jnp.ndarray, state):
        """(C, N) deltas + state -> (weighted-mean decoded delta (N,), new state).

        Error feedback in, encode, reduce off the encoded payload; what was
        not transmitted becomes the next residual, so the compression error
        telescopes across rounds instead of accumulating.
        """
        eff = deltas + state
        enc = self.encode_batch(eff)
        new_state = eff - self.decode_batch(enc)
        return self.reduce(enc, weights), new_state

    # ---- per-client surface: mesh shard_map region / sequential scan ----
    def transmit_tree(self, delta_tree: PyTree, state_row):
        """One client's uplink: -> (decoded delta tree, new state row).

        The returned tree contains exactly the information that survives the
        wire (encode -> decode); the caller aggregates it, so only codec-
        representable values ever cross the slow inter-pod links.
        """
        vec = tree_flatten_to_vector(delta_tree) + state_row
        enc = self.encode(vec)
        dec = self.decode(enc)
        return tree_unflatten_from_vector(dec, delta_tree), vec - dec

    # ---- wire serialization hooks (protocol.CompressedParameters) ----
    def wire_payload(self, enc) -> dict:
        """The exact fields that cross the wire (arrays + python scalars)."""
        return dict(enc)

    def from_wire(self, payload: dict) -> dict:
        """Rebuild the decodable payload from ``wire_payload`` fields."""
        return dict(payload)

    # ---- uplink accounting ----
    def _wire_bytes_scalar(self, n_params: int) -> int:
        raise NotImplementedError

    def wire_bytes(self, n_params):
        """Uplink bytes for an ``n_params``-sized update.

        Accepts an int (homogeneous fleet) or a sequence of per-client sizes
        (heterogeneous accounting) and returns an int or list respectively.
        """
        if isinstance(n_params, (list, tuple, np.ndarray)):
            return [self._wire_bytes_scalar(int(n)) for n in np.asarray(n_params).reshape(-1)]
        return self._wire_bytes_scalar(int(n_params))


@dataclass(frozen=True)
class NullCodec(UpdateCodec):
    """Identity codec: full-precision fp32 wire (the uncompressed baseline).

    Stateless: ``init_client_state`` is empty, ``transmit_tree`` is the
    identity on the delta pytree (no flatten — sharded sequential/fsdp
    models keep their layout), and ``aggregate_batch`` is exactly the fused
    weighted reduce of the uncompressed engine.
    """

    def _wire_bytes_scalar(self, n_params: int) -> int:
        return 4 * n_params

    def init_client_state(self, n_clients: int, n_params: int) -> PyTree:
        return ()

    def aggregate_updates(self, client_params, global_params, weights, state):
        """Leafwise fp32 weighted mean — the fp32 wire loses nothing, so the
        uncompressed path never flattens the model into one (C, N) matrix
        (same reasoning as the identity ``transmit_tree``)."""
        wf = weights.astype(jnp.float32)
        wsum = safe_weight_sum(wf)

        def leaf_mean(xs, g):
            wshape = (xs.shape[0],) + (1,) * (xs.ndim - 1)
            gf = g.astype(jnp.float32)
            acc = jnp.sum(
                (xs.astype(jnp.float32) - gf) * wf.reshape(wshape), axis=0
            )
            return (gf + acc / wsum).astype(g.dtype)

        return jax.tree.map(leaf_mean, client_params, global_params), ()

    def aggregate_batch(self, deltas, weights, state):
        return self.reduce(self.encode_batch(deltas), weights), ()

    def transmit_tree(self, delta_tree, state_row):
        return delta_tree, ()

    def encode(self, delta_vec: jnp.ndarray):
        return {"delta": delta_vec.astype(jnp.float32), "n": delta_vec.shape[0]}

    def decode(self, enc) -> jnp.ndarray:
        return enc["delta"]

    def encode_batch(self, deltas: jnp.ndarray):
        return {"delta": deltas.astype(jnp.float32), "n": deltas.shape[1]}

    def decode_batch(self, enc) -> jnp.ndarray:
        return enc["delta"]

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        return ops.fedavg_reduce(enc["delta"], weights, interpret=interpret)


@dataclass(frozen=True)
class Int8Codec(UpdateCodec):
    block: int = 256

    def _n_scales(self, n_params: int) -> int:
        return -(-n_params // self.block)  # ceil: encode pads to a block multiple

    def _wire_bytes_scalar(self, n_params: int) -> int:
        # int8 payload (pad blocks need not cross the wire: the receiver
        # re-pads from n) + one fp32 scale per ceil(n/block) block
        return n_params + 4 * self._n_scales(n_params)

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        pad = (-n) % self.block
        padded = jnp.pad(delta_vec, (0, pad))
        q, scale = ops.quantize_int8(padded, block=self.block)
        return {"q": q, "scale": scale, "n": n}

    def decode(self, enc) -> jnp.ndarray:
        vec = ops.dequantize_int8(enc["q"], enc["scale"], block=self.block)
        return vec[: enc["n"]]

    def wire_payload(self, enc) -> dict:
        # pad int8s never cross the wire: trim to n, the receiver re-pads
        return {"q": enc["q"][: enc["n"]], "scale": enc["scale"], "n": enc["n"]}

    def from_wire(self, payload: dict) -> dict:
        n = payload["n"]
        q = jnp.asarray(payload["q"])
        return {
            "q": jnp.pad(q, (0, (-n) % self.block)),
            "scale": jnp.asarray(payload["scale"]),
            "n": n,
        }

    # ---- batched (C, N) wire path used inside the jitted round step ----
    def encode_batch(self, deltas: jnp.ndarray):
        """(C, N) -> q (C, Np) int8 + scales (C, Np/block); Np = padded N.

        Rows are padded to a block multiple, so flattening (C, Np) keeps
        every quantization block inside one client row and the 1-D Pallas
        kernel applies unchanged.
        """
        c, n = deltas.shape
        pad = (-n) % self.block
        padded = jnp.pad(deltas, ((0, 0), (0, pad)))
        np_ = n + pad
        q, scale = ops.quantize_int8(padded.reshape(-1), block=self.block)
        return {
            "q": q.reshape(c, np_),
            "scale": scale.reshape(c, np_ // self.block),
            "n": n,
        }

    def decode_batch(self, enc) -> jnp.ndarray:
        c = enc["q"].shape[0]
        vec = ops.dequantize_int8(
            enc["q"].reshape(-1), enc["scale"].reshape(-1), block=self.block
        )
        return vec.reshape(c, -1)[:, : enc["n"]]

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        """Weighted-mean decode straight off the int8 payload (fused kernel)."""
        avg = ops.dequant_reduce(
            enc["q"], enc["scale"], weights, block=self.block, interpret=interpret
        )
        return avg[: enc["n"]]


@dataclass(frozen=True)
class TopKCodec(UpdateCodec):
    """Keep the k largest-|.| entries; the residual feeds back next round.

    Wire contract (load-bearing for the O(C·k) reduce):

    - selection is DETERMINISTIC: magnitudes tie-break toward the lower
      index via a stable sort (raw ``lax.top_k`` tie order is lowering-
      dependent), so a given delta produces bit-identical payloads under
      jit and eager alike;
    - payload indices are canonically sorted ascending — reproducible wire
      bytes, and the scatter kernel walks VMEM monotonically;
    - this encoder emits distinct indices, but every consumer treats
      duplicate indices as ACCUMULATE (scatter-add), so foreign payloads
      mean the same thing on all paths;
    - ``reduce`` consumes (idx, val) directly through the scatter-
      accumulate kernel — O(C·k) time and memory, no dense (C, N) matrix;
      ``decode_batch`` remains the explicit densify fallback for callers
      that want the per-client dense matrix (nothing on the reduce or
      error-feedback path does).
    """

    frac: float = 0.01

    def k_of(self, n_params: int) -> int:
        # math.floor, not int(): n_params is static, but this method is
        # jit-reachable and a py-cast here would read as tracer concretization
        return max(1, math.floor(n_params * self.frac))

    def _wire_bytes_scalar(self, n_params: int) -> int:
        return self.k_of(n_params) * 8  # int32 index + fp32 value

    @staticmethod
    def _topk_idx(mags: jnp.ndarray, k: int) -> jnp.ndarray:
        """Deterministic top-k positions along the last axis: stable sort by
        descending magnitude (ties keep ascending index order), then the
        selected k re-sorted to the canonical ascending-index wire order."""
        iota = jax.lax.broadcasted_iota(jnp.int32, mags.shape, mags.ndim - 1)
        _, idx = jax.lax.sort(
            (-mags.astype(jnp.float32), iota),
            dimension=-1, num_keys=1, is_stable=True,
        )
        return jnp.sort(idx[..., :k], axis=-1)

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        idx = self._topk_idx(jnp.abs(delta_vec), self.k_of(n))
        return {"idx": idx, "val": delta_vec[idx], "n": n}

    def decode(self, enc) -> jnp.ndarray:
        # scatter-ADD: duplicate indices accumulate (kernel semantics)
        return jnp.zeros((enc["n"],), enc["val"].dtype).at[enc["idx"]].add(enc["val"])

    def encode_batch(self, deltas: jnp.ndarray):
        n = deltas.shape[1]
        idx = self._topk_idx(jnp.abs(deltas), self.k_of(n))  # (C, k)
        return {"idx": idx, "val": jnp.take_along_axis(deltas, idx, axis=1), "n": n}

    def decode_batch(self, enc) -> jnp.ndarray:
        """Densify fallback: the dense (C, n) matrix for callers that want
        it — the reduce and error-feedback paths never call this."""
        c = enc["idx"].shape[0]
        rows = jnp.arange(c)[:, None]
        return (
            jnp.zeros((c, enc["n"]), enc["val"].dtype)
            .at[rows, enc["idx"]]
            .add(enc["val"])
        )

    def aggregate_batch(self, deltas: jnp.ndarray, weights: jnp.ndarray, state):
        """O(C·k) end to end: encode, scatter-reduce straight off the
        payload, and zero the transmitted coordinates out of the error-
        feedback state — TopK transmits exact values, so
        ``eff - decode(enc) == eff`` zeroed at idx; no dense decode."""
        eff = deltas + state
        enc = self.encode_batch(eff)
        rows = jnp.arange(eff.shape[0])[:, None]
        new_state = eff.at[rows, enc["idx"]].set(0.0)
        return self.reduce(enc, weights), new_state

    def transmit_tree(self, delta_tree: PyTree, state_row):
        """Per-client path (mesh shard_map / sequential scan): the decode
        stays per-client (N,) — never (C, N) — and the next state row zeroes
        the transmitted coordinates in O(k)."""
        vec = tree_flatten_to_vector(delta_tree) + state_row
        enc = self.encode(vec)
        new_row = vec.at[enc["idx"]].set(0.0)
        return tree_unflatten_from_vector(self.decode(enc), delta_tree), new_row

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        # sparse scatter-accumulate straight off the (idx, val) payload
        return ops.topk_scatter_reduce(
            enc["idx"], enc["val"], weights, enc["n"], interpret=interpret
        )


@dataclass(frozen=True)
class MixedCodec(UpdateCodec):
    """Shape-static per-client codec bank — mixed fleets in ONE jitted round.

    ``codecs`` is the bank (one entry per group); ``assignment`` maps each
    client to a bank index and is *static python data*, so the round step
    partitions the client axis into per-codec groups at trace time (see the
    module docstring's mixed-batch group semantics).  Build one from the
    fleet's measured hardware with ``MixedCodec.from_policy``.

    The batched aggregation surfaces (``aggregate_updates`` /
    ``aggregate_batch``) gather each group's rows with static indices, run
    the group codec's own encode + reduce kernel path, and combine the
    groups' partial weighted sums under a single ``safe_weight_sum``
    denominator.  The per-client surfaces (``encode`` / ``transmit_tree``)
    are deliberately absent: a single client belongs to exactly one group,
    so callers must dispatch through ``groups()`` (the sequential round
    engine does).

    Population mode is out of scope by construction: the static
    ``assignment`` binds codecs to client-axis *slots*, while a population
    round resamples which client occupies each slot every round —
    ``CohortState`` and the population ``Server`` both reject a MixedCodec
    (per-device codec choice there goes through ``BandwidthCodecPolicy``).
    """

    codecs: tuple = ()
    assignment: tuple = ()

    def __post_init__(self):
        assert self.codecs, "MixedCodec needs a non-empty codec bank"
        assert all(
            0 <= int(g) < len(self.codecs) for g in self.assignment
        ), f"assignment {self.assignment} out of range for {len(self.codecs)} codecs"
        # tuples, not lists: the codec is a static field of RoundSpec and a
        # jit-closure constant, so it must stay hashable
        object.__setattr__(self, "codecs", tuple(self.codecs))
        object.__setattr__(
            self, "assignment", tuple(int(g) for g in self.assignment)
        )

    @classmethod
    def from_policy(cls, policy, fleet) -> "MixedCodec":
        """Static group assignment from per-device facts.

        ``fleet``: one ``ClientProperties`` / ``DeviceProfile`` (anything
        with ``.uplink_mbps``) per client, in client order; ``policy``: a
        ``BandwidthCodecPolicy``-shaped object.  Equal codecs dedupe into
        one bank entry (frozen dataclasses compare by config)."""
        bank: list = []
        assignment = []
        for props in fleet:
            codec = policy.codec_for(props)
            if codec not in bank:
                bank.append(codec)
            assignment.append(bank.index(codec))
        return cls(codecs=tuple(bank), assignment=tuple(assignment))

    @property
    def n_clients(self) -> int:
        return len(self.assignment)

    def groups(self):
        """-> [(bank_index, codec, client-index list)] for every NON-EMPTY
        group, in bank order.  The index lists are static python data (the
        assignment is a trace-time constant): under jit they become constant
        gathers, so every group is shape-static."""
        return [
            (g, codec, idx)
            for g, codec in enumerate(self.codecs)
            if (idx := [i for i, a in enumerate(self.assignment) if a == g])
        ]

    # ---- per-client state: one entry per bank codec ----
    def init_client_state(self, n_clients: int, n_params: int) -> PyTree:
        assert n_clients == self.n_clients, (
            f"MixedCodec assigns {self.n_clients} clients, got {n_clients}"
        )
        assign = np.asarray(self.assignment, np.int64)
        return tuple(
            codec.init_client_state(int((assign == g).sum()), n_params)
            for g, codec in enumerate(self.codecs)
        )

    # ---- batched pytree surface: the vmap-parallel round step ----
    def aggregate_updates(self, client_params, global_params, weights, state):
        """Group-wise aggregation of vmapped client params.

        Each group's rows are gathered with static indices and aggregated by
        the group's own codec (TopK never densifies its payload, Null never
        flattens the model); the group means are scaled back to partial
        weighted sums and combined under one fleet-wide denominator."""
        assert weights.shape[0] == self.n_clients, (
            f"batch carries {weights.shape[0]} clients, MixedCodec assigns "
            f"{self.n_clients}"  # a static gather would silently clamp
        )
        wf = weights.astype(jnp.float32)
        wsum = safe_weight_sum(wf)
        total = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), global_params
        )
        new_states = list(state)
        for g, codec, idx in self.groups():
            ia = jnp.asarray(idx)  # static rows -> constant gather under jit
            params_g = jax.tree.map(lambda x: x[ia], client_params)
            avg_g, new_states[g] = codec.aggregate_updates(
                params_g, global_params, wf[ia], state[g]
            )
            wsum_g = jnp.sum(wf[ia])  # group mean * mass = partial sum
            total = jax.tree.map(
                lambda t, a, gp: t
                + (a.astype(jnp.float32) - gp.astype(jnp.float32)) * wsum_g,
                total, avg_g, global_params,
            )
        new_global = jax.tree.map(
            lambda gp, t: (gp.astype(jnp.float32) + t / wsum).astype(gp.dtype),
            global_params, total,
        )
        return new_global, tuple(new_states)

    # ---- batched flat surface ----
    def aggregate_batch(self, deltas: jnp.ndarray, weights: jnp.ndarray, state):
        assert deltas.shape[0] == self.n_clients, (
            f"batch carries {deltas.shape[0]} clients, MixedCodec assigns "
            f"{self.n_clients}"  # a static gather would silently clamp
        )
        wf = weights.astype(jnp.float32)
        total = jnp.zeros((deltas.shape[1],), jnp.float32)
        new_states = list(state)
        for g, codec, idx in self.groups():
            ia = jnp.asarray(idx)
            mean_g, new_states[g] = codec.aggregate_batch(
                deltas[ia], wf[ia], state[g]
            )
            total = total + mean_g.astype(jnp.float32) * jnp.sum(wf[ia])
        return total / safe_weight_sum(wf), tuple(new_states)

    # ---- per-group wire accounting ----
    def wire_bytes(self, n_params):
        """One uplink size per client (its group's codec), in client order.

        Accepts an int (every client ships an ``n_params``-sized update) or
        a per-client vector of sizes; always returns a per-client list —
        a mixed fleet has no single scalar wire size."""
        ns = np.asarray(n_params).reshape(-1)
        if ns.size == 1:
            ns = np.full(self.n_clients, int(ns[0]))
        assert len(ns) == self.n_clients, (
            f"per-client size vector ({len(ns)}) != clients ({self.n_clients})"
        )
        return [
            self.codecs[g]._wire_bytes_scalar(int(n))
            for g, n in zip(self.assignment, ns)
        ]

    def _wire_bytes_scalar(self, n_params: int) -> int:
        raise TypeError("MixedCodec has no scalar wire size; use wire_bytes")

    def _no_per_client_surface(self, name: str):
        raise TypeError(
            f"MixedCodec.{name}: per-client codec surfaces are group-owned; "
            "dispatch through groups()"
        )

    def encode(self, delta_vec):
        self._no_per_client_surface("encode")

    def decode(self, enc):
        self._no_per_client_surface("decode")

    def encode_batch(self, deltas):
        self._no_per_client_surface("encode_batch")

    def decode_batch(self, enc):
        self._no_per_client_surface("decode_batch")

    def reduce(self, enc, weights, *, interpret: bool = False):
        self._no_per_client_surface("reduce")

    def transmit_tree(self, delta_tree, state_row):
        self._no_per_client_surface("transmit_tree")


@dataclass(frozen=True)
class BandwidthCodecPolicy:
    """Per-device codec selection from the client's measured uplink.

    The Strategy consults this in ``configure_fit`` (the paper's system-cost
    quantification driving an algorithmic decision): slow phone-class
    uplinks get TopK sparsification, mid-tier edge boards get Int8, and
    datacenter-class backbone links ship the full-precision wire.
    """

    topk_below_mbps: float = 30.0       # Pixel-class cellular uplinks
    null_above_mbps: float = 100_000.0  # TPU-class datacenter backbone
    topk: TopKCodec = TopKCodec(frac=0.01)
    int8: Int8Codec = Int8Codec()
    null: NullCodec = NullCodec()

    def codec_for(self, properties) -> UpdateCodec:
        """properties: protocol.ClientProperties (or any .uplink_mbps owner)."""
        if properties.uplink_mbps >= self.null_above_mbps:
            return self.null
        if properties.uplink_mbps < self.topk_below_mbps:
            return self.topk
        return self.int8


@contextmanager
def ban_topk_densify():
    """Guard for the O(C·k) reduce contract: within the block, ANY call to
    ``TopKCodec.decode_batch`` (the explicit densify fallback) raises.
    Tests and the compression benchmark wrap aggregation paths in this to
    prove the sparse scatter reduce never regresses to densify-then-reduce.
    """
    def _boom(self, enc):
        raise AssertionError(
            "TopKCodec.decode_batch called on the aggregation path — the "
            "O(C·k) scatter reduce has regressed to densify"
        )

    orig = TopKCodec.decode_batch
    TopKCodec.decode_batch = _boom
    try:
        yield
    finally:
        TopKCodec.decode_batch = orig


def compress_update(
    codec, new_params: PyTree, global_params: PyTree, residual=None
) -> tuple[Any, PyTree]:
    """-> (wire_payload, new_residual) for error feedback.

    ``residual`` is the client's carried error-feedback vector (folded into
    the delta before encoding); None means no carried state.
    """
    delta = tree_flatten_to_vector(tree_sub(new_params, global_params))
    if residual is not None:
        delta = delta + residual
    enc = codec.encode(delta)
    new_residual = delta - codec.decode(enc)
    return enc, new_residual


def decompress_update(codec, enc, global_params: PyTree) -> PyTree:
    delta = codec.decode(enc)
    flat_global = tree_flatten_to_vector(global_params)
    return tree_unflatten_from_vector(flat_global + delta, global_params)

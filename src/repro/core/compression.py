"""Update compression codecs — first-class citizens of every execution path.

The paper measures communication as a first-class system cost; these codecs
shrink the client->server payload that the cost model charges for:

- ``Int8Codec``: int8 block quantization (~4x over fp32 wire), via the
  Pallas quantize kernel; decoded server-side through the fused
  dequantize+weighted-reduce kernel (one HBM pass over the int8 payload).
- ``TopKCodec``: top-k sparsification with error feedback (classic gradient
  compression).  Server-side aggregation is O(C·k): the (idx, val) payloads
  feed the scatter-accumulate kernel directly (see the O(C·k) reduce
  contract below) — the dense (C, n_params) delta matrix is never built.
- ``NullCodec``: identity fp32 wire — the uncompressed baseline with the
  same interface, and the *default* codec of ``RoundSpec``, so the round
  engine has exactly one code path.

The O(C·k) TopK reduce contract
-------------------------------

- **Payload layout**: per client, ``idx`` (k,) int32 positions and ``val``
  (k,) fp32 values, 8k wire bytes.  The encoder is deterministic (equal
  magnitudes tie-break toward the lower index via a stable sort) and emits
  indices in canonical ascending order, so a given delta yields
  bit-identical wire bytes under jit and eager alike.
- **Duplicate-index semantics**: our encoder emits distinct indices, but
  every consumer (``decode``, ``decode_batch``, ``reduce``, the Pallas
  kernel and its oracle) treats duplicates as scatter-ADD — a foreign
  payload with repeated indices means the same thing on every path.
- **Reduce paths**: ``aggregate_batch`` (jit-parallel engine) scatter-
  reduces the encoded payload and updates the error-feedback state by
  zeroing the transmitted coordinates — O(C·k), no dense decode;
  ``transmit_tree`` (mesh shard_map / sequential scan) decodes one
  client's (n_params,) vector at a time, never a (C, n_params) matrix;
  ``Strategy.aggregate_fit`` scatter-reduces serialized wire payloads when
  the whole fleet shipped TopK.
- **When densify still applies**: ``decode_batch`` exists for callers that
  explicitly want the dense per-client matrix, and ``aggregate_fit`` falls
  back to dense decoding for mixed-codec fleets (some clients on Int8/
  Null) — the homogeneous-TopK reduce itself never densifies.  The fused
  kernel additionally requires the (n_params,) accumulator to fit VMEM;
  above ``scatter_reduce.VMEM_ELEMS`` the dispatch falls back to the XLA
  scatter-add oracle, which is still O(C·k).

Codecs operate on the *delta* (client params - global params), which is
small-magnitude and quantizes well.  The ``UpdateCodec`` base class defines
the full surface the engine and protocol layer program against:

- ``init_client_state(n_clients, n_params)`` — the codec-owned per-client
  state pytree carried across rounds by ``round_step``.  Error-feedback
  codecs return a (C, n_params) fp32 residual buffer; ``NullCodec`` returns
  an empty pytree (no state is allocated for the uncompressed wire).
- ``aggregate_batch(deltas, weights, state)`` — the batched (C, N) path
  used inside the jitted parallel round step: fold the residual in, encode,
  reduce straight off the *encoded* payload (for Int8 the fused
  dequant+reduce kernel never materializes the fp32 (C, N) matrix), and
  return the new residual state.
- ``transmit_tree(delta_tree, state_row)`` — the per-client path used
  inside the mesh ``shard_map`` manual region and the sequential scan:
  what the server would decode from this one client's uplink, plus the
  client's next state row.  ``NullCodec`` overrides it to the identity so
  sharded models never round-trip through a flat replicated vector.
- ``wire_payload(enc)`` / ``from_wire(payload)`` — the exact arrays that
  cross the wire (Int8 trims encoder padding; the receiver re-pads), used
  by the protocol layer's ``CompressedParameters`` serialization.
- ``wire_bytes(n)`` — the per-client uplink charge; accepts an int or a
  vector of per-client sizes so ``CostModel.round_costs`` can account for
  a heterogeneous fleet where every client ships a different payload.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.utils.pytree import (
    safe_weight_sum,
    tree_flatten_to_vector,
    tree_sub,
    tree_unflatten_from_vector,
)

PyTree = Any


class UpdateCodec:
    """Base codec: error-feedback residual state + flat-vector wire.

    Subclasses implement the wire format (``encode``/``decode`` and their
    batched variants, ``reduce``, ``_wire_bytes_scalar``); the state and
    transport machinery below is shared.  ``NullCodec`` overrides the state
    hooks to be stateless/identity.
    """

    # ---- per-client state (carried by round_step across rounds) ----
    def init_client_state(self, n_clients: int, n_params: int) -> PyTree:
        """Zero error-feedback state: one flat fp32 residual per client."""
        return jnp.zeros((n_clients, n_params), jnp.float32)

    # ---- batched (C, N) surface: the jitted parallel round step ----
    def aggregate_updates(
        self, client_params: PyTree, global_params: PyTree,
        weights: jnp.ndarray, state,
    ):
        """Full aggregation of vmapped client params -> (avg params, state).

        Default: flatten per-client deltas to the (C, n_params) wire layout
        and aggregate off the encoded payload (``aggregate_batch``).
        ``NullCodec`` overrides this leafwise so the uncompressed engine
        never materializes the flat fp32 matrix.
        """
        flat_global = tree_flatten_to_vector(global_params)
        deltas = jax.vmap(
            lambda p: tree_flatten_to_vector(p) - flat_global
        )(client_params)
        avg_delta, new_state = self.aggregate_batch(deltas, weights, state)
        return (
            tree_unflatten_from_vector(flat_global + avg_delta, global_params),
            new_state,
        )

    def aggregate_batch(self, deltas: jnp.ndarray, weights: jnp.ndarray, state):
        """(C, N) deltas + state -> (weighted-mean decoded delta (N,), new state).

        Error feedback in, encode, reduce off the encoded payload; what was
        not transmitted becomes the next residual, so the compression error
        telescopes across rounds instead of accumulating.
        """
        eff = deltas + state
        enc = self.encode_batch(eff)
        new_state = eff - self.decode_batch(enc)
        return self.reduce(enc, weights), new_state

    # ---- per-client surface: mesh shard_map region / sequential scan ----
    def transmit_tree(self, delta_tree: PyTree, state_row):
        """One client's uplink: -> (decoded delta tree, new state row).

        The returned tree contains exactly the information that survives the
        wire (encode -> decode); the caller aggregates it, so only codec-
        representable values ever cross the slow inter-pod links.
        """
        vec = tree_flatten_to_vector(delta_tree) + state_row
        enc = self.encode(vec)
        dec = self.decode(enc)
        return tree_unflatten_from_vector(dec, delta_tree), vec - dec

    # ---- wire serialization hooks (protocol.CompressedParameters) ----
    def wire_payload(self, enc) -> dict:
        """The exact fields that cross the wire (arrays + python scalars)."""
        return dict(enc)

    def from_wire(self, payload: dict) -> dict:
        """Rebuild the decodable payload from ``wire_payload`` fields."""
        return dict(payload)

    # ---- uplink accounting ----
    def _wire_bytes_scalar(self, n_params: int) -> int:
        raise NotImplementedError

    def wire_bytes(self, n_params):
        """Uplink bytes for an ``n_params``-sized update.

        Accepts an int (homogeneous fleet) or a sequence of per-client sizes
        (heterogeneous accounting) and returns an int or list respectively.
        """
        if isinstance(n_params, (list, tuple, np.ndarray)):
            return [self._wire_bytes_scalar(int(n)) for n in np.asarray(n_params).reshape(-1)]
        return self._wire_bytes_scalar(int(n_params))


@dataclass(frozen=True)
class NullCodec(UpdateCodec):
    """Identity codec: full-precision fp32 wire (the uncompressed baseline).

    Stateless: ``init_client_state`` is empty, ``transmit_tree`` is the
    identity on the delta pytree (no flatten — sharded sequential/fsdp
    models keep their layout), and ``aggregate_batch`` is exactly the fused
    weighted reduce of the uncompressed engine.
    """

    def _wire_bytes_scalar(self, n_params: int) -> int:
        return 4 * n_params

    def init_client_state(self, n_clients: int, n_params: int) -> PyTree:
        return ()

    def aggregate_updates(self, client_params, global_params, weights, state):
        """Leafwise fp32 weighted mean — the fp32 wire loses nothing, so the
        uncompressed path never flattens the model into one (C, N) matrix
        (same reasoning as the identity ``transmit_tree``)."""
        wf = weights.astype(jnp.float32)
        wsum = safe_weight_sum(wf)

        def leaf_mean(xs, g):
            wshape = (xs.shape[0],) + (1,) * (xs.ndim - 1)
            gf = g.astype(jnp.float32)
            acc = jnp.sum(
                (xs.astype(jnp.float32) - gf) * wf.reshape(wshape), axis=0
            )
            return (gf + acc / wsum).astype(g.dtype)

        return jax.tree.map(leaf_mean, client_params, global_params), ()

    def aggregate_batch(self, deltas, weights, state):
        return self.reduce(self.encode_batch(deltas), weights), ()

    def transmit_tree(self, delta_tree, state_row):
        return delta_tree, ()

    def encode(self, delta_vec: jnp.ndarray):
        return {"delta": delta_vec.astype(jnp.float32), "n": delta_vec.shape[0]}

    def decode(self, enc) -> jnp.ndarray:
        return enc["delta"]

    def encode_batch(self, deltas: jnp.ndarray):
        return {"delta": deltas.astype(jnp.float32), "n": deltas.shape[1]}

    def decode_batch(self, enc) -> jnp.ndarray:
        return enc["delta"]

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        return ops.fedavg_reduce(enc["delta"], weights, interpret=interpret)


@dataclass(frozen=True)
class Int8Codec(UpdateCodec):
    block: int = 256

    def _n_scales(self, n_params: int) -> int:
        return -(-n_params // self.block)  # ceil: encode pads to a block multiple

    def _wire_bytes_scalar(self, n_params: int) -> int:
        # int8 payload (pad blocks need not cross the wire: the receiver
        # re-pads from n) + one fp32 scale per ceil(n/block) block
        return n_params + 4 * self._n_scales(n_params)

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        pad = (-n) % self.block
        padded = jnp.pad(delta_vec, (0, pad))
        q, scale = ops.quantize_int8(padded, block=self.block)
        return {"q": q, "scale": scale, "n": n}

    def decode(self, enc) -> jnp.ndarray:
        vec = ops.dequantize_int8(enc["q"], enc["scale"], block=self.block)
        return vec[: enc["n"]]

    def wire_payload(self, enc) -> dict:
        # pad int8s never cross the wire: trim to n, the receiver re-pads
        return {"q": enc["q"][: enc["n"]], "scale": enc["scale"], "n": enc["n"]}

    def from_wire(self, payload: dict) -> dict:
        n = payload["n"]
        q = jnp.asarray(payload["q"])
        return {
            "q": jnp.pad(q, (0, (-n) % self.block)),
            "scale": jnp.asarray(payload["scale"]),
            "n": n,
        }

    # ---- batched (C, N) wire path used inside the jitted round step ----
    def encode_batch(self, deltas: jnp.ndarray):
        """(C, N) -> q (C, Np) int8 + scales (C, Np/block); Np = padded N.

        Rows are padded to a block multiple, so flattening (C, Np) keeps
        every quantization block inside one client row and the 1-D Pallas
        kernel applies unchanged.
        """
        c, n = deltas.shape
        pad = (-n) % self.block
        padded = jnp.pad(deltas, ((0, 0), (0, pad)))
        np_ = n + pad
        q, scale = ops.quantize_int8(padded.reshape(-1), block=self.block)
        return {
            "q": q.reshape(c, np_),
            "scale": scale.reshape(c, np_ // self.block),
            "n": n,
        }

    def decode_batch(self, enc) -> jnp.ndarray:
        c = enc["q"].shape[0]
        vec = ops.dequantize_int8(
            enc["q"].reshape(-1), enc["scale"].reshape(-1), block=self.block
        )
        return vec.reshape(c, -1)[:, : enc["n"]]

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        """Weighted-mean decode straight off the int8 payload (fused kernel)."""
        avg = ops.dequant_reduce(
            enc["q"], enc["scale"], weights, block=self.block, interpret=interpret
        )
        return avg[: enc["n"]]


@dataclass(frozen=True)
class TopKCodec(UpdateCodec):
    """Keep the k largest-|.| entries; the residual feeds back next round.

    Wire contract (load-bearing for the O(C·k) reduce):

    - selection is DETERMINISTIC: magnitudes tie-break toward the lower
      index via a stable sort (raw ``lax.top_k`` tie order is lowering-
      dependent), so a given delta produces bit-identical payloads under
      jit and eager alike;
    - payload indices are canonically sorted ascending — reproducible wire
      bytes, and the scatter kernel walks VMEM monotonically;
    - this encoder emits distinct indices, but every consumer treats
      duplicate indices as ACCUMULATE (scatter-add), so foreign payloads
      mean the same thing on all paths;
    - ``reduce`` consumes (idx, val) directly through the scatter-
      accumulate kernel — O(C·k) time and memory, no dense (C, N) matrix;
      ``decode_batch`` remains the explicit densify fallback for callers
      that want the per-client dense matrix (nothing on the reduce or
      error-feedback path does).
    """

    frac: float = 0.01

    def k_of(self, n_params: int) -> int:
        return max(1, int(n_params * self.frac))

    def _wire_bytes_scalar(self, n_params: int) -> int:
        return self.k_of(n_params) * 8  # int32 index + fp32 value

    @staticmethod
    def _topk_idx(mags: jnp.ndarray, k: int) -> jnp.ndarray:
        """Deterministic top-k positions along the last axis: stable sort by
        descending magnitude (ties keep ascending index order), then the
        selected k re-sorted to the canonical ascending-index wire order."""
        iota = jax.lax.broadcasted_iota(jnp.int32, mags.shape, mags.ndim - 1)
        _, idx = jax.lax.sort(
            (-mags.astype(jnp.float32), iota),
            dimension=-1, num_keys=1, is_stable=True,
        )
        return jnp.sort(idx[..., :k], axis=-1)

    def encode(self, delta_vec: jnp.ndarray):
        n = delta_vec.shape[0]
        idx = self._topk_idx(jnp.abs(delta_vec), self.k_of(n))
        return {"idx": idx, "val": delta_vec[idx], "n": n}

    def decode(self, enc) -> jnp.ndarray:
        # scatter-ADD: duplicate indices accumulate (kernel semantics)
        return jnp.zeros((enc["n"],), enc["val"].dtype).at[enc["idx"]].add(enc["val"])

    def encode_batch(self, deltas: jnp.ndarray):
        n = deltas.shape[1]
        idx = self._topk_idx(jnp.abs(deltas), self.k_of(n))  # (C, k)
        return {"idx": idx, "val": jnp.take_along_axis(deltas, idx, axis=1), "n": n}

    def decode_batch(self, enc) -> jnp.ndarray:
        """Densify fallback: the dense (C, n) matrix for callers that want
        it — the reduce and error-feedback paths never call this."""
        c = enc["idx"].shape[0]
        rows = jnp.arange(c)[:, None]
        return (
            jnp.zeros((c, enc["n"]), enc["val"].dtype)
            .at[rows, enc["idx"]]
            .add(enc["val"])
        )

    def aggregate_batch(self, deltas: jnp.ndarray, weights: jnp.ndarray, state):
        """O(C·k) end to end: encode, scatter-reduce straight off the
        payload, and zero the transmitted coordinates out of the error-
        feedback state — TopK transmits exact values, so
        ``eff - decode(enc) == eff`` zeroed at idx; no dense decode."""
        eff = deltas + state
        enc = self.encode_batch(eff)
        rows = jnp.arange(eff.shape[0])[:, None]
        new_state = eff.at[rows, enc["idx"]].set(0.0)
        return self.reduce(enc, weights), new_state

    def transmit_tree(self, delta_tree: PyTree, state_row):
        """Per-client path (mesh shard_map / sequential scan): the decode
        stays per-client (N,) — never (C, N) — and the next state row zeroes
        the transmitted coordinates in O(k)."""
        vec = tree_flatten_to_vector(delta_tree) + state_row
        enc = self.encode(vec)
        new_row = vec.at[enc["idx"]].set(0.0)
        return tree_unflatten_from_vector(self.decode(enc), delta_tree), new_row

    def reduce(self, enc, weights: jnp.ndarray, *, interpret: bool = False):
        # sparse scatter-accumulate straight off the (idx, val) payload
        return ops.topk_scatter_reduce(
            enc["idx"], enc["val"], weights, enc["n"], interpret=interpret
        )


@dataclass(frozen=True)
class BandwidthCodecPolicy:
    """Per-device codec selection from the client's measured uplink.

    The Strategy consults this in ``configure_fit`` (the paper's system-cost
    quantification driving an algorithmic decision): slow phone-class
    uplinks get TopK sparsification, mid-tier edge boards get Int8, and
    datacenter-class backbone links ship the full-precision wire.
    """

    topk_below_mbps: float = 30.0       # Pixel-class cellular uplinks
    null_above_mbps: float = 100_000.0  # TPU-class datacenter backbone
    topk: TopKCodec = TopKCodec(frac=0.01)
    int8: Int8Codec = Int8Codec()
    null: NullCodec = NullCodec()

    def codec_for(self, properties) -> UpdateCodec:
        """properties: protocol.ClientProperties (or any .uplink_mbps owner)."""
        if properties.uplink_mbps >= self.null_above_mbps:
            return self.null
        if properties.uplink_mbps < self.topk_below_mbps:
            return self.topk
        return self.int8


def compress_update(
    codec, new_params: PyTree, global_params: PyTree, residual=None
) -> tuple[Any, PyTree]:
    """-> (wire_payload, new_residual) for error feedback.

    ``residual`` is the client's carried error-feedback vector (folded into
    the delta before encoding); None means no carried state.
    """
    delta = tree_flatten_to_vector(tree_sub(new_params, global_params))
    if residual is not None:
        delta = delta + residual
    enc = codec.encode(delta)
    new_residual = delta - codec.decode(enc)
    return enc, new_residual


def decompress_update(codec, enc, global_params: PyTree) -> PyTree:
    delta = codec.decode(enc)
    flat_global = tree_flatten_to_vector(global_params)
    return tree_unflatten_from_vector(flat_global + delta, global_params)

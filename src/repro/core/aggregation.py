"""Server aggregation primitives.

``aggregate_flat`` routes the weighted cross-client reduction through the
``fedavg_reduce`` Pallas kernel (flat fp32 vector path) — the server-side
compute hotspot when C x |params| is large.  ``hierarchical_mean`` is the
explicit two-stage multi-pod reduction (reduce within pod, then across pods)
used by the shard_map aggregation path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.utils.pytree import (
    tree_flatten_to_vector,
    tree_unflatten_from_vector,
)

PyTree = Any


def aggregate_flat(client_params: PyTree, weights: jnp.ndarray, like: PyTree) -> PyTree:
    """Weighted mean across client axis via the flat fedavg_reduce kernel.

    client_params leaves: (C, ...).  Equivalent to strategy.weighted_mean but
    exercises the kernel path (benchmarks/kernel_bench.py compares them).
    """
    c = weights.shape[0]
    flat = jax.vmap(tree_flatten_to_vector)(client_params)     # (C, N)
    wf = weights.astype(jnp.float32) / jnp.sum(weights.astype(jnp.float32))
    avg = ops.fedavg_reduce(flat, wf)
    return tree_unflatten_from_vector(avg, like)


def hierarchical_mean(x: jnp.ndarray, weights: jnp.ndarray, *, pod_axis: str, data_axis: str):
    """Two-stage weighted mean for shard_map bodies: within-pod psum first
    (cheap intra-pod ICI), then the small cross-pod reduction (expensive
    inter-pod links carry one pre-reduced tensor instead of C).

    x: per-client leaf slice on this device; weights: this client's weight.
    """
    wx = x.astype(jnp.float32) * weights
    local = jax.lax.psum(wx, axis_name=data_axis)
    local_w = jax.lax.psum(weights, axis_name=data_axis)
    total = jax.lax.psum(local, axis_name=pod_axis)
    total_w = jax.lax.psum(local_w, axis_name=pod_axis)
    return (total / total_w).astype(x.dtype)

"""Strategy interface — the paper's pluggable server-side decision maker.

The FL loop (server.py) orchestrates rounds but delegates every decision to
the Strategy, exactly as in Flower's architecture (paper §3, Figure 1):
which clients train, with what config (epochs / tau), and how results merge
into the global model.

Two integration surfaces:
- python-side hooks (configure_fit / aggregate_fit) used by the Server with
  Client objects (the paper-scale path).  ``configure_fit`` also performs
  per-device codec selection when a ``codec_policy`` is set: slow-uplink
  clients get aggressive compression, backbone clients the full wire; the
  chosen codec ships in FitIns config and the client answers with a
  ``CompressedParameters`` payload that ``aggregate_fit`` decodes.
- a jit-able ``server_update`` (plus the python-path ``aggregate``) used by
  the unified round step (the pod-scale path, core/rounds.py): the engine
  reduces codec-decoded deltas itself and hands the average to
  ``server_update``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.utils.pytree import (
    tree_flatten_to_vector, tree_scale, tree_sub, tree_unflatten_from_vector,
)

from ..protocol import (
    ClientProperties, CompressedParameters, FitIns, FitRes, Parameters,
    parameters_to_pytree, wire_to_pytree,
)

PyTree = Any


@dataclass
class Strategy:
    name: str = "base"
    fraction_fit: float = 1.0
    min_fit_clients: int = 1
    codec_policy: Any = None    # BandwidthCodecPolicy | None: per-device codecs

    # ---------------- python-side orchestration ----------------
    def num_fit_clients(self, available: int) -> int:
        return max(self.min_fit_clients, int(available * self.fraction_fit))

    def sample_clients(self, rnd: int, client_ids: Sequence[int]) -> list[int]:
        import numpy as np

        n = self.num_fit_clients(len(client_ids))
        rng = np.random.default_rng(10_000 + rnd)
        return sorted(rng.choice(client_ids, size=n, replace=False).tolist())

    def fit_config(self, rnd: int, client_id: int) -> dict:
        """Per-round, per-client config shipped in FitIns (epochs, tau, lr...)."""
        return {}

    def codec_for_client(self, client_id: int, properties=None):
        """Per-device codec selection (None = raw pytree transport)."""
        if self.codec_policy is None:
            return None
        props = properties or ClientProperties(client_id=client_id)
        return self.codec_policy.codec_for(props)

    def configure_fit(
        self,
        rnd: int,
        global_params: PyTree,
        client_ids: Sequence[int],
        client_properties: dict[int, ClientProperties] | None = None,
    ) -> list[tuple[int, FitIns]]:
        chosen = self.sample_clients(rnd, client_ids)
        out = []
        for cid in chosen:
            cfg = self.fit_config(rnd, cid)
            codec = self.codec_for_client(
                cid, (client_properties or {}).get(cid)
            )
            if codec is not None:
                cfg = {**cfg, "codec": codec}
            out.append((cid, FitIns(parameters=global_params, config=cfg)))
        return out

    @staticmethod
    def fitres_parameters(res: FitRes, global_params: PyTree) -> PyTree:
        """Materialize a FitRes payload as a params pytree: decodes the
        ``CompressedParameters`` delta wire (against the global the client
        trained from) and the serialized ``Parameters`` wire alike."""
        p = res.parameters
        if isinstance(p, CompressedParameters):
            return wire_to_pytree(p, global_params)
        if isinstance(p, Parameters):
            return parameters_to_pytree(p, global_params)
        return p

    def aggregate_fit(
        self, rnd: int, results: list[tuple[int, FitRes]], global_params: PyTree
    ) -> PyTree:
        """Default: examples-weighted average of returned parameters.

        A homogeneous-TopK fleet takes the sparse path: the serialized
        (idx, val) wire payloads feed the scatter-accumulate kernel directly
        — O(C·k), no per-client dense decode, no stacked (C, ...) params.
        Mixed-codec fleets (and raw-pytree transports) densify per client as
        before.
        """
        weights = jnp.asarray(
            [float(r.num_examples) for _, r in results], jnp.float32
        )
        if float(jnp.sum(weights)) == 0.0:
            # every sampled client reported zero examples: fall back to an
            # unweighted mean instead of poisoning the global with NaNs
            weights = jnp.ones_like(weights)
        sparse = self._aggregate_fit_topk(rnd, results, weights, global_params)
        if sparse is not None:
            return sparse
        trees = [self.fitres_parameters(r, global_params) for _, r in results]
        stacked = jax.tree.map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *trees
        )
        new_global, _ = self.aggregate(
            stacked, weights, global_params, self.init_state(global_params), rnd
        )
        return new_global

    def _sparse_fit_compatible(self) -> bool:
        """The sparse fast path computes weighted-mean + ``server_update``;
        that composition is only known to equal ``aggregate`` for the
        in-tree linear aggregators.  A subclass overriding ``aggregate``
        (robust aggregation: median, trimmed mean, ...) or pairing a stock
        ``aggregate`` with a custom ``server_update`` automatically falls
        back to the densify path — identity checks on the class attributes,
        so overrides anywhere in the MRO disqualify."""
        from .fedavg import FedAvg
        from .fedopt import FedOpt
        from .fedprox import FedProx
        from .fedtau import FedTau

        cls = type(self)
        if cls.aggregate in (FedAvg.aggregate, FedProx.aggregate, FedTau.aggregate):
            return cls.server_update is Strategy.server_update
        if cls.aggregate is FedOpt.aggregate:
            return cls.server_update is FedOpt.server_update
        return False

    def _aggregate_fit_topk(
        self, rnd: int, results, weights: jnp.ndarray, global_params: PyTree
    ) -> PyTree | None:
        """Sparse aggregation of an all-TopK round, or None to densify.

        Deserializes every client's (idx, val) payload, pads rows to the
        fleet max k (index 0 / value 0 — a zero-value scatter contributes
        nothing), scatter-reduces, and hands the reduced average to
        ``server_update`` — the same consumer the jitted engine uses, and
        identical to ``aggregate`` over stacked decoded params for every
        strategy ``_sparse_fit_compatible`` admits (FedAvg/FedProx/FedTau:
        weighted mean; FedOpt: pseudo-gradient of the mean).
        """
        from repro.kernels import ops

        from ..compression import TopKCodec

        if not results or not self._sparse_fit_compatible():
            return None
        payloads = []
        for _, res in results:
            cp = res.parameters
            # exact type, not isinstance: a TopKCodec subclass may redefine
            # the wire format (from_wire/decode), which only the dense path
            # interprets correctly
            if not isinstance(cp, CompressedParameters) or type(cp.codec) is not TopKCodec:
                return None
            payloads.append(cp)
        n_params = payloads[0].n_params
        if any(cp.n_params != n_params for cp in payloads):
            return None

        from ..protocol import _decode_array

        rows = []
        for cp in payloads:
            # rebuild the decodable payload exactly as wire_to_pytree does:
            # aux scalars + deserialized arrays through codec.from_wire
            payload = dict(cp.aux)
            for key, buf, (dtype, shape) in zip(cp.fields, cp.tensors, cp.manifest):
                payload[key] = _decode_array(buf, dtype, shape)
            enc = cp.codec.from_wire(payload)
            if not {"idx", "val"} <= set(enc):
                return None
            rows.append((jnp.asarray(enc["idx"]).reshape(-1),
                         jnp.asarray(enc["val"]).reshape(-1)))
        k_max = max(int(i.shape[0]) for i, _ in rows)
        if k_max == 0:
            return global_params
        idx = jnp.stack([
            jnp.pad(i.astype(jnp.int32), (0, k_max - i.shape[0])) for i, _ in rows
        ])
        val = jnp.stack([
            jnp.pad(v.astype(jnp.float32), (0, k_max - v.shape[0])) for _, v in rows
        ])
        avg_delta = ops.topk_scatter_reduce(idx, val, weights, n_params)
        flat_global = tree_flatten_to_vector(global_params)
        avg_params = tree_unflatten_from_vector(
            flat_global + avg_delta, global_params
        )
        new_global, _ = self.server_update(
            avg_params, global_params, self.init_state(global_params), rnd
        )
        return new_global

    # ---------------- jit-able core ----------------
    def init_state(self, global_params: PyTree) -> PyTree:
        return ()

    def aggregate(
        self,
        client_params: PyTree,   # leaves (C, ...): per-client updated params
        weights: jnp.ndarray,    # (C,) aggregation weights (num examples)
        global_params: PyTree,
        server_state: PyTree,
        rnd,
    ) -> tuple[PyTree, PyTree]:
        raise NotImplementedError

    def server_update(
        self, avg_params: PyTree, global_params: PyTree, server_state: PyTree, rnd
    ) -> tuple[PyTree, PyTree]:
        """Consume the already-reduced client average (shard_map path).

        FedAvg-family: the average IS the new global.  FedOpt overrides to
        apply a server optimizer to the pseudo-gradient.
        """
        return avg_params, server_state

    # client-side loss shaping hook (FedProx adds the proximal term)
    def client_loss_extra(self, params: PyTree, global_params: PyTree):
        return jnp.zeros((), jnp.float32)


def weighted_mean(client_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """Examples-weighted mean across the leading client axis (fp32 accumulate)."""
    wf = weights.astype(jnp.float32)
    wsum = jnp.sum(wf)

    def leaf_mean(x):
        wshape = (x.shape[0],) + (1,) * (x.ndim - 1)
        acc = jnp.sum(x.astype(jnp.float32) * wf.reshape(wshape), axis=0)
        return (acc / wsum).astype(x.dtype)

    return jax.tree.map(leaf_mean, client_params)


def pseudo_gradient(client_params: PyTree, weights, global_params: PyTree) -> PyTree:
    """FedOpt's server 'gradient': g = global - weighted_mean(clients)."""
    avg = weighted_mean(client_params, weights)
    return tree_sub(global_params, avg)

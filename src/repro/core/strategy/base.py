"""Strategy interface — the paper's pluggable server-side decision maker.

The FL loop (server.py) orchestrates rounds but delegates every decision to
the Strategy, exactly as in Flower's architecture (paper §3, Figure 1):
which clients train, with what config (epochs / tau), and how results merge
into the global model.

Two integration surfaces:
- python-side hooks (configure_fit / aggregate_fit) used by the Server with
  Client objects (the paper-scale path).  ``configure_fit`` also performs
  per-device codec selection when a ``codec_policy`` is set: slow-uplink
  clients get aggressive compression, backbone clients the full wire; the
  chosen codec ships in FitIns config and the client answers with a
  ``CompressedParameters`` payload that ``aggregate_fit`` decodes.
- a jit-able ``server_update`` (plus the python-path ``aggregate``) used by
  the unified round step (the pod-scale path, core/rounds.py): the engine
  reduces codec-decoded deltas itself and hands the average to
  ``server_update``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import (
    safe_weight_sum, tree_flatten_to_vector, tree_scale, tree_sub,
    tree_unflatten_from_vector,
)

from ..protocol import (
    ClientProperties, CompressedParameters, FitIns, FitRes, Parameters,
    parameters_to_pytree, wire_to_pytree,
)

PyTree = Any


@dataclass
class Strategy:
    name: str = "base"
    fraction_fit: float = 1.0
    min_fit_clients: int = 1
    codec_policy: Any = None    # BandwidthCodecPolicy | None: per-device codecs
    # client-sampling seed: the per-round stream is default_rng((seed, rnd))
    # — tuple-seeded like AvailabilityTrace, so one experiment is
    # reproducible AND two experiments with different seeds draw genuinely
    # independent cohorts.  (Additive seed+rnd would make seed 10_001's
    # round r replay seed 10_000's round r+1; the old hardcoded 10_000 made
    # every "independent" run sample identical cohorts outright.)
    seed: int = 10_000
    # python-path server state (e.g. FedOpt optimizer moments), carried
    # across aggregate_fit rounds exactly as the jitted engine threads
    # server_state through round_step; reset at the start of Server.run
    _server_state: Any = field(default=None, repr=False)

    # ---------------- python-path server state ----------------
    def reset_server_state(self) -> None:
        """Drop the carried server state (Server.run calls this per run)."""
        self._server_state = None

    def _server_state_for(self, global_params: PyTree) -> PyTree:
        """The carried python-path server state, lazily initialized.

        Regression guard: ``aggregate_fit`` used to pass a FRESH
        ``init_state`` every round and discard the returned state, so
        FedAdam/FedYogi/FedAvgM never accumulated optimizer moments under
        ``Server.run`` — diverging from the jitted engine."""
        if self._server_state is None:
            self._server_state = self.init_state(global_params)
        return self._server_state

    # ---------------- python-side orchestration ----------------
    def num_fit_clients(self, available: int) -> int:
        return max(self.min_fit_clients, int(available * self.fraction_fit))

    def sample_clients(self, rnd: int, client_ids: Sequence[int]) -> list[int]:
        if hasattr(client_ids, "profile_codes"):
            # population-backed overload: a packed Population instead of an
            # explicit id list — sample ids without instantiating clients
            return self.sample_cohort(
                rnd, client_ids, self.num_fit_clients(len(client_ids))
            )
        import numpy as np

        if not client_ids:
            return []  # availability dropouts can empty the eligible pool
        n = min(self.num_fit_clients(len(client_ids)), len(client_ids))
        rng = np.random.default_rng((self.seed, rnd))
        return sorted(rng.choice(client_ids, size=n, replace=False).tolist())

    def sample_cohort(
        self,
        rnd: int,
        population,
        cohort_size: int,
        *,
        exclude=(),
        availability=None,
        cost_model=None,
        deadline_s: float | None = None,
    ) -> list[int]:
        """Draw a cohort of ids from a packed ``Population`` — O(cohort)
        work and memory regardless of population size.

        Candidates are drawn id-first (with replacement, deduplicated) and
        availability is *streamed* over each candidate batch only
        (``AvailabilityTrace.available_for``); no O(N) id list, fleet
        vector, or client object is ever built.  Deterministic in
        ``(self.seed, rnd)`` like ``sample_clients``.  Redraws are bounded,
        so a mostly-unavailable fleet yields a short cohort rather than a
        livelock.  The base strategy samples blind — ``cost_model`` and
        ``deadline_s`` are the hooks ``CostAwareSampling`` ranks with.
        """
        del cost_model, deadline_s  # blind sampling: cost hooks unused
        import numpy as np

        n = len(population)
        want = min(int(cohort_size), n)
        if want <= 0:
            return []
        rng = np.random.default_rng((self.seed, rnd))
        chosen: list[int] = []
        seen = {int(c) for c in exclude}
        for _ in range(16):
            if len(chosen) >= want:
                break
            cand = rng.integers(0, n, size=max(64, 4 * want))
            if availability is not None:
                cand = cand[availability.available_for(rnd, cand)]
            for c in cand.tolist():
                if c not in seen:
                    seen.add(c)
                    chosen.append(c)
                    if len(chosen) >= want:
                        break
        return sorted(chosen)

    def fit_config(self, rnd: int, client_id: int) -> dict:
        """Per-round, per-client config shipped in FitIns (epochs, tau, lr...)."""
        return {}

    def round_deadline_s(self) -> float | None:
        """The strategy's per-round wall-clock cutoff, if it owns one.

        ``scheduler.Deadline(tau=None)`` reads this, so e.g. ``FedTau``'s
        tau and the virtual clock's round cutoff are ONE knob: the same
        seconds that budget each client's local steps also decide who the
        scheduler drops.  None = no deadline (a bare ``Deadline()`` then
        degenerates to ``SyncAll``).
        """
        return None

    def codec_for_client(self, client_id: int, properties=None):
        """Per-device codec selection (None = raw pytree transport)."""
        if self.codec_policy is None:
            return None
        props = properties or ClientProperties(client_id=client_id)
        return self.codec_policy.codec_for(props)

    def configure_fit(
        self,
        rnd: int,
        global_params: PyTree,
        client_ids: Sequence[int],
        client_properties: dict[int, ClientProperties] | None = None,
    ) -> list[tuple[int, FitIns]]:
        chosen = self.sample_clients(rnd, client_ids)
        out = []
        for cid in chosen:
            cfg = self.fit_config(rnd, cid)
            codec = self.codec_for_client(
                cid, (client_properties or {}).get(cid)
            )
            if codec is not None:
                cfg = {**cfg, "codec": codec}
            out.append((cid, FitIns(parameters=global_params, config=cfg)))
        return out

    @staticmethod
    def fitres_parameters(res: FitRes, global_params: PyTree) -> PyTree:
        """Materialize a FitRes payload as a params pytree: decodes the
        ``CompressedParameters`` delta wire (against the global the client
        trained from) and the serialized ``Parameters`` wire alike."""
        p = res.parameters
        if isinstance(p, CompressedParameters):
            return wire_to_pytree(p, global_params)
        if isinstance(p, Parameters):
            return parameters_to_pytree(p, global_params)
        return p

    def aggregate_fit(
        self, rnd: int, results: list[tuple[int, FitRes]], global_params: PyTree
    ) -> PyTree:
        """Default: examples-weighted average of returned parameters.

        Compressed-wire fleets — homogeneous OR mixed — take the grouped
        kernel-path reduce (``_aggregate_fit_wire``): clients partition by
        codec and each group's serialized payloads feed that codec's own
        reduce kernel (TopK → scatter-accumulate, O(C·k), never densified;
        Int8 → fused dequant+reduce; Null → fedavg reduce), the partial
        weighted sums combining under one fleet denominator.  Only raw-
        pytree transports, foreign codecs, and non-linear aggregators
        densify per client.  Server state (FedOpt moments) is carried
        across rounds on both paths.
        """
        weights = self._fit_weights(results)
        if float(jnp.sum(weights)) == 0.0:
            # every sampled client reported zero examples: fall back to an
            # unweighted mean instead of poisoning the global with NaNs
            weights = jnp.ones_like(weights)
        server_state = self._server_state_for(global_params)
        grouped = self._aggregate_fit_wire(
            rnd, results, weights, global_params, server_state
        )
        if grouped is not None:
            new_global, new_state = grouped
        else:
            trees = [self.fitres_parameters(r, global_params) for _, r in results]
            stacked = jax.tree.map(
                lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *trees
            )
            new_global, new_state = self.aggregate(
                stacked, weights, global_params, server_state, rnd
            )
        self._server_state = new_state
        return new_global

    def _fit_weights(self, results: list[tuple[int, "FitRes"]]) -> jnp.ndarray:
        """Per-result aggregation weights (the ONE hook both the grouped
        wire reduce and the densify path flow through).  Default: example
        counts; ``FedBuffStrategy`` discounts by staleness here."""
        return jnp.asarray(
            [float(r.num_examples) for _, r in results], jnp.float32
        )

    def _grouped_fit_compatible(self) -> bool:
        """The grouped wire reduce computes weighted-mean + ``server_update``;
        that composition is only known to equal ``aggregate`` for the
        in-tree linear aggregators.  A subclass overriding ``aggregate``
        (robust aggregation: median, trimmed mean, ...) or pairing a stock
        ``aggregate`` with a custom ``server_update`` automatically falls
        back to the densify path — identity checks on the class attributes,
        so overrides anywhere in the MRO disqualify."""
        from .fedavg import FedAvg
        from .fedbuff import FedBuffStrategy
        from .fedopt import FedOpt
        from .fedprox import FedProx
        from .fedtau import FedTau

        cls = type(self)
        if cls.aggregate in (
            FedAvg.aggregate, FedProx.aggregate, FedTau.aggregate,
            FedBuffStrategy.aggregate,
        ):
            return cls.server_update is Strategy.server_update
        if cls.aggregate is FedOpt.aggregate:
            return cls.server_update is FedOpt.server_update
        return False

    def _aggregate_fit_wire(
        self, rnd: int, results, weights: jnp.ndarray, global_params: PyTree,
        server_state: PyTree,
    ) -> tuple[PyTree, PyTree] | None:
        """Grouped kernel-path aggregation of a compressed-wire fleet, or
        None to densify.

        Partitions clients by codec (equal-config codecs share a group) and
        reduces each group's payloads on that codec's own kernel path —
        the same grouped reduce ``MixedCodec`` runs inside the jitted
        engine, so a Pixel→TopK / Jetson→Int8 / TPU→Null fleet never
        materializes per-client dense params here either.  Each group
        yields its partial weighted delta sum; one fleet-wide
        ``safe_weight_sum`` denominator turns the combined sum into the
        mean that feeds ``server_update`` (the jitted engine's consumer) —
        identical to ``aggregate`` over stacked decoded params for every
        strategy ``_grouped_fit_compatible`` admits.  A homogeneous-TopK
        pseudo-gradient stays EXACTLY zero at untransmitted coordinates, so
        FedOpt leaves them untouched (no fp-noise adam drift).

        This partial-weighted-sum-under-ONE-denominator contract is the
        same one the mesh round step's collective reduces device-side —
        and what ``compression.CompressedPsum`` quantizes when
        ``RoundSpec.collective="int8"``: partial sums commute with the
        reduction, so they may be combined group-wise here or psum'd
        (quantized on a shared scale grid) across the mesh there, with the
        single division happening once at the end either way.
        """
        from ..compression import (
            Int8Codec, NullCodec, StructuredUpdate, TopKCodec,
        )
        from ..protocol import wire_to_enc

        if not results or not self._grouped_fit_compatible():
            return None
        cps, encs = [], []
        for _, res in results:
            cp = res.parameters
            # exact types, not isinstance: a codec subclass may redefine
            # the wire format (from_wire/decode), which only the per-client
            # dense decode interprets correctly.  Segmented Null/Int8/TopK
            # qualify (same types, same wire per segment); a structure-
            # changing codec (LoRA) densifies per client instead.
            if not isinstance(cp, CompressedParameters) or type(cp.codec) not in (
                NullCodec, Int8Codec, TopKCodec
            ):
                return None
            enc = wire_to_enc(cp)
            required = (
                {"idx", "val"} if type(cp.codec) is TopKCodec
                else {"q", "scale"} if type(cp.codec) is Int8Codec
                else {"delta"}
            )
            payloads = (
                enc.payloads if isinstance(enc, StructuredUpdate) else (enc,)
            )
            if not all(required <= set(p) for p in payloads):
                return None
            cps.append(cp)
            encs.append(enc)
        n_params = cps[0].n_params
        if any(cp.n_params != n_params for cp in cps):
            return None

        groups: dict[Any, list[int]] = {}
        for i, cp in enumerate(cps):
            groups.setdefault(cp.codec, []).append(i)

        wf = weights.astype(jnp.float32)
        total = jnp.zeros((n_params,), jnp.float32)
        for codec, rows in groups.items():
            total = total + self._group_wire_sum(
                codec, [encs[i] for i in rows], wf[np.asarray(rows)], n_params
            )
        avg_delta = total / safe_weight_sum(wf)
        flat_global = tree_flatten_to_vector(global_params)
        avg_params = tree_unflatten_from_vector(
            flat_global + avg_delta, global_params
        )
        return self.server_update(avg_params, global_params, server_state, rnd)

    @staticmethod
    def _group_wire_sum(codec, encs: list, w_g, n_params: int):
        """One codec group's partial weighted delta sum (N,), on the group's
        own kernel path (``normalize=False``: the caller owns the ONE
        fleet-wide denominator).

        A segmented group reduces segment by segment through the SAME
        kernels, concatenating the per-segment partial sums — so the
        kernel dispatch's VMEM budget (``scatter_reduce.MAX_N_PARAMS``)
        gates on ``seg.size`` per call, not the whole model: a fleet whose
        total ``n_params`` is over budget still scatter-reduces every
        in-budget segment on the Pallas path."""
        if getattr(codec, "segments", None) is not None:
            parts = [
                Strategy._flat_wire_sum(
                    codec, [su.payloads[i] for su in encs], w_g, seg.size
                )
                for i, seg in enumerate(codec.segments)
            ]
            return jnp.concatenate(parts)
        return Strategy._flat_wire_sum(codec, encs, w_g, n_params)

    @staticmethod
    def _flat_wire_sum(codec, encs: list[dict], w_g, n_params: int):
        """The flat-format partial sum for ONE segment (or the whole update
        for an unsegmented codec)."""
        from repro.kernels import ops

        from ..compression import Int8Codec, TopKCodec

        if type(codec) is TopKCodec:
            rows = [(jnp.asarray(e["idx"]).reshape(-1),
                     jnp.asarray(e["val"]).reshape(-1)) for e in encs]
            # pad rows to the group max k: index 0 / value 0 — a zero-value
            # scatter contributes nothing
            k_max = max(int(i.shape[0]) for i, _ in rows)
            if k_max == 0:
                return jnp.zeros((n_params,), jnp.float32)
            idx = jnp.stack([
                jnp.pad(i.astype(jnp.int32), (0, k_max - i.shape[0]))
                for i, _ in rows
            ])
            val = jnp.stack([
                jnp.pad(v.astype(jnp.float32), (0, k_max - v.shape[0]))
                for _, v in rows
            ])
            return ops.topk_scatter_reduce(
                idx, val, w_g, n_params, normalize=False
            )
        if type(codec) is Int8Codec:
            q = jnp.stack([e["q"] for e in encs])
            scale = jnp.stack([e["scale"] for e in encs])
            return ops.dequant_reduce(
                q, scale, w_g, block=codec.block, normalize=False
            )[:n_params]
        deltas = jnp.stack([
            jnp.asarray(e["delta"], jnp.float32) for e in encs
        ])
        return ops.fedavg_reduce(deltas, w_g, normalize=False)

    # ---------------- jit-able core ----------------
    def init_state(self, global_params: PyTree) -> PyTree:
        return ()

    def aggregate(
        self,
        client_params: PyTree,   # leaves (C, ...): per-client updated params
        weights: jnp.ndarray,    # (C,) aggregation weights (num examples)
        global_params: PyTree,
        server_state: PyTree,
        rnd,
    ) -> tuple[PyTree, PyTree]:
        raise NotImplementedError

    def server_update(
        self, avg_params: PyTree, global_params: PyTree, server_state: PyTree, rnd
    ) -> tuple[PyTree, PyTree]:
        """Consume the already-reduced client average (shard_map path).

        FedAvg-family: the average IS the new global.  FedOpt overrides to
        apply a server optimizer to the pseudo-gradient.
        """
        return avg_params, server_state

    # client-side loss shaping hook (FedProx adds the proximal term)
    def client_loss_extra(self, params: PyTree, global_params: PyTree):
        return jnp.zeros((), jnp.float32)


def weighted_mean(client_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """Examples-weighted mean across the leading client axis (fp32 accumulate)."""
    wf = weights.astype(jnp.float32)
    wsum = jnp.sum(wf)

    def leaf_mean(x):
        wshape = (x.shape[0],) + (1,) * (x.ndim - 1)
        acc = jnp.sum(x.astype(jnp.float32) * wf.reshape(wshape), axis=0)
        return (acc / wsum).astype(x.dtype)

    return jax.tree.map(leaf_mean, client_params)


def pseudo_gradient(client_params: PyTree, weights, global_params: PyTree) -> PyTree:
    """FedOpt's server 'gradient': g = global - weighted_mean(clients)."""
    avg = weighted_mean(client_params, weights)
    return tree_sub(global_params, avg)

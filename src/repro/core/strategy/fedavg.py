"""FedAvg (McMahan et al., 2017) — the paper's default Strategy.

With the unified round engine, the jitted paths reduce codec-decoded deltas
themselves and call ``server_update`` (identity here: the weighted average
IS the new global); ``aggregate`` remains the python-side hook used by
``aggregate_fit`` after the wire payloads are decoded.
"""
from __future__ import annotations

from dataclasses import dataclass

from .base import Strategy, weighted_mean


@dataclass
class FedAvg(Strategy):
    name: str = "fedavg"
    local_epochs: int = 1
    local_lr: float = 0.05

    def fit_config(self, rnd: int, client_id: int) -> dict:
        return {"epochs": self.local_epochs, "lr": self.local_lr}

    def aggregate(self, client_params, weights, global_params, server_state, rnd):
        return weighted_mean(client_params, weights), server_state

"""Cost-aware client sampling (Oort-lite) — the paper's thesis, one level up.

The scheduler (core/scheduler.py) acts on system costs *after* the blind
draw: ``Deadline(tau)`` drops whoever misses the cutoff and charges their
wasted work.  ``CostAwareSampling`` moves the cost knowledge to the draw
itself: it consults the population's packed cost columns (one vectorized
``expected_round_s`` over the candidate pool) plus the streamed
``AvailabilityTrace`` and prefers clients *predicted to make the deadline*
— fewer drops, less wasted energy, at equal cohort size.

Oort-lite, not Oort: no statistical-utility term (no per-client loss
tracking), just the system-speed half — feasible candidates keep their
random draw order (diversity is preserved: any feasible client is as likely
as any other), and only if feasible candidates run short do infeasible ones
fill the remainder, fastest first.

Compose the mixin MRO-first so its ``sample_cohort`` wins::

    @dataclass
    class CostAwareFedAvg(CostAwareSampling, FedAvg): ...

The mixin only changes *which ids* are drawn in population mode; every
other Strategy surface (configure_fit, aggregation, deadlines) is the
composed strategy's own.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..scheduler import deadline_feasible
from .fedavg import FedAvg


@dataclass
class CostAwareSampling:
    """Mixin overriding ``Strategy.sample_cohort`` with deadline-aware
    preference (see module docstring).  ``expected_steps`` is the predicted
    local work per round (epochs x steps/epoch — the strategy cannot see
    client datasets, so the caller calibrates it); ``oversample`` scales
    the candidate pool the ranking chooses from."""

    oversample: float = 4.0
    expected_steps: int = 20

    def sample_cohort(
        self,
        rnd: int,
        population,
        cohort_size: int,
        *,
        exclude=(),
        availability=None,
        cost_model=None,
        deadline_s: float | None = None,
    ) -> list[int]:
        n = len(population)
        want = min(int(cohort_size), n)
        if want <= 0:
            return []
        rng = np.random.default_rng((self.seed, rnd))
        target = min(n, max(want, int(np.ceil(want * max(1.0, self.oversample)))))
        pool: list[int] = []
        seen = {int(c) for c in exclude}
        for _ in range(16):  # bounded redraws, as in the blind sampler
            if len(pool) >= target:
                break
            cand = rng.integers(0, n, size=max(64, 4 * target))
            if availability is not None:
                cand = cand[availability.available_for(rnd, cand)]
            for c in cand.tolist():
                if c not in seen:
                    seen.add(c)
                    pool.append(c)
                    if len(pool) >= target:
                        break
        if not pool:
            return []
        ids = np.asarray(pool, np.int64)
        # conservative wire estimate: full-precision both ways (a codec can
        # only shrink the uplink, making a feasible client more feasible)
        payload = float(cost_model.update_bytes) if cost_model is not None else 0.0
        t = population.expected_round_s(
            ids, steps=int(self.expected_steps),
            up_bytes=payload, down_bytes=payload,
        )
        tau = deadline_s if deadline_s is not None else self.round_deadline_s()
        ok = deadline_feasible(t, tau)
        ranked = np.concatenate([
            ids[ok],                                        # draw order: diverse
            ids[~ok][np.argsort(t[~ok], kind="stable")],    # then fastest-first
        ])
        return sorted(int(c) for c in ranked[:want])


@dataclass
class CostAwareFedAvg(CostAwareSampling, FedAvg):
    """FedAvg whose population-mode cohorts prefer deadline-feasible
    clients (the straggler_bench comparison row)."""

    name: str = "costaware-fedavg"

"""FedBuff (Nguyen et al., 2022): buffered asynchronous aggregation.

The canonical cost-driven FL design the virtual-clock layer exists for:
instead of the round ending when the *slowest* client reports (SyncAll) or
at a hard cutoff (Deadline), the server aggregates as soon as a buffer of
K updates has arrived — stragglers keep computing and their updates land
in a LATER aggregation, discounted by how stale they are.

Split of responsibilities (the staleness-weight contract,
core/scheduler.py):

- ``scheduler.BufferedAsync(K, max_staleness)`` owns the *timing*: which
  arrivals each round consumes, who stays in flight, who expires.
- this Strategy owns the *weighting*: a reported update with staleness
  ``s`` (rounds elapsed since its client pulled the global it trained
  from) aggregates at ``w_c / (1 + s)**alpha`` — fresh updates keep their
  example-count weight, stale ones fade polynomially (``alpha=0`` recovers
  plain FedAvg weighting; Nguyen et al.'s ``1/sqrt(1+s)`` is
  ``alpha=0.5``, the default).

The discount flows through ``Strategy._fit_weights``, so both aggregation
paths — the grouped compressed-wire kernel reduce (``_aggregate_fit_wire``)
and the per-client densify fallback — apply the same staleness weights; a
mixed Pixel→TopK / Jetson→Int8 / TPU→Null fleet aggregates its stale
updates without ever materializing per-client dense params.  Stale deltas
apply to the CURRENT global (the wire formats ship deltas; the Server
rebases raw-parameter payloads), which is exactly FedBuff's update rule.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from .base import Strategy, weighted_mean


@dataclass
class FedBuffStrategy(Strategy):
    name: str = "fedbuff"
    local_epochs: int = 1
    local_lr: float = 0.05
    alpha: float = 0.5          # staleness-discount exponent
    buffer_size: int = 2        # K — mirrored into make_policy()
    max_staleness: int = 4      # older arrivals are expired by the policy

    def fit_config(self, rnd: int, client_id: int) -> dict:
        return {"epochs": self.local_epochs, "lr": self.local_lr}

    def make_policy(self):
        """The matching scheduler policy: ONE place owns K/max_staleness."""
        from ..scheduler import BufferedAsync

        return BufferedAsync(
            buffer_size=self.buffer_size, max_staleness=self.max_staleness
        )

    def staleness_weight(self, staleness) -> float:
        return 1.0 / (1.0 + float(staleness)) ** self.alpha

    def _fit_weights(self, results) -> jnp.ndarray:
        """Example-count weights discounted by each result's staleness.

        The Server stamps ``FitRes.staleness`` from the scheduler's verdict
        (0 = trained on this round's global); results that never went
        through the scheduler aggregate undiscounted.
        """
        return jnp.asarray(
            [
                float(r.num_examples)
                * self.staleness_weight(getattr(r, "staleness", 0))
                for _, r in results
            ],
            jnp.float32,
        )

    def aggregate(self, client_params, weights, global_params, server_state, rnd):
        return weighted_mean(client_params, weights), server_state

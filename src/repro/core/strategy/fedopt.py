"""FedOpt family (Reddi et al., 2021): server-side adaptive optimizers.

Beyond-paper strategies: the aggregated client average becomes a pseudo-
gradient consumed by a server optimizer (momentum / Adam / Yogi).  Included
because the paper's stated goal — "this quantification could be used to
design more efficient FL algorithms" — is exactly the trade space these
occupy (fewer rounds at the same per-round system cost).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.optim import adam, sgd, yogi
from repro.utils.pytree import tree_cast

from .base import Strategy, pseudo_gradient


@dataclass
class FedOpt(Strategy):
    name: str = "fedopt"
    local_epochs: int = 1
    local_lr: float = 0.05
    server_opt: str = "adam"       # "sgdm" | "adam" | "yogi"
    server_lr: float = 0.1
    server_momentum: float = 0.9

    def _opt(self):
        if self.server_opt == "sgdm":
            return sgd(self.server_lr, momentum=self.server_momentum)
        if self.server_opt == "yogi":
            return yogi(self.server_lr)
        return adam(self.server_lr, b1=0.9, b2=0.99)

    def fit_config(self, rnd: int, client_id: int) -> dict:
        return {"epochs": self.local_epochs, "lr": self.local_lr}

    def init_state(self, global_params):
        return self._opt().init(global_params)

    def aggregate(self, client_params, weights, global_params, server_state, rnd):
        g = pseudo_gradient(client_params, weights, global_params)
        new_params, new_state = self._opt().update(g, global_params, server_state, rnd)
        return new_params, new_state

    def server_update(self, avg_params, global_params, server_state, rnd):
        g = jax.tree.map(
            lambda gp, ap: gp.astype(jnp.float32) - ap.astype(jnp.float32),
            global_params, avg_params,
        )
        return self._opt().update(g, global_params, server_state, rnd)


def FedAdam(**kw) -> FedOpt:
    return FedOpt(name="fedadam", server_opt="adam", **kw)


def FedYogi(**kw) -> FedOpt:
    return FedOpt(name="fedyogi", server_opt="yogi", **kw)


def FedAvgM(**kw) -> FedOpt:
    return FedOpt(name="fedavgm", server_opt="sgdm", **kw)

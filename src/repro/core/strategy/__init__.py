from .base import Strategy, weighted_mean, pseudo_gradient
from .fedavg import FedAvg
from .fedbuff import FedBuffStrategy
from .fedprox import FedProx
from .fedtau import FedTau, tau_from_reference_processor
from .fedopt import FedOpt, FedAdam, FedYogi, FedAvgM
from .sampling import CostAwareFedAvg, CostAwareSampling

STRATEGIES = {
    "fedavg": FedAvg,
    "fedprox": FedProx,
    "fedtau": FedTau,
    "fedbuff": FedBuffStrategy,
    "fedadam": FedAdam,
    "fedyogi": FedYogi,
    "fedavgm": FedAvgM,
    "costaware-fedavg": CostAwareFedAvg,
}

__all__ = [
    "Strategy", "weighted_mean", "pseudo_gradient",
    "FedAvg", "FedProx", "FedTau", "tau_from_reference_processor",
    "FedBuffStrategy", "FedOpt", "FedAdam", "FedYogi", "FedAvgM", "STRATEGIES",
    "CostAwareSampling", "CostAwareFedAvg",
]

"""FedTau — the paper's modified FedAvg with hardware-specific cutoff time.

Each client gets a wall-clock budget tau (FitIns config); when tau expires it
ships whatever parameters it has, even mid-epoch (paper §5, Table 3).  The
distinctive capability the paper highlights is *processor-specific* tau:
Flower's cost quantification lets the server set tau_CPU = round time of the
GPU fleet, equalizing round walls at a small accuracy cost.

In simulation the cutoff maps to a per-client step budget via the cost model
(steps_i = floor(tau / step_time_i)); the jitted round step realizes partial
work with a per-client step mask (core/rounds.py).

FedTau composes with per-device codec selection (``Strategy.codec_policy``):
the same hardware facts that set a client's tau also pick its wire codec, so
slow-uplink stragglers are helped on both the compute AND the communication
leg of the round.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..cost_model import CostModel
from .base import Strategy, weighted_mean


@dataclass
class FedTau(Strategy):
    name: str = "fedtau"
    local_epochs: int = 5
    local_lr: float = 0.05
    tau_s: float = 0.0                    # 0 = no cutoff (paper notation)
    cost_model: CostModel | None = None
    steps_per_epoch: int = 10
    weight_by_steps: bool = False         # weight updates by completed steps

    def round_deadline_s(self) -> float | None:
        """tau IS the scheduler's deadline: ``scheduler.Deadline(tau=None)``
        cuts the virtual round at the same instant that budgets the local
        steps.  The server-side ``max_steps`` budget is compute-only, so a
        client that fills it can still miss the cutoff on comm time; the
        ``deadline_s`` that ``configure_fit`` ships alongside lets clients
        with known profiles subtract their own transfer time (JaxClient
        does).  Drops remain possible for jittered step times or clients
        that don't know their links — which is the point of measuring."""
        return self.tau_s if self.tau_s > 0 else None

    def fit_config(self, rnd: int, client_id: int) -> dict:
        cfg = {"epochs": self.local_epochs, "lr": self.local_lr, "tau_s": self.tau_s}
        if self.cost_model is not None:
            full = self.local_epochs * self.steps_per_epoch
            cfg["max_steps"] = self.cost_model.steps_under_tau(
                client_id, self.tau_s, full
            )
        return cfg

    def client_step_budgets(self, client_ids) -> list[int]:
        full = self.local_epochs * self.steps_per_epoch
        if self.cost_model is None or self.tau_s <= 0:
            return [full for _ in client_ids]
        return [
            self.cost_model.steps_under_tau(cid, self.tau_s, full)
            for cid in client_ids
        ]

    def aggregate(self, client_params, weights, global_params, server_state, rnd):
        return weighted_mean(client_params, weights), server_state


def tau_from_reference_processor(
    cost_model: CostModel, reference_profile: str, *, epochs: int, steps_per_epoch: int
) -> float:
    """Paper Table 3: set tau to the reference (GPU) fleet's full round time."""
    return cost_model.tau_for_profile(
        reference_profile, epochs=epochs, steps_per_epoch=steps_per_epoch
    )

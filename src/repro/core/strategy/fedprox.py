"""FedProx (Li et al., 2018): proximal client objective + partial work.

The paper cites FedProx as the algorithmic relative of its tau-cutoff
mechanism ("accepts partial results from clients").  Client loss gains
mu/2 * ||w - w_global||^2; aggregation is FedAvg over whatever (possibly
partial) updates arrive.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.utils.pytree import tree_sq_norm, tree_sub

from .base import Strategy, weighted_mean


@dataclass
class FedProx(Strategy):
    name: str = "fedprox"
    local_epochs: int = 1
    local_lr: float = 0.05
    mu: float = 0.01

    def fit_config(self, rnd: int, client_id: int) -> dict:
        return {"epochs": self.local_epochs, "lr": self.local_lr, "mu": self.mu}

    def client_loss_extra(self, params, global_params):
        return 0.5 * self.mu * tree_sq_norm(tree_sub(params, global_params))

    def aggregate(self, client_params, weights, global_params, server_state, rnd):
        return weighted_mean(client_params, weights), server_state

"""The FL loop — Flower's server architecture (paper §3, Figure 1).

``Server`` orchestrates rounds and delegates all decisions to the Strategy;
the CostModel plays the role of the physical fleet, charging wall-time and
energy for every client's compute and communication.  History captures the
paper's evaluation axes: accuracy / convergence time / energy per round.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.utils.logging import MetricsLogger
from repro.utils.pytree import tree_bytes, tree_size

from .client import Client
from .cost_model import CostModel
from .protocol import CompressedParameters, EvaluateIns, FitIns, Parameters
from .strategy.base import Strategy

PyTree = Any


@dataclass
class RoundRecord:
    rnd: int
    train_loss: float
    eval_loss: float | None
    eval_acc: float | None
    wall_time_s: float       # simulated fleet wall-clock for the round
    energy_j: float          # simulated fleet energy
    comm_bytes: int
    steps: int


@dataclass
class History:
    rounds: list[RoundRecord] = field(default_factory=list)

    def add(self, rec: RoundRecord) -> None:
        self.rounds.append(rec)

    @property
    def total_time_s(self) -> float:
        return sum(r.wall_time_s for r in self.rounds)

    @property
    def total_energy_j(self) -> float:
        return sum(r.energy_j for r in self.rounds)

    def final_accuracy(self) -> float | None:
        for r in reversed(self.rounds):
            if r.eval_acc is not None:
                return r.eval_acc
        return None

    def accuracy_series(self) -> list[tuple[int, float]]:
        return [(r.rnd, r.eval_acc) for r in self.rounds if r.eval_acc is not None]

    def time_to_accuracy(self, target: float) -> float | None:
        """Simulated convergence time (paper: 'Convergence Time (mins)')."""
        t = 0.0
        for r in self.rounds:
            t += r.wall_time_s
            if r.eval_acc is not None and r.eval_acc >= target:
                return t
        return None


@dataclass
class Server:
    strategy: Strategy
    clients: list[Client]
    cost_model: CostModel | None = None
    eval_fn: Callable | None = None      # (params) -> dict (centralized eval)
    eval_every: int = 1
    codec: Any = None                    # UpdateCodec: uplink charged at
                                         # codec.wire_bytes, not tree_bytes
    logger: MetricsLogger = field(default_factory=lambda: MetricsLogger("server"))

    def run(self, global_params: PyTree, num_rounds: int) -> tuple[PyTree, History]:
        history = History()
        client_ids = list(range(len(self.clients)))
        client_props = {cid: self.clients[cid].properties() for cid in client_ids}
        for c in self.clients:  # fresh trajectory: no residual carry-over
            c.reset_state()
        # fresh server trajectory too: FedOpt moments must not leak from a
        # previous run, but DO accumulate across this run's rounds
        self.strategy.reset_server_state()

        for rnd in range(1, num_rounds + 1):
            fit_ins = self.strategy.configure_fit(
                rnd, global_params, client_ids, client_properties=client_props
            )

            results, steps_per_client = [], []
            for cid, ins in fit_ins:
                res = self.clients[cid].fit(ins)
                results.append((cid, res))
                steps_per_client.append(int(res.metrics.get("steps_done", 1)))

            # per-client uplink charge: the actual wire payload each client
            # shipped (heterogeneous codecs => heterogeneous sizes), BEFORE
            # the aggregate moves global_params past this round's baseline
            uplink = (
                self._uplink_bytes(results, global_params)
                if self.cost_model is not None else None
            )

            global_params = self.strategy.aggregate_fit(rnd, results, global_params)

            # ---- system-cost accounting (the paper's §5 measurement) ----
            # uplink is charged at each client's wire size (compressed-wire
            # path); the downlink stays the full-precision global model.
            wall, energy, comm = 0.0, 0.0, 0
            if self.cost_model is not None:
                costs = self.cost_model.round_costs(
                    steps_per_client, uplink_bytes=uplink
                )
                wall = self.cost_model.round_wall_time(costs)
                energy = self.cost_model.round_energy(costs)
                comm = self.cost_model.round_comm_bytes(
                    len(results), uplink_bytes=uplink
                )

            losses = [r.metrics.get("loss", 0.0) for _, r in results]
            ns = [r.num_examples for _, r in results]
            # all-zero example counts (empty shards / failed reads) must not
            # crash np.average with a ZeroDivisionError: unweighted fallback
            train_loss = float(
                np.average(losses, weights=ns) if sum(ns) > 0 else np.mean(losses)
            )

            eval_loss = eval_acc = None
            if rnd % self.eval_every == 0:
                eval_loss, eval_acc = self._evaluate(global_params)

            rec = RoundRecord(
                rnd=rnd, train_loss=train_loss, eval_loss=eval_loss,
                eval_acc=eval_acc, wall_time_s=wall, energy_j=energy,
                comm_bytes=comm, steps=sum(steps_per_client),
            )
            history.add(rec)
            self.logger.log(
                "round", rnd=rnd, loss=train_loss,
                acc=-1.0 if eval_acc is None else eval_acc,
                wall_s=wall, energy_kj=energy / 1e3,
            )
        return global_params, history

    def _uplink_bytes(self, results, global_params) -> list[int] | None:
        """Per-client uplink sizes for cost accounting.

        Wire-format payloads (Parameters/CompressedParameters) are charged
        at their actual serialized size; raw-pytree payloads fall back to
        the server-level codec's wire size, or None (the cost model's
        full-precision default) when no codec is configured anywhere.
        """
        if not results:
            return None
        any_wire = any(
            isinstance(r.parameters, (Parameters, CompressedParameters))
            for _, r in results
        )
        if not any_wire and self.codec is None:
            return None
        n = tree_size(global_params)
        # one per-client charge table for the whole round (MixedCodec builds
        # a per-client list; the helper also validates it against the fleet)
        fallback = CostModel.fleet_uplink_bytes(self.codec, n, len(self.clients))
        out = []
        for cid, res in results:
            p = res.parameters
            if isinstance(p, (Parameters, CompressedParameters)):
                out.append(p.num_bytes)
            elif fallback is not None:
                out.append(fallback[cid])
            else:
                out.append(tree_bytes(global_params))
        return out

    def _evaluate(self, global_params) -> tuple[float | None, float | None]:
        if self.eval_fn is not None:
            m = self.eval_fn(global_params)
            return m.get("loss"), m.get("acc")
        # federated evaluation: average client-side evaluate()
        losses, accs, ns = [], [], []
        for c in self.clients:
            res = c.evaluate(EvaluateIns(parameters=global_params))
            losses.append(res.loss)
            accs.append(res.metrics.get("acc", np.nan))
            ns.append(res.num_examples)
        w = np.asarray(ns, np.float64)
        return float(np.average(losses, weights=w)), float(np.average(accs, weights=w))


def make_cost_model_for(params: PyTree, profiles: list, **kw) -> CostModel:
    return CostModel(profiles=profiles, update_bytes=tree_bytes(params), **kw)

"""The FL loop — Flower's server architecture (paper §3, Figure 1).

``Server`` orchestrates rounds and delegates all decisions to the Strategy;
the CostModel plays the role of the physical fleet, charging wall-time and
energy for every client's compute and communication.  History captures the
paper's evaluation axes: accuracy / convergence time / energy per round.

``Server.run`` is a thin driver over the **virtual-clock scheduler**
(core/scheduler.py): every dispatched client becomes an ``Arrival`` event
on a simulated timeline, and the configured ``RoundPolicy`` — lockstep
``SyncAll`` (the default, reproducing the classic synchronous loop),
``Deadline(tau)`` straggler cutoffs, or ``BufferedAsync`` staleness-tolerant
aggregation — decides which arrivals each round consumes.  Wall time is the
clock's elapsed virtual time, idle burn comes from the actual wait
intervals the policy induced, and ``History`` records who participated and
how stale their updates were.  An ``AvailabilityTrace`` adds seeded
dropout/late-join churn and step-time jitter on top.

**Population mode** (``population`` + ``cohort_size`` set): the same loop
at fleet scale.  Nothing per-round is O(N): the cohort is sampled id-first
from the packed ``Population`` (``Strategy.sample_cohort``), availability
and jitter are *streamed* over just those ids, client objects come from a
``LazyClientPool`` that materializes on demand, properties/eval touch only
the round's cohort, and the uplink fallback is one scalar (``MixedCodec``
is rejected — its static client-slot assignment cannot follow a resampled
cohort).  With N == cohort_size, no churn, and the same strategy seed, the
population round is bitwise the legacy round (pinned in
tests/test_population.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.utils.logging import MetricsLogger
from repro.utils.pytree import tree_add, tree_bytes, tree_size, tree_sub

from .cost_model import AvailabilityTrace, CostModel
from .protocol import (
    CompressedParameters, EvaluateIns, Parameters, parameters_to_pytree,
)
from .scheduler import Arrival, Deadline, RoundPolicy, SyncAll, VirtualClock
from .strategy.base import Strategy

PyTree = Any


@dataclass
class RoundRecord:
    rnd: int
    train_loss: float
    eval_loss: float | None
    eval_acc: float | None
    wall_time_s: float       # simulated fleet wall-clock for the round
    energy_j: float          # simulated fleet energy
    comm_bytes: int
    steps: int
    # virtual-clock participation record: how many updates this round's
    # aggregation consumed, how many arrivals it discarded (deadline drops
    # + staleness expiries), and the mean staleness of what it kept
    participants: int = 0
    dropped: int = 0
    staleness_mean: float = 0.0


@dataclass
class History:
    rounds: list[RoundRecord] = field(default_factory=list)

    def add(self, rec: RoundRecord) -> None:
        self.rounds.append(rec)

    @property
    def total_time_s(self) -> float:
        return sum(r.wall_time_s for r in self.rounds)

    @property
    def total_energy_j(self) -> float:
        return sum(r.energy_j for r in self.rounds)

    def final_accuracy(self) -> float | None:
        for r in reversed(self.rounds):
            if r.eval_acc is not None:
                return r.eval_acc
        return None

    def accuracy_series(self) -> list[tuple[int, float]]:
        return [(r.rnd, r.eval_acc) for r in self.rounds if r.eval_acc is not None]

    def time_to_accuracy(self, target: float) -> float | None:
        """Simulated convergence time (paper: 'Convergence Time (mins)')."""
        t = 0.0
        for r in self.rounds:
            t += r.wall_time_s
            if r.eval_acc is not None and r.eval_acc >= target:
                return t
        return None


class _UniformUplink:
    """O(1) stand-in for the per-client uplink-fallback list in population
    mode: every client of a non-mixed codec ships the same wire size, so
    indexing by any client id answers the one scalar."""

    def __init__(self, nbytes: int):
        self.nbytes = int(nbytes)

    def __getitem__(self, client_id: int) -> int:
        return self.nbytes


@dataclass
class Server:
    strategy: Strategy
    clients: Any                         # list[Client] | population.LazyClientPool
    cost_model: CostModel | None = None
    eval_fn: Callable | None = None      # (params) -> dict (centralized eval)
    eval_every: int = 1
    codec: Any = None                    # UpdateCodec: uplink charged at
                                         # codec.wire_bytes, not tree_bytes
    policy: RoundPolicy | None = None    # None -> SyncAll (lockstep FedAvg)
    availability: AvailabilityTrace | None = None
    # population mode: a packed Population plus an explicit per-round cohort
    # size; `clients` is then typically a LazyClientPool over the same ids
    population: Any = None
    cohort_size: int | None = None
    logger: MetricsLogger = field(default_factory=lambda: MetricsLogger("server"))
    # compiled-program memo for run_scanned: without it every call builds a
    # fresh closure and jax.jit re-traces/re-compiles the WHOLE R-round
    # program (sweeps and benchmarks pay full compile per run)
    _scan_fns: dict = field(default_factory=dict, repr=False, compare=False)

    def run(self, global_params: PyTree, num_rounds: int) -> tuple[PyTree, History]:
        policy = self.policy if self.policy is not None else SyncAll()
        clock = VirtualClock()
        history = History()
        pop = self.population
        if pop is not None:
            # population mode: nothing O(N) per run or per round — no id
            # list, no all-client properties dict, no all-client reset loop
            if not self.cohort_size:
                raise ValueError("population mode needs an explicit cohort_size")
            from .compression import MixedCodec

            if isinstance(self.codec, MixedCodec):
                raise TypeError(
                    "MixedCodec binds codecs to static client slots; a "
                    "population cohort is resampled every round — use "
                    "BandwidthCodecPolicy for per-device codec choice"
                )
            client_ids = None
            reset_all = getattr(self.clients, "reset_state", None)
            if callable(reset_all):  # LazyClientPool: one call, not N
                reset_all()
            else:
                for c in self.clients:
                    c.reset_state()
        else:
            client_ids = list(range(len(self.clients)))
            client_props = {
                cid: self.clients[cid].properties() for cid in client_ids
            }
            for c in self.clients:  # fresh trajectory: no residual carry-over
                c.reset_state()
        # fresh server trajectory too: FedOpt moments must not leak from a
        # previous run, but DO accumulate across this run's rounds
        self.strategy.reset_server_state()

        # per-client uplink fallback for raw-pytree payloads under a
        # server-level codec (static across the run: the model shape is);
        # population mode charges one scalar — a non-mixed codec ships the
        # same wire size from every client, and an O(N) list would defeat
        # the packed representation
        if self.cost_model is None:
            uplink_fallback = None
        elif pop is not None:
            uplink_fallback = (
                None if self.codec is None else _UniformUplink(
                    self.codec.wire_bytes(tree_size(global_params))
                )
            )
        else:
            uplink_fallback = CostModel.fleet_uplink_bytes(
                self.codec, tree_size(global_params), len(self.clients)
            )

        # the cutoff rides in FitIns config ONLY when a Deadline policy will
        # actually enforce it: clients that know their own step time + links
        # then truncate local work to make the cutoff instead of being
        # dropped.  Under SyncAll nothing is ever dropped, so shipping a
        # deadline there would silently shrink step budgets (diverging from
        # the paper's compute-only tau semantics) for zero scheduling gain.
        deadline_cfg = None
        if isinstance(policy, Deadline):
            tau = policy.resolve_tau(self.strategy)
            deadline_cfg = tau if np.isfinite(tau) else None

        pending: list[Arrival] = []  # in-flight arrivals (BufferedAsync carry)
        for rnd in range(1, num_rounds + 1):
            # ---- dispatch: sampled ∩ available ∩ not already in flight ----
            busy = {a.client_id for a in pending}
            if pop is not None:
                # cohort first, availability streamed over candidates only
                # (inside sample_cohort) — then per-cohort properties and
                # per-dispatch streamed jitter: all O(cohort), never O(N)
                eligible = self.strategy.sample_cohort(
                    rnd, pop, self.cohort_size, exclude=busy,
                    availability=self.availability,
                    cost_model=self.cost_model, deadline_s=deadline_cfg,
                )
                # heavy churn can leave the bounded redraw short — or empty.
                # A short/empty cohort follows the legacy empty-round path
                # below: zero dispatches, the policy still advances the
                # clock, nothing aggregates, the round records participants=0
                # with NaN train_loss (pinned by tests/test_population.py
                # ::test_forced_churn_short_and_empty_cohorts)
                client_props = {
                    cid: self.clients[cid].properties() for cid in eligible
                }
                jitter = None
            else:
                # one trace draw per round (it is a deterministic function
                # of (seed, rnd)), not one full-fleet draw per client
                up = (
                    self.availability.available(rnd)
                    if self.availability is not None else None
                )
                eligible = [
                    cid for cid in client_ids
                    if cid not in busy and (up is None or up[cid])
                ]
                jitter = (
                    self.availability.step_jitter(rnd)
                    if self.availability is not None else None
                )
            fit_ins = self.strategy.configure_fit(
                rnd, global_params, eligible, client_properties=client_props
            ) if eligible else []
            jitter_by_cid = {}
            if pop is not None and self.availability is not None and fit_ins:
                cids = [cid for cid, _ in fit_ins]
                jitter_by_cid = dict(zip(
                    cids, self.availability.step_jitter_for(rnd, cids).tolist()
                ))

            launch_steps = 0
            for cid, ins in fit_ins:
                if deadline_cfg is not None:
                    ins.config.setdefault("deadline_s", deadline_cfg)
                res = self.clients[cid].fit(ins)
                steps = int(res.metrics.get("steps_done", 1))
                launch_steps += steps
                cost = None
                up_bytes = self._uplink_bytes_one(res, cid, uplink_fallback)
                if self.cost_model is not None:
                    if jitter is not None:
                        jit_c = float(jitter[cid])
                    else:
                        jit_c = float(jitter_by_cid.get(cid, 1.0))
                    cost = self.cost_model.client_round_cost(
                        cid, steps, uplink_bytes=up_bytes, jitter=jit_c,
                    )
                    # the cost record owns the arrival time; the scheduler
                    # event (Arrival.finish_t) is derived from it below
                    cost.t_arrival_s = clock.now + cost.t_total_s
                # keep the launch global only when a stale rebase could need
                # it: compressed payloads are deltas (global-independent), so
                # pinning a full model snapshot per in-flight arrival would
                # be O(pending x model) of provably dead memory
                launch_ref = (
                    None if isinstance(res.parameters, CompressedParameters)
                    else global_params
                )
                pending.append(Arrival(
                    client_id=cid, launch_rnd=rnd, launch_t=clock.now,
                    finish_t=cost.t_arrival_s if cost is not None else clock.now,
                    cost=cost, payload=(res, launch_ref), uplink_bytes=up_bytes,
                ))

            # ---- the policy's verdict on everything in flight ----
            outcome = policy.plan(clock, pending, rnd, strategy=self.strategy)
            pending = list(outcome.carried)
            clock.advance_to(outcome.round_end)

            # a discarded update never reached the aggregate: the client must
            # roll back any state (error-feedback residual) that its fit()
            # committed assuming delivery — the python-path twin of the
            # jitted mask's carry-residual-unchanged contract
            for a in (*outcome.dropped, *outcome.expired):
                self.clients[a.client_id].discard_update()

            results = []
            for a in outcome.reported:
                res, launch_global = a.payload
                res.staleness = a.staleness_at(rnd)
                if res.staleness > 0:
                    self._rebase_stale(res, launch_global, global_params)
                results.append((a.client_id, res))

            if results:  # an empty round advances the clock, aggregates nothing
                global_params = self.strategy.aggregate_fit(
                    rnd, results, global_params
                )

            # ---- system-cost accounting (the paper's §5 measurement) ----
            # wall time is the clock's elapsed virtual time for this round;
            # idle burn charges the actual wait each reporter endured; a
            # deadline-dropped client charges its (wasted) compute up to the
            # cutoff; uplink is charged at each reporter's wire size while
            # the downlink stays the full-precision global per dispatch.
            wall, energy, comm = outcome.wall_time_s, 0.0, 0
            if self.cost_model is not None:
                down = self.cost_model.update_bytes
                energy = self._outcome_energy(outcome)
                # expired arrivals that LANDED did cross the network (they
                # arrived, then aged out) — their bytes count like their
                # comm energy does; cancelled-in-flight expiries and
                # deadline-dropped clients never completed an uplink
                comm = down * len(fit_ins) + sum(
                    down if a.uplink_bytes is None else a.uplink_bytes
                    for a in (*outcome.reported, *outcome.expired)
                    if a.finish_t <= outcome.round_end
                )

            losses = [r.metrics.get("loss", 0.0) for _, r in results]
            ns = [r.num_examples for _, r in results]
            # all-zero example counts (empty shards / failed reads) must not
            # crash np.average with a ZeroDivisionError: unweighted fallback;
            # an empty round has no losses at all -> NaN, not a crash
            if not losses:
                train_loss = float("nan")
            else:
                train_loss = float(
                    np.average(losses, weights=ns) if sum(ns) > 0 else np.mean(losses)
                )

            eval_loss = eval_acc = None
            if rnd % self.eval_every == 0:
                # population mode restricts eval_fn-less federated eval to
                # the round's cohort: evaluating N clients would be the
                # O(N) loop this mode exists to avoid
                eval_loss, eval_acc = self._evaluate(
                    global_params,
                    eval_ids=eligible if pop is not None else None,
                )

            rec = RoundRecord(
                rnd=rnd, train_loss=train_loss, eval_loss=eval_loss,
                eval_acc=eval_acc, wall_time_s=wall, energy_j=energy,
                comm_bytes=comm, steps=launch_steps,
                participants=len(results),
                dropped=len(outcome.dropped) + len(outcome.expired),
                staleness_mean=outcome.mean_staleness,
            )
            history.add(rec)
            self.logger.log(
                "round", rnd=rnd, loss=train_loss,
                acc=-1.0 if eval_acc is None else eval_acc,
                wall_s=wall, energy_kj=energy / 1e3,
                clients=len(results), stale=outcome.mean_staleness,
            )

        # arrivals still in flight when the run ends are abandoned: their
        # clients roll back (the update never landed), and the wasted work
        # is charged to the final round — otherwise BufferedAsync's cost
        # totals would silently omit exactly its stragglers' burn
        self._abandon_pending(pending, clock, history)
        return global_params, history

    # ---- rounds-as-scan driver (PR 8) ----

    def run_scanned(
        self,
        global_params: PyTree,
        num_rounds: int,
        *,
        loss_fn: Callable,
        opt,
        spec,
        batches,
        weights=None,
        step_budgets=None,
        stacked_batches: bool = True,
        trainable_mask: PyTree | None = None,
        reference: bool = False,
        donate: bool = True,
    ) -> tuple[PyTree, History, dict]:
        """Run ``num_rounds`` rounds as ONE compiled ``lax.scan`` over the
        jitted engine (``rounds.make_multi_round_step``) instead of
        re-entering python every round.

        The whole run's schedule — availability churn, step jitter, cohort
        priorities, per-client finish times — is precomputed host-side as
        (R, C) matrices from the same seeded draws ``Server.run`` makes,
        then the scan computes each round's dispatch mask, the policy's
        pure-array verdict, and the round step on device; per-round
        metrics stack on device and decode to a ``History`` once at the
        end.  Cost accounting (energy/comm/steps) replays the CostModel's
        arithmetic over the returned masks post-hoc, so nothing syncs
        mid-run.  Differences from ``run``, by construction: evaluation
        happens once, on the final global (``eval_fn`` only — a per-round
        eval would reintroduce the per-round host sync this driver
        removes), ``train_loss`` is the engine's weights-weighted
        ``client_loss_mean``, and deadline stragglers are dropped rather
        than offered a truncated step budget.

        ``reference=True`` runs the SAME schedule, verdict helpers, and
        jitted ``round_step`` through a per-round python loop with a host
        sync each round — the bitwise-parity reference (and the rounds/sec
        baseline ``benchmarks/scan_bench.py`` measures against).

        ``batches`` leaves are (R, C, max_steps, ...) when
        ``stacked_batches``, else (C, max_steps, ...) reused every round
        (closed over as a scan constant — device memory stays flat in R).
        With ``donate`` the carry buffers (global/server/client state) are
        donated to the compiled program; inputs are copied first so the
        caller's arrays stay valid.

        Returns ``(final_global, history, stacked)`` where ``stacked`` is
        the numpy dict of per-round device outputs (metrics plus
        ``participation_mask``/``dispatch_mask``/``round_wall_s``/
        ``participants``/``dispatched``).
        """
        import jax
        import jax.numpy as jnp

        from repro.utils.pytree import tree_size as _tree_size

        from .rounds import (
            cohort_dispatch_mask, make_multi_round_step, make_round_step,
        )

        if self.population is not None:
            raise NotImplementedError(
                "run_scanned needs a static client axis; population-mode "
                "cohort gather/scatter is host-side — use Server.run"
            )
        policy = self.policy if self.policy is not None else SyncAll()
        tau = (
            policy.resolve_tau(self.strategy)
            if isinstance(policy, Deadline) else None
        )

        R = int(num_rounds)
        leaf = jax.tree.leaves(batches)[0]
        C = int(leaf.shape[1] if stacked_batches else leaf.shape[0])
        if stacked_batches and int(leaf.shape[0]) != R:
            raise ValueError(
                f"stacked batches carry {int(leaf.shape[0])} rounds, "
                f"run asked for {R}"
            )
        w = (
            jnp.ones((C,), jnp.float32) if weights is None
            else jnp.asarray(weights)
        )
        bud = (
            jnp.full((C,), spec.max_steps, jnp.int32) if step_budgets is None
            else jnp.asarray(step_budgets, jnp.int32)
        )
        n_params = _tree_size(global_params)
        sched = self._scan_schedule(spec, R, C, np.asarray(bud), n_params)
        avail = jnp.asarray(sched["avail"])
        t_verdict = jnp.asarray(sched["t_verdict"])
        pri = jnp.asarray(sched["pri"])

        self.strategy.reset_server_state()
        server_state = self.strategy.init_state(global_params)
        client_state = spec.codec.init_client_state(C, n_params)

        # memoize the jitted program: closures are fresh objects, so
        # without this every call re-traces AND re-compiles the whole
        # R-round scan (id()s are kept alive by the value tuple)
        key = (
            "ref" if reference else "scan", R, C, stacked_batches, donate,
            repr(spec), repr(policy), tau, self.cohort_size,
            id(loss_fn), id(opt), id(trainable_mask),
        )
        cached = self._scan_fns.get(key)

        if not reference:
            if cached is None:
                multi = make_multi_round_step(
                    loss_fn, opt, self.strategy, spec, R, policy=policy,
                    tau=tau, cohort_size=self.cohort_size,
                    trainable_mask=trainable_mask,
                    stacked_batches=stacked_batches,
                )
                fn = (
                    jax.jit(multi, donate_argnums=(0, 1, 2)) if donate
                    else jax.jit(multi)
                )
                self._scan_fns[key] = (fn, (loss_fn, opt, trainable_mask))
            else:
                fn = cached[0]
            if donate:
                # donated buffers alias in-place across the scan carry —
                # copy first so the CALLER's arrays stay valid
                global_params = jax.tree.map(jnp.array, global_params)
            g, _, _, stacked = fn(
                global_params, server_state, client_state, batches, w, bud,
                avail, t_verdict, pri,
            )
            stacked = jax.device_get(stacked)
        else:
            if cached is None:
                round_step = jax.jit(make_round_step(
                    loss_fn, opt, self.strategy, spec, trainable_mask
                ))
                self._scan_fns[key] = (
                    round_step, (loss_fn, opt, trainable_mask)
                )
            else:
                round_step = cached[0]
            g, ss, cs = global_params, server_state, client_state
            rows = []
            for r in range(R):
                if self.cohort_size is None:
                    dispatch_mask = avail[r]
                else:
                    dispatch_mask = cohort_dispatch_mask(
                        pri[r], avail[r], self.cohort_size
                    )
                mask, round_end = policy.plan_arrays(
                    dispatch_mask, t_verdict[r], tau=tau
                )
                batch_r = (
                    jax.tree.map(lambda x: x[r], batches)
                    if stacked_batches else batches
                )
                g, ss, cs, met = round_step(
                    g, ss, cs, batch_r, w, bud, jnp.int32(r + 1), mask
                )
                # the python driver's defining cost: one host round-trip
                # per round (Server.run pulls metrics exactly like this)
                rows.append(jax.device_get({
                    **met,
                    "participation_mask": mask,
                    "dispatch_mask": dispatch_mask,
                    "round_wall_s": round_end,
                    "participants": jnp.sum(jnp.where(mask > 0, 1.0, 0.0)),
                    "dispatched": jnp.sum(
                        jnp.where(dispatch_mask > 0, 1.0, 0.0)
                    ),
                }))
            stacked = {
                k: np.stack([row[k] for row in rows]) for k in rows[0]
            }

        eval_final = (
            self._evaluate(g) if self.eval_fn is not None else None
        )
        history = self._decode_scan_history(
            stacked, sched, np.asarray(bud), eval_final
        )
        self.logger.log(
            "scanned", rounds=R, driver="python" if reference else "scan",
            loss=history.rounds[-1].train_loss if history.rounds else -1.0,
            wall_s=history.total_time_s,
        )
        return g, history, stacked

    def _scan_schedule(
        self, spec, R: int, C: int, budgets: np.ndarray, n_params: int
    ) -> dict:
        """Host-side precompute of the whole run's (R, C) schedule.

        Rows reuse the exact per-round seeded draws ``run`` makes
        (``available``/``step_jitter`` stacked), plus stream-4 cohort
        priorities; finish times come from ``CostModel.fleet_time_matrix``
        (same arithmetic as ``client_round_cost``).  ``t_verdict`` is the
        float32 copy both drivers schedule against — the verdict must be
        computed at ONE precision or scanned/reference could disagree on
        a client landing exactly at tau.
        """
        rounds = range(1, R + 1)
        trace = self.availability
        if trace is None:
            avail = np.ones((R, C), np.float32)
            jitter = np.ones((R, C), np.float64)
        else:
            avail = trace.available_matrix(rounds)
            jitter = trace.step_jitter_matrix(rounds)
        if self.cohort_size is not None:
            pri_trace = trace if trace is not None else AvailabilityTrace.full(C)
            pri = pri_trace.cohort_priority_matrix(rounds)
        else:
            pri = np.zeros((R, C), np.float32)
        out = {"avail": avail, "pri": pri, "cols": None, "t_compute": None}
        if self.cost_model is None:
            out["t_verdict"] = np.zeros((R, C), np.float32)
            return out
        up = CostModel.fleet_uplink_bytes(spec.codec, n_params, C)
        cols = self.cost_model.fleet_columns(C, uplink_bytes=up)
        t_compute = (
            (np.asarray(budgets, np.float64) * cols["step_time_s"])[None, :]
            * jitter
        )
        out["cols"] = cols
        out["t_compute"] = t_compute
        out["t_verdict"] = np.asarray(
            t_compute + cols["t_comm_s"][None, :], np.float32
        )
        return out

    def _decode_scan_history(
        self, stacked: dict, sched: dict, budgets: np.ndarray, eval_final
    ) -> History:
        """Stacked device outputs -> History, once, after the run.

        Energy replays ``_outcome_energy``'s rules vectorized: reporters
        charge full compute+comm plus idle burn until round end; deadline-
        dropped dispatches charge ``wasted_energy``'s phase split
        (downlink radio, then compute, then uplink radio) within the round
        window; comm charges the downlink per dispatch and the codec wire
        uplink per reporter.
        """
        R, C = stacked["participation_mask"].shape
        cm = self.cost_model
        cols = sched["cols"]
        history = History()
        for r in range(R):
            reported = stacked["participation_mask"][r] > 0
            dispatched = stacked["dispatch_mask"][r] > 0
            wall = float(stacked["round_wall_s"][r])
            energy, comm = 0.0, 0
            if cm is not None:
                t_compute = sched["t_compute"][r]
                t_total = t_compute + cols["t_comm_s"]
                e_total = (
                    t_compute * cols["active_power_w"]
                    + cols["t_comm_s"] * cm.comm_power_w
                )
                idle = (
                    np.clip(wall - t_total, 0.0, None) * cols["idle_power_w"]
                )
                t_down = cols["t_down_s"]
                wasted = np.where(
                    wall >= t_total,
                    e_total,
                    np.minimum(wall, t_down) * cm.comm_power_w
                    + np.clip(wall - t_down, 0.0, t_compute)
                    * cols["active_power_w"]
                    + np.clip(wall - t_down - t_compute, 0.0, None)
                    * cm.comm_power_w,
                )
                per_client = np.where(reported, e_total + idle, wasted)
                energy = float(np.sum(per_client[dispatched]))
                comm = int(
                    cm.update_bytes * int(dispatched.sum())
                    + np.sum(cols["up_bytes"][reported])
                )
            eval_loss = eval_acc = None
            if r == R - 1 and eval_final is not None:
                eval_loss, eval_acc = eval_final
            history.add(RoundRecord(
                rnd=r + 1,
                train_loss=float(stacked["client_loss_mean"][r]),
                eval_loss=eval_loss, eval_acc=eval_acc, wall_time_s=wall,
                energy_j=energy, comm_bytes=comm,
                steps=int(np.sum(budgets[dispatched])),
                participants=int(reported.sum()),
                dropped=int(dispatched.sum() - reported.sum()),
            ))
        return history

    def _abandon_pending(self, pending, clock, history) -> None:
        for a in pending:
            self.clients[a.client_id].discard_update()
        if not pending or not history.rounds or self.cost_model is None:
            return
        rec = history.rounds[-1]
        down = self.cost_model.update_bytes
        for a in pending:
            if a.cost is None:
                continue
            # downlink-then-compute burn for the window that fit before the
            # experiment ended; uplink bytes only if the upload finished
            # (the downlink bytes were already counted at dispatch time)
            rec.energy_j += self._wasted_energy(a, clock.now)
            if a.finish_t <= clock.now:
                rec.comm_bytes += (
                    down if a.uplink_bytes is None else a.uplink_bytes
                )

    @staticmethod
    def _uplink_bytes_one(res, cid: int, fallback) -> int | None:
        """One client's uplink charge: the actual serialized wire size for
        wire-format payloads, the server-level codec's size for raw pytrees
        under a codec (a per-client list, or ``_UniformUplink`` in
        population mode), else None (the full-precision default)."""
        p = res.parameters
        if isinstance(p, (Parameters, CompressedParameters)):
            return p.num_bytes
        return None if fallback is None else fallback[cid]

    def _outcome_energy(self, outcome) -> float:
        """Fleet energy for one scheduled round.

        Reporters charge their full compute+comm plus idle burn for the
        wait between their arrival and the round end; deadline-dropped
        clients charge what they actually burned before the cutoff (the
        downlink happens FIRST on the arrival timeline, then compute —
        radio power for the downlink window, active power for whatever
        compute fit after it) and never uplink; staleness-expired arrivals
        completed their (wasted) work in full.  Each arrival is charged in
        the round that resolves it.
        """
        e = 0.0
        for a in outcome.reported:
            p = self._profile(a.client_id)
            e += a.cost.e_total_j
            e += max(0.0, outcome.round_end - a.finish_t) * p.idle_power_w
        for a in outcome.dropped:
            e += self._wasted_energy(a, outcome.round_end)
        for a in outcome.expired:
            # landed expiries burned their full cost; one still in flight
            # was cancelled at round end — only the window's burn happened
            e += self._wasted_energy(a, outcome.round_end)
        return e

    def _profile(self, cid: int):
        return self.cost_model.profile_for(cid)

    def _wasted_energy(self, a: Arrival, until: float) -> float:
        """Burn of an abandoned arrival inside its [launch_t, until) window
        (the CostModel owns the phase-split arithmetic)."""
        return self.cost_model.wasted_energy(
            a.cost, max(0.0, until - a.launch_t)
        )

    @staticmethod
    def _rebase_stale(res, launch_global: PyTree, global_params: PyTree) -> None:
        """Apply a stale update's *delta* to the current global.

        ``CompressedParameters`` already IS a delta wire (decoded against
        whatever global the aggregation holds), so it needs no rebase; raw
        parameter payloads trained from an older global are rewritten as
        ``current + (params - launch_global)`` — FedBuff's update rule.
        """
        p = res.parameters
        if isinstance(p, CompressedParameters):
            return
        if isinstance(p, Parameters):
            p = parameters_to_pytree(p, launch_global)
        res.parameters = tree_add(global_params, tree_sub(p, launch_global))

    def _evaluate(
        self, global_params, eval_ids=None
    ) -> tuple[float | None, float | None]:
        if self.eval_fn is not None:
            m = self.eval_fn(global_params)
            return m.get("loss"), m.get("acc")
        # federated evaluation: average client-side evaluate() — over the
        # whole fleet (legacy), or over `eval_ids` (population mode hands
        # the round's cohort; an empty cohort evaluates nothing)
        ids = range(len(self.clients)) if eval_ids is None else eval_ids
        losses, accs, ns = [], [], []
        for cid in ids:
            res = self.clients[cid].evaluate(
                EvaluateIns(parameters=global_params)
            )
            losses.append(res.loss)
            accs.append(res.metrics.get("acc", np.nan))
            ns.append(res.num_examples)
        if not losses:
            return None, None
        w = np.asarray(ns, np.float64)
        return float(np.average(losses, weights=w)), float(np.average(accs, weights=w))


def make_cost_model_for(params: PyTree, profiles: list, **kw) -> CostModel:
    return CostModel(profiles=profiles, update_bytes=tree_bytes(params), **kw)

"""The FL loop — Flower's server architecture (paper §3, Figure 1).

``Server`` orchestrates rounds and delegates all decisions to the Strategy;
the CostModel plays the role of the physical fleet, charging wall-time and
energy for every client's compute and communication.  History captures the
paper's evaluation axes: accuracy / convergence time / energy per round.

``Server.run`` is a thin driver over the **virtual-clock scheduler**
(core/scheduler.py): every dispatched client becomes an ``Arrival`` event
on a simulated timeline, and the configured ``RoundPolicy`` — lockstep
``SyncAll`` (the default, reproducing the classic synchronous loop),
``Deadline(tau)`` straggler cutoffs, or ``BufferedAsync`` staleness-tolerant
aggregation — decides which arrivals each round consumes.  Wall time is the
clock's elapsed virtual time, idle burn comes from the actual wait
intervals the policy induced, and ``History`` records who participated and
how stale their updates were.  An ``AvailabilityTrace`` adds seeded
dropout/late-join churn and step-time jitter on top.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.utils.logging import MetricsLogger
from repro.utils.pytree import tree_add, tree_bytes, tree_size, tree_sub

from .client import Client
from .cost_model import AvailabilityTrace, CostModel
from .protocol import (
    CompressedParameters, EvaluateIns, Parameters, parameters_to_pytree,
)
from .scheduler import Arrival, Deadline, RoundPolicy, SyncAll, VirtualClock
from .strategy.base import Strategy

PyTree = Any


@dataclass
class RoundRecord:
    rnd: int
    train_loss: float
    eval_loss: float | None
    eval_acc: float | None
    wall_time_s: float       # simulated fleet wall-clock for the round
    energy_j: float          # simulated fleet energy
    comm_bytes: int
    steps: int
    # virtual-clock participation record: how many updates this round's
    # aggregation consumed, how many arrivals it discarded (deadline drops
    # + staleness expiries), and the mean staleness of what it kept
    participants: int = 0
    dropped: int = 0
    staleness_mean: float = 0.0


@dataclass
class History:
    rounds: list[RoundRecord] = field(default_factory=list)

    def add(self, rec: RoundRecord) -> None:
        self.rounds.append(rec)

    @property
    def total_time_s(self) -> float:
        return sum(r.wall_time_s for r in self.rounds)

    @property
    def total_energy_j(self) -> float:
        return sum(r.energy_j for r in self.rounds)

    def final_accuracy(self) -> float | None:
        for r in reversed(self.rounds):
            if r.eval_acc is not None:
                return r.eval_acc
        return None

    def accuracy_series(self) -> list[tuple[int, float]]:
        return [(r.rnd, r.eval_acc) for r in self.rounds if r.eval_acc is not None]

    def time_to_accuracy(self, target: float) -> float | None:
        """Simulated convergence time (paper: 'Convergence Time (mins)')."""
        t = 0.0
        for r in self.rounds:
            t += r.wall_time_s
            if r.eval_acc is not None and r.eval_acc >= target:
                return t
        return None


@dataclass
class Server:
    strategy: Strategy
    clients: list[Client]
    cost_model: CostModel | None = None
    eval_fn: Callable | None = None      # (params) -> dict (centralized eval)
    eval_every: int = 1
    codec: Any = None                    # UpdateCodec: uplink charged at
                                         # codec.wire_bytes, not tree_bytes
    policy: RoundPolicy | None = None    # None -> SyncAll (lockstep FedAvg)
    availability: AvailabilityTrace | None = None
    logger: MetricsLogger = field(default_factory=lambda: MetricsLogger("server"))

    def run(self, global_params: PyTree, num_rounds: int) -> tuple[PyTree, History]:
        policy = self.policy if self.policy is not None else SyncAll()
        clock = VirtualClock()
        history = History()
        client_ids = list(range(len(self.clients)))
        client_props = {cid: self.clients[cid].properties() for cid in client_ids}
        for c in self.clients:  # fresh trajectory: no residual carry-over
            c.reset_state()
        # fresh server trajectory too: FedOpt moments must not leak from a
        # previous run, but DO accumulate across this run's rounds
        self.strategy.reset_server_state()

        # per-client uplink fallback for raw-pytree payloads under a
        # server-level codec (static across the run: the model shape is)
        uplink_fallback = (
            CostModel.fleet_uplink_bytes(
                self.codec, tree_size(global_params), len(self.clients)
            )
            if self.cost_model is not None else None
        )

        # the cutoff rides in FitIns config ONLY when a Deadline policy will
        # actually enforce it: clients that know their own step time + links
        # then truncate local work to make the cutoff instead of being
        # dropped.  Under SyncAll nothing is ever dropped, so shipping a
        # deadline there would silently shrink step budgets (diverging from
        # the paper's compute-only tau semantics) for zero scheduling gain.
        deadline_cfg = None
        if isinstance(policy, Deadline):
            tau = policy.resolve_tau(self.strategy)
            deadline_cfg = tau if np.isfinite(tau) else None

        pending: list[Arrival] = []  # in-flight arrivals (BufferedAsync carry)
        for rnd in range(1, num_rounds + 1):
            # ---- dispatch: sampled ∩ available ∩ not already in flight ----
            busy = {a.client_id for a in pending}
            # one trace draw per round (it is a deterministic function of
            # (seed, rnd)), not one full-fleet draw per client
            up = (
                self.availability.available(rnd)
                if self.availability is not None else None
            )
            eligible = [
                cid for cid in client_ids
                if cid not in busy and (up is None or up[cid])
            ]
            fit_ins = self.strategy.configure_fit(
                rnd, global_params, eligible, client_properties=client_props
            ) if eligible else []
            jitter = (
                self.availability.step_jitter(rnd)
                if self.availability is not None else None
            )

            launch_steps = 0
            for cid, ins in fit_ins:
                if deadline_cfg is not None:
                    ins.config.setdefault("deadline_s", deadline_cfg)
                res = self.clients[cid].fit(ins)
                steps = int(res.metrics.get("steps_done", 1))
                launch_steps += steps
                cost = None
                up_bytes = self._uplink_bytes_one(res, cid, uplink_fallback)
                if self.cost_model is not None:
                    cost = self.cost_model.client_round_cost(
                        cid, steps, uplink_bytes=up_bytes,
                        jitter=float(jitter[cid]) if jitter is not None else 1.0,
                    )
                    # the cost record owns the arrival time; the scheduler
                    # event (Arrival.finish_t) is derived from it below
                    cost.t_arrival_s = clock.now + cost.t_total_s
                # keep the launch global only when a stale rebase could need
                # it: compressed payloads are deltas (global-independent), so
                # pinning a full model snapshot per in-flight arrival would
                # be O(pending x model) of provably dead memory
                launch_ref = (
                    None if isinstance(res.parameters, CompressedParameters)
                    else global_params
                )
                pending.append(Arrival(
                    client_id=cid, launch_rnd=rnd, launch_t=clock.now,
                    finish_t=cost.t_arrival_s if cost is not None else clock.now,
                    cost=cost, payload=(res, launch_ref), uplink_bytes=up_bytes,
                ))

            # ---- the policy's verdict on everything in flight ----
            outcome = policy.plan(clock, pending, rnd, strategy=self.strategy)
            pending = list(outcome.carried)
            clock.advance_to(outcome.round_end)

            # a discarded update never reached the aggregate: the client must
            # roll back any state (error-feedback residual) that its fit()
            # committed assuming delivery — the python-path twin of the
            # jitted mask's carry-residual-unchanged contract
            for a in (*outcome.dropped, *outcome.expired):
                self.clients[a.client_id].discard_update()

            results = []
            for a in outcome.reported:
                res, launch_global = a.payload
                res.staleness = a.staleness_at(rnd)
                if res.staleness > 0:
                    self._rebase_stale(res, launch_global, global_params)
                results.append((a.client_id, res))

            if results:  # an empty round advances the clock, aggregates nothing
                global_params = self.strategy.aggregate_fit(
                    rnd, results, global_params
                )

            # ---- system-cost accounting (the paper's §5 measurement) ----
            # wall time is the clock's elapsed virtual time for this round;
            # idle burn charges the actual wait each reporter endured; a
            # deadline-dropped client charges its (wasted) compute up to the
            # cutoff; uplink is charged at each reporter's wire size while
            # the downlink stays the full-precision global per dispatch.
            wall, energy, comm = outcome.wall_time_s, 0.0, 0
            if self.cost_model is not None:
                down = self.cost_model.update_bytes
                energy = self._outcome_energy(outcome)
                # expired arrivals that LANDED did cross the network (they
                # arrived, then aged out) — their bytes count like their
                # comm energy does; cancelled-in-flight expiries and
                # deadline-dropped clients never completed an uplink
                comm = down * len(fit_ins) + sum(
                    down if a.uplink_bytes is None else a.uplink_bytes
                    for a in (*outcome.reported, *outcome.expired)
                    if a.finish_t <= outcome.round_end
                )

            losses = [r.metrics.get("loss", 0.0) for _, r in results]
            ns = [r.num_examples for _, r in results]
            # all-zero example counts (empty shards / failed reads) must not
            # crash np.average with a ZeroDivisionError: unweighted fallback;
            # an empty round has no losses at all -> NaN, not a crash
            if not losses:
                train_loss = float("nan")
            else:
                train_loss = float(
                    np.average(losses, weights=ns) if sum(ns) > 0 else np.mean(losses)
                )

            eval_loss = eval_acc = None
            if rnd % self.eval_every == 0:
                eval_loss, eval_acc = self._evaluate(global_params)

            rec = RoundRecord(
                rnd=rnd, train_loss=train_loss, eval_loss=eval_loss,
                eval_acc=eval_acc, wall_time_s=wall, energy_j=energy,
                comm_bytes=comm, steps=launch_steps,
                participants=len(results),
                dropped=len(outcome.dropped) + len(outcome.expired),
                staleness_mean=outcome.mean_staleness,
            )
            history.add(rec)
            self.logger.log(
                "round", rnd=rnd, loss=train_loss,
                acc=-1.0 if eval_acc is None else eval_acc,
                wall_s=wall, energy_kj=energy / 1e3,
                clients=len(results), stale=outcome.mean_staleness,
            )

        # arrivals still in flight when the run ends are abandoned: their
        # clients roll back (the update never landed), and the wasted work
        # is charged to the final round — otherwise BufferedAsync's cost
        # totals would silently omit exactly its stragglers' burn
        self._abandon_pending(pending, clock, history)
        return global_params, history

    def _abandon_pending(self, pending, clock, history) -> None:
        for a in pending:
            self.clients[a.client_id].discard_update()
        if not pending or not history.rounds or self.cost_model is None:
            return
        rec = history.rounds[-1]
        down = self.cost_model.update_bytes
        for a in pending:
            if a.cost is None:
                continue
            # downlink-then-compute burn for the window that fit before the
            # experiment ended; uplink bytes only if the upload finished
            # (the downlink bytes were already counted at dispatch time)
            rec.energy_j += self._wasted_energy(a, clock.now)
            if a.finish_t <= clock.now:
                rec.comm_bytes += (
                    down if a.uplink_bytes is None else a.uplink_bytes
                )

    @staticmethod
    def _uplink_bytes_one(res, cid: int, fallback: list[int] | None) -> int | None:
        """One client's uplink charge: the actual serialized wire size for
        wire-format payloads, the server-level codec's size for raw pytrees
        under a codec, else None (the cost model's full-precision default)."""
        p = res.parameters
        if isinstance(p, (Parameters, CompressedParameters)):
            return p.num_bytes
        return None if fallback is None else fallback[cid]

    def _outcome_energy(self, outcome) -> float:
        """Fleet energy for one scheduled round.

        Reporters charge their full compute+comm plus idle burn for the
        wait between their arrival and the round end; deadline-dropped
        clients charge what they actually burned before the cutoff (the
        downlink happens FIRST on the arrival timeline, then compute —
        radio power for the downlink window, active power for whatever
        compute fit after it) and never uplink; staleness-expired arrivals
        completed their (wasted) work in full.  Each arrival is charged in
        the round that resolves it.
        """
        e = 0.0
        for a in outcome.reported:
            p = self._profile(a.client_id)
            e += a.cost.e_total_j
            e += max(0.0, outcome.round_end - a.finish_t) * p.idle_power_w
        for a in outcome.dropped:
            e += self._wasted_energy(a, outcome.round_end)
        for a in outcome.expired:
            # landed expiries burned their full cost; one still in flight
            # was cancelled at round end — only the window's burn happened
            e += self._wasted_energy(a, outcome.round_end)
        return e

    def _profile(self, cid: int):
        profiles = self.cost_model.profiles
        return profiles[cid % len(profiles)]

    def _wasted_energy(self, a: Arrival, until: float) -> float:
        """Burn of an abandoned arrival inside its [launch_t, until) window
        (the CostModel owns the phase-split arithmetic)."""
        return self.cost_model.wasted_energy(
            a.cost, max(0.0, until - a.launch_t)
        )

    @staticmethod
    def _rebase_stale(res, launch_global: PyTree, global_params: PyTree) -> None:
        """Apply a stale update's *delta* to the current global.

        ``CompressedParameters`` already IS a delta wire (decoded against
        whatever global the aggregation holds), so it needs no rebase; raw
        parameter payloads trained from an older global are rewritten as
        ``current + (params - launch_global)`` — FedBuff's update rule.
        """
        p = res.parameters
        if isinstance(p, CompressedParameters):
            return
        if isinstance(p, Parameters):
            p = parameters_to_pytree(p, launch_global)
        res.parameters = tree_add(global_params, tree_sub(p, launch_global))

    def _evaluate(self, global_params) -> tuple[float | None, float | None]:
        if self.eval_fn is not None:
            m = self.eval_fn(global_params)
            return m.get("loss"), m.get("acc")
        # federated evaluation: average client-side evaluate()
        losses, accs, ns = [], [], []
        for c in self.clients:
            res = c.evaluate(EvaluateIns(parameters=global_params))
            losses.append(res.loss)
            accs.append(res.metrics.get("acc", np.nan))
            ns.append(res.num_examples)
        w = np.asarray(ns, np.float64)
        return float(np.average(losses, weights=w)), float(np.average(accs, weights=w))


def make_cost_model_for(params: PyTree, profiles: list, **kw) -> CostModel:
    return CostModel(profiles=profiles, update_bytes=tree_bytes(params), **kw)

"""Jit-able FL round step — the pod-scale realization of the paper's FL loop.

One ``round_step`` = every sampled client runs (up to) ``max_steps`` local
SGD steps from the current global model, then the Strategy aggregates.  All
execution modes share ONE uniform contract::

    round_step(global_params, server_state, client_state, batches, weights,
               step_budgets, rnd, mask=None)
        -> (new_global, new_server_state, new_client_state, metrics)

``mask`` is the scheduler's **participation mask** — a static-shaped (C,)
0/1 float vector realizing a virtual-clock decision (core/scheduler.py:
deadline drops, availability dropouts) inside ONE jitted round: a masked
client still runs its shape-static local work, but contributes zero weight
under the existing ``safe_weight_sum`` denominator (so the aggregate is
bitwise what it would be without the client), its error-feedback residual
row carries UNCHANGED (it never transmitted, so no compression error
telescopes), and it is excluded from the loss/steps metrics.  ``mask=None``
(the default) takes the exact pre-mask code path — an all-ones mask and
``None`` produce bitwise-identical results on every mode.

``client_state`` is a codec-owned pytree
(``spec.codec.init_client_state(n_clients, n_params)``): error-feedback
codecs carry a fp32 residual buffer — one (C, n_params) block for a flat
codec, or a per-segment tuple of (C, seg.size) blocks when the codec
carries a ``SegmentMap`` (stateless segments hold ``()``) — so the
compression error telescopes across rounds; ``NullCodec`` — the default —
carries empty state, so the uncompressed engine allocates no client state
at all.  The engine never inspects the structure: it threads whatever the
codec initialized through ``aggregate_updates`` / ``transmit_tree``, so
flat and segmented codecs share every code path below.  The same
signature holds whether or not anything is compressed: there is no forked
"compressed round step" anymore.

Population mode (core/population.py) changes none of this: the engine
still receives dense, static-shaped ``client_state`` arrays (one
``(C, n_params)`` block, or the per-segment tuple) — the population layer
*gathers* the sampled cohort's resident rows into those arrays before the
call (row i belongs to cohort id i, missing/evicted rows are zeros) and
*scatters* ``new_client_state`` back by the same id order afterwards.  C is the fixed cohort size, never the population size,
so the jitted program, the participation mask, and the codec contracts are
unchanged shape-wise round to round.

Three mesh mappings (DESIGN.md §4), every one codec-aware:

- **parallel** (no mesh): params/batches carry a leading client axis C;
  local training is vmapped over clients; per-client flat deltas (plus the
  carried residual) are encoded and the server aggregates straight off the
  encoded payload (``codec.aggregate_batch`` — for Int8 the fused
  dequantize+weighted-reduce Pallas kernel: one HBM pass over the int8
  payload; for TopK the scatter-accumulate kernel over the (idx, val)
  payloads: O(C·k), the dense (C, n_params) delta matrix is never built).
- **parallel + mesh**: clients map 1:1 onto ``client_axes`` via shard_map
  (manual over client axes, auto over model axes).  Each client's delta is
  encoded *before* the hierarchical cross-client/cross-pod psum — the slow
  inter-pod links are exactly where wire shrinkage pays — so the values
  crossing the links carry only codec-representable information
  (``codec.transmit_tree``: encode -> decode inside the manual region; the
  psum operand is the decoded payload, numerically identical to the server
  decoding every client's uplink).

  **Collective wire contract** (``RoundSpec.collective``): the psum operand
  is always a *partial weighted sum* — ``decoded_delta * w_c`` — which is
  the one form that commutes with the reduction (sum of weighted terms,
  divided once by the psum'd ``safe_weight_sum`` denominator; the same
  contract the strategy-side wire reduce uses group-wise).  ``"fp32"``
  (default) psums that operand as-is, bitwise the pre-compression path.
  ``"int8"`` (``CompressedPsum``) quantizes it per 256-elem block against
  a scale *shared by every reducing device* — each device computes its
  local block-absmax, a cheap ``lax.pmax`` sidecar (4 B/block + the fp32
  weight denominator) agrees on the max BEFORE anything quantizes, and
  then every device's payload lives on one scale grid, so the int32 psum
  accumulates exactly (``unpack(sum_d pack(x_d))`` matches
  ``sum_d unpack(pack(x_d))`` to one final fp32 rounding — no per-hop
  requantization error).  Payload values are clipped to [-127, 127]
  (one byte on the wire; the int32 container is the *accumulator* dtype,
  not the wire format) so the summed accumulator provably cannot overflow
  below a fan-in of 2^31/127 ≈ 16.9M devices — no per-hop requantization,
  ONE fused dequant after the last hop.  The per-device quantization error
  lands in a collective error-feedback residual (``client_state =
  (codec_state, resid)``, rows sharded P(client_axes)) that telescopes
  across rounds exactly like the uplink codecs'.  A masked device
  transmits nothing — not even its carried residual — and keeps its
  residual row unchanged.  This shared-scale/partial-sum layout is also
  the substrate a secure-aggregation codec needs: masked integer payloads
  on a common grid sum server-side without per-client decode.
- **sequential**: one client at a time occupies the whole mesh (scan over
  clients); each client's delta goes through the codec round-trip before
  entering the accumulated weighted delta, and the per-client state rows
  are scanned alongside.  ``NullCodec``'s identity ``transmit_tree`` keeps
  the bf16 dense accumulator and never flattens a sharded model.  Caveat:
  an error-feedback codec here still materializes a replicated flat delta
  per scan step; a segmented codec at least splits its fp32 state into
  per-segment (C, seg.size) blocks (so no single (C, n_params) monolith),
  but the blocks remain unsharded by default — fine for models whose flat
  update fits on one host; for multi-B fsdp archs lay the per-segment
  (C, seg.size) blocks out along the mesh with
  ``models.sharding.shard_client_state`` (parameter dim over the fsdp
  axes, client dim whole — placement only, values bitwise unchanged), so
  per-device state memory drops by the full fsdp factor.

A heterogeneous fleet runs inside ONE jitted round via ``MixedCodec``: its
static per-client assignment partitions the client axis into per-codec
groups at trace time — the parallel path aggregates group-wise through
``codec.aggregate_updates`` (each group on its own kernel path, partial
weighted sums combined under one fleet denominator), the sequential path
runs one scan per group (each scan body closes over its group's wire
format) with the carried delta accumulator threading across scans.
``client_state`` is then a per-group tuple.  The mesh shard_map path
rejects ``MixedCodec`` at build time: one SPMD program, one wire format.

The paper's tau-cutoff becomes a *per-client step budget* ``step_budgets``
(int (C,)): clients keep stepping while ``i < budget_c`` and freeze their
parameters afterwards — shape-static, mask-realized partial work.

Rounds-as-scan (``make_multi_round_step``)
------------------------------------------

The uniform ``round_step`` is also the body of ONE ``lax.scan`` over R
rounds, so a whole training run compiles to a single traced program
(``Server.run_scanned`` is the driver; ``benchmarks/scan_bench.py``
measures the rounds/sec win over the per-round python loop).

- **Carry**: ``(global_params, server_state, client_state)`` — exactly
  the three state pytrees every ``round_step`` threads.  The driver jits
  with ``donate_argnums=(0, 1, 2)`` so XLA aliases the carry buffers
  in place and peak memory stays flat in R.
- **xs**: ``rnd`` (int32 (R,)), per-round batch slices when batches are
  stacked (R, C, ...) (per-round-constant (C, ...) batches are instead
  closed over, keeping memory flat in R), and the precomputed (R, C)
  schedule rows — availability (``AvailabilityTrace.available_matrix``),
  finish-time offsets (``CostModel.fleet_time_matrix``), and cohort
  priorities (``cohort_priority_matrix``).  All churn/jitter randomness
  is decided host-side before the trace, from the same seeded draws the
  event-driven driver makes.
- **Body**: dispatch mask = availability ∩ on-device cohort top-k
  (``cohort_dispatch_mask``), then the static policy's pure-array
  verdict (``RoundPolicy.plan_arrays``) picks the reporters and the
  round's wall clock, and ``round_step`` runs under that mask.
- **ys**: the per-round metrics dict plus masks/wall/participation
  counts, stacked on device and decoded to a ``History`` once at the
  end — no host sync inside the run.

Which policies can trace: ``SyncAll`` and ``Deadline`` — their verdict
is a pure function of THIS round's dispatch set and finish times.
``BufferedAsync`` cannot (v1): its pending set is data-dependent-size
state threaded between rounds (an arrival consumed at round r may have
launched at r-3), which has no static-shape scan carry without a
fixed-slot in-flight buffer — future work, documented out of scope.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.sharding import shard_map_compat as _shard_map
from repro.optim import Optimizer
from repro.utils.pytree import safe_weight_sum, tree_where

from .compression import CompressedPsum, MixedCodec, NullCodec
from .strategy.base import Strategy

PyTree = Any


@dataclass(frozen=True)
class RoundSpec:
    """Static configuration of the jitted round step."""

    max_steps: int               # scanned local steps (tau masks within)
    execution_mode: str          # "parallel" | "sequential" | "fsdp"
    prox_mu: float = 0.0         # FedProx proximal coefficient (0 = off)
    microbatches: int = 1        # gradient accumulation within one local step
    codec: Any = field(default_factory=NullCodec)  # UpdateCodec (wire format)
    # mesh-path collective wire: "fp32" (default — bitwise the pre-existing
    # psum) or "int8" (CompressedPsum; opt-in, tolerance-bounded parity)
    collective: str = "fp32"
    collective_block: int = 256  # scale-block size of the int8 collective


def make_client_update(
    loss_fn: Callable,           # (params, batch) -> (loss, metrics)
    opt: Optimizer,
    spec: RoundSpec,
    trainable_mask: PyTree | None = None,
):
    """Returns client_update(global_params, batches, step_budget) ->
    (new_params, mean_loss, steps_done) for ONE client.

    batches: pytree with leading (max_steps, ...) axis.
    """

    def total_loss(params, batch, global_params):
        loss, metrics = loss_fn(params, batch)
        if spec.prox_mu > 0.0:
            from repro.utils.pytree import tree_sq_norm, tree_sub

            loss = loss + 0.5 * spec.prox_mu * tree_sq_norm(
                tree_sub(params, global_params)
            )
        return loss, metrics

    def client_update(global_params, batches, step_budget):
        opt_state = opt.init(global_params)

        def grad_of(params, batch):
            if spec.microbatches <= 1:
                (loss, _), grads = jax.value_and_grad(total_loss, has_aux=True)(
                    params, batch, global_params
                )
                return loss, grads

            # gradient accumulation: scan over microbatch slices of the batch
            # dim (activation memory / microbatches; bf16 accumulators)
            mb = spec.microbatches

            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mbatch):
                loss_acc, gacc = carry
                (loss, _), grads = jax.value_and_grad(total_loss, has_aux=True)(
                    params, mbatch, global_params
                )
                gacc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), gacc, grads)
                return (loss_acc + loss, gacc), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            (loss_sum, gacc), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zeros), micro
            )
            grads = jax.tree.map(lambda g: (g / mb).astype(jnp.bfloat16), gacc)
            return loss_sum / mb, grads

        def one_step(carry, xs):
            params, opt_state, i = carry
            batch = xs
            loss, grads = grad_of(params, batch)
            new_params, new_opt_state = opt.update(grads, params, opt_state, i)
            if trainable_mask is not None:
                new_params = jax.tree.map(
                    lambda n, o, m: n if m else o, new_params, params, trainable_mask
                )
            live = i < step_budget
            params = tree_where(live, new_params, params)
            opt_state = tree_where(live, new_opt_state, opt_state)
            loss = jnp.where(live, loss, 0.0)
            return (params, opt_state, i + 1), loss

        (params, _, _), losses = jax.lax.scan(
            one_step, (global_params, opt_state, jnp.zeros((), jnp.int32)), batches,
            length=spec.max_steps,
        )
        steps_done = jnp.minimum(step_budget, spec.max_steps)
        mean_loss = jnp.sum(losses) / jnp.maximum(1, steps_done)
        return params, mean_loss, steps_done

    return client_update


def init_collective_residual(global_params: PyTree, n_clients: int) -> PyTree:
    """Zero per-device error-feedback state for the int8 collective
    (``RoundSpec(collective="int8")``): one fp32 buffer per model leaf with
    a leading client axis — on the mesh path clients map 1:1 onto devices,
    so row i is device i's residual and shards P(client_axes) like every
    other client-state block.  The mesh ``round_step`` then expects
    ``client_state = (codec_state, this)``."""
    return jax.tree.map(
        lambda g: jnp.zeros((n_clients,) + g.shape, jnp.float32),
        global_params,
    )


def _state_metrics(new_client_state) -> dict:
    """Residual-norm telemetry when the codec carries per-client state.

    Handles the per-group tuple state of ``MixedCodec`` too: every leaf is a
    (C_g, n_params) residual block; the mean is over ALL residual rows of
    the fleet (groups without state — Null — simply contribute no rows)."""
    rows = [
        jnp.linalg.norm(leaf.reshape(leaf.shape[0], -1), axis=-1)
        for leaf in jax.tree.leaves(new_client_state)
        if leaf.ndim >= 2 and leaf.shape[0] > 0
    ]
    if not rows:
        return {}
    return {"residual_norm_mean": jnp.mean(jnp.concatenate(rows))}


def _carry_masked_state(codec, mask, old_state, new_state):
    """Masked (non-participating) clients' codec state rows carry unchanged.

    A dropped client never transmitted, so its error-feedback residual must
    not absorb this round's untransmitted delta — the row it entered the
    round with is the row it leaves with.  Handles ``MixedCodec``'s
    per-group tuple state by slicing the fleet mask with each group's
    static client indices.
    """
    def keep_rows(m):
        mc = jnp.asarray(m)

        def leaf(o, n):
            return jnp.where(
                mc.reshape((-1,) + (1,) * (n.ndim - 1)) > 0, n, o
            )

        return leaf

    if isinstance(codec, MixedCodec):
        out = list(new_state)
        for g in range(len(codec.codecs)):
            if not jax.tree.leaves(new_state[g]):
                continue  # stateless group (Null): nothing to carry
            # static python index list (the assignment is a trace-time
            # constant) — no host numpy inside the traced region
            idx = [i for i, a in enumerate(codec.assignment) if a == g]
            out[g] = jax.tree.map(
                keep_rows(mask[jnp.asarray(idx)]), old_state[g], new_state[g]
            )
        return tuple(out)
    if not jax.tree.leaves(new_state):
        return new_state
    return jax.tree.map(keep_rows(mask), old_state, new_state)


def _masked_metrics(losses, steps, weights, mask):
    """Participation-aware loss/steps metrics (one definition, all modes).

    ``jnp.where`` — not multiplication — so a masked client's loss can be
    NaN/inf (it diverged, which may be WHY it was dropped) without
    poisoning the fleet metrics.
    """
    wf = weights.astype(jnp.float32)
    if mask is None:
        return {
            "client_loss_mean": jnp.sum(losses * wf) / safe_weight_sum(wf),
            "client_loss_max": jnp.max(losses),
            "steps_total": jnp.sum(steps),
        }
    mf = mask.astype(jnp.float32)
    w_eff = wf * mf
    losses_eff = jnp.where(mf > 0, losses, 0.0)
    # a fully-masked round has no defined loss: NaN (matching the Server's
    # empty-round train_loss), never a 0.0 that reads like convergence or a
    # -inf max that poisons series mins downstream
    any_live = jnp.any(mf > 0)
    return {
        "client_loss_mean": jnp.where(
            any_live,
            jnp.sum(losses_eff * w_eff) / safe_weight_sum(w_eff), jnp.nan,
        ),
        "client_loss_max": jnp.where(
            any_live, jnp.max(jnp.where(mf > 0, losses, -jnp.inf)), jnp.nan
        ),
        "steps_total": jnp.sum(jnp.where(mf > 0, steps, 0)),
    }


def make_round_step(
    loss_fn: Callable,
    opt: Optimizer,
    strategy: Strategy,
    spec: RoundSpec,
    trainable_mask: PyTree | None = None,
    mesh=None,
    client_axes: tuple[str, ...] = ("data",),
    param_shardings: PyTree | None = None,
):
    """Builds the uniform round_step (module docstring) for ``spec``.

    parallel:   batches leaves (C, max_steps, B, ...); weights/budgets (C,);
                client_state leaves lead with C.  With a mesh, clients map
                1:1 onto `client_axes` via shard_map; without one (CPU
                tests) local training vmaps over clients.
    sequential: identical signature; clients are scanned, not mapped.

    Aggregation is codec-mediated on every path: the weighted mean of the
    codec-decoded deltas feeds ``strategy.server_update`` (FedAvg-family:
    identity; FedOpt: server optimizer on the pseudo-gradient).
    """
    client_update = make_client_update(loss_fn, opt, spec, trainable_mask)
    codec = spec.codec if spec.codec is not None else NullCodec()

    if spec.collective not in ("fp32", "int8"):
        raise ValueError(
            f"RoundSpec.collective={spec.collective!r}: expected fp32 | int8"
        )
    compressed_collective = spec.collective == "int8"
    if compressed_collective and (
        mesh is None or spec.execution_mode != "parallel"
    ):
        raise NotImplementedError(
            "collective='int8' compresses the mesh shard_map psum — it "
            "requires execution_mode='parallel' with a mesh; the vmap and "
            "sequential modes have no cross-device collective to compress"
        )

    if spec.execution_mode == "parallel" and mesh is not None:
        if isinstance(codec, MixedCodec):
            raise NotImplementedError(
                "MixedCodec is not supported on the mesh shard_map path: an "
                "SPMD program runs ONE wire format per device; use the "
                "vmap-parallel or sequential execution mode for mixed fleets"
            )
        from jax.sharding import PartitionSpec as P

        axes = client_axes
        cpsum = (
            CompressedPsum(block=spec.collective_block)
            if compressed_collective else None
        )

        def per_client(global_params, batches, weight, budget, mask_c, state):
            if compressed_collective:
                codec_state, coll_resid = state
            else:
                codec_state, coll_resid = state, None
            b0 = jax.tree.map(lambda x: x[0], batches)
            new_p, loss, steps = client_update(global_params, b0, budget[0])

            # this client's uplink: encode the delta BEFORE anything crosses
            # the mesh — only codec-representable values enter the psum
            delta = jax.tree.map(
                lambda n, g: n.astype(jnp.float32) - g.astype(jnp.float32),
                new_p, global_params,
            )
            state_row = jax.tree.map(lambda x: x[0], codec_state)
            dec_delta, new_row = codec.transmit_tree(delta, state_row)
            if mask_c is not None:
                # participation mask: a dropped client never transmitted —
                # its residual row carries unchanged across the round, and
                # its delta is zeroed BEFORE the psum (zero weight alone
                # would let a diverged client's 0 * NaN poison the sum)
                new_row = jax.tree.map(
                    lambda n, o: jnp.where(mask_c[0] > 0, n, o),
                    new_row, state_row,
                )
                dec_delta = jax.tree.map(
                    lambda d: jnp.where(mask_c[0] > 0, d, jnp.zeros_like(d)),
                    dec_delta,
                )

            wf = weight[0].astype(jnp.float32)
            if mask_c is not None:
                wf = wf * mask_c[0].astype(jnp.float32)
            wsum = wf
            for ax in reversed(axes):
                wsum = jax.lax.psum(wsum, ax)
            wsum = jnp.where(wsum == 0.0, 1.0, wsum)  # safe_weight_sum, post-psum

            if not compressed_collective:
                def wmean(d):
                    wx = d.astype(jnp.float32) * wf
                    # hierarchical aggregation: reduce inside the pod first,
                    # then across pods (one pre-reduced tensor crosses the
                    # slow links)
                    for ax in reversed(axes):
                        wx = jax.lax.psum(wx, ax)
                    return wx / wsum

                avg = jax.tree.map(
                    lambda g, d: (g.astype(jnp.float32) + wmean(d)).astype(g.dtype),
                    global_params, dec_delta,
                )
                return avg, loss[None], steps[None], jax.tree.map(
                    lambda x: x[None], new_row
                )

            # int8 collective (module docstring: the collective wire
            # contract): quantize this device's partial weighted sum per
            # leaf against a pmax-shared block scale, psum the int payload
            # hierarchically, dequant ONCE after the last hop.  The
            # per-device quantization residual stays local and telescopes.
            resid_row = jax.tree.map(lambda x: x[0], coll_resid)
            live = None if mask_c is None else mask_c[0] > 0

            def leaf_psum(d, r):
                wx = d.astype(jnp.float32).reshape(-1) * wf
                r = r.reshape(-1)
                if live is not None:
                    # a dropped device transmits NOTHING — not even its
                    # carried residual — and keeps the residual unchanged
                    r_in = jnp.where(live, r, 0.0)
                else:
                    r_in = r
                total, new_r = cpsum.psum(wx, r_in, axes)
                if live is not None:
                    new_r = jnp.where(live, new_r, r)
                return total.reshape(d.shape), new_r.reshape(d.shape)

            leaves_d, treedef = jax.tree_util.tree_flatten(dec_delta)
            leaves_r = treedef.flatten_up_to(resid_row)
            pairs = [leaf_psum(d, r) for d, r in zip(leaves_d, leaves_r)]
            sums = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
            new_resid_row = jax.tree_util.tree_unflatten(
                treedef, [p[1] for p in pairs]
            )
            avg = jax.tree.map(
                lambda g, s: (g.astype(jnp.float32) + s / wsum).astype(g.dtype),
                global_params, sums,
            )
            return avg, loss[None], steps[None], (
                jax.tree.map(lambda x: x[None], new_row),
                jax.tree.map(lambda x: x[None], new_resid_row),
            )

        def round_step(
            global_params, server_state, client_state, batches, weights,
            step_budgets, rnd, mask=None,
        ):
            batch_specs = jax.tree.map(lambda x: P(axes), batches)
            param_specs_manual = jax.tree.map(lambda x: P(), global_params)
            state_specs = jax.tree.map(
                lambda x: P(axes, *([None] * (x.ndim - 1))), client_state
            )
            if mask is None:
                body = lambda gp, b, w, bu, st: per_client(gp, b, w, bu, None, st)
                in_specs = (
                    param_specs_manual, batch_specs, P(axes), P(axes), state_specs,
                )
                args = (global_params, batches, weights, step_budgets, client_state)
            else:
                body = per_client
                in_specs = (
                    param_specs_manual, batch_specs, P(axes), P(axes), P(axes),
                    state_specs,
                )
                args = (
                    global_params, batches, weights, step_budgets, mask,
                    client_state,
                )
            avg, losses, steps, new_client_state = _shard_map(
                body,
                mesh,
                in_specs=in_specs,
                out_specs=(param_specs_manual, P(axes), P(axes), state_specs),
                axis_names=set(axes),
            )(*args)
            new_global, new_state = strategy.server_update(
                avg, global_params, server_state, rnd
            )
            metrics = {
                # examples-weighted, like every other execution mode: the
                # same round must report the same metric everywhere
                **_masked_metrics(losses, steps, weights, mask),
            }
            if compressed_collective:
                # keep the uplink codec's residual telemetry separate from
                # the collective's own error-feedback buffer
                metrics.update(_state_metrics(new_client_state[0]))
                coll = _state_metrics(
                    tuple(
                        leaf.reshape(leaf.shape[0], -1)
                        for leaf in jax.tree.leaves(new_client_state[1])
                    )
                )
                if coll:
                    metrics["collective_residual_norm_mean"] = coll[
                        "residual_norm_mean"
                    ]
            else:
                metrics.update(_state_metrics(new_client_state))
            return new_global, new_state, new_client_state, metrics

        return round_step

    if spec.execution_mode == "parallel":

        def round_step(
            global_params, server_state, client_state, batches, weights,
            step_budgets, rnd, mask=None,
        ):
            new_params, losses, steps = jax.vmap(
                client_update, in_axes=(None, 0, 0)
            )(global_params, batches, step_budgets)

            # codec-owned aggregation: wire layout + encoded-payload reduce
            # for compressing codecs, a leafwise weighted mean for NullCodec.
            # A masked client aggregates at zero weight (zero contribution
            # under the one safe_weight_sum denominator); its params are
            # pinned back to the global FIRST — zero weight alone is not
            # enough, a diverged (NaN/inf) dropped client would still
            # poison the reduce through 0 * NaN...
            if mask is not None:
                new_params = jax.tree.map(
                    lambda p, g: jnp.where(
                        mask.reshape((-1,) + (1,) * g.ndim) > 0, p, g[None]
                    ),
                    new_params, global_params,
                )
            w_agg = weights if mask is None else (
                weights.astype(jnp.float32) * mask.astype(jnp.float32)
            )
            avg_params, new_client_state = codec.aggregate_updates(
                new_params, global_params, w_agg, client_state
            )
            if mask is not None:
                # ...and, having transmitted nothing, keeps its residual row
                new_client_state = _carry_masked_state(
                    codec, mask, client_state, new_client_state
                )
            new_global, new_state = strategy.server_update(
                avg_params, global_params, server_state, rnd
            )
            metrics = {
                # examples-weighted (matches the sequential scan's running
                # weighted mean): one metric definition across all modes
                **_masked_metrics(losses, steps, weights, mask),
                **_state_metrics(new_client_state),
            }
            return new_global, new_state, new_client_state, metrics

        return round_step

    def _pin(tree):
        """Pin the fp32 delta accumulator to the parameter sharding —
        without this the scan carry (initialized from plain zeros) can end
        up replicated, which for a multi-B model is fatal."""
        if param_shardings is None:
            return tree
        return jax.lax.with_sharding_constraint(tree, param_shardings)

    def round_step(
        global_params, server_state, client_state, batches, weights,
        step_budgets, rnd, mask=None,
    ):
        wf = weights.astype(jnp.float32)
        mf = None if mask is None else mask.astype(jnp.float32)
        wsum = safe_weight_sum(wf if mf is None else wf * mf)

        def make_per_client(codec_g):
            def per_client(carry, xs):
                delta_acc, loss_acc, loss_max, steps_acc = carry
                if mf is None:
                    client_batches, w, budget, state_row = xs
                    m = None
                else:
                    client_batches, w, budget, m, state_row = xs
                new_params, loss, steps = client_update(
                    global_params, client_batches, budget
                )
                delta = jax.tree.map(jnp.subtract, new_params, global_params)
                # codec round-trip: only what survives the wire is accumulated
                dec_delta, new_row = codec_g.transmit_tree(delta, state_row)
                if m is not None:
                    # masked client: zero aggregation weight AND a zeroed
                    # delta (0 * NaN from a diverged dropped client would
                    # still poison the accumulator), residual row carried
                    # unchanged (it never transmitted), metrics skip
                    w = w * m
                    dec_delta = jax.tree.map(
                        lambda d: jnp.where(m > 0, d, jnp.zeros_like(d)),
                        dec_delta,
                    )
                    new_row = jax.tree.map(
                        lambda n, o: jnp.where(m > 0, n, o), new_row, state_row
                    )
                    loss = jnp.where(m > 0, loss, 0.0)
                    loss_for_max = jnp.where(m > 0, loss, -jnp.inf)
                    steps = jnp.where(m > 0, steps, 0)
                else:
                    loss_for_max = loss
                scale = (w / wsum).astype(jnp.bfloat16)
                delta_acc = _pin(jax.tree.map(
                    lambda acc, d: acc + scale * d.astype(jnp.bfloat16),
                    delta_acc, dec_delta,
                ))
                carry = (
                    delta_acc,
                    loss_acc + loss * w / wsum,
                    jnp.maximum(loss_max, loss_for_max),
                    steps_acc + steps,
                )
                return carry, new_row

            return per_client

        # bf16 delta accumulator: halves the largest param-state buffer; the
        # single-round accumulation error is far below local-SGD noise
        zero_delta = _pin(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.bfloat16), global_params
        ))
        carry = (
            zero_delta, jnp.zeros(()), jnp.full((), -jnp.inf),
            jnp.zeros((), jnp.int32),
        )
        if isinstance(codec, MixedCodec):
            # one scan per codec group: the assignment is static, so each
            # group's rows are gathered at trace time and its wire format is
            # a trace-time constant inside its scan body; the carried delta
            # accumulator and loss/steps stats thread across the group
            # scans, all normalized by the ONE fleet-wide weight sum
            new_states = list(client_state)
            for g, codec_g, idx in codec.groups():
                ia = jnp.asarray(idx)  # static rows -> constant gather
                xs_g = (
                    jax.tree.map(lambda x: x[ia], batches),
                    wf[ia], step_budgets[ia],
                    *(() if mf is None else (mf[ia],)),
                    client_state[g],
                )
                carry, new_states[g] = jax.lax.scan(
                    make_per_client(codec_g), carry, xs_g
                )
            new_client_state = tuple(new_states)
        else:
            carry, new_client_state = jax.lax.scan(
                make_per_client(codec), carry,
                (batches, wf, step_budgets,
                 *(() if mf is None else (mf,)), client_state),
            )
        delta, loss_mean, loss_max, steps_total = carry
        if mf is not None:
            # fully-masked round: no defined loss (see _masked_metrics)
            any_live = jnp.any(mf > 0)
            loss_mean = jnp.where(any_live, loss_mean, jnp.nan)
            loss_max = jnp.where(any_live, loss_max, jnp.nan)
        # the averaged delta goes straight through server_update (FedAvg:
        # identity; FedOpt: server optimizer) — no stacked fp32 detour.
        avg_params = _pin(jax.tree.map(
            lambda g, d: (g.astype(jnp.float32) + d.astype(jnp.float32)).astype(g.dtype),
            global_params, delta,
        ))
        new_global, new_state = strategy.server_update(
            avg_params, global_params, server_state, rnd
        )
        metrics = {
            "client_loss_mean": loss_mean,
            "client_loss_max": loss_max,
            "steps_total": steps_total,
            **_state_metrics(new_client_state),
        }
        return new_global, new_state, new_client_state, metrics

    return round_step


def cohort_dispatch_mask(priorities, avail_mask, cohort_size: int):
    """On-device cohort sampling: the ``cohort_size`` available clients
    with the LOWEST priorities win (uniform priorities == a uniform draw
    without replacement).

    Pure array code so it runs identically traced inside the scan body and
    eagerly in the reference driver.  Unavailable clients rank at +inf, so
    a round with fewer than ``cohort_size`` available clients dispatches
    only whoever is up (including nobody) — the scan-world analogue of
    ``Strategy.sample_cohort``'s short-cohort contract.  The double stable
    argsort turns priorities into dense ranks; ties (exactly equal float
    priorities) break by client id, deterministically.
    """
    pri = jnp.where(avail_mask > 0, priorities, jnp.inf)
    order = jnp.argsort(pri, stable=True)
    ranks = jnp.argsort(order, stable=True)
    return jnp.where((ranks < cohort_size) & (avail_mask > 0), 1.0, 0.0)


def make_multi_round_step(
    loss_fn: Callable,
    opt: Optimizer,
    strategy: Strategy,
    spec: RoundSpec,
    num_rounds: int,
    *,
    policy=None,
    tau: float | None = None,
    cohort_size: int | None = None,
    trainable_mask: PyTree | None = None,
    mesh=None,
    client_axes: tuple[str, ...] = ("data",),
    param_shardings: PyTree | None = None,
    stacked_batches: bool = True,
):
    """Compile ``num_rounds`` FL rounds into ONE ``lax.scan`` over the
    uniform ``round_step`` (module docstring: "the scanned trainer").

    Returns::

        multi_round_step(global_params, server_state, client_state,
                         batches, weights, step_budgets,
                         avail, t_total, priorities)
            -> (new_global, new_server_state, new_client_state, stacked)

    where ``avail`` / ``t_total`` / ``priorities`` are the precomputed
    (R, C) schedule matrices (``AvailabilityTrace.available_matrix``,
    ``CostModel.fleet_time_matrix``, ``cohort_priority_matrix``) and
    ``stacked`` is a dict of (R,)- and (R, C)-shaped per-round outputs
    (the round_step metrics plus ``participation_mask``,
    ``dispatch_mask``, ``round_wall_s``, ``participants``,
    ``dispatched``) decoded to a ``History`` once, after the scan.

    ``batches``: leaves lead with (R, C, max_steps, ...) when
    ``stacked_batches`` (each round gets its own slice) or (C, max_steps,
    ...) when not — the same batch every round, closed over as a
    scan-invariant constant so device memory stays flat in R.

    Scheduling is the static ``policy``'s pure-array verdict
    (``RoundPolicy.plan_arrays``): each round the body computes a
    dispatch mask (availability ∩ on-device cohort top-k when
    ``cohort_size`` is set), asks the policy who reports and how long the
    round ran, and feeds the reporter mask to ``round_step`` — deadline
    drops, churn, and sampling all happen on device.  ``tau`` must be a
    pre-resolved host float (``Deadline.resolve_tau``); only
    ``traceable`` policies are accepted (``SyncAll``, ``Deadline`` —
    ``BufferedAsync`` carries a cross-round pending set and cannot trace,
    see ``core/scheduler.py``).
    """
    from .scheduler import SyncAll

    round_step = make_round_step(
        loss_fn, opt, strategy, spec, trainable_mask, mesh, client_axes,
        param_shardings,
    )
    policy = SyncAll() if policy is None else policy
    if not getattr(policy, "traceable", False):
        raise NotImplementedError(
            f"{type(policy).__name__} cannot run inside lax.scan: its "
            "verdict depends on cross-round pending-arrival state (see "
            "core/scheduler.py); use Server.run, or a traceable policy "
            "(SyncAll, Deadline)"
        )
    R = num_rounds  # build-time static (no cast: this fn is a lint root)

    def multi_round_step(
        global_params, server_state, client_state, batches, weights,
        step_budgets, avail, t_total, priorities,
    ):
        def body(carry, xs):
            g, ss, cs = carry
            if stacked_batches:
                rnd, batch_r, avail_r, t_r, pri_r = xs
            else:
                rnd, avail_r, t_r, pri_r = xs
                batch_r = batches
            if cohort_size is None:
                dispatch_mask = avail_r
            else:
                dispatch_mask = cohort_dispatch_mask(
                    pri_r, avail_r, cohort_size
                )
            mask, round_end = policy.plan_arrays(dispatch_mask, t_r, tau=tau)
            g, ss, cs, met = round_step(
                g, ss, cs, batch_r, weights, step_budgets, rnd, mask
            )
            ys = {
                **met,
                "participation_mask": mask,
                "dispatch_mask": dispatch_mask,
                "round_wall_s": round_end,
                "participants": jnp.sum(jnp.where(mask > 0, 1.0, 0.0)),
                "dispatched": jnp.sum(jnp.where(dispatch_mask > 0, 1.0, 0.0)),
            }
            return (g, ss, cs), ys

        rnds = jnp.arange(1, R + 1, dtype=jnp.int32)
        xs = (
            rnds,
            *((batches,) if stacked_batches else ()),
            avail, t_total, priorities,
        )
        (g, ss, cs), stacked = jax.lax.scan(
            body, (global_params, server_state, client_state), xs
        )
        return g, ss, cs, stacked

    return multi_round_step

"""Jit-able FL round step — the pod-scale realization of the paper's FL loop.

One ``round_step`` = every sampled client runs (up to) ``max_steps`` local
SGD steps from the current global model, then the Strategy aggregates.  Two
mesh mappings (DESIGN.md §4):

- **parallel**: params/batches carry a leading client axis C sharded over the
  mesh's client axes ((pod,) data); local training is vmapped over clients;
  aggregation is a cross-client weighted reduction (an all-reduce over the
  client axes at the XLA level).
- **sequential**: one client at a time occupies the whole mesh (scan over
  clients); the aggregate is an accumulated weighted delta.  Used for archs
  whose per-client replica cannot fit (mixtral, jamba).

The paper's tau-cutoff becomes a *per-client step budget* ``step_budgets``
(int (C,)): clients keep stepping while ``i < budget_c`` and freeze their
parameters afterwards — shape-static, mask-realized partial work.

**Compressed wire** (``RoundSpec.codec``): when a codec (core/compression.py)
is set, the parallel round step encodes each client's flat delta *inside the
jitted step* — delta + carried error-feedback residual -> codec payload —
and the server decodes through the codec's fused reduce (for Int8 the
dequantize+weighted-reduce Pallas kernel: one HBM pass over the int8
payload).  What was not transmitted (quantization error / untransmitted
top-k mass) becomes the client's new residual, carried across rounds as a
(C, n_params) leaf of the client state pytree (``init_residuals``), so the
compression error telescopes instead of accumulating.  The compressed round
step takes that residual state after ``server_state`` and returns its
updated value: ``round_step(global, server_state, residuals, batches,
weights, budgets, rnd) -> (new_global, new_server_state, new_residuals,
metrics)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.utils.pytree import tree_where

from .strategy.base import Strategy

PyTree = Any


@dataclass(frozen=True)
class RoundSpec:
    """Static configuration of the jitted round step."""

    max_steps: int               # scanned local steps (tau masks within)
    execution_mode: str          # "parallel" | "sequential" | "fsdp"
    prox_mu: float = 0.0         # FedProx proximal coefficient (0 = off)
    microbatches: int = 1        # gradient accumulation within one local step
    codec: Any = None            # UpdateCodec -> compressed-wire round path


def make_client_update(
    loss_fn: Callable,           # (params, batch) -> (loss, metrics)
    opt: Optimizer,
    spec: RoundSpec,
    trainable_mask: PyTree | None = None,
):
    """Returns client_update(global_params, batches, step_budget) ->
    (new_params, mean_loss, steps_done) for ONE client.

    batches: pytree with leading (max_steps, ...) axis.
    """

    def total_loss(params, batch, global_params):
        loss, metrics = loss_fn(params, batch)
        if spec.prox_mu > 0.0:
            from repro.utils.pytree import tree_sq_norm, tree_sub

            loss = loss + 0.5 * spec.prox_mu * tree_sq_norm(
                tree_sub(params, global_params)
            )
        return loss, metrics

    def client_update(global_params, batches, step_budget):
        opt_state = opt.init(global_params)

        def grad_of(params, batch):
            if spec.microbatches <= 1:
                (loss, _), grads = jax.value_and_grad(total_loss, has_aux=True)(
                    params, batch, global_params
                )
                return loss, grads

            # gradient accumulation: scan over microbatch slices of the batch
            # dim (activation memory / microbatches; bf16 accumulators)
            mb = spec.microbatches

            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mbatch):
                loss_acc, gacc = carry
                (loss, _), grads = jax.value_and_grad(total_loss, has_aux=True)(
                    params, mbatch, global_params
                )
                gacc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), gacc, grads)
                return (loss_acc + loss, gacc), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            (loss_sum, gacc), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zeros), micro
            )
            grads = jax.tree.map(lambda g: (g / mb).astype(jnp.bfloat16), gacc)
            return loss_sum / mb, grads

        def one_step(carry, xs):
            params, opt_state, i = carry
            batch = xs
            loss, grads = grad_of(params, batch)
            new_params, new_opt_state = opt.update(grads, params, opt_state, i)
            if trainable_mask is not None:
                new_params = jax.tree.map(
                    lambda n, o, m: n if m else o, new_params, params, trainable_mask
                )
            live = i < step_budget
            params = tree_where(live, new_params, params)
            opt_state = tree_where(live, new_opt_state, opt_state)
            loss = jnp.where(live, loss, 0.0)
            return (params, opt_state, i + 1), loss

        (params, _, _), losses = jax.lax.scan(
            one_step, (global_params, opt_state, jnp.zeros((), jnp.int32)), batches,
            length=spec.max_steps,
        )
        steps_done = jnp.minimum(step_budget, spec.max_steps)
        mean_loss = jnp.sum(losses) / jnp.maximum(1, steps_done)
        return params, mean_loss, steps_done

    return client_update


def make_round_step(
    loss_fn: Callable,
    opt: Optimizer,
    strategy: Strategy,
    spec: RoundSpec,
    trainable_mask: PyTree | None = None,
    mesh=None,
    client_axes: tuple[str, ...] = ("data",),
    param_shardings: PyTree | None = None,
):
    """Builds round_step(global_params, server_state, batches, weights,
    step_budgets, rnd) -> (new_global, new_server_state, metrics).

    parallel:   batches leaves (C, max_steps, B, ...); weights/budgets (C,).
                With a mesh, clients map 1:1 onto `client_axes` via shard_map
                (manual over client axes, auto over the model axes) so local
                training is provably communication-free across clients and
                aggregation is an explicit — hierarchical when multi-pod —
                cross-client psum.  Without a mesh (CPU tests) it vmaps.
    sequential: identical signature; clients are scanned, not mapped.
    """
    client_update = make_client_update(loss_fn, opt, spec, trainable_mask)

    if spec.codec is not None:
        if spec.execution_mode != "parallel" or mesh is not None:
            raise NotImplementedError(
                "codec is only supported on the single-host parallel round "
                "path for now (mesh shard_map / sequential: ROADMAP open item)"
            )
        return _make_compressed_round_step(client_update, strategy, spec)

    if spec.execution_mode == "parallel" and mesh is not None:
        from jax.sharding import PartitionSpec as P

        axes = client_axes

        def per_client(global_params, batches, weight, budget):
            b0 = jax.tree.map(lambda x: x[0], batches)
            new_p, loss, steps = client_update(global_params, b0, budget[0])

            wf = weight[0].astype(jnp.float32)

            def wmean(n, g):
                wx = n.astype(jnp.float32) * wf
                # hierarchical aggregation: reduce inside the pod first, then
                # across pods (one pre-reduced tensor crosses the slow links)
                for ax in reversed(axes):
                    wx = jax.lax.psum(wx, ax)
                return wx

            wsum = wf
            for ax in reversed(axes):
                wsum = jax.lax.psum(wsum, ax)
            avg = jax.tree.map(
                lambda n, g: (wmean(n, g) / wsum).astype(g.dtype),
                new_p, global_params,
            )
            return avg, loss[None], steps[None]

        def round_step(global_params, server_state, batches, weights, step_budgets, rnd):
            batch_specs = jax.tree.map(lambda x: P(axes), batches)
            param_specs_manual = jax.tree.map(lambda x: P(), global_params)
            avg, losses, steps = jax.shard_map(
                per_client,
                mesh=mesh,
                in_specs=(param_specs_manual, batch_specs, P(axes), P(axes)),
                out_specs=(param_specs_manual, P(axes), P(axes)),
                axis_names=set(axes),
                check_vma=False,
            )(global_params, batches, weights, step_budgets)
            new_global, new_state = strategy.server_update(
                avg, global_params, server_state, rnd
            )
            metrics = {
                "client_loss_mean": jnp.mean(losses),
                "client_loss_max": jnp.max(losses),
                "steps_total": jnp.sum(steps),
            }
            return new_global, new_state, metrics

        return round_step

    if spec.execution_mode == "parallel":

        def round_step(global_params, server_state, batches, weights, step_budgets, rnd):
            new_params, losses, steps = jax.vmap(
                client_update, in_axes=(None, 0, 0)
            )(global_params, batches, step_budgets)
            new_global, new_state = strategy.aggregate(
                new_params, weights, global_params, server_state, rnd
            )
            metrics = {
                "client_loss_mean": jnp.mean(losses),
                "client_loss_max": jnp.max(losses),
                "steps_total": jnp.sum(steps),
            }
            return new_global, new_state, metrics

        return round_step

    def _pin(tree):
        """Pin the fp32 delta accumulator to the parameter sharding —
        without this the scan carry (initialized from plain zeros) can end
        up replicated, which for a multi-B model is fatal."""
        if param_shardings is None:
            return tree
        return jax.lax.with_sharding_constraint(tree, param_shardings)

    def round_step(global_params, server_state, batches, weights, step_budgets, rnd):
        wf = weights.astype(jnp.float32)
        wsum = jnp.sum(wf)

        def per_client(carry, xs):
            delta_acc, loss_acc, steps_acc = carry
            client_batches, w, budget = xs
            new_params, loss, steps = client_update(
                global_params, client_batches, budget
            )
            scale = (w / wsum).astype(jnp.bfloat16)
            delta_acc = _pin(jax.tree.map(
                lambda acc, n, g: acc + scale * (n - g).astype(jnp.bfloat16),
                delta_acc, new_params, global_params,
            ))
            return (delta_acc, loss_acc + loss * w / wsum, steps_acc + steps), None

        # bf16 delta accumulator: halves the largest param-state buffer; the
        # single-round accumulation error is far below local-SGD noise
        zero_delta = _pin(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.bfloat16), global_params
        ))
        (delta, loss_mean, steps_total), _ = jax.lax.scan(
            per_client,
            (zero_delta, jnp.zeros(()), jnp.zeros((), jnp.int32)),
            (batches, wf, step_budgets),
        )
        # the averaged delta goes straight through server_update (FedAvg:
        # identity; FedOpt: server optimizer) — no stacked fp32 detour.
        avg_params = _pin(jax.tree.map(
            lambda g, d: (g.astype(jnp.float32) + d.astype(jnp.float32)).astype(g.dtype),
            global_params, delta,
        ))
        new_global, new_state = strategy.server_update(
            avg_params, global_params, server_state, rnd
        )
        metrics = {
            "client_loss_mean": loss_mean,
            "client_loss_max": loss_mean,
            "steps_total": steps_total,
        }
        return new_global, new_state, metrics

    return round_step


def init_residuals(global_params: PyTree, n_clients: int) -> jnp.ndarray:
    """Zero error-feedback state for the compressed round path: one flat
    fp32 residual vector per client, (C, n_params)."""
    from repro.utils.pytree import tree_size

    return jnp.zeros((n_clients, tree_size(global_params)), jnp.float32)


def _make_compressed_round_step(client_update, strategy: Strategy, spec: RoundSpec):
    """Compressed-wire parallel round step (see module docstring).

    Per round: vmap local training, flatten per-client deltas, add the
    carried residual, encode with ``spec.codec``, aggregate straight off the
    encoded payload (``codec.reduce`` — the fused dequant+reduce kernel for
    Int8), and keep ``delta - decode(payload)`` as the next residual.
    """
    from repro.utils.pytree import tree_flatten_to_vector, tree_unflatten_from_vector

    codec = spec.codec

    def round_step(
        global_params, server_state, residuals, batches, weights, step_budgets, rnd
    ):
        new_params, losses, steps = jax.vmap(
            client_update, in_axes=(None, 0, 0)
        )(global_params, batches, step_budgets)

        flat_global = tree_flatten_to_vector(global_params)
        deltas = jax.vmap(
            lambda p: tree_flatten_to_vector(p) - flat_global
        )(new_params)                                     # (C, n_params) fp32
        deltas = deltas + residuals                       # error feedback in
        enc = codec.encode_batch(deltas)                  # the wire payload
        new_residuals = deltas - codec.decode_batch(enc)  # untransmitted mass

        avg_delta = codec.reduce(enc, weights)            # fused server decode
        avg_params = tree_unflatten_from_vector(
            flat_global + avg_delta, global_params
        )
        new_global, new_state = strategy.server_update(
            avg_params, global_params, server_state, rnd
        )
        metrics = {
            "client_loss_mean": jnp.mean(losses),
            "client_loss_max": jnp.max(losses),
            "steps_total": jnp.sum(steps),
            "residual_norm_mean": jnp.mean(
                jnp.linalg.norm(new_residuals, axis=1)
            ),
        }
        return new_global, new_state, new_residuals, metrics

    return round_step

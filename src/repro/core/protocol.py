"""The Flower protocol, as in-process message dataclasses.

The paper's server speaks ``fit`` / ``evaluate`` messages carrying serialized
global parameters plus a strategy-controlled config dict (e.g. the number of
local epochs, or a cutoff time tau).  We keep the message *shape* —
FitIns/FitRes/EvaluateIns/EvaluateRes with an opaque config mapping — and the
parameter serialization round-trip, while transport is in-process
(DESIGN.md §7.2).
"""
from __future__ import annotations

import io
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

PyTree = Any


# ---------------- parameter wire format ----------------
@dataclass
class Parameters:
    """Serialized pytree: list of raw ndarray buffers + dtype/shape manifest."""

    tensors: list[bytes]
    manifest: list[tuple[str, tuple[int, ...]]]  # (dtype_str, shape)

    @property
    def num_bytes(self) -> int:
        return sum(len(t) for t in self.tensors)


def pytree_to_parameters(tree: PyTree) -> Parameters:
    leaves = jax.tree.leaves(tree)
    tensors, manifest = [], []
    for leaf in leaves:
        arr = np.asarray(leaf)
        # bfloat16 has no portable buffer protocol: ship as uint16 view
        if arr.dtype.name == "bfloat16":
            raw = arr.view(np.uint16)
            tensors.append(raw.tobytes())
            manifest.append(("bfloat16", tuple(arr.shape)))
        else:
            tensors.append(arr.tobytes())
            manifest.append((arr.dtype.name, tuple(arr.shape)))
    return Parameters(tensors=tensors, manifest=manifest)


def parameters_to_pytree(params: Parameters, like: PyTree) -> PyTree:
    import jax.numpy as jnp

    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(params.tensors), "wire/client structure mismatch"
    out = []
    for buf, (dtype, shape), leaf in zip(params.tensors, params.manifest, leaves):
        if dtype == "bfloat16":
            arr = np.frombuffer(buf, dtype=np.uint16).reshape(shape)
            out.append(jnp.asarray(arr).view(jnp.bfloat16))
        else:
            out.append(jnp.asarray(np.frombuffer(buf, dtype=dtype).reshape(shape)))
    return jax.tree.unflatten(treedef, out)


# ---------------- messages ----------------
@dataclass
class FitIns:
    parameters: Parameters | PyTree
    config: dict = field(default_factory=dict)   # e.g. {"epochs": 5, "tau_s": 120.0}


@dataclass
class FitRes:
    parameters: Parameters | PyTree               # updated params (or delta)
    num_examples: int
    metrics: dict = field(default_factory=dict)  # incl. steps_done, t_compute_s


@dataclass
class EvaluateIns:
    parameters: Parameters | PyTree
    config: dict = field(default_factory=dict)


@dataclass
class EvaluateRes:
    loss: float
    num_examples: int
    metrics: dict = field(default_factory=dict)


@dataclass
class ClientProperties:
    """What the RPC layer knows about a device (drives tau assignment)."""

    client_id: int
    device_profile: str = "generic"
    uplink_mbps: float = 20.0
    downlink_mbps: float = 50.0

"""The Flower protocol, as in-process message dataclasses.

The paper's server speaks ``fit`` / ``evaluate`` messages carrying serialized
global parameters plus a strategy-controlled config dict (e.g. the number of
local epochs, or a cutoff time tau).  We keep the message *shape* —
FitIns/FitRes/EvaluateIns/EvaluateRes with an opaque config mapping — and the
parameter serialization round-trip, while transport is in-process
(DESIGN.md §7.2).

Two wire formats for parameters:

- ``Parameters``: the full-precision pytree wire (list of raw ndarray
  buffers + dtype/shape manifest) — what FitIns downlinks carry.
- ``CompressedParameters``: a codec-encoded *delta* payload (the serialized
  output of ``codec.encode`` via ``codec.wire_payload``, so e.g. Int8
  encoder padding never crosses the wire).  ``FitRes.parameters`` carries
  this on the compressed uplink; ``Strategy.aggregate_fit`` decodes it
  against the round's global parameters.  ``num_bytes`` is the actual
  payload size — by construction equal to ``codec.wire_bytes(n_params)`` —
  which is what the Server charges the CostModel per client.

Transport is in-process, so ``CompressedParameters`` carries the codec
instance itself; an RPC deployment would replace that field with a codec
registry key plus its config, leaving the payload bytes unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

PyTree = Any


# ---------------- ndarray buffer codec (shared by both wire formats) ----------------
def _encode_array(arr: np.ndarray) -> tuple[bytes, str, tuple[int, ...]]:
    """-> (raw buffer, dtype name, shape); bfloat16 ships as a uint16 view
    (it has no portable buffer protocol)."""
    arr = np.asarray(arr)
    if arr.dtype.name == "bfloat16":
        return arr.view(np.uint16).tobytes(), "bfloat16", tuple(arr.shape)
    return arr.tobytes(), arr.dtype.name, tuple(arr.shape)


def _decode_array(buf: bytes, dtype: str, shape: tuple[int, ...]):
    import jax.numpy as jnp

    if dtype == "bfloat16":
        arr = np.frombuffer(buf, dtype=np.uint16).reshape(shape)
        return jnp.asarray(arr).view(jnp.bfloat16)
    return jnp.asarray(np.frombuffer(buf, dtype=dtype).reshape(shape))


# ---------------- parameter wire format ----------------
@dataclass
class Parameters:
    """Serialized pytree: list of raw ndarray buffers + dtype/shape manifest."""

    tensors: list[bytes]
    manifest: list[tuple[str, tuple[int, ...]]]  # (dtype_str, shape)

    @property
    def num_bytes(self) -> int:
        return sum(len(t) for t in self.tensors)


def pytree_to_parameters(tree: PyTree) -> Parameters:
    tensors, manifest = [], []
    for leaf in jax.tree.leaves(tree):
        buf, dtype, shape = _encode_array(leaf)
        tensors.append(buf)
        manifest.append((dtype, shape))
    return Parameters(tensors=tensors, manifest=manifest)


def parameters_to_pytree(params: Parameters, like: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(params.tensors), "wire/client structure mismatch"
    out = [
        _decode_array(buf, dtype, shape)
        for buf, (dtype, shape) in zip(params.tensors, params.manifest)
    ]
    return jax.tree.unflatten(treedef, out)


# ---------------- compressed-delta wire format ----------------
@dataclass
class CompressedParameters:
    """A codec-encoded delta payload: what the compressed uplink carries.

    ``tensors``/``manifest`` serialize the array fields of the codec's wire
    payload (named by ``fields``); python scalars (e.g. the unpadded length
    ``n``) ride in ``aux``.  Decode against the global params the client
    trained from: ``global + codec.decode(payload)``.
    """

    codec: Any                                   # UpdateCodec (registry key in RPC)
    tensors: list[bytes]
    manifest: list[tuple[str, tuple[int, ...]]]  # (dtype_str, shape)
    fields: list[str]                            # payload dict key per tensor
    aux: dict = field(default_factory=dict)      # non-array payload fields
    n_params: int = 0

    @property
    def num_bytes(self) -> int:
        """Actual uplink payload size (== codec.wire_bytes(n_params))."""
        return sum(len(t) for t in self.tensors)


def compress_to_wire(codec, enc, n_params: int) -> CompressedParameters:
    """Serialize a codec payload into the uplink wire object.

    ``enc`` is either a flat ``codec.encode`` payload dict or a
    ``StructuredUpdate`` (segmented codecs): per segment i, the fields of
    ``codec.segment_wire_payload`` are namespaced ``s{i}.<key>`` — one flat
    field list, so the tensors/aux/num_bytes machinery is shared."""
    from .compression import StructuredUpdate

    if isinstance(enc, StructuredUpdate):
        items = [
            (f"s{i}.{key}", value)
            for i, (seg, p) in enumerate(zip(enc.segments, enc.payloads))
            for key, value in codec.segment_wire_payload(p, seg).items()
        ]
    else:
        items = list(codec.wire_payload(enc).items())
    tensors, manifest, fields, aux = [], [], [], {}
    for key, value in items:
        if isinstance(value, (int, float)):
            aux[key] = value
            continue
        buf, dtype, shape = _encode_array(value)
        tensors.append(buf)
        manifest.append((dtype, shape))
        fields.append(key)
    return CompressedParameters(
        codec=codec, tensors=tensors, manifest=manifest, fields=fields,
        aux=aux, n_params=n_params,
    )


def wire_to_enc(cp: CompressedParameters) -> dict:
    """Rebuild the decodable codec payload from the serialized wire object:
    aux scalars + deserialized arrays through ``codec.from_wire``.  The ONE
    place the CompressedParameters deserialization protocol lives — both
    the per-client dense decode (``wire_to_pytree``) and the Strategy's
    grouped kernel reduce consume it."""
    from .compression import StructuredUpdate

    payload = dict(cp.aux)
    for key, buf, (dtype, shape) in zip(cp.fields, cp.tensors, cp.manifest):
        payload[key] = _decode_array(buf, dtype, shape)
    codec = cp.codec
    if getattr(codec, "segments", None) is not None:
        segs = codec.segments
        per: list[dict] = [{} for _ in segs]
        for key, value in payload.items():
            si, sub = key.split(".", 1)
            per[int(si[1:])][sub] = value
        return StructuredUpdate(segs, tuple(
            codec.segment_from_wire(fields, seg)
            for fields, seg in zip(per, segs)
        ))
    return codec.from_wire(payload)


def wire_to_pytree(cp: CompressedParameters, global_params: PyTree) -> PyTree:
    """Decode a compressed uplink against the round's global parameters."""
    from .compression import decompress_update

    return decompress_update(cp.codec, wire_to_enc(cp), global_params)


# ---------------- messages ----------------
@dataclass
class FitIns:
    parameters: Parameters | PyTree
    config: dict = field(default_factory=dict)   # e.g. {"epochs": 5, "tau_s": 120.0}


@dataclass
class FitRes:
    parameters: Parameters | CompressedParameters | PyTree  # update (or delta)
    num_examples: int
    metrics: dict = field(default_factory=dict)  # incl. steps_done, t_compute_s
    # rounds elapsed between the global this update trained from and the
    # round that consumes it; the scheduler-driven Server stamps it when a
    # buffered-async arrival is aggregated late (0 = fresh, the default).
    # FedBuffStrategy discounts aggregation weight by (1 + staleness)^-alpha.
    staleness: int = 0


@dataclass
class EvaluateIns:
    parameters: Parameters | PyTree
    config: dict = field(default_factory=dict)


@dataclass
class EvaluateRes:
    loss: float
    num_examples: int
    metrics: dict = field(default_factory=dict)


@dataclass
class ClientProperties:
    """What the RPC layer knows about a device (drives tau + codec choice)."""

    client_id: int
    device_profile: str = "generic"
    uplink_mbps: float = 20.0
    downlink_mbps: float = 50.0

"""FL clients — the paper's §4 on-device trainers, as JAX processes.

``Client`` mirrors the Flower client surface the paper describes (§4.1):
``get_weights`` / ``fit`` / ``evaluate`` / ``properties``.  ``JaxClient``
owns a local dataset shard + device profile and runs jitted local SGD; it
honors the server's config knobs: ``epochs``, the cutoff step budget
``max_steps`` (tau), and the uplink ``codec``.  When a codec is configured
the client ships a ``CompressedParameters`` delta payload (the actual
encoded wire, not an fp32 pytree) and carries its error-feedback residual
across rounds, mirroring the jitted engine's codec-owned client state.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import ClientDataset
from repro.optim import Optimizer, sgd
from repro.utils.pytree import (
    tree_bytes, tree_size, tree_sq_norm, tree_sub, tree_where,
)

from .compression import compress_update
from .cost_model import PROFILES
from .protocol import (
    ClientProperties, EvaluateIns, EvaluateRes, FitIns, FitRes,
    compress_to_wire,
)

PyTree = Any

# jitted local-training fns shared across clients (same loss/steps/config ->
# same program; per-instance caches would recompile for every client)
_GLOBAL_FIT_CACHE: dict = {}


class Client:
    """Protocol-level client interface (paper §4.1)."""

    def get_weights(self, config: dict) -> PyTree:
        raise NotImplementedError

    def fit(self, ins: FitIns) -> FitRes:
        raise NotImplementedError

    def evaluate(self, ins: EvaluateIns) -> EvaluateRes:
        raise NotImplementedError

    def properties(self) -> ClientProperties:
        """Device/network facts the server's codec + tau policies consume."""
        return ClientProperties(client_id=-1)

    def reset_state(self) -> None:
        """Drop per-trajectory carry (e.g. error-feedback residuals).

        The Server calls this at the start of every ``run`` so reused client
        objects do not leak one experiment's compression state into the
        next."""

    def discard_update(self) -> None:
        """The scheduler discarded this client's last ``fit`` (deadline
        drop / staleness expiry): roll back any state that assumed the
        update was delivered.  ``fit`` commits the error-feedback residual
        as if the wire reached the server; an update that never did must
        leave the residual exactly as it entered the round — the same
        contract as the jitted engine's participation mask.  One level of
        rollback suffices: a client has at most one fit in flight (the
        Server never re-samples a busy client)."""

    def export_state(self):
        """Round-to-round carry as one flat fp32 row (or, for a segmented
        codec, a tuple of per-segment rows), or None if there is none —
        what ``LazyClientPool`` spills into a ``CohortState`` when it
        evicts this client (core/population.py's eviction contract)."""
        return None

    def import_state(self, state) -> None:
        """Rehydrate a previously ``export_state``-ed row on a freshly
        materialized client."""


@dataclass
class JaxClient(Client):
    client_id: int
    loss_fn: Callable                    # (params, batch) -> (loss, metrics)
    dataset: ClientDataset
    batch_size: int = 32
    optimizer: Optimizer | None = None
    trainable_mask: PyTree | None = None
    device_profile: str = "generic"
    _params: PyTree = None
    _fit_cache: dict = field(default_factory=dict, repr=False)
    _residual: Any = field(default=None, repr=False)  # error-feedback carry
    # pre-fit residual, kept until the scheduler's verdict: discard_update
    # rolls back to it when the arrival is dropped/expired
    _residual_prev: Any = field(default=None, repr=False)

    def __post_init__(self):
        if self.optimizer is None:
            self.optimizer = sgd(0.05)

    def get_weights(self, config: dict) -> PyTree:
        return self._params

    def properties(self) -> ClientProperties:
        prof = PROFILES.get(self.device_profile)
        return ClientProperties(
            client_id=self.client_id,
            device_profile=self.device_profile,
            uplink_mbps=prof.uplink_mbps if prof else 20.0,
            downlink_mbps=prof.downlink_mbps if prof else 50.0,
        )

    def reset_state(self) -> None:
        self._residual = None
        self._residual_prev = None

    def discard_update(self) -> None:
        self._residual = self._residual_prev

    def export_state(self):
        if self._residual is None:
            return None
        if isinstance(self._residual, tuple):  # segmented: leafwise rows
            return tuple(
                r if isinstance(r, tuple) else np.asarray(r)
                for r in self._residual
            )
        return np.asarray(self._residual)

    def import_state(self, state) -> None:
        if isinstance(state, (tuple, list)):  # segmented: leafwise rows
            row = tuple(
                r if isinstance(r, tuple) else jnp.asarray(r, jnp.float32)
                for r in state
            )
        else:
            row = jnp.asarray(state, jnp.float32)
        self._residual = row
        # the rollback point is the rehydrated row: a discard_update right
        # after re-materialization must be a no-op, not a reset to None
        self._residual_prev = row

    def steps_per_epoch(self) -> int:
        return self.dataset.steps_per_epoch(self.batch_size)

    @staticmethod
    def _comm_time_s(ins: FitIns, cfg: dict, prof) -> float:
        """This round's transfer time on the device's own links: the full
        global model down, the codec's wire (or the full model) up.  The
        downlink is always a raw pytree on the in-process transport."""
        codec = cfg.get("codec")
        down_b = tree_bytes(ins.parameters)
        up_b = (
            codec.wire_bytes(tree_size(ins.parameters))
            if codec is not None else down_b
        )
        return prof.comm_time_s(up_b, down_b)

    def _build_fit(self, n_steps: int, mu: float, lr: float):
        opt = sgd(lr) if lr else self.optimizer
        mask = self.trainable_mask

        def total_loss(params, batch, global_params):
            loss, metrics = self.loss_fn(params, batch)
            if mu > 0:
                loss = loss + 0.5 * mu * tree_sq_norm(tree_sub(params, global_params))
            return loss, metrics

        @jax.jit
        def fit_steps(global_params, batches, budget):
            opt_state = opt.init(global_params)

            def step(carry, batch):
                params, opt_state, i = carry
                (loss, _), grads = jax.value_and_grad(total_loss, has_aux=True)(
                    params, batch, global_params
                )
                new_params, new_opt = opt.update(grads, params, opt_state, i)
                if mask is not None:
                    new_params = jax.tree.map(
                        lambda n, o, m: n if m else o, new_params, params, mask
                    )
                live = i < budget
                params = tree_where(live, new_params, params)
                opt_state = tree_where(live, new_opt, opt_state)
                return (params, opt_state, i + 1), jnp.where(live, loss, 0.0)

            (params, _, _), losses = jax.lax.scan(
                step, (global_params, opt_state, jnp.zeros((), jnp.int32)), batches
            )
            n_steps_done = jnp.minimum(budget, losses.shape[0])
            return params, jnp.sum(losses) / jnp.maximum(1, n_steps_done)

        return fit_steps

    def fit(self, ins: FitIns) -> FitRes:
        self._residual_prev = self._residual  # rollback point (discard_update)
        cfg = ins.config
        epochs = int(cfg.get("epochs", 1))
        spe = self.steps_per_epoch()
        full_steps = epochs * spe
        budget = int(cfg.get("max_steps", full_steps))
        # on-device deadline enforcement: a client that knows its own step
        # time AND link speeds truncates local work so compute + comm fit
        # the round cutoff, instead of being dropped by the scheduler (the
        # server-side FedTau budget is compute-only; this closes the gap
        # for comm-heavy rounds and covers strategies shipping only the
        # deadline).  If even one step + comm cannot fit, the client tries
        # anyway — the scheduler will judge it.
        deadline = float(cfg.get("deadline_s", 0.0))
        prof = PROFILES.get(self.device_profile)
        if deadline > 0.0 and prof is not None:
            budget = max(
                1, min(budget, prof.steps_in_budget(
                    max(0.0, deadline - self._comm_time_s(ins, cfg, prof))
                ))
            )
        mu = float(cfg.get("mu", 0.0))
        lr = float(cfg.get("lr", 0.0))

        batches = [self.dataset.next_batch(self.batch_size) for _ in range(full_steps)]
        stacked = {k: np.stack([b[k] for b in batches]) for k in batches[0]}

        # lr == 0.0 means the built closure captures self.optimizer, so the
        # optimizer's identity must be part of the key — without it, two
        # clients sharing a loss_fn but constructed with different
        # optimizers (e.g. different SGD momenta) would silently share the
        # first client's update rule
        cache_key = (
            id(self.loss_fn), id(self.trainable_mask), full_steps, mu, lr,
            None if lr else id(self.optimizer),
        )
        if cache_key not in _GLOBAL_FIT_CACHE:
            _GLOBAL_FIT_CACHE[cache_key] = self._build_fit(full_steps, mu, lr)
        fit_steps = _GLOBAL_FIT_CACHE[cache_key]
        params, mean_loss = fit_steps(
            ins.parameters, stacked, jnp.asarray(budget, jnp.int32)
        )
        self._params = params
        steps_done = min(budget, full_steps)
        metrics = {
            "loss": float(mean_loss),
            "steps_done": steps_done,
            "device_profile": self.device_profile,
        }

        codec = cfg.get("codec")
        if codec is not None:
            # compressed uplink: encode the delta (plus the carried error-
            # feedback residual) and ship the actual wire payload
            n_params = tree_size(params)
            residual = self._residual
            if codec.segments is not None:
                # segmented carry is a tuple of per-segment rows; anything
                # else (fresh client, codec switch) re-inits inside
                # compress_update
                if not isinstance(residual, tuple) or len(residual) != len(
                    codec.segments
                ):
                    residual = None
            elif (
                residual is None
                or isinstance(residual, tuple)
                or residual.shape != (n_params,)
            ):
                residual = jnp.zeros((n_params,), jnp.float32)
            enc, self._residual = compress_update(
                codec, params, ins.parameters, residual=residual
            )
            wire = compress_to_wire(codec, enc, n_params)
            metrics["wire_bytes"] = wire.num_bytes
            return FitRes(
                parameters=wire, num_examples=len(self.dataset), metrics=metrics,
            )

        return FitRes(
            parameters=params, num_examples=len(self.dataset), metrics=metrics,
        )

    def evaluate(self, ins: EvaluateIns) -> EvaluateRes:
        n = min(len(self.dataset), 512)
        batch = {"x": self.dataset.x[:n], "y": self.dataset.y[:n]}
        loss, metrics = jax.jit(self.loss_fn)(ins.parameters, batch)
        return EvaluateRes(
            loss=float(loss),
            num_examples=n,
            metrics={k: float(v) for k, v in metrics.items()},
        )

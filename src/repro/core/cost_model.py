"""System-cost model: per-device step time + power -> round time & energy.

The paper's central measurement (§5) is that FL accuracy gains carry *system
costs* — convergence time and energy — that depend on device hardware.  With
no physical fleet here, we keep the *mechanism* and calibrate the constants
to the paper's own tables:

- Table 2a (Jetson TX2 GPU, ResNet-18/CIFAR-10, C=10, 40 rounds):
    E=1: 17.63 min, 10.21 kJ | E=5: 36.83, 50.54 | E=10: 80.32, 100.95
- Table 3: CPU training is 1.27x slower than GPU at equal E
  (102 vs 80.32 min); per-round GPU compute ~1.99 min.
- Table 2b (Android, head model, E=5, 20 rounds):
    C=4: 30.7 min/10.4 kJ | C=7: 31.3/19.72 | C=10: 31.8/28.0

Derivations used for calibration (documented in benchmarks/table2a.py):
per-round GPU time at E=10 is ~1.99 min -> with ~78 steps/epoch that is
~153 ms/step; energy 100.95 kJ / (10 clients * 40 rounds * 780 steps) ~ 32 J
of marginal energy per client-step plus idle draw.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np


def link_time_s(up_bytes, down_bytes, uplink_mbps, downlink_mbps):
    """The ONE link-time formula (CostModel charges it, JaxClient truncates
    its deadline budget by it, the Server windows wasted work with it, and
    the population layer evaluates it vectorized over candidate pools) —
    elementwise over arrays, scalar for scalars."""
    return up_bytes * 8 / (uplink_mbps * 1e6) + down_bytes * 8 / (
        downlink_mbps * 1e6
    )


@dataclass(frozen=True)
class DeviceProfile:
    """Hardware profile of one FL client class."""

    name: str
    step_time_s: float          # wall time per local training step (batch fixed)
    active_power_w: float       # board power while training
    idle_power_w: float = 2.0   # draw while waiting (stragglers burn this)
    uplink_mbps: float = 20.0
    downlink_mbps: float = 50.0

    def steps_in_budget(self, tau_s: float) -> int:
        """How many local steps fit in a cutoff budget tau (paper Table 3)."""
        return int(np.floor(tau_s / self.step_time_s))

    def comm_time_s(self, up_bytes: float, down_bytes: float) -> float:
        """Transfer time on this device's links (``link_time_s``)."""
        return link_time_s(
            up_bytes, down_bytes, self.uplink_mbps, self.downlink_mbps
        )


# calibrated against the paper's tables (see module docstring)
JETSON_TX2_GPU = DeviceProfile("jetson-tx2-gpu", step_time_s=0.153, active_power_w=9.0,
                               idle_power_w=2.5, uplink_mbps=80, downlink_mbps=120)
JETSON_TX2_CPU = DeviceProfile("jetson-tx2-cpu", step_time_s=0.194, active_power_w=7.5,
                               idle_power_w=2.0, uplink_mbps=80, downlink_mbps=120)
PIXEL_4 = DeviceProfile("pixel-4", step_time_s=0.210, active_power_w=4.5, idle_power_w=0.8,
                        uplink_mbps=20, downlink_mbps=50)
PIXEL_3 = DeviceProfile("pixel-3", step_time_s=0.290, active_power_w=4.2, idle_power_w=0.8,
                        uplink_mbps=18, downlink_mbps=45)
PIXEL_2 = DeviceProfile("pixel-2", step_time_s=0.370, active_power_w=4.0, idle_power_w=0.7,
                        uplink_mbps=15, downlink_mbps=40)
GALAXY_TAB_S6 = DeviceProfile("galaxy-tab-s6", step_time_s=0.240, active_power_w=5.0,
                              idle_power_w=0.9, uplink_mbps=22, downlink_mbps=55)
GALAXY_TAB_S4 = DeviceProfile("galaxy-tab-s4", step_time_s=0.330, active_power_w=4.8,
                              idle_power_w=0.9, uplink_mbps=18, downlink_mbps=48)
TPU_V5E_CHIP = DeviceProfile("tpu-v5e-chip", step_time_s=0.010, active_power_w=170.0,
                             idle_power_w=60.0, uplink_mbps=400_000, downlink_mbps=400_000)

PROFILES: dict[str, DeviceProfile] = {
    p.name: p
    for p in (
        JETSON_TX2_GPU, JETSON_TX2_CPU, PIXEL_4, PIXEL_3, PIXEL_2,
        GALAXY_TAB_S6, GALAXY_TAB_S4, TPU_V5E_CHIP,
    )
}

# the paper's AWS Device Farm fleet (Table 1)
AWS_DEVICE_FARM = ("pixel-4", "pixel-3", "pixel-2", "galaxy-tab-s6", "galaxy-tab-s4")


# battery-powered device classes sit below this idle draw; they churn (lose
# charge, lose WiFi, get picked up) far more than plugged-in edge boards
_BATTERY_IDLE_W = 1.5


def _stream_uniform(seed: int, rnd: int, stream: int, ids: np.ndarray) -> np.ndarray:
    """Deterministic uniforms in [0, 1) per (seed, rnd, stream, client_id).

    A splitmix64 finalizer over the id array: each client's draw depends
    only on its own id and the (seed, rnd, stream) key, so streaming any
    candidate pool — in any order, of any size — yields the same verdict
    per client as streaming the full fleet.  O(len(ids)), never O(N).
    """
    u64 = np.uint64
    key = (
        seed * 0x9E3779B97F4A7C15
        + rnd * 0xBF58476D1CE4E5B9
        + stream * 0x94D049BB133111EB
    ) & 0xFFFFFFFFFFFFFFFF
    with np.errstate(over="ignore"):  # mod-2^64 wraparound is the algorithm
        x = np.asarray(ids).astype(np.uint64) ^ u64(key)
        x = x + u64(0x9E3779B97F4A7C15)
        x = (x ^ (x >> u64(30))) * u64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> u64(27))) * u64(0x94D049BB133111EB)
        x = x ^ (x >> u64(31))
    return (x >> u64(11)).astype(np.float64) * (1.0 / (1 << 53))


@dataclass(frozen=True)
class AvailabilityTrace:
    """Seeded per-client availability + step-time jitter schedules.

    Real fleets churn: phones drop off charger/WiFi mid-experiment, new
    devices enroll late, and a device's step time wobbles round-to-round
    with thermals and background load.  This trace makes that churn a
    *deterministic function of (seed, round)* so an experiment — and its
    control — can be replayed exactly:

    - ``dropout``: per-client probability of sitting a round out, drawn
      i.i.d. per (seed, round).  ``from_profiles`` derives it from the
      ``DeviceProfile``: battery-class devices (idle draw < 1.5 W) churn at
      ``mobile_dropout``, plugged-in boards at ``plugged_dropout``.
    - ``join_round``: the first round a client exists (late enrollment).
    - ``jitter_std``: sigma of a lognormal multiplicative step-time factor
      (1.0 = nominal), fed to ``CostModel.client_round_cost``.

    ``full(n)`` is the degenerate trace (everyone always up, no jitter) —
    by construction it reproduces the pre-scheduler lockstep fleet.

    Two execution paths, one schedule each:

    - the legacy **full-vector** path (``available`` / ``step_jitter``)
      draws the whole fleet per round from ``default_rng((seed, rnd,
      stream))`` — O(N), bitwise-pinned by the PR-5 tests;
    - the **streamed** path (``available_for`` / ``step_jitter_for``)
      evaluates only the ids handed to it, via a per-(seed, rnd, id)
      splitmix64 hash — O(pool), pool-composition-independent, what
      population-mode sampling uses.  A population-backed trace
      (``from_profiles`` over packed columns) runs the streamed schedule on
      *both* surfaces, so the two views of one trace always agree; a legacy
      per-client-tuple trace keeps its original full-vector draws, which
      are a *different* (equally deterministic) schedule from its streamed
      draws.
    """

    n_clients: int
    seed: int = 0
    dropout: tuple[float, ...] = ()        # () = nobody drops
    join_round: tuple[int, ...] = ()       # () = everyone from round 1
    jitter_std: float = 0.0
    # population-backed traces: one dropout per device *class*, resolved
    # per-id through the packed profile codes — nothing here is O(N)
    class_dropout: tuple[float, ...] = ()
    population: Any = None

    def __post_init__(self):
        if self.dropout:
            assert len(self.dropout) == self.n_clients
        if self.join_round:
            assert len(self.join_round) == self.n_clients
        if self.class_dropout:
            assert self.population is not None and len(self.class_dropout) == (
                self.population.n_profiles
            )
        if self.population is not None:
            assert not self.dropout and not self.join_round, (
                "population-backed traces stream per-class schedules; "
                "per-client tuples would be the O(N) state this layer avoids"
            )

    @classmethod
    def full(cls, n_clients: int) -> "AvailabilityTrace":
        return cls(n_clients=n_clients)

    @classmethod
    def from_profiles(
        cls,
        profiles,
        *,
        seed: int = 0,
        mobile_dropout: float = 0.15,
        plugged_dropout: float = 0.02,
        jitter_std: float = 0.1,
        late_join: int = 0,
    ) -> "AvailabilityTrace":
        """Churn schedule from the fleet's hardware profiles.

        ``profiles`` is either a ``list[DeviceProfile]`` (the legacy
        per-client fleet) or a packed ``Population``: the population path
        reads the per-*class* idle-power column directly and stores one
        dropout rate per class — it never materializes N python objects,
        and the resulting trace streams (``available_for``) on every
        surface.  ``late_join`` > 0 enrolls that many of the slowest
        clients only from round ``late_join + 1`` (a staggered rollout;
        legacy path only — it is inherently a per-client schedule).
        """
        if hasattr(profiles, "profile_codes"):  # a packed Population
            if late_join:
                raise ValueError(
                    "late_join needs a per-client schedule; pass an explicit "
                    "list[DeviceProfile] instead of a packed Population"
                )
            class_drop = tuple(
                mobile_dropout if w < _BATTERY_IDLE_W else plugged_dropout
                for w in profiles.idle_power_w_table
            )
            return cls(
                n_clients=len(profiles), seed=seed, jitter_std=jitter_std,
                class_dropout=class_drop, population=profiles,
            )
        drop = tuple(
            mobile_dropout if p.idle_power_w < _BATTERY_IDLE_W else plugged_dropout
            for p in profiles
        )
        join = [1] * len(profiles)
        if late_join > 0:
            slowest = np.argsort([-p.step_time_s for p in profiles])
            for cid in slowest[:late_join]:
                join[int(cid)] = late_join + 1
        return cls(
            n_clients=len(profiles), seed=seed, dropout=drop,
            join_round=tuple(join), jitter_std=jitter_std,
        )

    def _rng(self, rnd: int, stream: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, rnd, stream))

    def _dropout_for(self, ids: np.ndarray) -> np.ndarray | None:
        if self.population is not None and self.class_dropout:
            codes = self.population.profile_codes[ids]
            return np.asarray(self.class_dropout)[codes]
        if self.dropout:
            return np.asarray(self.dropout)[ids]
        return None

    def available_for(self, rnd: int, ids) -> np.ndarray:
        """Streamed availability: one bool per id in ``ids``, O(len(ids)).

        Each client's draw is a pure function of (seed, rnd, client_id) —
        the verdict for client c is identical whatever candidate pool (or
        full fleet) it is evaluated in.  This is the population-scale path:
        sampling consults it for the candidate pool only, never drawing an
        O(N) fleet vector.
        """
        ids = np.asarray(ids, np.int64)
        up = np.ones(ids.shape, bool)
        drop = self._dropout_for(ids)
        if drop is not None:
            up &= _stream_uniform(self.seed, rnd, 0, ids) >= drop
        if self.join_round:
            up &= np.asarray(self.join_round)[ids] <= rnd
        return up

    def step_jitter_for(self, rnd: int, ids) -> np.ndarray:
        """Streamed lognormal step-time factors per id (Box-Muller over two
        hash streams; same pool-independence contract as available_for)."""
        ids = np.asarray(ids, np.int64)
        if self.jitter_std <= 0.0:
            return np.ones(ids.shape)
        u1 = _stream_uniform(self.seed, rnd, 2, ids)
        u2 = _stream_uniform(self.seed, rnd, 3, ids)
        z = np.sqrt(-2.0 * np.log1p(-u1)) * np.cos(2.0 * np.pi * u2)
        return np.exp(self.jitter_std * z)

    def available(self, rnd: int, client_id: int | None = None):
        """(n_clients,) bool — who is up this round (or one client's bool).

        Population-backed traces answer from the streamed schedule (still
        O(N) on *this* surface — prefer ``available_for`` over a pool);
        legacy traces keep their bitwise-pinned full-vector draws.
        """
        if self.population is not None:
            up = self.available_for(rnd, np.arange(self.n_clients))
            return up if client_id is None else bool(up[client_id])
        up = np.ones(self.n_clients, bool)
        if self.join_round:
            up &= np.asarray(self.join_round) <= rnd
        if self.dropout:
            u = self._rng(rnd, 0).random(self.n_clients)
            up &= u >= np.asarray(self.dropout)
        return up if client_id is None else bool(up[client_id])

    def step_jitter(self, rnd: int) -> np.ndarray:
        """(n_clients,) multiplicative step-time factors for this round."""
        if self.population is not None:
            return self.step_jitter_for(rnd, np.arange(self.n_clients))
        if self.jitter_std <= 0.0:
            return np.ones(self.n_clients)
        return np.exp(
            self._rng(rnd, 1).normal(0.0, self.jitter_std, self.n_clients)
        )

    # ---- vectorized schedule precompute (rounds-as-scan, PR 8) ----
    #
    # The scanned trainer needs the whole run's churn/jitter decided up
    # front as (R, C) matrices it can slice per round inside lax.scan.
    # Rows are the SAME per-round draws the event-driven Server.run makes
    # (same hash streams / tuple-seeded generators), just stacked — so a
    # scanned run and a python-driven run see identical schedules.

    def available_matrix(self, rounds) -> np.ndarray:
        """(R, C) float32 0/1 — ``available(r)`` stacked over ``rounds``."""
        return np.stack(
            [self.available(int(r)) for r in rounds]
        ).astype(np.float32)

    def step_jitter_matrix(self, rounds) -> np.ndarray:
        """(R, C) float64 — ``step_jitter(r)`` stacked over ``rounds``."""
        return np.stack([self.step_jitter(int(r)) for r in rounds])

    def cohort_priority_matrix(self, rounds) -> np.ndarray:
        """(R, C) float32 uniforms on hash stream 4 — per-round sampling
        priorities for on-device cohort selection (lowest-k available
        priorities win; see ``rounds.cohort_dispatch_mask``).  Stream 4 is
        unused by dropout (0) and jitter (2, 3), so cohort draws never
        perturb the churn schedule."""
        ids = np.arange(self.n_clients)
        return np.stack(
            [_stream_uniform(self.seed, int(r), 4, ids) for r in rounds]
        ).astype(np.float32)


@dataclass
class ClientCost:
    """Per-round, per-client accounting record.

    ``t_arrival_s`` records when the report lands on the round's *virtual
    timeline* (launch time + t_total on the scheduler's clock).  The Server
    stamps it at dispatch and derives ``scheduler.Arrival.finish_t`` from
    it, so this field is the source of truth the policies ultimately
    schedule against.  0.0 means "not scheduled" (legacy lockstep
    accounting, where only t_total_s matters).
    """

    client_id: int
    profile: str
    steps: int
    t_compute_s: float
    t_comm_s: float
    e_compute_j: float
    e_comm_j: float
    t_arrival_s: float = 0.0

    @property
    def t_total_s(self) -> float:
        return self.t_compute_s + self.t_comm_s

    @property
    def e_total_j(self) -> float:
        return self.e_compute_j + self.e_comm_j


@dataclass
class CostModel:
    """Simulates the fleet's time/energy for each FL round."""

    profiles: list[DeviceProfile]
    update_bytes: int                      # full-precision model payload
    comm_power_w: float = 1.2
    # packed Population: client_id -> device class via profile codes instead
    # of the legacy round-robin over `profiles` (which may then be empty)
    population: Any = None
    # mesh-collective accounting (the shard_map execution substrate): tiers
    # ordered outer->inner like `client_axes`, e.g. (("pod", 2), ("data", 4)).
    # None = no mesh (vmap/sequential): rounds ship client uplinks only and
    # `round_comm_bytes` is unchanged.  `collective` mirrors
    # RoundSpec.collective ("fp32" | "int8").
    mesh_tiers: tuple = None
    collective: str = "fp32"
    collective_block: int = 256

    def profile_for(self, client_id: int) -> DeviceProfile:
        """The device class behind a client id — the ONE id->profile map
        (every charge below and Server accounting resolve through it)."""
        if self.population is not None:
            return self.population.profile(client_id)
        return self.profiles[client_id % len(self.profiles)]

    def client_round_cost(
        self,
        client_id: int,
        steps: int,
        *,
        payload_bytes: int | None = None,
        uplink_bytes: int | None = None,
        jitter: float = 1.0,
    ) -> ClientCost:
        """Time/energy for one client-round.

        ``payload_bytes`` overrides both directions (legacy callers);
        ``uplink_bytes`` overrides only the client->server leg — the codec-
        compressed wire — while the downlink stays the full global model.
        ``jitter`` is a multiplicative step-time factor for this round
        (thermal throttling, background load): an ``AvailabilityTrace``
        draws one per client per round, 1.0 means nominal.
        """
        p = self.profile_for(client_id)
        down = self.update_bytes if payload_bytes is None else payload_bytes
        up = down if uplink_bytes is None else uplink_bytes
        t_compute = steps * p.step_time_s * jitter
        t_comm = p.comm_time_s(up, down)
        return ClientCost(
            client_id=client_id,
            profile=p.name,
            steps=steps,
            t_compute_s=t_compute,
            t_comm_s=t_comm,
            e_compute_j=t_compute * p.active_power_w,
            e_comm_j=t_comm * self.comm_power_w,
        )

    def round_costs(
        self,
        steps_per_client: list[int],
        *,
        payload_bytes: int | None = None,
        uplink_bytes: int | list[int] | None = None,
    ) -> list[ClientCost]:
        """Per-client costs for one round.

        ``uplink_bytes`` may be a single size (homogeneous fleet) or a
        vector with one wire size per client — the per-device codec path
        ships a different payload from every device class.
        """
        ups = self._per_client(uplink_bytes, len(steps_per_client))
        return [
            self.client_round_cost(
                cid, s, payload_bytes=payload_bytes, uplink_bytes=up
            )
            for (cid, s), up in zip(enumerate(steps_per_client), ups)
        ]

    @staticmethod
    def _per_client(uplink_bytes, n_clients: int) -> list[int | None]:
        if uplink_bytes is None or isinstance(uplink_bytes, (int, np.integer)):
            return [uplink_bytes] * n_clients
        assert len(uplink_bytes) == n_clients, (
            f"per-client uplink vector ({len(uplink_bytes)}) != clients ({n_clients})"
        )
        return [int(u) for u in uplink_bytes]

    # ---------------- mesh-collective accounting ----------------
    def _per_device_hop_bytes(self, n_elems: int) -> int:
        """Bytes ONE device ships for ONE psum transfer of an ``n_elems``
        partial sum — owned by the collective codecs themselves so this
        model can never drift from what the round step actually ships."""
        from .compression import CompressedPsum, fp32_collective_bytes

        if self.collective == "int8":
            return CompressedPsum(block=self.collective_block).collective_bytes(
                n_elems
            )
        if self.collective == "fp32":
            return fp32_collective_bytes(n_elems)
        raise ValueError(
            f"CostModel.collective={self.collective!r}: expected fp32 | int8"
        )

    def collective_bytes_by_tier(self, n_elems: int | None = None) -> dict:
        """Per-tier cross-link traffic of ONE hierarchical psum (reduce +
        broadcast), ``{axis_name: bytes}``.

        Tiers are ordered outer->inner like ``client_axes`` and the round
        step reduces inner-first, so by the time tier i (counting from the
        outside) transfers, the axes inside it are already reduced: tier i
        runs ``prod(sizes[:i])`` independent groups of ``s_i`` devices, and
        a ring reduce+broadcast over ``s_i`` devices moves ``2 * (s_i - 1)``
        transfers per group.  Each transfer ships the full partial sum —
        payload (1 B/elem int8 or 4 B/elem fp32) + the scale sidecar + the
        fp32 weight denominator (``CompressedPsum.collective_bytes`` /
        ``fp32_collective_bytes``).
        """
        if not self.mesh_tiers:
            return {}
        n = (self.update_bytes // 4) if n_elems is None else int(n_elems)
        per_hop = self._per_device_hop_bytes(n)
        out = {}
        groups = 1
        for name, size in self.mesh_tiers:
            out[name] = groups * 2 * (int(size) - 1) * per_hop
            groups *= int(size)
        return out

    def collective_bytes(self, n_elems: int | None = None) -> int:
        """Total cross-link bytes of one hierarchical psum, all tiers."""
        return sum(self.collective_bytes_by_tier(n_elems).values())

    def round_comm_bytes(
        self,
        n_clients: int,
        *,
        payload_bytes: int | None = None,
        uplink_bytes: int | list[int] | None = None,
        n_elems: int | None = None,
    ) -> int:
        """Total bytes crossing the network this round (up + down, all clients,
        plus — on the mesh path — the aggregation collective itself).

        Honors the same ``payload_bytes`` override as ``round_costs`` /
        ``client_round_cost`` (both directions), so the reported byte count
        can never disagree with the time/energy charge for the same round;
        ``uplink_bytes`` still overrides only the client->server leg.

        With ``mesh_tiers`` set, the cross-device psum traffic of the
        shard_map round is billed on top of the client uplinks (it used to
        be silently omitted, under-reporting mesh rounds by a full model
        per link hop); ``n_elems`` sizes the psum operand (default: the
        fp32 element count of ``update_bytes``).
        """
        down = self.update_bytes if payload_bytes is None else payload_bytes
        ups = self._per_client(uplink_bytes, n_clients)
        wire = sum((down if up is None else up) + down for up in ups)
        return wire + self.collective_bytes(n_elems)

    def round_wall_time(self, costs: list[ClientCost]) -> float:
        """Synchronous FedAvg: the round ends when the slowest client reports.

        An *empty* round — availability dropouts can leave zero reporters —
        costs zero wall time (the clock still advances by whatever the
        scheduler decides, but there is no slowest client to wait for).
        """
        return max((c.t_total_s for c in costs), default=0.0)

    def wasted_energy(self, cost: ClientCost, window_s: float) -> float:
        """Burn of an aborted client-round within its first ``window_s``
        seconds — the ONE owner of the phase split a scheduler cutoff
        induces (downlink radio, then compute, then uplink radio; each
        phase charges only the fraction that fit).  A window covering the
        whole round charges the complete cost.
        """
        if window_s >= cost.t_total_s:
            return cost.e_total_j
        p = self.profile_for(cost.client_id)
        window = max(0.0, window_s)
        t_down = p.comm_time_s(0, self.update_bytes)
        t_active = min(cost.t_compute_s, max(0.0, window - t_down))
        t_up_used = max(0.0, window - t_down - cost.t_compute_s)
        return (
            (min(window, t_down) + t_up_used) * self.comm_power_w
            + t_active * p.active_power_w
        )

    def round_energy(self, costs: list[ClientCost]) -> float:
        """Active energy + straggler idle burn while waiting for the round.

        Empty rounds burn nothing (no client computed, nobody idled).
        """
        if not costs:
            return 0.0
        wall = self.round_wall_time(costs)
        idle = sum(
            (wall - c.t_total_s) * self.profile_for(c.client_id).idle_power_w
            for c in costs
        )
        return sum(c.e_total_j for c in costs) + idle

    # ---- vectorized fleet accounting (rounds-as-scan, PR 8) ----

    def fleet_columns(
        self, n_clients: int, *, uplink_bytes=None
    ) -> dict[str, np.ndarray]:
        """Static per-client cost columns as (C,) float64 arrays.

        The id->profile map (``profile_for``) and the per-leg comm-time
        rule, resolved once for the whole fleet: ``step_time_s``,
        ``active_power_w``, ``idle_power_w``, ``up_bytes``, ``t_comm_s``
        (uplink of the codec wire + downlink of the full global) and
        ``t_down_s`` (downlink alone — the first phase of
        ``wasted_energy``'s split).  These never change across rounds;
        everything per-round is availability/jitter (the matrices above)
        and the policy verdict.
        """
        profs = [self.profile_for(c) for c in range(n_clients)]
        ups = self._per_client(uplink_bytes, n_clients)
        up = np.asarray(
            [self.update_bytes if u is None else u for u in ups], np.float64
        )
        return {
            "step_time_s": np.asarray([p.step_time_s for p in profs]),
            "active_power_w": np.asarray([p.active_power_w for p in profs]),
            "idle_power_w": np.asarray([p.idle_power_w for p in profs]),
            "up_bytes": up,
            "t_comm_s": np.asarray(
                [p.comm_time_s(u, self.update_bytes)
                 for p, u in zip(profs, up)]
            ),
            "t_down_s": np.asarray(
                [p.comm_time_s(0, self.update_bytes) for p in profs]
            ),
        }

    def fleet_time_matrix(
        self, step_budgets, jitter_matrix, *, uplink_bytes=None
    ) -> np.ndarray:
        """(R, C) finish-time offsets: ``steps*step_time*jitter + t_comm``.

        Same arithmetic (and evaluation order) as ``client_round_cost``,
        vectorized over the round axis — entry [r, c] equals
        ``client_round_cost(c, steps[c], jitter=jitter_matrix[r, c])
        .t_total_s`` bitwise.
        """
        cols = self.fleet_columns(
            jitter_matrix.shape[1], uplink_bytes=uplink_bytes
        )
        steps = np.asarray(step_budgets, np.float64)
        t_compute = (steps * cols["step_time_s"])[None, :] * jitter_matrix
        return t_compute + cols["t_comm_s"][None, :]

    @staticmethod
    def fleet_uplink_bytes(
        codec, n_params: int, n_clients: int
    ) -> list[int] | None:
        """Per-client uplink charge for a (possibly mixed) codec.

        A plain codec ships the same wire size from every client; a
        ``MixedCodec`` returns one size per client (its group's codec) —
        this is the per-group wire accounting the paper's system-cost
        tables need for a heterogeneous fleet.  None codec -> None (the
        cost model's full-precision default applies).
        """
        if codec is None:
            return None
        wb = codec.wire_bytes(n_params)
        if isinstance(wb, list):
            assert len(wb) == n_clients, (
                f"codec charges {len(wb)} clients, round has {n_clients}"
            )
            return wb
        return [int(wb)] * n_clients

    # ---- the paper's tau mechanism (§5, Table 3) ----
    def tau_for_profile(self, reference: str, *, epochs: int, steps_per_epoch: int) -> float:
        """Hardware-specific cutoff: the wall time the *reference* processor
        needs for a full E-epoch round (paper: GPU round time 1.99 min)."""
        ref = PROFILES[reference]
        return epochs * steps_per_epoch * ref.step_time_s

    def steps_under_tau(
        self, client_id: int, tau_s: float, full_steps: int
    ) -> int:
        if tau_s <= 0:  # tau = 0 means no cutoff (paper notation)
            return full_steps
        p = self.profile_for(client_id)
        return max(1, min(full_steps, p.steps_in_budget(tau_s)))

"""Virtual-clock execution layer: who reports this round, and when.

The paper's claim is that quantifying per-device system costs "could be
used to design more efficient FL algorithms".  This module is where the
engine *acts* on those costs instead of just reporting them: every client
dispatch becomes an event on a per-round **virtual timeline**, and a
``RoundPolicy`` decides — from arrival times alone — who reports this
round, who is dropped, and who carries a stale update forward.

The event model
---------------

One ``VirtualClock`` per ``Server.run``; time is simulated seconds and
only ever moves forward.  Each round:

1. the Server *dispatches* the sampled, available, not-still-busy clients:
   client ``c`` launched at ``t0 = clock.now`` finishes (compute + uplink)
   at ``t0 + cost.t_total_s`` — an ``Arrival`` event carrying the client's
   result payload and its ``ClientCost`` (whose ``t_arrival_s`` records the
   finish time on this timeline);
2. the policy ``plan``s the round over *all* pending arrivals (this
   round's dispatches plus any still in flight from earlier rounds) and
   partitions them into

   - ``reported``  — consumed by this round's aggregation,
   - ``dropped``   — deadline-missed: work wasted, update discarded,
   - ``expired``   — arrived too stale for the policy to accept,
   - ``carried``   — still in flight; they stay pending and will report in
     a later round with staleness > 0;

3. the clock advances to ``RoundOutcome.round_end`` and the Server
   aggregates the reported payloads (an empty ``reported`` list is a legal
   outcome: the round records, the clock advances, nothing aggregates).

Policies
--------

- ``SyncAll``     — today's lockstep FedAvg: everyone reports, the round
  ends when the slowest client does.
- ``Deadline(tau)`` — the round ends at ``now + tau``; whoever has not
  arrived is dropped (their compute until the cutoff is still charged —
  wasted work is the *point* of measuring this).  ``tau=None`` defers to
  the Strategy's own deadline (``Strategy.round_deadline_s()``), so
  ``FedTau``'s tau and the scheduler's cutoff are the same knob;
  ``tau=inf`` (or a strategy with no deadline) reproduces ``SyncAll``
  exactly — arrival order, round end, and reporters are identical.
- ``BufferedAsync(K, max_staleness)`` — FedBuff-style buffered
  asynchrony: the round ends the moment the ``K``-th pending arrival
  lands; later arrivals stay in flight and report in a subsequent round.
  An arrival consumed at round ``r`` that was launched at round ``l`` has
  **staleness** ``s = r - l``; arrivals with ``s > max_staleness`` are
  expired (discarded, work wasted) instead of reported.

The staleness-weight contract
-----------------------------

Staleness is *decided here* and *applied in the Strategy*: the Server
stamps each reported ``FitRes.staleness = r - l``, and
``FedBuffStrategy`` discounts that client's aggregation weight to
``w_c / (1 + s)**alpha`` (``alpha=0`` recovers plain FedAvg weighting).
A stale update is a *delta* against the global the client trained from;
the compressed wire formats already ship deltas, and the Server rebases
raw-parameter payloads (``current_global + (params - launch_global)``)
before aggregation, so every reported update applies to the current
global regardless of age.  Weight semantics downstream are unchanged:
zero weight == no contribution under the one ``safe_weight_sum``
denominator, which is exactly how the jitted engine's participation mask
realizes a scheduler decision inside ``round_step``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from .cost_model import ClientCost


def deadline_feasible(t_total_s, tau: float | None) -> np.ndarray:
    """Which predicted round times fit a ``Deadline`` cutoff — vectorized
    over a candidate pool.  The scheduler owns deadline semantics, so the
    one predicate cost-aware sampling ranks candidates by lives here: a
    client whose compute+comm lands at exactly ``tau`` still reports
    (``Deadline.plan`` keeps ``finish_t <= round_end``); ``tau`` of None or
    inf means no cutoff — everyone is feasible, matching ``Deadline``
    degenerating to ``SyncAll``."""
    t = np.asarray(t_total_s, np.float64)
    if tau is None or not np.isfinite(tau):
        return np.ones(t.shape, bool)
    return t <= tau


@dataclass
class VirtualClock:
    """Monotone simulated time (seconds since ``Server.run`` started)."""

    now: float = 0.0

    def advance_to(self, t: float) -> None:
        assert t >= self.now - 1e-9, f"virtual clock moving backwards: {self.now} -> {t}"
        self.now = max(self.now, t)


@dataclass
class Arrival:
    """One dispatched client-round: an event on the virtual timeline."""

    client_id: int
    launch_rnd: int            # the round (and thus the global) it trained from
    launch_t: float
    finish_t: float            # launch_t + cost.t_total_s
    cost: ClientCost | None    # None when the Server runs without a cost model
    payload: Any = None        # opaque to the scheduler (the Server's FitRes)
    uplink_bytes: int | None = None  # actual wire size (None = fp32 default)

    def staleness_at(self, rnd: int) -> int:
        return rnd - self.launch_rnd


@dataclass
class RoundOutcome:
    """A policy's verdict on one round's pending arrivals."""

    rnd: int
    round_start: float
    round_end: float
    reported: list[Arrival] = field(default_factory=list)
    dropped: list[Arrival] = field(default_factory=list)    # missed the deadline
    expired: list[Arrival] = field(default_factory=list)    # too stale to accept
    carried: list[Arrival] = field(default_factory=list)    # still in flight

    @property
    def wall_time_s(self) -> float:
        return self.round_end - self.round_start

    @property
    def mean_staleness(self) -> float:
        if not self.reported:
            return 0.0
        return sum(a.staleness_at(self.rnd) for a in self.reported) / len(self.reported)


def _by_arrival(pending: list[Arrival]) -> list[Arrival]:
    """Deterministic event order: finish time, then dispatch round, then id."""
    return sorted(pending, key=lambda a: (a.finish_t, a.launch_rnd, a.client_id))


class RoundPolicy:
    """Decides which pending arrivals a round consumes (module docstring).

    Policies whose verdict is a pure function of *this round's* dispatch
    set and finish times additionally expose ``plan_arrays`` — the same
    decision restated as array code so the rounds-as-scan trainer
    (``make_multi_round_step``) can trace it inside ``lax.scan``.  A
    policy is ``traceable`` iff its verdict carries no cross-round state:
    ``SyncAll`` and ``Deadline`` qualify; ``BufferedAsync`` does not (its
    pending set is data-dependent-size state threaded *between* rounds —
    a fixed-slot in-flight buffer in the scan carry is future work).
    """

    traceable: bool = False

    def plan(
        self, clock: VirtualClock, pending: list[Arrival], rnd: int,
        strategy: Any = None,
    ) -> RoundOutcome:
        raise NotImplementedError

    def plan_arrays(self, dispatch_mask, t_total, *, tau: float | None = None):
        """Pure-array round verdict: ``(participation_mask, round_end)``.

        ``dispatch_mask`` is the float ``(C,)`` 0/1 mask of clients
        launched this round; ``t_total`` their ``(C,)`` finish offsets
        (compute + comm, seconds from round start).  Returns the float
        ``(C,)`` mask of *reporters* (a subset of the dispatch mask) and
        the round's wall-clock duration — both as traced arrays, bitwise
        consistent with ``plan`` on the same inputs.  ``tau`` must be a
        static host float (resolve it once via ``Deadline.resolve_tau``
        *before* tracing; ``resolve_tau`` itself is host-only code).
        """
        raise NotImplementedError(
            f"{type(self).__name__} has no pure-array form "
            "(traceable=False); use the event-driven Server.run driver"
        )


@dataclass(frozen=True)
class SyncAll(RoundPolicy):
    """Lockstep FedAvg: wait for everyone; the slowest client ends the round."""

    traceable = True

    def plan(self, clock, pending, rnd, strategy=None):
        order = _by_arrival(pending)
        end = max((a.finish_t for a in order), default=clock.now)
        return RoundOutcome(
            rnd=rnd, round_start=clock.now, round_end=max(end, clock.now),
            reported=order,
        )

    def plan_arrays(self, dispatch_mask, t_total, *, tau=None):
        import jax.numpy as jnp

        mask = dispatch_mask
        # empty dispatch -> all-zero where -> end 0.0, matching plan's
        # `default=clock.now` (round_end - round_start == 0)
        end = jnp.max(jnp.where(mask > 0, t_total, 0.0))
        return mask, end


@dataclass(frozen=True)
class Deadline(RoundPolicy):
    """Cut the round at ``now + tau``; late clients are dropped.

    ``tau=None`` reads the Strategy's deadline (``round_deadline_s``) so
    e.g. ``FedTau(tau_s=...)`` and the scheduler cut at the same instant;
    no deadline anywhere (or ``tau=inf``) degenerates to ``SyncAll``.
    """

    tau: float | None = None
    traceable = True

    def resolve_tau(self, strategy=None) -> float:
        tau = self.tau
        if tau is None and strategy is not None:
            tau = getattr(strategy, "round_deadline_s", lambda: None)()
        return math.inf if tau is None or tau <= 0 else float(tau)

    def plan(self, clock, pending, rnd, strategy=None):
        tau = self.resolve_tau(strategy)
        cutoff = clock.now + tau
        order = _by_arrival(pending)
        reported = [a for a in order if a.finish_t <= cutoff]
        dropped = [a for a in order if a.finish_t > cutoff]
        # no stragglers -> the round ends with the last reporter (no point
        # idling until the cutoff); any straggler -> the server waits the
        # full tau before giving up on them
        end = cutoff if dropped else max(
            (a.finish_t for a in reported), default=clock.now
        )
        return RoundOutcome(
            rnd=rnd, round_start=clock.now, round_end=max(end, clock.now),
            reported=reported, dropped=dropped,
        )

    def plan_arrays(self, dispatch_mask, t_total, *, tau=None):
        import jax.numpy as jnp

        # tau is static; a strategy-deferred tau (self.tau=None +
        # Strategy.round_deadline_s) must be resolved by the CALLER via
        # resolve_tau — that path is host-only and stays out of the trace
        if tau is None:
            tau = math.inf if self.tau is None or self.tau <= 0 else self.tau
        if not math.isfinite(tau):
            return SyncAll.plan_arrays(self, dispatch_mask, t_total)
        mask = jnp.where((dispatch_mask > 0) & (t_total <= tau), 1.0, 0.0)
        missed = jnp.max(jnp.where((dispatch_mask > 0) & (t_total > tau), 1.0, 0.0))
        # same wall rule as plan: any straggler -> the server idles out the
        # full tau; none -> the round ends with the last reporter
        end = jnp.where(
            missed > 0, tau, jnp.max(jnp.where(mask > 0, t_total, 0.0))
        )
        return mask, end


@dataclass(frozen=True)
class BufferedAsync(RoundPolicy):
    """FedBuff-style buffered asynchrony: aggregate the first K usable
    arrivals.

    Anything already staler than ``max_staleness`` this round is expired
    up front (discarded — a stale update only gets MORE stale, so holding
    a buffer slot for it would starve the aggregation of usable updates);
    the round then ends when the K-th *usable* arrival lands — an expired
    straggler NEVER gates the round (waiting for a discarded update is
    exactly the straggler wall this policy exists to avoid; one still in
    flight at round end is simply cancelled, and the Server charges only
    the work that fit before the cutoff).  Everyone usable beyond K stays
    in flight and reports in a later round with staleness
    ``consume_round - launch_round``.
    """

    buffer_size: int = 2       # K
    max_staleness: int = 4

    def plan(self, clock, pending, rnd, strategy=None):
        order = _by_arrival(pending)
        expired = [a for a in order if a.staleness_at(rnd) > self.max_staleness]
        usable = [a for a in order if a.staleness_at(rnd) <= self.max_staleness]
        reported = usable[: self.buffer_size]
        carried = usable[self.buffer_size:]
        end = max((a.finish_t for a in reported), default=clock.now)
        return RoundOutcome(
            rnd=rnd, round_start=clock.now, round_end=max(end, clock.now),
            reported=reported, expired=expired, carried=carried,
        )

"""Model API: a uniform functional interface over every architecture family.

``build_model(arch_name_or_cfg)`` returns a `Model` whose methods are pure
functions suitable for jit/pjit:

    init(key) -> params
    loss_fn(params, batch) -> (loss, metrics)
    param_specs(rules) -> PartitionSpec pytree (transformers)
    prefill / decode_step / init_cache / cache_specs (transformers)
    trainable_mask() -> bool pytree (head models; None = all trainable)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

from repro.configs.base import ArchConfig, get_config

PyTree = Any


@dataclass(frozen=True)
class Model:
    cfg: Any
    arch: ArchConfig
    init: Callable
    loss_fn: Callable                       # (params, batch) -> (loss, metrics)
    param_specs: Callable                   # (rules) -> spec pytree
    trainable_mask: Optional[Callable] = None
    prefill: Callable | None = None         # (params, batch, context_len) -> (logits, cache)
    decode_step: Callable | None = None     # (params, batch, cache, context_len)
    init_cache: Callable | None = None      # (batch, context_len) -> cache
    cache_specs: Callable | None = None

    @property
    def name(self) -> str:
        return self.arch.name


def build_model(arch, *, ce_chunk: int = 0) -> Model:
    arch_cfg = get_config(arch) if isinstance(arch, str) else arch

    if arch_cfg.family == "cnn":
        from repro.configs.resnet18_cifar10 import CNN_CONFIG
        from . import resnet

        cfg = CNN_CONFIG if not arch_cfg.name.endswith("reduced") else CNN_CONFIG.reduced()
        return Model(
            cfg=cfg,
            arch=arch_cfg,
            init=lambda key: resnet.init_params(key, cfg),
            loss_fn=lambda p, b: resnet.loss_fn(cfg, p, b),
            param_specs=lambda rules: None,
        )

    if arch_cfg.family == "head":
        from repro.configs.mobilenet_head_office31 import HEAD_CONFIG
        from . import headmodel

        cfg = HEAD_CONFIG if not arch_cfg.name.endswith("reduced") else HEAD_CONFIG.reduced()
        return Model(
            cfg=cfg,
            arch=arch_cfg,
            init=lambda key: headmodel.init_params(key, cfg),
            loss_fn=lambda p, b: headmodel.loss_fn(cfg, p, b),
            param_specs=lambda rules: None,
            trainable_mask=lambda params: headmodel.trainable_mask(params),
        )

    from . import transformer as tfm

    cfg = arch_cfg
    return Model(
        cfg=cfg,
        arch=arch_cfg,
        init=lambda key: tfm.init_params(key, cfg),
        loss_fn=lambda p, b: tfm.loss_fn(cfg, p, b, ce_chunk=ce_chunk),
        param_specs=lambda rules: tfm.param_specs(cfg, rules),
        prefill=lambda p, b, ctx: tfm.prefill(cfg, p, b, context_len=ctx),
        decode_step=lambda p, b, cache, ctx: tfm.decode_step(cfg, p, b, cache, context_len=ctx),
        init_cache=lambda batch, ctx: tfm.init_cache(cfg, batch, ctx),
        cache_specs=lambda rules, batch, ctx: tfm.cache_specs(cfg, rules, batch, ctx),
    )

"""Base/Head split model — the paper's §4.1 Android personalization design.

The frozen *Base Model* (MobileNetV2 feature extractor in the paper) is a
fixed random projection producing `feature_dim` features; FL trains only the
2-layer *Head Model*.  ``trainable_mask`` realizes the freeze as a pytree
partition consumed by core.rounds (frozen leaves pass through local SGD
untouched and are excluded from aggregation traffic accounting).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "base": {  # frozen feature extractor (identity-ish random projection)
            "w": jax.random.normal(ks[0], (cfg.feature_dim, cfg.feature_dim), jnp.float32)
            / np.sqrt(cfg.feature_dim),
        },
        "head": {
            "w1": jax.random.normal(ks[1], (cfg.feature_dim, cfg.hidden_dim), jnp.float32)
            / np.sqrt(cfg.feature_dim),
            "b1": jnp.zeros((cfg.hidden_dim,), jnp.float32),
            "w2": jax.random.normal(ks[2], (cfg.hidden_dim, cfg.num_classes), jnp.float32)
            / np.sqrt(cfg.hidden_dim),
            "b2": jnp.zeros((cfg.num_classes,), jnp.float32),
        },
    }


def trainable_mask(params) -> dict:
    """True = FL-trainable (head), False = frozen (base)."""
    return {
        "base": jax.tree.map(lambda _: False, params["base"]),
        "head": jax.tree.map(lambda _: True, params["head"]),
    }


def forward(cfg, params, x):
    feats = jax.nn.relu(x @ params["base"]["w"])  # frozen base
    h = jax.nn.relu(feats @ params["head"]["w1"] + params["head"]["b1"])
    return h @ params["head"]["w2"] + params["head"]["b2"]


def loss_fn(cfg, params, batch):
    logits = forward(cfg, params, batch["x"])
    labels = batch["y"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"ce": loss, "acc": acc}


def param_specs(cfg, params) -> dict:
    return jax.tree.map(lambda x: P(), params)

"""Sharding rules: map logical parameter/activation dims to mesh axes.

Two FL execution modes (DESIGN.md §4):

- parallel:   the `data` mesh axis indexes *clients*; params get a leading
              client dim (added by core.rounds, P(data_axes)) and are
              tensor-parallel over `model` only.
- sequential: one client occupies the whole mesh; params are 2D-sharded
              (FSDP-style over `data` + tensor-parallel over `model`),
              batch is sharded over (`pod`, `data`).

Spec helpers return None (replicate) for any dim not divisible by its axis —
divisibility is checked against the actual mesh shape so every assigned
architecture lowers on both the 256-chip and 512-chip meshes.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def shard_map_compat(f, mesh, in_specs, out_specs, axis_names):
    """shard_map across jax versions — the ONE shim (used by core.rounds'
    mesh path, launch-side mesh drivers, and the sharded-client-state tests;
    it used to live inline in core/rounds.py, where every new mesh caller
    re-derived it).  Manual over ``axis_names`` (the client axes), automatic
    over every other mesh axis (the model axes) — the top-level API when
    present, else the jax.experimental fallback, whose ``auto=`` set
    expresses the same manual/auto split."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as sm

    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False, auto=auto)


@dataclass(frozen=True)
class ShardRules:
    """parallel: clients on `data`, TP on `model`.
    sequential: FSDP on `data` + TP on `model`, batch on (pod, data).
    fsdp: pure ZeRO — weights AND batch over ALL mesh axes, no TP (right
    regime for mid-size MoE: activations per chip shrink by the full mesh)."""

    mode: str = "parallel"              # "parallel" | "sequential" | "fsdp"
    data_axis: str = "data"
    pod_axis: str | None = None         # "pod" on the multi-pod mesh
    axis_sizes: tuple[tuple[str, int], ...] = (("data", 16), ("model", 16))

    def size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, (tuple, list)):
            n = 1
            for a in axis:
                n *= self.size(a)
            return n
        return dict(self.axis_sizes).get(axis, 1)

    # ---- logical axis resolution ----
    @property
    def all_axes(self) -> tuple[str, ...]:
        axes = ("data", "model")
        if self.pod_axis:
            axes = ("pod",) + axes
        return axes

    @property
    def model_axis(self):
        """Tensor-parallel axis (None in pure-FSDP mode)."""
        return None if self.mode == "fsdp" else "model"

    @property
    def fsdp(self):
        """Axis (or axes) FSDP-sharding the params.

        fsdp mode shards weights over the in-pod 256 chips; on the multi-pod
        mesh the pod axis is a data-parallel replica (hybrid FSDP+DP), since
        a 256-sequence global batch cannot split 512 ways."""
        if self.mode == "sequential":
            return self.data_axis
        if self.mode == "fsdp":
            return ("data", "model")
        return None

    @property
    def client_axes(self):
        """Mesh axes that enumerate clients (parallel mode)."""
        axes = (self.data_axis,)
        if self.pod_axis:
            axes = (self.pod_axis, self.data_axis)
        return axes

    @property
    def batch_axes(self):
        """Axes sharding the (per-client or global) batch dim."""
        if self.mode == "sequential":
            axes = (self.data_axis,)
            if self.pod_axis:
                axes = (self.pod_axis, self.data_axis)
            return axes
        if self.mode == "fsdp":
            if self.pod_axis:
                return (self.pod_axis, self.data_axis)  # 32-way, 8 seq/chip
            return ("data", "model")                    # 256-way, 1 seq/chip
        return None  # parallel: batch dim is per-client, unsharded

    def spec(self, *dims, dim_sizes: tuple[int, ...] | None = None) -> P:
        """Build a PartitionSpec; drop any axis that does not divide its dim.

        dims entries: None | axis-name | tuple of axis-names.
        """
        out = []
        for i, d in enumerate(dims):
            if d is None:
                out.append(None)
                continue
            if dim_sizes is not None:
                need = self.size(d)
                if need == 0 or dim_sizes[i] % max(1, need) != 0:
                    out.append(None)
                    continue
            out.append(d)
        return P(*out)


def client_state_specs(rules: ShardRules, segments) -> tuple:
    """PartitionSpecs laying each segment's ``(C, seg.size)`` codec
    client-state rows out along the mesh (fsdp archs).

    The client dim stays whole (row i is one client's residual — gather/
    scatter and the sequential scan index it); the *parameter* dim shards
    over the rules' fsdp axes, so per-device state memory drops by the
    full fsdp factor and the residual never materializes replicated.
    Segments whose size the axes do not divide replicate (P(None, None)) —
    same divisibility contract as ``ShardRules.spec``.  ``segments`` is a
    ``SegmentMap`` (or any iterable of objects with ``.size``).
    """
    ax = rules.fsdp
    return tuple(
        rules.spec(None, ax, dim_sizes=(1, seg.size)) for seg in segments
    )


def client_state_shardings(mesh, rules: ShardRules, segments) -> tuple:
    """``client_state_specs`` bound to a concrete mesh: one NamedSharding
    per segment, the layout ``CohortState(shardings=...)`` gathers into and
    ``shard_client_state`` pins an existing state pytree to."""
    return tuple(
        NamedSharding(mesh, spec)
        for spec in client_state_specs(rules, segments)
    )


def shard_client_state(state, mesh, rules: ShardRules, segments=None):
    """Lay an existing codec client state out along the mesh.

    ``state`` is whatever ``codec.init_client_state`` returned: a flat
    ``(C, n_params)`` block, or the per-segment tuple of ``(C, seg.size)``
    blocks (``()`` entries for stateless segments pass through).  Values
    are unchanged — only placement moves (``jax.device_put`` with the
    ``client_state_shardings`` layout), so sharded and unsharded rounds
    stay bitwise-identical.  With ``segments=None`` the flat block is
    treated as one full-width segment.
    """
    class _Flat:
        def __init__(self, size):
            self.size = size

    leaves = state if isinstance(state, (tuple, list)) else (state,)
    if segments is None:
        # stateless () entries get a placeholder segment; never placed
        segs = [_Flat(x.shape[1] if hasattr(x, "shape") else 1) for x in leaves]
    else:
        segs = list(segments)
        assert len(segs) == len(leaves), (
            f"state has {len(leaves)} entries, segment map has {len(segs)}"
        )
    specs = client_state_specs(rules, segs)
    out = tuple(
        jax.device_put(x, NamedSharding(mesh, spec))
        if hasattr(x, "shape") else x
        for x, spec in zip(leaves, specs)
    )
    return out if isinstance(state, (tuple, list)) else out[0]


def serve_rules(mesh, multi_pod: bool) -> ShardRules:
    """Serving always FSDP/TP-shards (no client axis)."""
    sizes = tuple((n, s) for n, s in zip(mesh.axis_names, mesh.devices.shape))
    return ShardRules(
        mode="sequential",
        pod_axis="pod" if multi_pod else None,
        axis_sizes=sizes,
    )


def train_rules(mesh, multi_pod: bool, execution_mode: str) -> ShardRules:
    sizes = tuple((n, s) for n, s in zip(mesh.axis_names, mesh.devices.shape))
    return ShardRules(
        mode=execution_mode,
        pod_axis="pod" if multi_pod else None,
        axis_sizes=sizes,
    )

"""ResNet-18 in pure JAX — the paper's Jetson-TX2 FL workload (§5).

GroupNorm replaces BatchNorm: FedAvg over divergent client BN statistics is a
known failure mode and the paper's system conclusions do not depend on the
norm choice (DESIGN.md §7).  CIFAR stem (3x3, no max-pool).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .layers.norms import groupnorm


def _conv_init(key, shape):  # HWIO
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape, jnp.float32) * np.sqrt(2.0 / fan_in)


def conv2d(x, w, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _init_norm(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def _init_block(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(ks[0], (3, 3, cin, cout)),
        "n1": _init_norm(cout),
        "conv2": _conv_init(ks[1], (3, 3, cout, cout)),
        "n2": _init_norm(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], (1, 1, cin, cout))
        p["proj_n"] = _init_norm(cout)
    return p


def _block(p, x, stride, groups=8):
    h = conv2d(x, p["conv1"], stride)
    h = jax.nn.relu(groupnorm(h, p["n1"]["scale"], p["n1"]["bias"], groups))
    h = conv2d(h, p["conv2"])
    h = groupnorm(h, p["n2"]["scale"], p["n2"]["bias"], groups)
    if "proj" in p:
        x = conv2d(x, p["proj"], stride)
        x = groupnorm(x, p["proj_n"]["scale"], p["proj_n"]["bias"], groups)
    return jax.nn.relu(x + h)


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 2 + sum(cfg.stage_sizes))
    params = {
        "stem": _conv_init(ks[0], (3, 3, cfg.channels, cfg.stage_widths[0])),
        "stem_n": _init_norm(cfg.stage_widths[0]),
        "stages": [],
        "fc_w": jax.random.normal(
            ks[1], (cfg.stage_widths[-1], cfg.num_classes), jnp.float32
        ) / np.sqrt(cfg.stage_widths[-1]),
        "fc_b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }
    ki = 2
    cin = cfg.stage_widths[0]
    for si, (n, cout) in enumerate(zip(cfg.stage_sizes, cfg.stage_widths)):
        stage = []
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            stage.append(_init_block(ks[ki], cin, cout, stride))
            ki += 1
            cin = cout
        params["stages"].append(stage)
    return params


def forward(cfg, params, x):
    """x: (N,H,W,C) -> logits (N,classes)."""
    h = conv2d(x, params["stem"])
    h = jax.nn.relu(groupnorm(h, params["stem_n"]["scale"], params["stem_n"]["bias"]))
    for si, stage in enumerate(params["stages"]):
        for bi, bp in enumerate(stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = _block(bp, h, stride)
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["fc_w"] + params["fc_b"]


def loss_fn(cfg, params, batch):
    logits = forward(cfg, params, batch["x"])
    labels = batch["y"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"ce": loss, "acc": acc}


def param_specs(cfg, params) -> dict:
    """CNNs are tiny: replicate everything (client axis added by the engine)."""
    return jax.tree.map(lambda x: P(), params)

"""Mixture-of-Experts layer: top-k token-choice routing, sort-based dispatch.

TPU-native adaptation: instead of GShard's one-hot dispatch tensors
(T x E x C blows up for fine-grained MoE like DeepSeek's 64-expert top-6),
we sort (token, choice) pairs by expert id and scatter into a contiguous
(E, capacity, d) buffer — O(Tk) memory, MXU-friendly batched expert matmuls.
Supports shared experts (DeepSeekMoE) and capacity-based token dropping.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .mlp import init_mlp, mlp_forward, spec_mlp


def _dense_init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)


# Sequential-mode batch pinning: GSPMD flip-flops between batch-sharded and
# model-sharded layouts around the dispatch scatter (multi-GB reshards);
# launch/specs.py sets this to NamedSharding(mesh, P(batch_axes)) so every
# dispatch-side tensor stays batch-sharded.  None inside shard_map / on CPU.
BATCH_SHARDING = None
FF_SHARDING = None  # (B, e, cap, dff) expert-hidden sharding (dff over model)


def _pin_batch(x):
    if BATCH_SHARDING is None:
        return x
    return jax.lax.with_sharding_constraint(x, BATCH_SHARDING)


def _pin_ff(x):
    if FF_SHARDING is None:
        return _pin_batch(x)
    return jax.lax.with_sharding_constraint(x, FF_SHARDING)


MODEL_LAST_SHARDING = None  # (B, ..., d) with d over model


def _pin_model_last(x):
    if MODEL_LAST_SHARDING is None:
        return _pin_batch(x)
    return jax.lax.with_sharding_constraint(x, MODEL_LAST_SHARDING)


def expert_ff_dim(cfg) -> int:
    return cfg.moe.d_expert or cfg.d_ff


def init_moe(key, cfg, dtype):
    mc = cfg.moe
    d, dff = cfg.d_model, expert_ff_dim(cfg)
    ks = jax.random.split(key, 5)
    params = {
        "router": _dense_init(ks[0], (d, mc.n_experts), d, jnp.float32),
        "w_gate": _dense_init(ks[1], (mc.n_experts, d, dff), d, dtype),
        "w_up": _dense_init(ks[2], (mc.n_experts, d, dff), d, dtype),
        "w_down": _dense_init(ks[3], (mc.n_experts, dff, d), dff, dtype),
    }
    if mc.n_shared_experts:
        params["shared"] = init_mlp(ks[4], d, dff * mc.n_shared_experts, dtype)
    return params


def spec_moe(cfg, rules):
    mc = cfg.moe
    d, dff = cfg.d_model, expert_ff_dim(cfg)
    m, f = rules.model_axis, rules.fsdp
    e = mc.n_experts
    # 2D-shard expert weights (d over fsdp, d_ff over model); the expert dim
    # stays whole so token dispatch remains batch-local (see moe_forward)
    ew = (None, f, m)
    ed = (None, m, f)
    specs = {
        "router": rules.spec(None, None, dim_sizes=(d, e)),
        "w_gate": rules.spec(*ew, dim_sizes=(e, d, dff)),
        "w_up": rules.spec(*ew, dim_sizes=(e, d, dff)),
        "w_down": rules.spec(*ed, dim_sizes=(e, dff, d)),
    }
    if mc.n_shared_experts:
        specs["shared"] = spec_mlp(rules, d, dff * mc.n_shared_experts)
    return specs


def router_topk(cfg, params, x_flat):
    """x_flat: (T, d) -> (probs (T,k), idx (T,k), aux_losses dict)."""
    mc = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), params["router"])
    probs_full = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs_full, mc.top_k)
    topv = topv / jnp.sum(topv, -1, keepdims=True)  # renormalize over chosen

    # load-balance aux (Switch): E * sum_e f_e * p_e
    e = mc.n_experts
    assign = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    f_e = assign / jnp.maximum(1.0, topi.size)
    p_e = jnp.mean(probs_full, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return topv, topi, {"moe_aux": aux, "moe_z": z}


def _dispatch_one(x_seq, topi, topv, *, e: int, k: int, capacity: int):
    """Dispatch ONE sequence's tokens. x_seq: (S,d); topi/topv: (S,k).

    Returns (buf (e, capacity, d), dst (S*k,), scale (S*k,), src_tok, keep).
    Sequence-local so a batch-sharded vmap keeps every sort/scatter on its
    own shard (global-token dispatch defeats GSPMD and replicates T*k
    gathers; see DESIGN.md §Perf notes).
    """
    s, d = x_seq.shape
    flat_e = topi.reshape(-1)                       # (S*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(sorted_e, length=e)
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)]
    )[:-1]
    pos = jnp.arange(s * k) - seg_start[sorted_e]
    keep = pos < capacity
    dst = jnp.where(keep, sorted_e * capacity + pos, e * capacity)  # drop row
    src_tok = order // k
    buf = jnp.zeros((e * capacity + 1, d), x_seq.dtype).at[dst].set(x_seq[src_tok])
    scale = topv.reshape(-1)[order]
    return buf[:-1].reshape(e, capacity, d), dst, scale, src_tok, keep


def moe_forward(cfg, params, x, *, capacity_factor: float = 1.25):
    """x: (B, S, d) -> (out, aux_losses).  Per-sequence capacity dispatch."""
    mc = cfg.moe
    b, s, d = x.shape
    k = mc.top_k
    e = mc.n_experts

    topv, topi, aux = router_topk(cfg, params, x.reshape(b * s, d))
    topv = topv.reshape(b, s, k)
    topi = topi.reshape(b, s, k)

    capacity = max(1, int(np.ceil(s * k * capacity_factor / e)))
    buf, dst, scale, src_tok, keep = jax.vmap(
        partial(_dispatch_one, e=e, k=k, capacity=capacity)
    )(x, topi, topv)                                 # buf: (B, e, cap, d)
    buf = _pin_batch(buf)  # d stays whole: the cheap gather is the weights

    # batched expert FFN (MXU path)
    g = _pin_ff(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
    u = _pin_ff(jnp.einsum("becd,edf->becf", buf, params["w_up"]))
    h = _pin_ff((jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)) * u)
    out_buf = _pin_batch(jnp.einsum("becf,efd->becd", h, params["w_down"]))
    out_buf = out_buf.reshape(b, e * capacity, d)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((b, 1, d), out_buf.dtype)], axis=1
    )

    def _combine_one(ob, dst_i, scale_i, src_i):
        gathered = ob[dst_i] * scale_i[:, None].astype(ob.dtype)
        return jnp.zeros((s, d), ob.dtype).at[src_i].add(gathered)

    out = _pin_batch(jax.vmap(_combine_one)(out_buf, dst, scale, src_tok))

    if mc.n_shared_experts:
        out = out + mlp_forward(params["shared"], x, cfg.act)

    aux["moe_drop_frac"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out, aux


def moe_loss(aux: dict, cfg) -> jnp.ndarray:
    mc = cfg.moe
    return mc.router_aux_coef * aux["moe_aux"] + mc.router_z_coef * aux["moe_z"]

"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Q and KV are produced from low-rank latents; the decode cache stores only the
compressed KV latent (+ the shared rope key), and decode uses the *absorbed*
formulation so per-head K/V are never materialized over the whole cache:

    score_h(t) = q_nope_h^T W_uk_h c_t + q_rope_h^T k_rope_t
               = (W_uk_h^T q_nope_h)^T c_t + ...
    out_h      = W_uv_h^T ( sum_t p_t c_t )
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import ref as kref
from .embeddings import apply_rope, rope_angles


def _dense_init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)


def init_mla(key, cfg, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": _dense_init(ks[0], (d, m.q_lora_rank), d, dtype),
        "wq_b": _dense_init(ks[1], (m.q_lora_rank, h, qk_dim), m.q_lora_rank, dtype),
        "wkv_a": _dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), d, dtype),
        "wk_b": _dense_init(ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim), m.kv_lora_rank, dtype),
        "wv_b": _dense_init(ks[4], (m.kv_lora_rank, h, m.v_head_dim), m.kv_lora_rank, dtype),
        "wo": _dense_init(ks[5], (h, m.v_head_dim, d), h * m.v_head_dim, dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), jnp.float32),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
    }


def spec_mla(cfg, rules):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    mdl, f = rules.model_axis, rules.fsdp
    return {
        "wq_a": rules.spec(f, mdl, dim_sizes=(d, m.q_lora_rank)),
        "wq_b": rules.spec(None, mdl, None, dim_sizes=(m.q_lora_rank, h, qk_dim)),
        "wkv_a": rules.spec(f, None, dim_sizes=(d, m.kv_lora_rank + m.qk_rope_head_dim)),
        "wk_b": rules.spec(None, mdl, None, dim_sizes=(m.kv_lora_rank, h, m.qk_nope_head_dim)),
        "wv_b": rules.spec(None, mdl, None, dim_sizes=(m.kv_lora_rank, h, m.v_head_dim)),
        "wo": rules.spec(mdl, None, f, dim_sizes=(h, m.v_head_dim, d)),
        "q_norm": P(None),
        "kv_norm": P(None),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * (1.0 + scale)).astype(x.dtype)


def _latents(cfg, params, x, positions):
    """Shared Q/KV latent computation. Returns per-head q parts + latent kv."""
    m = cfg.mla
    ql = _rms(jnp.einsum("bsd,dr->bsr", x, params["wq_a"]), params["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", ql, params["wq_b"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = q[..., m.qk_nope_head_dim :]

    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv = _rms(kv_a[..., : m.kv_lora_rank], params["kv_norm"])
    k_rope = kv_a[..., m.kv_lora_rank :]  # (B,S,rope_dim), shared across heads

    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(cfg, params, x, *, window=None):
    """Full-sequence causal MLA (train / prefill). x: (B,S,D)."""
    m = cfg.mla
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope, c_kv, k_rope = _latents(cfg, params, x, positions)

    # expand per-head K/V from the latent (fine for prefill: O(S) memory)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"])

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], q_rope.shape[:2] + (cfg.n_heads, m.qk_rope_head_dim))],
        -1,
    )
    out = kref.attention(qf, kf, v, causal=True, window=window or cfg.sliding_window, scale=scale)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def init_mla_cache(cfg, batch: int, cache_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dtype),
    }


def spec_mla_cache(cfg, rules, batch: int, cache_len: int):
    m = cfg.mla
    return {
        "c_kv": rules.spec(rules.batch_axes, rules.model_axis, None,
                           dim_sizes=(batch, cache_len, m.kv_lora_rank)),
        "k_rope": rules.spec(rules.batch_axes, rules.model_axis, None,
                             dim_sizes=(batch, cache_len, m.qk_rope_head_dim)),
    }


def mla_decode(cfg, params, x, cache, pos, *, ring: bool):
    """Absorbed one-token MLA decode. x: (B,1,D)."""
    m = cfg.mla
    b = x.shape[0]
    cache_len = cache["c_kv"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _latents(cfg, params, x, positions)

    slot = pos % cache_len if ring else jnp.minimum(pos, cache_len - 1)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, slot, 0))

    idx = jnp.arange(cache_len)
    if ring:
        age = (slot - idx) % cache_len
        valid = (pos - age) >= jnp.maximum(0, pos + 1 - cache_len)
    else:
        valid = idx <= pos

    # absorbed scores: q_abs = W_uk^T q_nope -> (B,H,rank)
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0].astype(jnp.float32),
                       params["wk_b"].astype(jnp.float32))
    scores = jnp.einsum("bhr,bsr->bhs", q_abs, c_kv.astype(jnp.float32))
    scores += jnp.einsum("bhk,bsk->bhs", q_rope[:, 0].astype(jnp.float32),
                         k_rope.astype(jnp.float32))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = jnp.where(valid[None, None], scores * scale, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)

    ctx = jnp.einsum("bhs,bsr->bhr", probs, c_kv.astype(jnp.float32))  # latent ctx
    out = jnp.einsum("bhr,rhk->bhk", ctx, params["wv_b"].astype(jnp.float32))
    out = jnp.einsum("bhk,hkd->bd", out.astype(x.dtype), params["wo"])[:, None]
    return out, {"c_kv": c_kv, "k_rope": k_rope}

"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, true recurrence) [arXiv:2405.04517].

TPU adaptation (DESIGN.md): mLSTM's training path uses the stabilized
quadratic form evaluated in query chunks (lax.map + checkpoint) so peak
memory is O(S * chunk) instead of O(S^2); decode carries the (C, n, m)
matrix-memory state with O(1) per-token cost.  sLSTM has a hidden-to-hidden
recurrence with no parallel form, so it scans over time (block-diagonal
per-head recurrent weights).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def _dense_init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)


# ============================== mLSTM ==============================
def _mlstm_dims(cfg):
    d_inner = 2 * cfg.d_model
    hd = d_inner // cfg.n_heads
    return d_inner, cfg.n_heads, hd


def init_mlstm(key, cfg, dtype):
    d = cfg.d_model
    di, h, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up_proj": _dense_init(ks[0], (d, 2 * di), d, dtype),
        "wq": _dense_init(ks[1], (di, h, hd), di, dtype),
        "wk": _dense_init(ks[2], (di, h, hd), di, dtype),
        "wv": _dense_init(ks[3], (di, h, hd), di, dtype),
        "w_gates": _dense_init(ks[4], (di, h, 2), di, jnp.float32),
        # forget-gate bias init ~ +3..6 keeps early memories (xLSTM paper)
        "b_gates": jnp.stack(
            [jnp.zeros((h,)), jnp.linspace(3.0, 6.0, h)], axis=-1
        ).astype(jnp.float32),
        "o_norm": jnp.zeros((h, hd), jnp.float32),
        "down_proj": _dense_init(ks[5], (di, d), di, dtype),
    }


def spec_mlstm(cfg, rules):
    d = cfg.d_model
    di, h, hd = _mlstm_dims(cfg)
    m, f = rules.model_axis, rules.fsdp
    return {
        "up_proj": rules.spec(f, m, dim_sizes=(d, 2 * di)),
        "wq": rules.spec(m, None, None, dim_sizes=(di, h, hd)),
        "wk": rules.spec(m, None, None, dim_sizes=(di, h, hd)),
        "wv": rules.spec(m, None, None, dim_sizes=(di, h, hd)),
        "w_gates": rules.spec(m, None, None, dim_sizes=(di, h, 2)),
        "b_gates": P(None, None),
        "o_norm": P(None, None),
        "down_proj": rules.spec(m, f, dim_sizes=(di, d)),
    }


def _headwise_rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * (1.0 + scale)).astype(x.dtype)


def _mlstm_qkv_gates(cfg, params, x_in):
    q = jnp.einsum("bsd,dhk->bshk", x_in, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x_in, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_in, params["wv"])
    gates = (
        jnp.einsum("bsd,dhg->bshg", x_in.astype(jnp.float32), params["w_gates"])
        + params["b_gates"]
    )
    ig = gates[..., 0]                      # raw input-gate logit (B,S,H)
    lf = jax.nn.log_sigmoid(gates[..., 1])  # log forget gate
    return q, k, v, ig, lf


def mlstm_parallel(q, k, v, ig, lf, *, chunk: int = 256):
    """Stabilized quadratic mLSTM, chunked over queries.

    q,k,v: (B,S,H,D); ig, lf: (B,S,H).  Returns (B,S,H,D).
    """
    b, s, h, d = q.shape
    if s % chunk != 0:
        chunk = s  # single tile for short/ragged sequences
    scale = d ** -0.5
    F = jnp.cumsum(lf, axis=1)  # (B,S,H) cumulative log-forget
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kpos = jnp.arange(s)

    n_chunks = max(1, s // chunk)
    qc = qf.reshape(b, n_chunks, chunk, h, d)
    Fc = F.reshape(b, n_chunks, chunk, h)

    @jax.checkpoint
    def one_chunk(args):
        ci, q_i, F_i = args  # q_i (B,L,H,D), F_i (B,L,H)
        qpos = ci * chunk + jnp.arange(chunk)
        # logD_ij = F_i - F_j + lf_j... precisely: F_i - F_j + ig_j, j <= i
        logD = (
            F_i[:, :, None] - F[:, None, :, :] + lf[:, None, :, :] + ig[:, None, :, :]
        )  # (B,L,S,H); note D_ii uses F_i - F_i + lf_i + ig_i? see below
        # xLSTM defines D_ij = exp(sum_{t=j+1..i} lf_t + ig_j); rewrite:
        # sum_{t=j+1..i} lf_t = F_i - F_j, so logD = F_i - F_j + ig_j.
        logD = F_i[:, :, None] - F[:, None, :, :] + ig[:, None, :, :]
        causal = kpos[None, :] <= qpos[:, None]  # (L,S)
        logD = jnp.where(causal[None, :, :, None], logD, -jnp.inf)
        m = jnp.max(logD, axis=2, keepdims=True)          # (B,L,1,H)
        m = jnp.maximum(m, -1e30)                         # guard all -inf rows
        dmat = jnp.exp(logD - m)                          # (B,L,S,H)
        scores = jnp.einsum("blhd,bshd->blsh", q_i, kf) * dmat
        denom = jnp.maximum(
            jnp.abs(jnp.sum(scores, axis=2)), jnp.exp(-m[:, :, 0])
        )  # (B,L,H)
        out = jnp.einsum("blsh,bshd->blhd", scores, vf) / denom[..., None]
        return out

    outs = jax.lax.map(
        one_chunk, (jnp.arange(n_chunks), qc.transpose(1, 0, 2, 3, 4),
                    Fc.transpose(1, 0, 2, 3))
    )  # (n_chunks, B, L, H, D)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d).astype(q.dtype)


def mlstm_forward(cfg, params, x):
    """x: (B,S,d) -> (B,S,d)."""
    di, h, hd = _mlstm_dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"])
    x_in, z = jnp.split(up, 2, axis=-1)
    q, k, v, ig, lf = _mlstm_qkv_gates(cfg, params, x_in)
    out = mlstm_parallel(q, k, v, ig, lf)
    out = _headwise_rms(out, params["o_norm"])
    out = out.reshape(*out.shape[:2], di) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", out, params["down_proj"])


def init_mlstm_cache(cfg, batch: int):
    di, h, hd = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def spec_mlstm_cache(cfg, rules, batch: int):
    di, h, hd = _mlstm_dims(cfg)
    ba = rules.batch_axes
    return {
        "C": rules.spec(ba, None, rules.model_axis, None, dim_sizes=(batch, h, hd, hd)),
        "n": rules.spec(ba, None, rules.model_axis, dim_sizes=(batch, h, hd)),
        "m": rules.spec(ba, None, dim_sizes=(batch, h)),
    }


def mlstm_decode(cfg, params, x, cache):
    """x: (B,1,d); stabilized recurrent step."""
    di, h, hd = _mlstm_dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"])
    x_in, z = jnp.split(up, 2, axis=-1)
    q, k, v, ig, lf = _mlstm_qkv_gates(cfg, params, x_in)
    qf = q[:, 0].astype(jnp.float32) * hd ** -0.5  # (B,H,D)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    ig, lf = ig[:, 0], lf[:, 0]                    # (B,H)

    m_new = jnp.maximum(lf + cache["m"], ig)
    f_sc = jnp.exp(lf + cache["m"] - m_new)[..., None]
    i_sc = jnp.exp(ig - m_new)[..., None]
    C = f_sc[..., None] * cache["C"] + i_sc[..., None] * kf[..., None] * vf[..., :, None].transpose(0, 1, 3, 2)
    # (B,H,Dk,Dv): outer product k x v
    n = f_sc * cache["n"] + i_sc * kf
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), jnp.exp(-m_new))
    out = (num / den[..., None]).astype(x.dtype)   # (B,H,Dv)
    out = _headwise_rms(out, params["o_norm"]).reshape(x.shape[0], 1, di)
    out = out * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", out, params["down_proj"])
    return out, {"C": C, "n": n, "m": m_new}


# ============================== sLSTM ==============================
def _slstm_dims(cfg):
    hd = cfg.d_model // cfg.n_heads
    return cfg.n_heads, hd


def init_slstm(key, cfg, dtype):
    d = cfg.d_model
    h, hd = _slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        # input weights for 4 gates (i, f, z, o)
        "w_in": _dense_init(ks[0], (d, 4, h, hd), d, dtype),
        # block-diagonal recurrent weights per head
        "r": _dense_init(ks[1], (4, h, hd, hd), hd, jnp.float32),
        "b": jnp.zeros((4, h, hd), jnp.float32).at[1].set(3.0),  # forget bias
        "o_norm": jnp.zeros((h, hd), jnp.float32),
        "up": _dense_init(ks[2], (d, 2 * cfg.d_model), d, dtype),
        "down": _dense_init(ks[3], (cfg.d_model, d), cfg.d_model, dtype),
    }


def spec_slstm(cfg, rules):
    d = cfg.d_model
    h, hd = _slstm_dims(cfg)
    m, f = rules.model_axis, rules.fsdp
    return {
        "w_in": rules.spec(f, None, None, m, dim_sizes=(d, 4, h, hd)),
        "r": rules.spec(None, None, None, m, dim_sizes=(4, h, hd, hd)),
        "b": P(None, None, None),
        "o_norm": P(None, None),
        "up": rules.spec(f, m, dim_sizes=(d, 2 * d)),
        "down": rules.spec(m, f, dim_sizes=(d, d)),
    }


def init_slstm_cache(cfg, batch: int):
    h, hd = _slstm_dims(cfg)
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, hd), -1e30, jnp.float32)}


def spec_slstm_cache(cfg, rules, batch: int):
    h, hd = _slstm_dims(cfg)
    s = rules.spec(rules.batch_axes, None, rules.model_axis, dim_sizes=(batch, h, hd))
    return {"c": s, "n": s, "h": s, "m": s}


def _slstm_cell(params, carry, gates_in):
    """One timestep. gates_in: (B,4,H,D) pre-activations from the input path."""
    c, n, h_prev, m_prev = carry["c"], carry["n"], carry["h"], carry["m"]
    rec = jnp.einsum("bhd,ghde->bghe", h_prev, params["r"])  # (B,4,H,D)
    pre = gates_in.astype(jnp.float32) + rec + params["b"][None]
    i_t, f_t, z_t, o_t = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]

    lf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(lf + m_prev, i_t)
    i_sc = jnp.exp(i_t - m_new)
    f_sc = jnp.exp(lf + m_prev - m_new)
    c_new = f_sc * c + i_sc * jnp.tanh(z_t)
    n_new = f_sc * n + i_sc
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_forward(cfg, params, x, cache=None):
    """x: (B,S,d) -> (B,S,d). Time-recurrent scan (no parallel form exists)."""
    b, s, d = x.shape
    h, hd = _slstm_dims(cfg)
    gates = jnp.einsum("bsd,dghe->bsghe", x, params["w_in"])  # (B,S,4,H,D)
    carry = cache if cache is not None else init_slstm_cache(cfg, b)

    def step(carry, g_t):
        new = _slstm_cell(params, carry, g_t)
        return new, new["h"]

    carry, hs = jax.lax.scan(step, carry, gates.transpose(1, 0, 2, 3, 4))
    hs = hs.transpose(1, 0, 2, 3)  # (B,S,H,D)
    hs = _headwise_rms(hs, params["o_norm"]).reshape(b, s, d)

    up = jnp.einsum("bsd,de->bse", hs.astype(x.dtype), params["up"])
    a, g = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bsd,de->bse", a * jax.nn.silu(g), params["down"])
    return out, carry


def slstm_decode(cfg, params, x, cache):
    out, carry = slstm_forward(cfg, params, x, cache)
    return out, carry

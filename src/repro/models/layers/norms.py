"""Normalization layers (pure functions + init/spec pairs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def rmsnorm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dtype)


def groupnorm(x, scale, bias, groups: int = 8, eps: float = 1e-5):
    """GroupNorm over channel-last conv activations (N,H,W,C).

    Used by the FL ResNet: BatchNorm's running statistics break under
    federated averaging of divergent clients (DESIGN.md), GroupNorm is the
    standard FL substitute.
    """
    n, h, w, c = x.shape
    dtype = x.dtype
    xg = x.astype(jnp.float32).reshape(n, h, w, groups, c // groups)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    y = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(n, h, w, c)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def init_norm(cfg, d: int):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def spec_norm(cfg):
    if cfg.norm == "rmsnorm":
        return {"scale": P(None)}
    return {"scale": P(None), "bias": P(None)}


def apply_norm(cfg, params, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])

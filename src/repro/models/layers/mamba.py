"""Mamba (selective SSM) block — Jamba's recurrent layer.

Train/prefill use the parallel associative-scan selective scan (Pallas kernel
on TPU, jnp oracle elsewhere); decode is the O(1) recurrent step carrying
(conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import ops


def _dense_init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)


def _dims(cfg):
    d_inner = cfg.ssm.expand * cfg.d_model
    dt_rank = cfg.ssm.dt_rank or int(np.ceil(cfg.d_model / 16))
    return d_inner, dt_rank, cfg.ssm.d_state, cfg.ssm.d_conv


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    di, dtr, n, dc = _dims(cfg)
    ks = jax.random.split(key, 6)
    # dt bias init so softplus(dt) spans ~[1e-3, 1e-1] (mamba reference)
    dt_init = jnp.exp(
        jax.random.uniform(ks[4], (di,), jnp.float32)
        * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), d, dtype),
        "conv_w": _dense_init(ks[1], (dc, di), dc, dtype),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": _dense_init(ks[2], (di, dtr + 2 * n), di, dtype),
        "dt_proj": _dense_init(ks[3], (dtr, di), dtr, dtype),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[5], (di, d), di, dtype),
    }


def spec_mamba(cfg, rules):
    d = cfg.d_model
    di, dtr, n, dc = _dims(cfg)
    m, f = rules.model_axis, rules.fsdp
    return {
        "in_proj": rules.spec(f, m, dim_sizes=(d, 2 * di)),
        "conv_w": rules.spec(None, m, dim_sizes=(dc, di)),
        "conv_b": rules.spec(m, dim_sizes=(di,)),
        "x_proj": rules.spec(m, None, dim_sizes=(di, dtr + 2 * n)),
        "dt_proj": rules.spec(None, m, dim_sizes=(dtr, di)),
        "dt_bias": rules.spec(m, dim_sizes=(di,)),
        "A_log": rules.spec(m, None, dim_sizes=(di, n)),
        "D": rules.spec(m, dim_sizes=(di,)),
        "out_proj": rules.spec(m, f, dim_sizes=(di, d)),
    }


def _ssm_inputs(cfg, params, xc):
    """xc: post-conv activations (B,S,di) -> (dt, B, C)."""
    di, dtr, n, _ = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", xc, params["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", proj[..., :dtr], params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"]
    )
    Bm = proj[..., dtr : dtr + n].astype(jnp.float32)
    Cm = proj[..., dtr + n :].astype(jnp.float32)
    return dt, Bm, Cm


def mamba_forward(cfg, params, u):
    """u: (B,S,d) -> (B,S,d). Parallel selective scan over the sequence."""
    di, dtr, n, dc = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", u, params["in_proj"])
    x, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv1d
    x_pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(
        x_pad[:, i : i + x.shape[1]] * params["conv_w"][i][None, None]
        for i in range(dc)
    ) + params["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)

    dt, Bm, Cm = _ssm_inputs(cfg, params, xc)
    A = -jnp.exp(params["A_log"])
    y, _ = ops.selective_scan(xc, dt, A, Bm, Cm, params["D"])
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, params["out_proj"])


# ---------------- decode ----------------
def init_mamba_cache(cfg, batch: int, dtype):
    di, _, n, dc = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, n), jnp.float32),
    }


def spec_mamba_cache(cfg, rules, batch: int):
    di, _, n, dc = _dims(cfg)
    return {
        "conv": rules.spec(rules.batch_axes, None, rules.model_axis,
                           dim_sizes=(batch, dc - 1, di)),
        "ssm": rules.spec(rules.batch_axes, rules.model_axis, None,
                          dim_sizes=(batch, di, n)),
    }


def mamba_decode(cfg, params, u, cache):
    """u: (B,1,d) -> (out (B,1,d), new_cache). O(1) recurrent step."""
    di, dtr, n, dc = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", u, params["in_proj"])[:, 0]
    x, z = jnp.split(xz, 2, axis=-1)  # (B, di)

    conv_buf = jnp.concatenate([cache["conv"], x[:, None]], axis=1)  # (B, dc, di)
    xc = jnp.einsum("bcd,cd->bd", conv_buf, params["conv_w"]) + params["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)

    dt, Bm, Cm = _ssm_inputs(cfg, params, xc[:, None])
    A = -jnp.exp(params["A_log"])
    y, new_ssm = ops.selective_scan_step(
        xc, dt[:, 0], A, Bm[:, 0], Cm[:, 0], params["D"], cache["ssm"]
    )
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bd,de->be", y, params["out_proj"])[:, None]
    return out, {"conv": conv_buf[:, 1:], "ssm": new_ssm}

"""Token embeddings + rotary position encodings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def init_embedding(key, vocab: int, d_model: int, dtype):
    scale = 1.0 / np.sqrt(d_model)
    return {
        "table": (jax.random.normal(key, (vocab, d_model), jnp.float32) * scale).astype(dtype)
    }


def spec_embedding(rules, vocab: int, d_model: int):
    return {"table": rules.spec(rules.model_axis, rules.fsdp, dim_sizes=(vocab, d_model))}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2), fp32."""
    half = head_dim // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (..., S, D/2) broadcast over heads."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]  # add head dim
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dtype)

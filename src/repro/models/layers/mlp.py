"""Gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dense_init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)


def init_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d_model, d_ff), d_model, dtype),
        "w_up": _dense_init(ks[1], (d_model, d_ff), d_model, dtype),
        "w_down": _dense_init(ks[2], (d_ff, d_model), d_ff, dtype),
    }


def spec_mlp(rules, d_model: int, d_ff: int):
    m, f = rules.model_axis, rules.fsdp
    return {
        "w_gate": rules.spec(f, m, dim_sizes=(d_model, d_ff)),
        "w_up": rules.spec(f, m, dim_sizes=(d_model, d_ff)),
        "w_down": rules.spec(m, f, dim_sizes=(d_ff, d_model)),
    }


def mlp_forward(params, x, act: str = "silu"):
    a = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    g = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)
    h = g * jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])

"""GQA/MHA attention block: projections + RoPE + (flash) attention + caches.

Supports: grouped-query attention, per-head QK-RMSNorm (qwen3), sliding
window (mixtral / long-context SWA variant), full and ring KV caches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from .embeddings import apply_rope, rope_angles


def _dense_init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)


def init_attention(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": _dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": _dense_init(ks[1], (d, kv, hd), d, dtype),
        "wv": _dense_init(ks[2], (d, kv, hd), d, dtype),
        "wo": _dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }
    if cfg.qk_norm:
        params["q_scale"] = jnp.zeros((hd,), jnp.float32)
        params["k_scale"] = jnp.zeros((hd,), jnp.float32)
    return params


def spec_attention(cfg, rules):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    m, f = rules.model_axis, rules.fsdp
    specs = {
        "wq": rules.spec(f, m, None, dim_sizes=(d, h, hd)),
        "wk": rules.spec(f, m, None, dim_sizes=(d, kv, hd)),
        "wv": rules.spec(f, m, None, dim_sizes=(d, kv, hd)),
        "wo": rules.spec(m, None, f, dim_sizes=(h, hd, d)),
    }
    if cfg.qk_norm:
        specs["q_scale"] = P(None)
        specs["k_scale"] = P(None)
    return specs


def _qk_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * (1.0 + scale)).astype(x.dtype)


def _project_qkv(cfg, params, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = _qk_norm(q, params["q_scale"])
        k = _qk_norm(k, params["k_scale"])
    cos, sin = rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def attention_forward(cfg, params, x, *, window=None):
    """Full-sequence causal attention (train / prefill). x: (B,S,D)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(cfg, params, x, positions)
    win = window if window is not None else cfg.sliding_window
    out = ops.flash_attention(q, k, v, causal=True, window=win)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# ---------------- caches ----------------
def init_kv_cache(cfg, batch: int, cache_len: int, dtype):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cache_len, kv, hd), dtype),
        "v": jnp.zeros((batch, cache_len, kv, hd), dtype),
    }


def spec_kv_cache(cfg, rules, batch: int, cache_len: int):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    m = rules.model_axis
    msize = rules.size(m)
    if m is not None and kv % max(1, msize) == 0:
        s = rules.spec(rules.batch_axes, None, m, None,
                       dim_sizes=(batch, cache_len, kv, hd))
    else:
        # GQA heads don't divide the model axis: shard the sequence dim —
        # decode attention reduces over S, XLA partial-softmaxes across it
        s = rules.spec(rules.batch_axes, m, None, None,
                       dim_sizes=(batch, cache_len, kv, hd))
    return {"k": s, "v": s}


def attention_decode(cfg, params, x, cache, pos, *, ring: bool):
    """One-token decode. x: (B,1,D); pos: scalar int32 absolute position.

    ring=True -> sliding-window ring buffer of size cache_len; else linear
    cache of the full context.  Returns (out (B,1,D), new_cache).
    """
    b = x.shape[0]
    cache_len = cache["k"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, params, x, positions)

    slot = pos % cache_len if ring else jnp.minimum(pos, cache_len - 1)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))

    idx = jnp.arange(cache_len)
    if ring:
        # slot i holds absolute position: the most recent write at that slot
        age = (slot - idx) % cache_len           # 0 = newest
        abs_pos = pos - age
        valid = abs_pos >= jnp.maximum(0, pos + 1 - cache_len)
    else:
        valid = idx <= pos
    kv_valid = jnp.broadcast_to(valid[None], (b, cache_len))

    out = ops.decode_attention(q[:, 0], k_cache, v_cache, kv_valid=kv_valid)
    out = jnp.einsum("bhk,hkd->bd", out, params["wo"])[:, None]
    return out, {"k": k_cache, "v": v_cache}

"""Generic decoder-only stack covering every assigned architecture family.

One block = pre-norm mixer (attention | MLA | mamba | mLSTM | sLSTM)
[+ pre-norm FFN (dense MLP | MoE) when d_ff > 0].  The layer plan comes from
``ArchConfig.layer_plan()``; homogeneous plans scan over layers (stacked
params, small HLO), hybrid plans scan over the repeating *period* with one
param pytree per position-in-period.

All functions are pure; params/caches are dicts mirrored 1:1 by spec
functions (PartitionSpec pytrees) used for pjit in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, LayerSpec
from .layers import attention as attn_lib
from .layers import mamba as mamba_lib
from .layers import mla as mla_lib
from .layers import moe as moe_lib
from .layers import xlstm as xlstm_lib
from .layers.embeddings import embed, init_embedding, spec_embedding
from .layers.mlp import init_mlp, mlp_forward, spec_mlp
from .layers.norms import apply_norm, init_norm, spec_norm

PyTree = Any
AUX_KEYS = ("moe_aux", "moe_z", "moe_drop_frac")

# Sequence-parallel residual saves: when set (by launch/specs.py) to a
# PartitionSpec for the (B, S, d) carry, the layer-scan carry is pinned to it
# so per-layer remat saves are sharded (Megatron sequence-parallelism at scan
# boundaries) instead of replicated over the model axis.  None on CPU tests.
CARRY_SHARDING = None


def _pin_carry(x):
    if CARRY_SHARDING is None:
        return x
    return jax.lax.with_sharding_constraint(x, CARRY_SHARDING)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ============================ block ============================
def init_block(key, cfg: ArchConfig, spec: LayerSpec):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 2)
    p: dict = {"norm1": init_norm(cfg, cfg.d_model)}
    if spec.kind == "attn":
        p["mixer"] = (
            mla_lib.init_mla(ks[0], cfg, dt)
            if cfg.mla is not None
            else attn_lib.init_attention(ks[0], cfg, dt)
        )
    elif spec.kind == "mamba":
        p["mixer"] = mamba_lib.init_mamba(ks[0], cfg, dt)
    elif spec.kind == "mlstm":
        p["mixer"] = xlstm_lib.init_mlstm(ks[0], cfg, dt)
    elif spec.kind == "slstm":
        p["mixer"] = xlstm_lib.init_slstm(ks[0], cfg, dt)
    else:
        raise ValueError(spec.kind)
    if cfg.d_ff > 0:
        p["norm2"] = init_norm(cfg, cfg.d_model)
        p["ffn"] = (
            moe_lib.init_moe(ks[1], cfg, dt)
            if spec.moe
            else init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt)
        )
    return p


def spec_block(cfg: ArchConfig, spec: LayerSpec, rules):
    s: dict = {"norm1": spec_norm(cfg)}
    if spec.kind == "attn":
        s["mixer"] = (
            mla_lib.spec_mla(cfg, rules)
            if cfg.mla is not None
            else attn_lib.spec_attention(cfg, rules)
        )
    elif spec.kind == "mamba":
        s["mixer"] = mamba_lib.spec_mamba(cfg, rules)
    elif spec.kind == "mlstm":
        s["mixer"] = xlstm_lib.spec_mlstm(cfg, rules)
    elif spec.kind == "slstm":
        s["mixer"] = xlstm_lib.spec_slstm(cfg, rules)
    if cfg.d_ff > 0:
        s["norm2"] = spec_norm(cfg)
        s["ffn"] = (
            moe_lib.spec_moe(cfg, rules)
            if spec.moe
            else spec_mlp(rules, cfg.d_model, cfg.d_ff)
        )
    return s


def _zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def block_forward(cfg: ArchConfig, spec: LayerSpec, params, x, *, window=None):
    """Full-sequence training/prefill pass. Returns (x, aux)."""
    aux = _zero_aux()
    h = apply_norm(cfg, params["norm1"], x)
    if spec.kind == "attn":
        if cfg.mla is not None:
            h = mla_lib.mla_forward(cfg, params["mixer"], h, window=window)
        else:
            h = attn_lib.attention_forward(cfg, params["mixer"], h, window=window)
        x = x + h
    elif spec.kind == "mamba":
        x = x + mamba_lib.mamba_forward(cfg, params["mixer"], h)
    elif spec.kind == "mlstm":
        x = x + xlstm_lib.mlstm_forward(cfg, params["mixer"], h)
    elif spec.kind == "slstm":
        out, _ = xlstm_lib.slstm_forward(cfg, params["mixer"], h)
        x = x + out
    if cfg.d_ff > 0:
        h = apply_norm(cfg, params["norm2"], x)
        if spec.moe:
            out, moe_aux = moe_lib.moe_forward(cfg, params["ffn"], h)
            aux = {**aux, **{k: aux[k] + moe_aux.get(k, 0.0) for k in AUX_KEYS}}
        else:
            out = mlp_forward(params["ffn"], h, cfg.act)
        x = x + out
    return x, aux


# ---- block caches (decode) ----
def init_block_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, cache_len: int):
    dt = _dtype(cfg)
    if spec.kind == "attn":
        if cfg.mla is not None:
            return mla_lib.init_mla_cache(cfg, batch, cache_len, dt)
        return attn_lib.init_kv_cache(cfg, batch, cache_len, dt)
    if spec.kind == "mamba":
        return mamba_lib.init_mamba_cache(cfg, batch, dt)
    if spec.kind == "mlstm":
        return xlstm_lib.init_mlstm_cache(cfg, batch)
    if spec.kind == "slstm":
        return xlstm_lib.init_slstm_cache(cfg, batch)
    raise ValueError(spec.kind)


def spec_block_cache(cfg: ArchConfig, spec: LayerSpec, rules, batch: int, cache_len: int):
    if spec.kind == "attn":
        if cfg.mla is not None:
            return mla_lib.spec_mla_cache(cfg, rules, batch, cache_len)
        return attn_lib.spec_kv_cache(cfg, rules, batch, cache_len)
    if spec.kind == "mamba":
        return mamba_lib.spec_mamba_cache(cfg, rules, batch)
    if spec.kind == "mlstm":
        return xlstm_lib.spec_mlstm_cache(cfg, rules, batch)
    if spec.kind == "slstm":
        return xlstm_lib.spec_slstm_cache(cfg, rules, batch)
    raise ValueError(spec.kind)


def block_decode(cfg: ArchConfig, spec: LayerSpec, params, x, cache, pos, *, ring: bool):
    """One-token decode. x: (B,1,d). Returns (x, new_cache)."""
    h = apply_norm(cfg, params["norm1"], x)
    if spec.kind == "attn":
        if cfg.mla is not None:
            out, cache = mla_lib.mla_decode(cfg, params["mixer"], h, cache, pos, ring=ring)
        else:
            out, cache = attn_lib.attention_decode(cfg, params["mixer"], h, cache, pos, ring=ring)
    elif spec.kind == "mamba":
        out, cache = mamba_lib.mamba_decode(cfg, params["mixer"], h, cache)
    elif spec.kind == "mlstm":
        out, cache = xlstm_lib.mlstm_decode(cfg, params["mixer"], h, cache)
    elif spec.kind == "slstm":
        out, cache = xlstm_lib.slstm_decode(cfg, params["mixer"], h, cache)
    x = x + out
    if cfg.d_ff > 0:
        h = apply_norm(cfg, params["norm2"], x)
        if spec.moe:
            out, _ = moe_lib.moe_forward(cfg, params["ffn"], h, capacity_factor=2.0)
        else:
            out = mlp_forward(params["ffn"], h, cfg.act)
        x = x + out
    return x, cache


# ============================ full model ============================
def init_params(key, cfg: ArchConfig) -> PyTree:
    dt = _dtype(cfg)
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: dict = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": (
                jax.random.normal(keys[1], (cfg.d_model, cfg.vocab_size), jnp.float32)
                / np.sqrt(cfg.d_model)
            ).astype(dt)
        }
    if cfg.frontend_tokens:
        fd = cfg.frontend_dim or cfg.d_model
        params["frontend_proj"] = {
            "w": (
                jax.random.normal(keys[2], (fd, cfg.d_model), jnp.float32) / np.sqrt(fd)
            ).astype(dt)
        }

    plan = cfg.layer_plan()
    per_layer = [init_block(keys[3 + i], cfg, plan[i]) for i in range(cfg.n_layers)]
    if cfg.scan_layers:
        period = cfg.plan_period
        blocks = []
        for pos in range(period):
            stacked = jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *per_layer[pos::period]
            )
            blocks.append(stacked)
        params["blocks"] = tuple(blocks)
    else:
        params["blocks"] = tuple(per_layer)
    return params


def param_specs(cfg: ArchConfig, rules) -> PyTree:
    specs: dict = {
        "embed": spec_embedding(rules, cfg.vocab_size, cfg.d_model),
        "final_norm": spec_norm(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = {
            "w": rules.spec(rules.fsdp, rules.model_axis,
                            dim_sizes=(cfg.d_model, cfg.vocab_size))
        }
    if cfg.frontend_tokens:
        fd = cfg.frontend_dim or cfg.d_model
        specs["frontend_proj"] = {
            "w": rules.spec(None, rules.fsdp, dim_sizes=(fd, cfg.d_model))
        }
    plan = cfg.layer_plan()
    if cfg.scan_layers:
        period = cfg.plan_period

        def add_layer_dim(spec_tree):
            return jax.tree.map(
                lambda s: P(None, *s), spec_tree,
                is_leaf=lambda s: isinstance(s, P),
            )

        specs["blocks"] = tuple(
            add_layer_dim(spec_block(cfg, plan[pos], rules)) for pos in range(period)
        )
    else:
        specs["blocks"] = tuple(
            spec_block(cfg, plan[i], rules) for i in range(cfg.n_layers)
        )
    return specs


def _embed_inputs(cfg: ArchConfig, params, batch):
    """tokens (+ frontend embeddings) -> (B, S_total, d) residual stream."""
    x = embed(params["embed"], batch["tokens"])
    if cfg.frontend_tokens:
        fe = batch["frontend"].astype(x.dtype)  # (B, F, frontend_dim)
        fe = jnp.einsum("bfd,de->bfe", fe, params["frontend_proj"]["w"])
        x = jnp.concatenate([fe, x], axis=1)
    if cfg.dtype:
        x = x.astype(_dtype(cfg))
    return x


def _run_stack(cfg: ArchConfig, params, x, *, window=None):
    """Apply all blocks. Returns (x, aux_sum)."""
    plan = cfg.layer_plan()
    aux = _zero_aux()
    if not cfg.scan_layers:
        for i, p in enumerate(params["blocks"]):
            x, a = block_forward(cfg, plan[i], p, x, window=window)
            aux = {k: aux[k] + a[k] for k in AUX_KEYS}
        return x, aux

    period = cfg.plan_period

    def period_body(x, layer_params):
        a_sum = _zero_aux()
        for pos in range(period):
            x, a = block_forward(cfg, plan[pos], layer_params[pos], x, window=window)
            a_sum = {k: a_sum[k] + a[k] for k in AUX_KEYS}
        return _pin_carry(x), a_sum

    if cfg.remat:
        period_body = jax.checkpoint(period_body)

    def scan_fn(x, layer_params):
        return period_body(x, layer_params)

    x, auxs = jax.lax.scan(scan_fn, x, params["blocks"])
    aux = {k: jnp.sum(auxs[k]) for k in AUX_KEYS}
    return x, aux


def _logits(cfg: ArchConfig, params, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["w"])


def forward(cfg: ArchConfig, params, batch, *, window=None):
    """Training/prefill forward -> (logits, aux)."""
    x = _embed_inputs(cfg, params, batch)
    x, aux = _run_stack(cfg, params, x, window=window)
    x = apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x), aux


# ---------------- losses ----------------
def cross_entropy(cfg: ArchConfig, params, x_final, labels, *, chunk: int = 0):
    """Token CE over the final residual stream; labels==-1 are masked.

    chunk > 0 computes logits sequence-chunkwise under checkpoint so the full
    (B,S,V) logits tensor is never materialized (big-vocab memory saver).
    """
    b, s, _ = x_final.shape

    def ce_of(xc, yc):
        logits = _logits(cfg, params, xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(yc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (yc >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    if chunk and s % chunk == 0 and s > chunk:
        xs = x_final.reshape(b, s // chunk, chunk, -1).transpose(1, 0, 2, 3)
        ys = labels.reshape(b, s // chunk, chunk).transpose(1, 0, 2)
        losses, counts = jax.lax.map(jax.checkpoint(lambda a: ce_of(*a)), (xs, ys))
        total, n = jnp.sum(losses), jnp.sum(counts)
    else:
        total, n = ce_of(x_final, labels)
    return total / jnp.maximum(n, 1.0)


def loss_fn(cfg: ArchConfig, params, batch, *, ce_chunk: int = 0):
    """FL-client local loss: CE + MoE aux. Returns (loss, metrics)."""
    x = _embed_inputs(cfg, params, batch)
    x, aux = _run_stack(cfg, params, x)
    x = apply_norm(cfg, params["final_norm"], x)

    labels = batch["labels"]
    if cfg.frontend_tokens:
        pad = jnp.full((labels.shape[0], cfg.frontend_tokens), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)

    ce = cross_entropy(cfg, params, x, labels, chunk=ce_chunk)
    loss = ce
    if cfg.moe is not None:
        loss = loss + moe_lib.moe_loss(aux, cfg)
    metrics = {"ce": ce, **aux}
    return loss, metrics


# ---------------- prefill / decode ----------------
def _ring(cfg: ArchConfig, shape_seq_len: int) -> tuple[bool, int]:
    """(use ring buffer?, cache_len) for a given context length."""
    win = cfg.sliding_window
    if win is None and shape_seq_len > 65_536:
        win = cfg.long_context_window  # SWA variant for long_500k (DESIGN.md §5)
    if win is not None and win < shape_seq_len:
        return True, win
    return False, shape_seq_len


def init_cache(cfg: ArchConfig, batch: int, context_len: int):
    ring, cache_len = _ring(cfg, context_len)
    plan = cfg.layer_plan()
    if not cfg.scan_layers:
        caches = tuple(
            init_block_cache(cfg, plan[i], batch, cache_len)
            for i in range(cfg.n_layers)
        )
    else:
        period = cfg.plan_period
        caches = tuple(
            jax.tree.map(
                lambda *xs: jnp.stack(xs, 0),
                *[
                    init_block_cache(cfg, plan[pos], batch, cache_len)
                    for _ in range(cfg.n_layers // period)
                ],
            )
            for pos in range(period)
        )
    return {"layers": caches, "pos": jnp.zeros((), jnp.int32)}


def cache_specs(cfg: ArchConfig, rules, batch: int, context_len: int):
    ring, cache_len = _ring(cfg, context_len)
    plan = cfg.layer_plan()
    if not cfg.scan_layers:
        caches = tuple(
            spec_block_cache(cfg, plan[i], rules, batch, cache_len)
            for i in range(cfg.n_layers)
        )
    else:
        period = cfg.plan_period

        def add_layer_dim(spec_tree):
            return jax.tree.map(
                lambda s: P(None, *s), spec_tree,
                is_leaf=lambda s: isinstance(s, P),
            )

        caches = tuple(
            add_layer_dim(spec_block_cache(cfg, plan[pos], rules, batch, cache_len))
            for pos in range(period)
        )
    return {"layers": caches, "pos": P()}


def decode_step(cfg: ArchConfig, params, batch, cache, *, context_len: int):
    """One-token decode: batch {"tokens": (B,1)} -> (logits (B,1,V), cache)."""
    ring, _ = _ring(cfg, context_len)
    pos = cache["pos"]
    x = embed(params["embed"], batch["tokens"]).astype(_dtype(cfg))
    plan = cfg.layer_plan()

    if not cfg.scan_layers:
        new_caches = []
        for i, p in enumerate(params["blocks"]):
            x, c = block_decode(cfg, plan[i], p, x, cache["layers"][i], pos, ring=ring)
            new_caches.append(c)
        new_caches = tuple(new_caches)
    else:
        period = cfg.plan_period

        def scan_fn(x, xs):
            layer_params, layer_cache = xs
            new_cache = []
            for pp in range(period):
                x, c = block_decode(
                    cfg, plan[pp], layer_params[pp], x, layer_cache[pp], pos, ring=ring
                )
                new_cache.append(c)
            return x, tuple(new_cache)

        x, new_caches = jax.lax.scan(
            scan_fn, x, (params["blocks"], cache["layers"])
        )

    x = apply_norm(cfg, params["final_norm"], x)
    logits = _logits(cfg, params, x)
    return logits, {"layers": new_caches, "pos": pos + 1}


def prefill(cfg: ArchConfig, params, batch, *, context_len: int):
    """Prefill: full forward + cache construction. Returns (logits, cache)."""
    ring, cache_len = _ring(cfg, context_len)
    x = _embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    plan = cfg.layer_plan()

    def mixer_prefill(spec, p, h, pos0):
        """Returns (mixer_out, cache) for one block, full-sequence."""
        if spec.kind == "attn":
            if cfg.mla is not None:
                out = mla_lib.mla_forward(cfg, p["mixer"], h)
                cache = _mla_prefill_cache(cfg, p["mixer"], h, cache_len, ring)
            else:
                out = attn_lib.attention_forward(cfg, p["mixer"], h)
                cache = _attn_prefill_cache(cfg, p["mixer"], h, cache_len, ring)
            return out, cache
        if spec.kind == "mamba":
            out, cache = _mamba_prefill(cfg, p["mixer"], h)
            return out, cache
        if spec.kind == "mlstm":
            return xlstm_lib_prefill_mlstm(cfg, p["mixer"], h)
        if spec.kind == "slstm":
            out, carry = xlstm_lib.slstm_forward(cfg, p["mixer"], h)
            return out, carry
        raise ValueError(spec.kind)

    def one_block(spec, p, x):
        h = apply_norm(cfg, p["norm1"], x)
        out, cache = mixer_prefill(spec, p, h, 0)
        x = x + out
        if cfg.d_ff > 0:
            h = apply_norm(cfg, p["norm2"], x)
            if spec.moe:
                out, _ = moe_lib.moe_forward(cfg, p["ffn"], h)
            else:
                out = mlp_forward(p["ffn"], h, cfg.act)
            x = x + out
        return x, cache

    if not cfg.scan_layers:
        caches = []
        for i, p in enumerate(params["blocks"]):
            x, c = one_block(plan[i], p, x)
            caches.append(c)
        caches = tuple(caches)
    else:
        period = cfg.plan_period

        def scan_fn(x, layer_params):
            cs = []
            for pp in range(period):
                x, c = one_block(plan[pp], layer_params[pp], x)
                cs.append(c)
            return _pin_carry(x), tuple(cs)

        if cfg.remat:
            scan_fn = jax.checkpoint(scan_fn)
        x, caches = jax.lax.scan(scan_fn, x, params["blocks"])

    x = apply_norm(cfg, params["final_norm"], x)
    logits = _logits(cfg, params, x[:, -1:])  # next-token logits only
    return logits, {"layers": caches, "pos": jnp.asarray(s, jnp.int32)}


def _ring_arrange(full, cache_len: int, ring: bool):
    """full: (B,S,...) per-position tensor -> cache layout (B,cache_len,...)."""
    s = full.shape[1]
    if not ring or s <= cache_len:
        if s == cache_len:
            return full
        pad = [(0, 0)] * full.ndim
        pad[1] = (0, cache_len - s)
        return jnp.pad(full, pad)
    last = full[:, s - cache_len :]
    # absolute positions s-cache_len .. s-1 -> slot = pos % cache_len
    slots = (jnp.arange(s - cache_len, s)) % cache_len
    inv = jnp.argsort(slots)
    return last[:, inv]


def _attn_prefill_cache(cfg, p, h, cache_len, ring):
    b, s, _ = h.shape
    positions = jnp.arange(s)[None, :]
    _, k, v = attn_lib._project_qkv(cfg, p, h, positions)
    return {"k": _ring_arrange(k, cache_len, ring), "v": _ring_arrange(v, cache_len, ring)}


def _mla_prefill_cache(cfg, p, h, cache_len, ring):
    b, s, _ = h.shape
    positions = jnp.arange(s)[None, :]
    _, _, c_kv, k_rope = mla_lib._latents(cfg, p, h, positions)
    return {
        "c_kv": _ring_arrange(c_kv, cache_len, ring),
        "k_rope": _ring_arrange(k_rope, cache_len, ring),
    }


def _mamba_prefill(cfg, p, u):
    """Mamba forward that also returns the final (conv, ssm) state."""
    from repro.kernels import ops

    di, dtr, n, dc = mamba_lib._dims(cfg)
    xz = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    xraw, z = jnp.split(xz, 2, axis=-1)
    x_pad = jnp.pad(xraw, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(
        x_pad[:, i : i + xraw.shape[1]] * p["conv_w"][i][None, None] for i in range(dc)
    ) + p["conv_b"].astype(xraw.dtype)
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = mamba_lib._ssm_inputs(cfg, p, xc)
    A = -jnp.exp(p["A_log"])
    y, final_state = ops.selective_scan(xc, dt, A, Bm, Cm, p["D"])
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    conv_state = x_pad[:, x_pad.shape[1] - (dc - 1) :]
    return out, {"conv": conv_state, "ssm": final_state}


def xlstm_lib_prefill_mlstm(cfg, p, h):
    """mLSTM forward + final (C, n, m) state for decode continuation."""
    di, nh, hd = xlstm_lib._mlstm_dims(cfg)
    up = jnp.einsum("bsd,de->bse", h, p["up_proj"])
    x_in, z = jnp.split(up, 2, axis=-1)
    q, k, v, ig, lf = xlstm_lib._mlstm_qkv_gates(cfg, p, x_in)
    out = xlstm_lib.mlstm_parallel(q, k, v, ig, lf)
    out = xlstm_lib._headwise_rms(out, p["o_norm"])
    out = out.reshape(*out.shape[:2], di) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", out, p["down_proj"])

    # final state: C_S = sum_j exp(F_S - F_j + ig_j - m) k_j v_j^T
    F = jnp.cumsum(lf, axis=1)                      # (B,S,H)
    w_log = F[:, -1:, :] - F + ig                    # (B,S,H)
    m = jnp.max(w_log, axis=1)                       # (B,H)
    w = jnp.exp(w_log - m[:, None])                  # (B,S,H)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = jnp.einsum("bsh,bshk,bshv->bhkv", w, kf, vf)
    n = jnp.einsum("bsh,bshk->bhk", w, kf)
    return out, {"C": C, "n": n, "m": m}

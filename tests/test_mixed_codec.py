"""MixedCodec — per-device mixed-codec batches inside the jitted engine.

ISSUE-4 tentpole acceptance, on the paper's heterogeneous fleet shape
(Pixel→TopK, Jetson→Int8, TPU→Null in ONE round):

- one jitted ``round_step`` aggregates all three groups, each on its own
  kernel path, with NO dense materialization of the TopK group's payload
  (``decode_batch`` is banned during the round);
- jitted MixedCodec round == sequential-scan round == python ``Server.run``
  aggregate within tolerance, round after round (error feedback included);
- per-client uplink bytes match each group codec's ``wire_bytes``;
- the per-group client state rides the uniform round_step signature;
- the mesh shard_map path rejects MixedCodec at build time.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BandwidthCodecPolicy, CompressedParameters, FedAvg, Int8Codec, JaxClient,
    MixedCodec, NullCodec, RoundSpec, Server, TopKCodec, make_round_step,
    PROFILES,
)
from repro.core.cost_model import CostModel
from repro.core.server import make_cost_model_for
from repro.data.federated import ClientDataset
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_flatten_to_vector, tree_size

FLEET = ("pixel-4", "jetson-tx2-gpu", "tpu-v5e-chip")  # TopK / Int8 / Null


def _fleet_codec(profile_names=FLEET) -> MixedCodec:
    return MixedCodec.from_policy(
        BandwidthCodecPolicy(), [PROFILES[p] for p in profile_names]
    )


# ---------------- construction ----------------
def test_from_policy_assignment_and_bank():
    codec = _fleet_codec(("pixel-4", "jetson-tx2-gpu", "tpu-v5e-chip", "pixel-3"))
    kinds = [type(codec.codecs[g]) for g in codec.assignment]
    assert kinds == [TopKCodec, Int8Codec, NullCodec, TopKCodec]
    # equal-config codecs dedupe into one bank entry
    assert len(codec.codecs) == 3
    assert codec.n_clients == 4
    # groups are static index arrays in bank order
    groups = {type(c).__name__: list(idx) for _, c, idx in codec.groups()}
    assert groups == {"TopKCodec": [0, 3], "Int8Codec": [1], "NullCodec": [2]}


def test_assignment_out_of_range_rejected():
    with pytest.raises(AssertionError):
        MixedCodec(codecs=(NullCodec(),), assignment=(0, 1))


def test_init_client_state_per_group_rows():
    codec = _fleet_codec(("pixel-4", "pixel-3", "jetson-tx2-gpu", "tpu-v5e-chip"))
    state = codec.init_client_state(4, 100)
    assert isinstance(state, tuple) and len(state) == 3
    assert state[0].shape == (2, 100)   # TopK group: 2 residual rows
    assert state[1].shape == (1, 100)   # Int8 group: 1 residual row
    assert state[2] == ()               # Null group: stateless
    with pytest.raises(AssertionError):
        codec.init_client_state(3, 100)  # fleet size is part of the codec


def test_wire_bytes_is_per_client():
    codec = _fleet_codec()
    n = 4096
    wb = codec.wire_bytes(n)
    assert wb == [
        TopKCodec().wire_bytes(n), Int8Codec().wire_bytes(n),
        NullCodec().wire_bytes(n),
    ]
    # vector form: one size per client
    assert codec.wire_bytes([100, 200, 300]) == [
        TopKCodec().wire_bytes(100), Int8Codec().wire_bytes(200),
        NullCodec().wire_bytes(300),
    ]
    with pytest.raises(TypeError):
        codec._wire_bytes_scalar(n)


def test_per_client_surfaces_are_group_owned():
    codec = _fleet_codec()
    for call in (
        lambda: codec.encode(jnp.zeros(8)),
        lambda: codec.decode({}),
        lambda: codec.transmit_tree({"w": jnp.zeros(8)}, ()),
        lambda: codec.reduce({}, jnp.ones(3)),
    ):
        with pytest.raises(TypeError, match="group"):
            call()


# ---------------- flat-batch aggregation semantics ----------------
def test_aggregate_batch_matches_per_group_decode_reference():
    """Group partial sums under ONE denominator == flat weighted mean of the
    per-client decoded deltas (each client decoded by its own codec)."""
    rng = np.random.default_rng(3)
    codec = _fleet_codec(("pixel-4", "jetson-tx2-gpu", "tpu-v5e-chip", "pixel-3"))
    C, n = 4, 700
    deltas = jnp.asarray(rng.normal(size=(C, n)) * 0.01, jnp.float32)
    w = jnp.asarray(rng.random(C) + 0.1, jnp.float32)
    state = codec.init_client_state(C, n)

    avg, new_state = codec.aggregate_batch(deltas, w, state)

    dec_rows = []
    for c in range(C):
        cc = codec.codecs[codec.assignment[c]]
        dec_rows.append(cc.decode(cc.encode(deltas[c])))
    exp = jnp.einsum("c,cn->n", w, jnp.stack(dec_rows)) / jnp.sum(w)
    np.testing.assert_allclose(np.asarray(avg), np.asarray(exp),
                               atol=1e-5, rtol=1e-5)
    # per-group error-feedback rows: what the wire dropped
    assert new_state[0].shape == (2, n) and new_state[1].shape == (1, n)
    np.testing.assert_allclose(   # Int8 row: delta - dequantized
        np.asarray(new_state[1][0]),
        np.asarray(deltas[1] - dec_rows[1]), atol=1e-6,
    )


def test_aggregate_batch_size_must_match_assignment():
    """A mismatched batch would silently clamp the static gather indices —
    the aggregation surfaces reject it up front like init_client_state."""
    codec = _fleet_codec()
    with pytest.raises(AssertionError, match="clients"):
        codec.aggregate_batch(
            jnp.ones((2, 64)), jnp.ones(2), codec.init_client_state(3, 64)
        )


def test_aggregate_batch_zero_weights_yield_zeros():
    codec = _fleet_codec()
    deltas = jnp.ones((3, 512), jnp.float32) * 0.01
    avg, _ = codec.aggregate_batch(
        deltas, jnp.zeros(3), codec.init_client_state(3, 512)
    )
    np.testing.assert_array_equal(np.asarray(avg), 0.0)


# ---------------- the jitted round engine ----------------
C, STEPS, B = 3, 2, 16


def _setup(seed=0):
    m = build_model("mobilenet-head-office31")
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 1.0, size=(m.cfg.num_classes, m.cfg.feature_dim))

    def batch_of(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, m.cfg.num_classes, n)
        x = centers[y] + 0.4 * r.normal(size=(n, m.cfg.feature_dim))
        return x.astype(np.float32), y.astype(np.int32)

    xs, ys = zip(*[batch_of(STEPS * B, 100 + c) for c in range(C)])
    train = {
        "x": jnp.asarray(np.stack(xs).reshape(C, STEPS, B, -1)),
        "y": jnp.asarray(np.stack(ys).reshape(C, STEPS, B)),
    }
    return m, m.init(jax.random.key(seed)), train


def _run_engine(m, params, train, codec, mode, rounds=2, weights=None):
    strat = FedAvg()
    spec = RoundSpec(max_steps=STEPS, execution_mode=mode, codec=codec)
    rs = jax.jit(make_round_step(m.loss_fn, sgd(0.1), strat, spec))
    w = jnp.ones(C) if weights is None else weights
    bud = jnp.full((C,), STEPS, jnp.int32)
    p, state = params, strat.init_state(params)
    cstate = codec.init_client_state(C, tree_size(params))
    mets = []
    for rnd in range(rounds):
        p, state, cstate, met = rs(p, state, cstate, train, w, bud, rnd)
        mets.append(met)
    return p, cstate, mets


def test_mixed_round_uniform_signature_and_state():
    m, params, train = _setup()
    codec = _fleet_codec()
    p, cstate, mets = _run_engine(m, params, train, codec, "parallel")
    met = mets[-1]
    assert jax.tree.structure(p) == jax.tree.structure(params)
    assert isinstance(cstate, tuple) and len(cstate) == 3
    n = tree_size(params)
    assert cstate[0].shape == (1, n) and cstate[1].shape == (1, n)
    assert cstate[2] == ()
    assert {"client_loss_mean", "client_loss_max", "steps_total",
            "residual_norm_mean"} <= set(met)
    # the residual telemetry covers ALL stateful groups' rows
    assert float(met["residual_norm_mean"]) > 0.0


def test_mixed_round_no_dense_topk_materialization():
    """Acceptance: the TopK group's payload is never densified inside the
    jitted mixed round — decode_batch raises if anything calls it."""
    from repro.core.compression import ban_topk_densify

    m, params, train = _setup()
    codec = _fleet_codec()
    with ban_topk_densify():
        p, _, _ = _run_engine(m, params, train, codec, "parallel")
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(p))


def test_mixed_round_matches_manual_group_combination():
    """One mixed round == gathering each group and running its own codec,
    combining partial weighted sums under the fleet denominator."""
    m, params, train = _setup()
    codec = _fleet_codec()
    w = jnp.asarray([1.0, 2.0, 0.5])
    p_mixed, _, _ = _run_engine(m, params, train, codec, "parallel",
                                rounds=1, weights=w)

    # manual: train all clients, aggregate each group with its own codec
    from repro.core.rounds import make_client_update
    spec = RoundSpec(max_steps=STEPS, execution_mode="parallel", codec=codec)
    cu = make_client_update(m.loss_fn, sgd(0.1), spec)
    new_params, _, _ = jax.vmap(cu, in_axes=(None, 0, 0))(
        params, train, jnp.full((C,), STEPS, jnp.int32)
    )
    flat_global = tree_flatten_to_vector(params)
    deltas = jax.vmap(lambda p: tree_flatten_to_vector(p) - flat_global)(new_params)
    total = jnp.zeros_like(flat_global)
    for g, cc, idx in codec.groups():
        ia = jnp.asarray(idx)  # groups() yields static python index lists
        mean_g, _ = cc.aggregate_batch(
            deltas[ia], w[ia], cc.init_client_state(len(idx), flat_global.size)
        )
        total = total + mean_g * jnp.sum(w[ia])
    exp = flat_global + total / jnp.sum(w)
    np.testing.assert_allclose(   # atol: jit-vs-eager local-training noise
        np.asarray(tree_flatten_to_vector(p_mixed)), np.asarray(exp),
        atol=1e-4, rtol=1e-4,
    )


def test_mixed_sequential_matches_parallel():
    """The per-group scans land the same global and the same per-group
    state rows as the vmap path (bf16 sequential accumulator tolerance)."""
    m, params, train = _setup()
    codec = _fleet_codec()
    w = jnp.asarray([1.0, 2.0, 0.5])
    outs = {}
    for mode in ("parallel", "sequential"):
        outs[mode] = _run_engine(m, params, train, codec, mode,
                                 rounds=2, weights=w)
    p_p, cs_p, mets_p = outs["parallel"]
    p_s, cs_s, mets_s = outs["sequential"]
    for a, b in zip(jax.tree.leaves(p_p), jax.tree.leaves(p_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=2e-3)
    for a, b in zip(jax.tree.leaves(cs_p), jax.tree.leaves(cs_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-2, rtol=2e-2)
    # satellite: the SAME metric definition on every execution mode —
    # round 1 starts from identical globals, so the weighted means must
    # agree to fp noise (later rounds drift with the bf16 accumulator)
    assert float(mets_s[0]["client_loss_mean"]) == pytest.approx(
        float(mets_p[0]["client_loss_mean"]), rel=1e-4
    )


def test_mixed_mesh_path_rejected_at_build_time():
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 host devices (see conftest.py)")
    m, params, _ = _setup()
    mesh = jax.make_mesh((2, 2), ("pod", "data"))
    spec = RoundSpec(max_steps=STEPS, execution_mode="parallel",
                     codec=_fleet_codec())
    with pytest.raises(NotImplementedError, match="MixedCodec"):
        make_round_step(m.loss_fn, sgd(0.1), FedAvg(), spec, mesh=mesh,
                        client_axes=("pod", "data"))


# ---------------- jitted engine == python Server parity ----------------
def test_mixed_fleet_jitted_matches_python_server():
    """Satellite acceptance: one heterogeneous fleet (Pixel→TopK,
    Jetson→Int8, TPU→Null), three assertions — jitted MixedCodec round ==
    sequential-scan round == python Server.run aggregate within tolerance,
    and per-client uplink bytes match each group codec's wire_bytes."""
    m, params, train = _setup()
    n = tree_size(params)
    policy = BandwidthCodecPolicy()
    codec = _fleet_codec()

    # python fleet: each client's shard is EXACTLY one full batch, so local
    # training (1 step of full-batch SGD) is permutation-invariant and
    # bitwise-comparable to the jitted engine fed the same rows
    clients = []
    for c, profile in enumerate(FLEET):
        x = np.asarray(train["x"][c]).reshape(STEPS * B, -1)
        y = np.asarray(train["y"][c]).reshape(STEPS * B)
        clients.append(JaxClient(
            client_id=c, loss_fn=m.loss_fn,
            dataset=ClientDataset(client_id=c, x=x, y=y),
            batch_size=STEPS * B, device_profile=profile,
        ))
    strat = FedAvg(local_epochs=1, local_lr=0.1, codec_policy=policy)
    cm = make_cost_model_for(params, [PROFILES[p] for p in FLEET])
    server = Server(strategy=strat, clients=clients, cost_model=cm)
    server.logger.quiet = True

    # jitted engine: same rows as ONE full-batch step per round
    flat_train = {
        "x": train["x"].reshape(C, 1, STEPS * B, -1),
        "y": train["y"].reshape(C, 1, STEPS * B),
    }
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), FedAvg(),
        RoundSpec(max_steps=1, execution_mode="parallel", codec=codec),
    ))
    rs_seq = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), FedAvg(),
        RoundSpec(max_steps=1, execution_mode="sequential", codec=codec),
    ))
    w = jnp.full((C,), float(STEPS * B))
    bud = jnp.ones((C,), jnp.int32)

    p_server, hist = server.run(params, num_rounds=2)
    p_jit, p_seq = params, params
    cs_jit = codec.init_client_state(C, n)
    cs_seq = codec.init_client_state(C, n)
    for rnd in range(2):
        p_jit, _, cs_jit, _ = rs(p_jit, (), cs_jit, flat_train, w, bud, rnd)
        p_seq, _, cs_seq, _ = rs_seq(p_seq, (), cs_seq, flat_train, w, bud, rnd)

    vec = {k: np.asarray(tree_flatten_to_vector(v))
           for k, v in (("server", p_server), ("jit", p_jit), ("seq", p_seq))}
    np.testing.assert_allclose(vec["jit"], vec["server"], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(vec["seq"], vec["jit"], atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(vec["seq"], vec["server"], atol=2e-3, rtol=2e-3)

    # per-client uplink: each client shipped its group codec's wire size,
    # and MixedCodec's per-client accounting agrees
    mixed_wb = codec.wire_bytes(n)
    props = {c.client_id: c.properties() for c in clients}
    for cid, ins in strat.configure_fit(1, params, [0, 1, 2],
                                        client_properties=props):
        res = clients[cid].fit(ins)
        assert isinstance(res.parameters, CompressedParameters)
        assert res.parameters.num_bytes == ins.config["codec"].wire_bytes(n)
        assert res.parameters.num_bytes == mixed_wb[cid]
    assert hist.rounds[0].comm_bytes == sum(mixed_wb) + C * cm.update_bytes


# ---------------- per-group cost accounting ----------------
def test_cost_model_fleet_uplink_bytes():
    cm = CostModel(profiles=[PROFILES[p] for p in FLEET], update_bytes=4_000_000)
    codec = _fleet_codec()
    n = 10_000
    ups = cm.fleet_uplink_bytes(codec, n, 3)
    assert ups == codec.wire_bytes(n)
    assert cm.fleet_uplink_bytes(Int8Codec(), n, 3) == [Int8Codec().wire_bytes(n)] * 3
    assert cm.fleet_uplink_bytes(None, n, 3) is None
    with pytest.raises(AssertionError):
        cm.fleet_uplink_bytes(codec, n, 5)  # fleet size mismatch

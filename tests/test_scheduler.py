"""Virtual-clock execution layer (core/scheduler.py + the participation
mask in core/rounds.py + the scheduler-driven Server).

ISSUE-5 acceptance criteria asserted here:
- ``Deadline(tau=inf)`` + full availability reproduces today's synchronous
  results BITWISE — on all three round_step execution modes (all-ones mask
  == no mask) and end-to-end through ``Server.run``;
- a masked (dropped) client provably leaves its error-feedback residual
  row and the aggregate untouched (its data is garbled and nothing moves);
- ``BufferedAsync`` ends rounds earlier than ``SyncAll`` on a straggler-
  heavy fleet while FedBuff keeps learning, with staleness recorded.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AvailabilityTrace, BufferedAsync, Client, Deadline, FedAvg,
    FedBuffStrategy, FedTau, FitRes, JaxClient, PROFILES, RoundSpec, Server,
    Strategy, SyncAll, VirtualClock, make_round_step,
)
from repro.core.compression import Int8Codec, MixedCodec, NullCodec, TopKCodec
from repro.core.scheduler import Arrival
from repro.core.server import make_cost_model_for
from repro.data.federated import dirichlet_partition
from repro.data.synthetic import make_features
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_size


# ---------------- policies on a synthetic timeline ----------------
def _arr(cid, launch_rnd=1, launch_t=0.0, dur=1.0):
    return Arrival(client_id=cid, launch_rnd=launch_rnd, launch_t=launch_t,
                   finish_t=launch_t + dur, cost=None)


def test_syncall_waits_for_slowest():
    clock = VirtualClock()
    pending = [_arr(0, dur=1.0), _arr(1, dur=30.0), _arr(2, dur=5.0)]
    out = SyncAll().plan(clock, pending, 1)
    assert [a.client_id for a in out.reported] == [0, 2, 1]  # arrival order
    assert out.round_end == 30.0 and out.wall_time_s == 30.0
    assert not out.dropped and not out.carried and not out.expired


def test_deadline_drops_stragglers_and_waits_full_tau():
    clock = VirtualClock()
    pending = [_arr(0, dur=1.0), _arr(1, dur=30.0), _arr(2, dur=5.0)]
    out = Deadline(tau=10.0).plan(clock, pending, 1)
    assert [a.client_id for a in out.reported] == [0, 2]
    assert [a.client_id for a in out.dropped] == [1]
    assert out.round_end == 10.0  # a straggler exists: wait the full cutoff
    # no stragglers: the round ends with the last reporter, not the cutoff
    out2 = Deadline(tau=10.0).plan(clock, pending[:1] + pending[2:], 1)
    assert out2.round_end == 5.0 and not out2.dropped


def test_deadline_infinite_tau_matches_syncall():
    clock = VirtualClock()
    pending = [_arr(0, dur=1.0), _arr(1, dur=30.0), _arr(2, dur=5.0)]
    sync = SyncAll().plan(clock, pending, 1)
    inf = Deadline(tau=float("inf")).plan(clock, pending, 1)
    assert [a.client_id for a in inf.reported] == [a.client_id for a in sync.reported]
    assert inf.round_end == sync.round_end and not inf.dropped


def test_deadline_tau_none_reads_the_strategy_knob():
    """FedTau's tau and the scheduler's deadline are ONE knob."""
    assert Deadline().resolve_tau(FedTau(tau_s=5.0)) == 5.0
    assert Deadline().resolve_tau(FedTau(tau_s=0.0)) == float("inf")  # 0 = off
    assert Deadline().resolve_tau(FedAvg()) == float("inf")
    assert Deadline(tau=3.0).resolve_tau(FedTau(tau_s=5.0)) == 3.0  # explicit wins
    out = Deadline().plan(VirtualClock(), [_arr(0, dur=9.0)], 1, FedTau(tau_s=5.0))
    assert not out.reported and [a.client_id for a in out.dropped] == [0]


def test_buffered_async_takes_first_k_and_carries():
    clock = VirtualClock()
    pending = [_arr(0, dur=1.0), _arr(1, dur=30.0), _arr(2, dur=5.0)]
    out = BufferedAsync(buffer_size=2, max_staleness=4).plan(clock, pending, 1)
    assert [a.client_id for a in out.reported] == [0, 2]
    assert [a.client_id for a in out.carried] == [1]
    assert out.round_end == 5.0  # the K-th arrival ends the round
    # the carried straggler reports next round with staleness 1
    clock.advance_to(out.round_end)
    out2 = BufferedAsync(buffer_size=2, max_staleness=4).plan(
        clock, out.carried, 2
    )
    assert [a.client_id for a in out2.reported] == [1]
    assert out2.reported[0].staleness_at(2) == 1
    assert out2.round_end == 30.0


def test_buffered_async_expires_too_stale():
    clock = VirtualClock()
    old = _arr(0, launch_rnd=1, dur=2.0)
    out = BufferedAsync(buffer_size=2, max_staleness=2).plan(clock, [old], 9)
    assert not out.reported and [a.client_id for a in out.expired] == [0]


def test_buffered_async_expired_do_not_consume_buffer_slots():
    """Stale junk is flushed up front: the K buffer slots go to USABLE
    arrivals, so a burst of expiries cannot starve the aggregation."""
    clock = VirtualClock()
    stale = [_arr(i, launch_rnd=1, dur=0.5 + 0.1 * i) for i in range(3)]
    fresh = [_arr(10, launch_rnd=9, dur=5.0), _arr(11, launch_rnd=9, dur=6.0)]
    out = BufferedAsync(buffer_size=3, max_staleness=2).plan(
        clock, stale + fresh, 9
    )
    assert [a.client_id for a in out.expired] == [0, 1, 2]
    assert [a.client_id for a in out.reported] == [10, 11]
    assert not out.carried
    assert out.round_end == 6.0  # the last usable reporter gates the round


def test_buffered_async_inflight_expiry_never_gates_the_round():
    """An expired straggler still in flight is cancelled, not waited for —
    waiting for a discarded update is the straggler wall async avoids."""
    clock = VirtualClock()
    clock.advance_to(10.0)
    slow_stale = _arr(0, launch_rnd=1, launch_t=0.0, dur=60.0)  # flies on
    fresh = _arr(1, launch_rnd=9, launch_t=10.0, dur=2.0)
    out = BufferedAsync(buffer_size=1, max_staleness=2).plan(
        clock, [slow_stale, fresh], 9
    )
    assert [a.client_id for a in out.reported] == [1]
    assert [a.client_id for a in out.expired] == [0]
    assert out.round_end == 12.0  # NOT 60: the cancelled straggler is ignored


def test_virtual_clock_is_monotone():
    clock = VirtualClock()
    clock.advance_to(5.0)
    clock.advance_to(5.0)  # no-op, not an error
    assert clock.now == 5.0
    with pytest.raises(AssertionError):
        clock.advance_to(1.0)


# ---------------- availability traces ----------------
def test_availability_trace_deterministic_and_seed_sensitive():
    profiles = [PROFILES["pixel-4"]] * 6 + [PROFILES["jetson-tx2-gpu"]] * 2
    t1 = AvailabilityTrace.from_profiles(profiles, seed=0, mobile_dropout=0.5)
    t2 = AvailabilityTrace.from_profiles(profiles, seed=0, mobile_dropout=0.5)
    t3 = AvailabilityTrace.from_profiles(profiles, seed=1, mobile_dropout=0.5)
    for rnd in range(1, 6):
        np.testing.assert_array_equal(t1.available(rnd), t2.available(rnd))
        np.testing.assert_allclose(t1.step_jitter(rnd), t2.step_jitter(rnd))
    assert any(
        not np.array_equal(t1.available(r), t3.available(r)) for r in range(1, 20)
    )


def test_availability_full_trace_is_always_up():
    t = AvailabilityTrace.full(5)
    for rnd in (1, 7, 100):
        assert t.available(rnd).all()
        np.testing.assert_array_equal(t.step_jitter(rnd), np.ones(5))


def test_from_profiles_battery_churns_more_than_plugged():
    profiles = [PROFILES["pixel-2"], PROFILES["jetson-tx2-gpu"]]
    t = AvailabilityTrace.from_profiles(
        profiles, mobile_dropout=0.4, plugged_dropout=0.01
    )
    assert t.dropout == (0.4, 0.01)  # pixel idles at 0.7 W (battery class)
    ups = np.stack([t.available(r) for r in range(1, 200)])
    assert ups[:, 0].mean() < ups[:, 1].mean()  # phone sits out more rounds


def test_from_profiles_late_join_benches_slowest():
    profiles = [PROFILES["tpu-v5e-chip"], PROFILES["pixel-2"], PROFILES["pixel-3"]]
    t = AvailabilityTrace.from_profiles(
        profiles, late_join=1, mobile_dropout=0.0, plugged_dropout=0.0
    )
    assert t.join_round == (1, 2, 1)  # pixel-2 is the slowest: joins late
    assert not t.available(1, 1) and t.available(2, 1)


def test_step_jitter_positive_and_spread():
    t = AvailabilityTrace(n_clients=64, seed=3, jitter_std=0.2)
    j = t.step_jitter(1)
    assert (j > 0).all() and j.std() > 0.01


# ---------------- round_step participation mask ----------------
CODECS = {
    "null": NullCodec(),
    "int8": Int8Codec(),
    "topk": TopKCodec(frac=0.05),
    "mixed": MixedCodec(
        codecs=(TopKCodec(frac=0.05), Int8Codec(), NullCodec()),
        assignment=(0, 1, 2, 0),
    ),
}


def _round_fixture(seed=0, C=4, steps=2, B=8):
    m = build_model("mobilenet-head-office31")
    rng = np.random.default_rng(seed)
    batch = {
        "x": rng.normal(size=(C, steps, B, m.cfg.feature_dim)).astype(np.float32),
        "y": rng.integers(0, m.cfg.num_classes, (C, steps, B)).astype(np.int32),
    }
    params = m.init(jax.random.key(seed))
    w = jnp.asarray([1.0, 2.0, 0.5, 1.5])
    bud = jnp.full((C,), steps, jnp.int32)
    return m, params, batch, w, bud


def _bitwise_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if not (np.asarray(x) == np.asarray(y)).all():
            return False
    return True


@pytest.mark.parametrize("mode", ["parallel", "sequential"])
@pytest.mark.parametrize("codec_name", list(CODECS))
def test_all_ones_mask_is_bitwise_identity(mode, codec_name):
    """Full participation == today's synchronous round, bit for bit."""
    codec = CODECS[codec_name]
    m, params, batch, w, bud = _round_fixture()
    strat = FedAvg()
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat,
        RoundSpec(max_steps=2, execution_mode=mode, codec=codec),
    ))
    cs = codec.init_client_state(4, tree_size(params))
    g0, _, cs0, met0 = rs(params, strat.init_state(params), cs, batch, w, bud, 0)
    g1, _, cs1, met1 = rs(params, strat.init_state(params), cs, batch, w, bud, 0,
                          jnp.ones((4,), jnp.float32))
    assert _bitwise_equal(g0, g1) and _bitwise_equal(cs0, cs1)
    for k in met0:
        assert float(met0[k]) == pytest.approx(float(met1[k]), rel=1e-6), k


def test_all_ones_mask_is_bitwise_identity_mesh():
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 host devices (see conftest.py)")
    mesh, axes = jax.make_mesh((2, 2), ("pod", "data")), ("pod", "data")
    codec = Int8Codec()
    m, params, batch, w, bud = _round_fixture()
    strat = FedAvg()
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat,
        RoundSpec(max_steps=2, execution_mode="parallel", codec=codec),
        mesh=mesh, client_axes=axes,
    ))
    cs = codec.init_client_state(4, tree_size(params))
    g0, _, cs0, _ = rs(params, strat.init_state(params), cs, batch, w, bud, 0)
    g1, _, cs1, _ = rs(params, strat.init_state(params), cs, batch, w, bud, 0,
                       jnp.ones((4,), jnp.float32))
    assert _bitwise_equal(g0, g1) and _bitwise_equal(cs0, cs1)
    # masked diverged client on the mesh: NaN data, bit-identical aggregate
    garbled = {"x": np.array(batch["x"]), "y": batch["y"]}
    garbled["x"][1] = np.nan
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    g2, _, _, _ = rs(params, strat.init_state(params), cs, batch, w, bud, 0, mask)
    g3, _, _, _ = rs(params, strat.init_state(params), cs, garbled, w, bud, 0, mask)
    assert _bitwise_equal(g2, g3)


@pytest.mark.parametrize("mode", ["parallel", "sequential"])
@pytest.mark.parametrize("codec_name", ["topk", "mixed"])
def test_masked_client_leaves_residual_and_aggregate_untouched(mode, codec_name):
    """ISSUE-5 acceptance: garble a dropped client's data — with NaNs, the
    worst case: a diverged client is exactly who gets dropped, and 0-weight
    alone would poison the reduce through 0 * NaN — the new global and
    every OTHER client's residual row must be bit-identical, and the
    dropped client's own residual row carries through unchanged."""
    codec = CODECS[codec_name]
    m, params, batch, w, bud = _round_fixture()
    strat = FedAvg()
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat,
        RoundSpec(max_steps=2, execution_mode=mode, codec=codec),
    ))
    n = tree_size(params)
    # non-trivial carried state: run one full round first
    cs = codec.init_client_state(4, n)
    _, _, cs, _ = rs(params, strat.init_state(params), cs, batch, w, bud, 0)
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])  # drop client 2

    g_a, _, cs_a, _ = rs(params, strat.init_state(params), cs, batch, w, bud, 1, mask)
    garbled = dict(batch)
    garbled["x"] = np.array(batch["x"])
    garbled["x"][2] = np.nan  # the dropped client diverged
    g_b, _, cs_b, _ = rs(params, strat.init_state(params), cs, garbled, w, bud, 1, mask)

    assert _bitwise_equal(g_a, g_b)          # the aggregate never saw client 2
    assert _bitwise_equal(cs_a, cs_b)        # nor did anyone's residual state
    # and client 2's own residual row is exactly the row it entered with
    if codec_name == "topk":
        np.testing.assert_array_equal(np.asarray(cs_a)[2], np.asarray(cs)[2])
        assert not np.array_equal(np.asarray(cs_a)[0], np.asarray(cs)[0])
    else:  # mixed: client 2 is group 2 (Null, stateless); check a TopK drop
        mask2 = jnp.asarray([0.0, 1.0, 1.0, 1.0])  # client 0 -> TopK group row 0
        _, _, cs_c, _ = rs(params, strat.init_state(params), cs, batch, w, bud, 1,
                           mask2)
        np.testing.assert_array_equal(
            np.asarray(cs_c[0])[0], np.asarray(cs[0])[0]
        )
        assert not np.array_equal(np.asarray(cs_c[0])[1], np.asarray(cs[0])[1])


@pytest.mark.parametrize("mode", ["parallel", "sequential"])
def test_fully_masked_round_is_noop_with_nan_metrics(mode):
    """Everyone dropped: the global is untouched and the loss metrics are
    NaN (undefined), not a 0.0 that reads like convergence or a -inf max."""
    m, params, batch, w, bud = _round_fixture()
    strat = FedAvg()
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat,
        RoundSpec(max_steps=2, execution_mode=mode),
    ))
    g, _, _, met = rs(params, strat.init_state(params), (), batch, w, bud, 0,
                      jnp.zeros((4,), jnp.float32))
    assert _bitwise_equal(g, params)
    assert np.isnan(float(met["client_loss_mean"]))
    assert np.isnan(float(met["client_loss_max"]))
    assert int(met["steps_total"]) == 0


def test_mask_equals_smaller_fleet():
    """Masking client j matches an aggregation in which only the other
    clients' weights carry mass (zero-weight equivalence on the wire)."""
    m, params, batch, w, bud = _round_fixture()
    strat = FedAvg()
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat,
        RoundSpec(max_steps=2, execution_mode="parallel"),
    ))
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    g_mask, _, _, _ = rs(params, strat.init_state(params), (), batch, w, bud, 0, mask)
    g_zero, _, _, _ = rs(params, strat.init_state(params), (), batch, w * mask, bud, 0)
    assert _bitwise_equal(g_mask, g_zero)


# ---------------- the scheduler-driven Server ----------------
def _fl_setup(n_clients=4, seed=0, profile_names=None):
    m = build_model("mobilenet-head-office31")
    data = make_features(n=1200, num_classes=31, feature_dim=m.cfg.feature_dim,
                         seed=seed)
    shards = dirichlet_partition(data, n_clients=n_clients, alpha=100.0, seed=seed)
    params = m.init(jax.random.key(seed))
    mask = m.trainable_mask(params)
    clients = [
        JaxClient(client_id=c.client_id, loss_fn=m.loss_fn, dataset=c,
                  batch_size=32, trainable_mask=mask)
        for c in shards
    ]
    if profile_names:
        for c, name in zip(clients, profile_names):
            c.device_profile = name
        cm = make_cost_model_for(params, [PROFILES[p] for p in profile_names])
    else:
        cm = make_cost_model_for(params, [PROFILES["pixel-4"]] * n_clients)
    return m, params, clients, cm


def test_deadline_inf_full_availability_reproduces_sync_bitwise():
    """ISSUE-5 acceptance: the scheduler is a no-op until a policy bites."""
    m, params, clients, cm = _fl_setup()
    base = Server(strategy=FedAvg(local_epochs=1, local_lr=0.1),
                  clients=clients, cost_model=cm)
    base.logger.quiet = True
    p_base, h_base = base.run(params, num_rounds=3)

    # fresh clients: the dataset batch cursor is stateful across runs
    m, params2, clients2, cm2 = _fl_setup()
    sched = Server(strategy=FedAvg(local_epochs=1, local_lr=0.1),
                   clients=clients2, cost_model=cm2,
                   policy=Deadline(tau=float("inf")),
                   availability=AvailabilityTrace.full(len(clients2)))
    sched.logger.quiet = True
    p_sched, h_sched = sched.run(params, num_rounds=3)

    assert _bitwise_equal(p_base, p_sched)
    for rb, rs_ in zip(h_base.rounds, h_sched.rounds):
        assert rb.eval_acc == rs_.eval_acc and rb.train_loss == rs_.train_loss
        assert rb.wall_time_s == pytest.approx(rs_.wall_time_s)
        assert rb.energy_j == pytest.approx(rs_.energy_j)
        assert rb.comm_bytes == rs_.comm_bytes
        assert rs_.participants == len(clients) and rs_.dropped == 0
        assert rs_.staleness_mean == 0.0


def test_deadline_drops_stragglers_end_to_end():
    names = ["tpu-v5e-chip", "tpu-v5e-chip", "pixel-2", "pixel-2"]
    m, params, clients, cm = _fl_setup(profile_names=names)
    spe = clients[0].steps_per_epoch()
    # a cutoff the TPUs easily make and the pixels (0.37 s/step) cannot
    tau = 2.0 * spe * PROFILES["tpu-v5e-chip"].step_time_s + 1.0
    srv = Server(strategy=FedAvg(local_epochs=2, local_lr=0.1),
                 clients=clients, cost_model=cm, policy=Deadline(tau=tau))
    srv.logger.quiet = True
    _, hist = srv.run(params, num_rounds=2)
    for rec in hist.rounds:
        assert rec.participants == 2 and rec.dropped == 2
        assert rec.wall_time_s == pytest.approx(tau)  # waited the full cutoff
        assert rec.energy_j > 0
    # dropped stragglers never uplinked: comm < full fleet both ways
    assert hist.rounds[0].comm_bytes == 4 * cm.update_bytes + 2 * cm.update_bytes


def test_buffered_async_beats_syncall_on_straggler_fleet():
    """ISSUE-5 acceptance: FedBuff's clock runs ahead of lockstep."""
    names = ["tpu-v5e-chip", "jetson-tx2-gpu", "pixel-2", "pixel-2"]
    m, params, clients, cm = _fl_setup(profile_names=names)

    sync = Server(strategy=FedAvg(local_epochs=1, local_lr=0.1),
                  clients=clients, cost_model=cm)
    sync.logger.quiet = True
    _, h_sync = sync.run(params, num_rounds=4)

    strat = FedBuffStrategy(local_epochs=1, local_lr=0.1, buffer_size=2,
                            max_staleness=4)
    buf = Server(strategy=strat, clients=clients, cost_model=cm,
                 policy=strat.make_policy())
    buf.logger.quiet = True
    _, h_buf = buf.run(params, num_rounds=4)

    assert h_buf.total_time_s < h_sync.total_time_s
    # stragglers reported late at least once, and their staleness was logged
    assert any(r.staleness_mean > 0 for r in h_buf.rounds)
    accs = [a for _, a in h_buf.accuracy_series()]
    assert accs[-1] > accs[0]  # async aggregation still learns


def test_empty_rounds_are_graceful():
    """Total dropout: the clock advances, rounds record, nothing crashes."""
    m, params, clients, cm = _fl_setup()
    trace = AvailabilityTrace(n_clients=len(clients),
                              dropout=(1.0,) * len(clients))
    srv = Server(strategy=FedAvg(local_epochs=1, local_lr=0.1),
                 clients=clients, cost_model=cm, availability=trace)
    srv.logger.quiet = True
    final, hist = srv.run(params, num_rounds=2)
    assert len(hist.rounds) == 2
    for rec in hist.rounds:
        assert rec.participants == 0 and np.isnan(rec.train_loss)
        assert rec.wall_time_s == 0.0 and rec.energy_j == 0.0
    assert _bitwise_equal(final, params)  # nothing ever aggregated


def test_cost_model_empty_round_is_zero():
    from repro.core import CostModel
    cm = CostModel(profiles=[PROFILES["pixel-4"]], update_bytes=1000)
    assert cm.round_wall_time([]) == 0.0
    assert cm.round_energy([]) == 0.0


def test_partial_dropout_still_learns():
    m, params, clients, cm = _fl_setup()
    trace = AvailabilityTrace(n_clients=len(clients), seed=5,
                              dropout=(0.5, 0.0, 0.5, 0.0))
    srv = Server(strategy=FedAvg(local_epochs=1, local_lr=0.1),
                 clients=clients, cost_model=cm, availability=trace)
    srv.logger.quiet = True
    _, hist = srv.run(params, num_rounds=4)
    parts = [r.participants for r in hist.rounds]
    assert min(parts) < len(clients)  # somebody actually sat out
    accs = [a for _, a in hist.accuracy_series()]
    assert accs[-1] > accs[0]


def test_step_jitter_perturbs_cost_not_result():
    def one_run(trace):
        # fresh clients per run: the dataset batch cursor is stateful
        m, params, clients, cm = _fl_setup()
        s = Server(strategy=FedAvg(local_epochs=1, local_lr=0.1),
                   clients=clients, cost_model=cm, availability=trace)
        s.logger.quiet = True
        return s.run(params, num_rounds=2)

    p1, h1 = one_run(AvailabilityTrace.full(4))
    p2, h2 = one_run(AvailabilityTrace(n_clients=4, seed=2, jitter_std=0.3))
    assert _bitwise_equal(p1, p2)  # jitter is a cost phenomenon only
    assert h1.total_time_s != h2.total_time_s


# ---------------- strategy-side plumbing ----------------
def test_run_end_abandons_in_flight_arrivals():
    """Arrivals still flying when the run ends roll their clients back and
    charge their wasted burn to the final round — async totals must not
    silently omit exactly the stragglers they created."""
    names = ["tpu-v5e-chip", "pixel-2"]
    m, params, clients, cm = _fl_setup(n_clients=2, profile_names=names)
    discards = []
    clients[1].discard_update = lambda: discards.append(1)
    strat = FedBuffStrategy(local_epochs=1, local_lr=0.1, buffer_size=1)
    srv = Server(strategy=strat, clients=clients, cost_model=cm,
                 policy=strat.make_policy())
    srv.logger.quiet = True
    _, hist = srv.run(params, num_rounds=1)
    # K=1: the TPU reports, the pixel is still in flight at run end
    assert hist.rounds[0].participants == 1
    assert discards == [1]
    # the pixel's partial compute burn landed in the final record: more
    # than the TPU-only accounting could explain
    tpu_only = cm.client_round_cost(0, hist.rounds[0].steps // 2).e_total_j
    assert hist.rounds[0].energy_j > tpu_only


def test_sampling_is_seedable_and_streams_are_independent():
    ids = list(range(16))
    a, b = Strategy(fraction_fit=0.5, seed=1), Strategy(fraction_fit=0.5, seed=1)
    assert a.sample_clients(2, ids) == b.sample_clients(2, ids)
    c = Strategy(fraction_fit=0.5, seed=2)
    assert any(a.sample_clients(r, ids) != c.sample_clients(r, ids)
               for r in range(1, 10))
    # tuple seeding, not seed+rnd: seed k+1's rounds must NOT replay seed
    # k's rounds shifted by one (that correlation defeats an "independent"
    # control experiment)
    shifted = [
        Strategy(fraction_fit=0.5, seed=2).sample_clients(r, ids)
        == Strategy(fraction_fit=0.5, seed=1).sample_clients(r + 1, ids)
        for r in range(1, 12)
    ]
    assert not all(shifted)
    # dropout hardening: tiny eligible pools never crash the sampler
    assert Strategy(min_fit_clients=4).sample_clients(1, [7]) == [7]
    assert Strategy().sample_clients(1, []) == []


def test_fedbuff_staleness_discounts_weights():
    strat = FedBuffStrategy(alpha=0.5)
    results = [
        (0, FitRes(parameters=None, num_examples=100, staleness=0)),
        (1, FitRes(parameters=None, num_examples=100, staleness=3)),
    ]
    w = np.asarray(strat._fit_weights(results))
    assert w[0] == pytest.approx(100.0)
    assert w[1] == pytest.approx(100.0 / 2.0)  # (1+3)^0.5 = 2
    assert np.allclose(
        np.asarray(FedBuffStrategy(alpha=0.0)._fit_weights(results)), 100.0
    )


def test_fedbuff_takes_grouped_wire_path():
    assert FedBuffStrategy()._grouped_fit_compatible()


def test_client_honors_deadline_config():
    from repro.core import FitIns
    from repro.utils.pytree import tree_bytes

    m, params, clients, cm = _fl_setup()
    c = clients[0]
    c.device_profile = "pixel-2"  # 0.37 s/step
    prof = PROFILES["pixel-2"]
    # the client budgets compute + ITS OWN transfer time into the deadline
    t_comm = tree_bytes(params) * 8 * (
        1 / (prof.uplink_mbps * 1e6) + 1 / (prof.downlink_mbps * 1e6)
    )
    deadline = t_comm + 5 * prof.step_time_s + 1e-6
    res = c.fit(FitIns(parameters=params,
                       config={"epochs": 2, "deadline_s": deadline}))
    assert res.metrics["steps_done"] == 5
    # the truncated client actually makes the scheduler's cutoff
    assert t_comm + 5 * prof.step_time_s <= deadline
    res_full = c.fit(FitIns(parameters=params, config={"epochs": 2}))
    assert res_full.metrics["steps_done"] == 2 * c.steps_per_epoch()
    # an impossible deadline still tries one step (the scheduler judges it)
    res_min = c.fit(FitIns(parameters=params,
                           config={"epochs": 2, "deadline_s": 1e-6}))
    assert res_min.metrics["steps_done"] == 1


def test_discarded_update_rolls_back_residual():
    """A deadline-dropped compressed update must leave the client's error-
    feedback residual as it entered the round (python twin of the jitted
    mask contract) — fit() commits it optimistically, discard reverts."""
    from repro.core import FitIns
    from repro.core.compression import TopKCodec

    m, params, clients, cm = _fl_setup()
    c = clients[0]
    codec = TopKCodec(frac=0.05)
    c.fit(FitIns(parameters=params, config={"epochs": 1, "codec": codec}))
    r1 = np.asarray(c._residual).copy()
    c.fit(FitIns(parameters=params, config={"epochs": 1, "codec": codec}))
    assert not np.array_equal(np.asarray(c._residual), r1)
    c.discard_update()  # the scheduler threw the second update away
    np.testing.assert_array_equal(np.asarray(c._residual), r1)


def test_server_discards_dropped_clients_state():
    """Server.run notifies every dropped/expired arrival's client."""
    names = ["tpu-v5e-chip", "tpu-v5e-chip", "pixel-2", "pixel-2"]
    m, params, clients, cm = _fl_setup(profile_names=names)
    discards = []
    for c in clients:
        c.discard_update = (lambda cid=c.client_id: discards.append(cid))
    spe = clients[0].steps_per_epoch()
    tau = 1.25 * cm.client_round_cost(0, spe).t_total_s  # only TPUs make it
    srv = Server(strategy=FedAvg(local_epochs=1, local_lr=0.1),
                 clients=clients, cost_model=cm, policy=Deadline(tau=tau))
    srv.logger.quiet = True
    _, hist = srv.run(params, num_rounds=2)
    assert sum(r.dropped for r in hist.rounds) == len(discards)
    assert set(discards) == {2, 3}  # exactly the pixel stragglers


def test_deadline_policy_ships_deadline_in_fit_config():
    """The cutoff rides to clients ONLY when a Deadline policy enforces it:
    under SyncAll nothing is dropped, so shipping one would silently shrink
    step budgets (breaking the paper's compute-only tau baselines)."""
    class _ConfigSpy(Client):
        def __init__(self):
            self.configs = []

        def fit(self, ins):
            self.configs.append(ins.config)
            return FitRes(parameters=ins.parameters, num_examples=1,
                          metrics={"loss": 1.0, "steps_done": 1})

        def evaluate(self, ins):
            from repro.core import EvaluateRes

            return EvaluateRes(loss=1.0, num_examples=1, metrics={"acc": 0.0})

    gp = {"w": jnp.zeros(2)}
    for policy, expect in (
        (Deadline(), 7.0),              # tau=None -> FedTau's knob
        (Deadline(tau=3.0), 3.0),       # explicit tau wins
        (None, None),                   # SyncAll: no deadline shipped
        (SyncAll(), None),
    ):
        spy = _ConfigSpy()
        srv = Server(strategy=FedTau(tau_s=7.0), clients=[spy], policy=policy)
        srv.logger.quiet = True
        srv.run(gp, num_rounds=1)
        assert spy.configs[0].get("deadline_s") == expect, (policy, expect)

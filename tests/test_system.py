"""End-to-end behaviour tests: the paper's FL loop on real (synthetic) data.

Validates the paper's qualitative claims at CPU scale:
- FL training improves accuracy over rounds (Server + FedAvg + clients);
- the frozen-base/trainable-head split trains only the head (§4.1);
- more local epochs E -> better accuracy at equal rounds (Table 2a trend);
- the tau cutoff reduces slow-client work at bounded accuracy cost (Table 3).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BandwidthCodecPolicy, Client, CompressedParameters, FedAdam, FedAvg,
    FedTau, FitRes, Int8Codec, JaxClient, NullCodec, Server, TopKCodec,
    PROFILES,
)
from repro.core.server import make_cost_model_for
from repro.data.federated import dirichlet_partition
from repro.data.synthetic import make_features
from repro.models import build_model
from repro.utils.pytree import tree_bytes, tree_size


def _make_setup(n_clients=4, seed=0):
    m = build_model("mobilenet-head-office31")
    data = make_features(n=1200, num_classes=31, feature_dim=m.cfg.feature_dim, seed=seed)
    shards = dirichlet_partition(data, n_clients=n_clients, alpha=1.0, seed=seed)
    params = m.init(jax.random.key(seed))
    mask = m.trainable_mask(params)
    clients = [
        JaxClient(client_id=c.client_id, loss_fn=m.loss_fn, dataset=c,
                  batch_size=32, trainable_mask=mask)
        for c in shards
    ]
    return m, params, clients


def test_fl_training_improves_accuracy():
    m, params, clients = _make_setup()
    cm = make_cost_model_for(params, [PROFILES["pixel-4"]] * len(clients))
    server = Server(strategy=FedAvg(local_epochs=2, local_lr=0.1),
                    clients=clients, cost_model=cm)
    server.logger.quiet = True
    final, hist = server.run(params, num_rounds=4)
    accs = [a for _, a in hist.accuracy_series()]
    assert accs[-1] > accs[0] + 0.1, accs
    assert hist.total_time_s > 0 and hist.total_energy_j > 0


def test_head_base_split_freezes_base():
    m, params, clients = _make_setup()
    server = Server(strategy=FedAvg(local_epochs=1, local_lr=0.1), clients=clients)
    server.logger.quiet = True
    final, _ = server.run(params, num_rounds=2)
    np.testing.assert_allclose(
        np.asarray(final["base"]["w"]), np.asarray(params["base"]["w"]),
        atol=1e-6,  # fp32 weighted-mean wobble; the head moves ~1e-3
    )
    assert not np.allclose(
        np.asarray(final["head"]["w1"]), np.asarray(params["head"]["w1"])
    )


def test_more_local_epochs_better_accuracy():
    """Paper Table 2a trend: E=3 beats E=1 at equal round count."""
    finals = {}
    for epochs in (1, 3):
        m, params, clients = _make_setup()
        server = Server(strategy=FedAvg(local_epochs=epochs, local_lr=0.1),
                        clients=clients)
        server.logger.quiet = True
        _, hist = server.run(params, num_rounds=3)
        finals[epochs] = hist.final_accuracy()
    assert finals[3] > finals[1], finals


def test_tau_cutoff_limits_steps():
    """Paper Table 3: cutoff tau truncates slow clients' local work."""
    m, params, clients = _make_setup()
    profiles = [PROFILES["jetson-tx2-gpu"], PROFILES["jetson-tx2-cpu"],
                PROFILES["jetson-tx2-cpu"], PROFILES["jetson-tx2-gpu"]]
    cm = make_cost_model_for(params, profiles)
    spe = clients[0].steps_per_epoch()
    tau = cm.tau_for_profile("jetson-tx2-gpu", epochs=2, steps_per_epoch=spe)
    strat = FedTau(local_epochs=2, local_lr=0.1, tau_s=tau,
                   cost_model=cm, steps_per_epoch=spe)
    budgets = strat.client_step_budgets(range(4))
    full = 2 * spe
    assert budgets[0] == full            # GPU client completes
    assert budgets[1] < full             # CPU client truncated
    server = Server(strategy=strat, clients=clients, cost_model=cm)
    server.logger.quiet = True
    _, hist = server.run(params, num_rounds=2)
    assert hist.rounds[-1].steps < 4 * full
    assert hist.final_accuracy() > 0.1   # still learns


def test_heterogeneous_fleet_per_device_codecs():
    """ISSUE acceptance: Pixel-class (slow uplink) ships TopK, Jetson-class
    Int8, TPU-class the full fp32 wire; FitRes payload bytes equal the
    codec's wire size (not fp32 tree bytes) and History.comm_bytes reflects
    the per-client wire sizes."""
    m, params, clients = _make_setup(n_clients=3)
    profile_names = ["pixel-4", "jetson-tx2-gpu", "tpu-v5e-chip"]
    for c, name in zip(clients, profile_names):
        c.device_profile = name
    cm = make_cost_model_for(params, [PROFILES[p] for p in profile_names])
    strat = FedAvg(local_epochs=1, local_lr=0.1, codec_policy=BandwidthCodecPolicy())
    n = tree_size(params)

    # per-device selection + actual wire payloads
    props = {c.client_id: c.properties() for c in clients}
    fit_ins = strat.configure_fit(1, params, [0, 1, 2], client_properties=props)
    expected_codecs = {0: TopKCodec, 1: Int8Codec, 2: NullCodec}
    wire_sizes = {}
    for cid, ins in fit_ins:
        codec = ins.config["codec"]
        assert type(codec) is expected_codecs[cid]
        res = clients[cid].fit(ins)
        assert isinstance(res.parameters, CompressedParameters)
        assert res.parameters.num_bytes == codec.wire_bytes(n)
        assert res.parameters.num_bytes != tree_bytes(params) or isinstance(
            codec, NullCodec
        )
        wire_sizes[cid] = res.parameters.num_bytes
    assert wire_sizes[0] < wire_sizes[1] < wire_sizes[2]

    # end-to-end: the server charges each client its own wire size
    server = Server(strategy=strat, clients=clients, cost_model=cm)
    server.logger.quiet = True
    _, hist = server.run(params, num_rounds=2)
    expected_comm = sum(wire_sizes.values()) + 3 * cm.update_bytes
    assert hist.rounds[0].comm_bytes == expected_comm
    accs = [a for _, a in hist.accuracy_series()]
    assert accs[-1] > accs[0]  # compressed fleet still learns

    # run() resets error-feedback state so experiments don't leak into
    # each other when the same client objects are reused
    assert clients[0]._residual is not None  # set during the run above
    server.run(params, num_rounds=0)
    assert clients[0]._residual is None


class _FixedDeltaClient(Client):
    """Deterministic client: returns global + its fixed delta, no training —
    lets a python Server round be replayed exactly against the jitted
    engine's aggregation semantics."""

    def __init__(self, delta, num_examples=10):
        self.delta = delta
        self._n = num_examples

    def fit(self, ins):
        newp = jax.tree.map(lambda g, d: g + d, ins.parameters, self.delta)
        return FitRes(parameters=newp, num_examples=self._n,
                      metrics={"loss": 1.0, "steps_done": 1})

    def evaluate(self, ins):
        from repro.core import EvaluateRes

        return EvaluateRes(loss=1.0, num_examples=1, metrics={"acc": 0.0})


def test_fedopt_server_state_accumulates_across_rounds():
    """Regression (FedOpt server-state amnesia): aggregate_fit used to pass
    a fresh init_state every round and discard the returned state, so
    FedAdam never accumulated moments under Server.run.  Now: Adam moments
    are nonzero after round 2, the python path matches the jitted engine's
    state threading on an identical round sequence, and the state resets
    per run."""
    from repro.core.strategy.base import weighted_mean

    rng = np.random.default_rng(0)
    gp = {"w": jnp.asarray(rng.normal(size=(40,)), jnp.float32)}
    deltas = [
        {"w": 0.05 * jnp.asarray(rng.normal(size=(40,)), jnp.float32)}
        for _ in range(3)
    ]
    clients = [_FixedDeltaClient(d) for d in deltas]
    strat = FedAdam(server_lr=0.1)
    server = Server(strategy=strat, clients=clients)
    server.logger.quiet = True
    final, _ = server.run(gp, num_rounds=3)

    # Adam moments accumulated (nonzero after round >= 2)
    moments = jax.tree.leaves(strat._server_state)
    assert moments and any(float(jnp.abs(m).sum()) > 0 for m in moments)

    # parity with the jitted engine's threading: round_step hands
    # weighted_mean(clients) to server_update and carries the state
    ref = FedAdam(server_lr=0.1)
    p, state = gp, ref.init_state(gp)
    for rnd in range(1, 4):
        stacked = jax.tree.map(
            lambda g, *ds: jnp.stack([g + d for d in ds]), p,
            *[d for d in deltas],
        )
        avg = weighted_mean(stacked, jnp.full(3, 10.0))
        p, state = ref.server_update(avg, p, state, rnd)
    np.testing.assert_allclose(
        np.asarray(final["w"]), np.asarray(p["w"]), atol=1e-5, rtol=1e-5
    )
    # amnesia sanity: re-initializing the state every round lands elsewhere
    p_amnesia = gp
    for rnd in range(1, 4):
        stacked = jax.tree.map(
            lambda g, *ds: jnp.stack([g + d for d in ds]), p_amnesia,
            *[d for d in deltas],
        )
        avg = weighted_mean(stacked, jnp.full(3, 10.0))
        p_amnesia, _ = ref.server_update(avg, p_amnesia, ref.init_state(gp), rnd)
    assert not np.allclose(np.asarray(final["w"]), np.asarray(p_amnesia["w"]),
                           atol=1e-5)

    # reset per run: a second run from the same params reproduces the first
    final2, _ = server.run(gp, num_rounds=3)
    np.testing.assert_allclose(
        np.asarray(final["w"]), np.asarray(final2["w"]), atol=1e-7
    )


def test_fit_cache_keyed_on_optimizer():
    """Regression: with lr=0.0 the built fit closure captures the client's
    own optimizer, but the cache key omitted it — two clients sharing a
    loss_fn but constructed with different SGD momenta silently shared the
    first client's update rule."""
    from repro.core.client import _GLOBAL_FIT_CACHE
    from repro.data.federated import ClientDataset
    from repro.optim import sgd as make_sgd

    m = build_model("mobilenet-head-office31")
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    B = 16
    x = rng.normal(size=(B, m.cfg.feature_dim)).astype(np.float32)
    y = rng.integers(0, m.cfg.num_classes, B).astype(np.int32)

    def client(opt):
        # identical shard (one full batch per step: order-invariant); two
        # epochs so momentum shows up on the second step
        return JaxClient(client_id=0, loss_fn=m.loss_fn,
                         dataset=ClientDataset(client_id=0, x=x, y=y),
                         batch_size=B, optimizer=opt)

    opt_plain = make_sgd(0.05)
    c_plain = client(opt_plain)
    c_momentum = client(make_sgd(0.05, momentum=0.9))
    from repro.core import FitIns as _FitIns

    ins = lambda: _FitIns(parameters=params, config={"epochs": 2})
    r_plain = c_plain.fit(ins())
    size_after_first = len(_GLOBAL_FIT_CACHE)
    r_momentum = c_momentum.fit(ins())
    # different optimizers must NOT share a compiled closure...
    assert len(_GLOBAL_FIT_CACHE) == size_after_first + 1
    assert not np.allclose(
        np.asarray(r_plain.parameters["head"]["w1"]),
        np.asarray(r_momentum.parameters["head"]["w1"]),
    )
    # ...while a client sharing the SAME optimizer object still hits cache
    c_same = client(opt_plain)
    r_same = c_same.fit(ins())
    assert len(_GLOBAL_FIT_CACHE) == size_after_first + 1
    np.testing.assert_allclose(
        np.asarray(r_same.parameters["head"]["w1"]),
        np.asarray(r_plain.parameters["head"]["w1"]),
    )


class _ZeroExampleClient(Client):
    """A degenerate client: trains nothing, reports zero examples."""

    def __init__(self, params):
        self._params = params

    def fit(self, ins):
        return FitRes(parameters=ins.parameters, num_examples=0,
                      metrics={"loss": 1.25, "steps_done": 1})

    def evaluate(self, ins):
        from repro.core import EvaluateRes

        return EvaluateRes(loss=1.25, num_examples=1, metrics={"acc": 0.0})


def test_server_survives_all_zero_example_clients():
    """Regression: all sampled clients reporting num_examples == 0 used to
    crash np.average with ZeroDivisionError; now an unweighted mean."""
    m, params, _ = _make_setup(n_clients=2)
    clients = [_ZeroExampleClient(params), _ZeroExampleClient(params)]
    server = Server(strategy=FedAvg(local_epochs=1), clients=clients)
    server.logger.quiet = True
    final, hist = server.run(params, num_rounds=1)
    assert hist.rounds[0].train_loss == pytest.approx(1.25)
    # the unweighted-mean fallback keeps the global finite (no NaN poison)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(final))

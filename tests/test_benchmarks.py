"""Benchmark-driver drift gate (ISSUE 8 satellite).

The benchmark entry points call the library through its public signatures
but are not imported by anything else, so they silently rot when those
signatures move.  This module pins them: every driver must import, and the
cheap paths must run against the CURRENT library — a signature change that
breaks a bench now fails here, not in a release-week CI artifact.
"""
import importlib
import json
import sys

import numpy as np
import pytest


@pytest.mark.parametrize("mod", [
    "benchmarks.run",
    "benchmarks.paper_tables",
    "benchmarks.roofline_report",
    "benchmarks.scan_bench",
    "benchmarks.mesh_bench",
    "benchmarks.compression_bench",
    "benchmarks.population_bench",
    "benchmarks.straggler_bench",
])
def test_benchmark_module_imports(mod):
    importlib.import_module(mod)


def test_run_smoke_microbenches(capsys):
    """``benchmarks.run --smoke`` exercises make_round_step, the
    aggregation oracle, and the int8 quantizer against live signatures."""
    from benchmarks import run as bench_run

    argv, sys.argv = sys.argv, ["run.py", "--smoke"]
    try:
        bench_run.main()
    finally:
        sys.argv = argv
    out = capsys.readouterr().out
    lines = [ln for ln in out.strip().splitlines() if ln]
    assert lines[0] == "name,us_per_call,derived"
    names = [ln.split(",")[0] for ln in lines[1:]]
    assert any(n.startswith("fl_round_step") for n in names)
    assert any(n.startswith("fedavg_reduce") for n in names)
    assert any(n.startswith("quantize_int8") for n in names)
    assert any(n.startswith("collective_pack") for n in names)
    assert any(n.startswith("structured_lora_roundtrip") for n in names)
    # --smoke skips the paper tables (minutes of training)
    assert not any(n.startswith("table") for n in names)


def test_lora_frontier_writes_json_and_guards(tmp_path, capsys):
    """The lora[] section: frontier rows at full LLM scale, the acceptance
    run on the reduced LM, and BENCH_lora.json with both."""
    from benchmarks.compression_bench import bench_lora_frontier

    out = tmp_path / "BENCH_lora.json"
    rows = bench_lora_frontier(rounds=1, smoke=True, out=str(out))
    names = [r.split(",")[0] for r in rows]
    assert any(n.startswith("lora[qwen3-0.6b/r4]") for n in names)
    assert any(n.startswith("lora[mixtral-8x7b/r4]") for n in names)
    assert any(n.startswith("lora[qwen3_reduced/lora_r4]") for n in names)
    data = json.loads(out.read_text())
    assert data["bench"] == "lora" and data["frontier"]
    runs = data["runs"]
    assert runs["int8"]["wire_bytes"] >= 10 * runs["lora_r4"]["wire_bytes"]


def test_paper_tables_one_cell():
    """One tiny cell of table2a end-to-end through Server.run — the bench
    that trains must still agree with the Server/Strategy signatures."""
    from benchmarks.paper_tables import table2a

    rows = table2a(rounds=1, epochs_grid=(1,))
    assert len(rows) == 1
    label, acc, mins, kj = rows[0]
    assert label == "E=1"
    assert 0.0 <= acc <= 1.0
    assert mins > 0 and kj > 0


def test_roofline_render_matches_dryrun_fields(tmp_path):
    """The report reads exactly the field names dryrun emits; a renamed
    field shows up here as a KeyError instead of a broken EXPERIMENTS.md."""
    from benchmarks.roofline_report import render

    row = {
        "arch": "qwen3-0.6b", "shape": "train_4k", "mesh": "16x16",
        "per_device_gb": 3.21, "compute_ms": 12.5, "memory_ms": 4.2,
        "collective_ms": 1.7, "dominant": "compute",
        "useful_flops_frac": 0.61,
    }
    path = tmp_path / "dryrun_results.json"
    path.write_text(json.dumps([row]))
    table = render(str(path))
    assert "| qwen3-0.6b | train_4k | 3.21 | 12.5 | 4.2 | 1.7 | compute | 0.61 |" in table
    # missing cells render as pending, not crash
    assert "(pending)" in table

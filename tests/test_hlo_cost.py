"""HLO cost analyzer validation: matches XLA's cost_analysis on loop-free
programs and correctly multiplies scan (while-loop) bodies by trip count."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hlo_cost import analyze_hlo
from repro.utils.roofline import RooflineReport
from repro.utils.xla_cost import xla_cost_dict


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_matmul_flops_match_xla():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = _compile(lambda a, b: a @ b, a, b)
    mc = analyze_hlo(c.as_text())
    assert mc.flops == pytest.approx(2 * 256 * 512 * 128, rel=0.01)
    xla = xla_cost_dict(c).get("flops", 0.0)
    assert mc.flops == pytest.approx(xla, rel=0.05)


def test_scan_body_flops_multiplied_by_trip_count():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)

    def f(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    c = _compile(f, x, w)
    mc = analyze_hlo(c.as_text())
    expected = 12 * 2 * 8 * 128 * 128
    assert mc.flops == pytest.approx(expected, rel=0.05)
    # XLA's own analysis counts the body once: we must exceed it ~12x
    xla = xla_cost_dict(c).get("flops", 1.0)
    assert mc.flops > 6 * xla


def test_bytes_match_xla_on_loop_free():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda a: (a * 2 + 1).sum(), a)
    mc = analyze_hlo(c.as_text())
    xla = xla_cost_dict(c).get("bytes accessed", 0.0)
    assert mc.bytes == pytest.approx(xla, rel=0.5)


def test_roofline_report_terms_and_dominance():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=197e12, hlo_bytes=819e9 * 2, collective_bytes=50e9 * 0.5,
        model_flops=197e12 * 256 * 0.5,
    ).finalize()
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.memory_s == pytest.approx(2.0)
    assert rep.collective_s == pytest.approx(0.5)
    assert rep.dominant == "memory"
    assert rep.useful_flops_frac == pytest.approx(0.5)

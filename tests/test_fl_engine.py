"""FL engine unit tests: strategies, round step, tau masking, protocol,
cost model, compression, data partitioner, checkpoint, optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint.io import load_pytree, save_pytree
from repro.core import (
    CompressedParameters, FedAdam, FedAvg, FedProx, FedTau, RoundSpec,
    compress_to_wire, make_round_step, parameters_to_pytree,
    pytree_to_parameters, wire_to_pytree,
)
from repro.core.compression import (
    Int8Codec, NullCodec, TopKCodec, compress_update, decompress_update,
)
from repro.core.cost_model import PROFILES, CostModel
from repro.core.strategy.base import weighted_mean
from repro.data.federated import dirichlet_partition, iid_partition, partition_stats
from repro.data.synthetic import ClassificationData, make_classification, make_lm_tokens
from repro.models import build_model
from repro.optim import adam, sgd, yogi
from repro.utils.pytree import (
    tree_flatten_to_vector, tree_sub, tree_unflatten_from_vector,
)


# ---------------- strategies ----------------
def test_weighted_mean_exact():
    cp = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}  # 2 clients
    w = jnp.asarray([1.0, 3.0])
    out = weighted_mean(cp, w)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.5, 3.5])


def test_fedavg_aggregate_ignores_server_state():
    s = FedAvg()
    cp = {"w": jnp.ones((3, 4))}
    new, state = s.aggregate(cp, jnp.ones(3), {"w": jnp.zeros(4)}, (), 0)
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0)


def test_fedadam_server_update_moves_toward_average():
    s = FedAdam(server_lr=0.5)
    g = {"w": jnp.zeros(4)}
    state = s.init_state(g)
    avg = {"w": jnp.ones(4)}
    new, state = s.server_update(avg, g, state, 0)
    assert (np.asarray(new["w"]) > 0).all()  # moved toward the average
    assert (np.asarray(new["w"]) <= 1.0 + 1e-6).all()


def test_fedprox_loss_extra_is_quadratic():
    s = FedProx(mu=2.0)
    p = {"w": jnp.asarray([1.0, 1.0])}
    g = {"w": jnp.asarray([0.0, 0.0])}
    assert float(s.client_loss_extra(p, g)) == pytest.approx(2.0)  # mu/2 * 2


# ---------------- jitted round step ----------------
def _tiny_model():
    m = build_model("mobilenet-head-office31")
    cfg = m.cfg
    return m, cfg


def _round_inputs(cfg, C=3, steps=2, B=8, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "x": rng.normal(size=(C, steps, B, cfg.feature_dim)).astype(np.float32),
        "y": rng.integers(0, cfg.num_classes, (C, steps, B)).astype(np.int32),
    }


def test_round_step_parallel_reduces_loss_over_rounds():
    m, cfg = _tiny_model()
    params = m.init(jax.random.key(0))
    strat = FedAvg()
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat, RoundSpec(max_steps=2, execution_mode="parallel")
    ))
    batch = _round_inputs(cfg)
    w = jnp.ones(3)
    budgets = jnp.full((3,), 2, jnp.int32)
    losses = []
    state = strat.init_state(params)
    for rnd in range(4):
        params, state, _, metrics = rs(params, state, (), batch, w, budgets, rnd)
        losses.append(float(metrics["client_loss_mean"]))
    assert losses[-1] < losses[0]


def test_round_step_sequential_matches_parallel_fedavg():
    """Same clients, same data -> identical new global params in both modes."""
    m, cfg = _tiny_model()
    params = m.init(jax.random.key(0))
    batch = _round_inputs(cfg)
    w = jnp.asarray([1.0, 2.0, 0.5])
    budgets = jnp.full((3,), 2, jnp.int32)
    outs, metrics = {}, {}
    for mode in ("parallel", "sequential"):
        strat = FedAvg()
        rs = jax.jit(make_round_step(
            m.loss_fn, sgd(0.1), strat, RoundSpec(max_steps=2, execution_mode=mode)
        ))
        new, _, _, met = rs(params, strat.init_state(params), (), batch, w, budgets, 0)
        outs[mode] = new
        metrics[mode] = met
    for a, b in zip(jax.tree.leaves(outs["parallel"]), jax.tree.leaves(outs["sequential"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3)
    # the sequential path tracks the true running max (not loss_mean)
    assert float(metrics["sequential"]["client_loss_max"]) == pytest.approx(
        float(metrics["parallel"]["client_loss_max"]), rel=1e-3
    )
    assert (
        float(metrics["sequential"]["client_loss_max"])
        >= float(metrics["sequential"]["client_loss_mean"]) - 1e-6
    )
    # same round, same metric: client_loss_mean is the examples-weighted
    # mean on EVERY execution mode (weights above are non-uniform, so an
    # unweighted mean on either path would break this)
    assert float(metrics["sequential"]["client_loss_mean"]) == pytest.approx(
        float(metrics["parallel"]["client_loss_mean"]), rel=1e-4
    )


def test_round_step_tau_budget_masks_steps():
    """budget=0 client contributes its unchanged params to the average."""
    m, cfg = _tiny_model()
    params = m.init(jax.random.key(0))
    strat = FedAvg()
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat, RoundSpec(max_steps=2, execution_mode="parallel")
    ))
    batch = _round_inputs(cfg, C=2)
    w = jnp.ones(2)
    # both frozen -> global unchanged
    new, _, _, met = rs(params, (), (), batch, w, jnp.zeros(2, jnp.int32), 0)
    assert int(met["steps_total"]) == 0
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("mode", ["parallel", "sequential"])
def test_round_step_all_zero_weights_is_noop(mode):
    """Zero aggregation weight for every client (all reported 0 examples)
    must leave the global finite on every engine path, not NaN-poison it."""
    m, cfg = _tiny_model()
    params = m.init(jax.random.key(0))
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), FedAvg(), RoundSpec(max_steps=2, execution_mode=mode)
    ))
    batch = _round_inputs(cfg, C=2)
    new, _, _, _ = rs(params, (), (), batch, jnp.zeros(2),
                      jnp.full((2,), 2, jnp.int32), 0)
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params)):
        assert np.isfinite(np.asarray(a)).all()
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_round_step_microbatching_equivalent():
    """grad accumulation over microbatches ~= single big batch step."""
    m, cfg = _tiny_model()
    params = m.init(jax.random.key(0))
    batch = _round_inputs(cfg, C=2, steps=1, B=8)
    outs = {}
    for mb in (1, 4):
        strat = FedAvg()
        rs = jax.jit(make_round_step(
            m.loss_fn, sgd(0.1), strat,
            RoundSpec(max_steps=1, execution_mode="parallel", microbatches=mb),
        ))
        new, _, _, _ = rs(params, (), (), batch, jnp.ones(2), jnp.ones(2, jnp.int32), 0)
        outs[mb] = new
    for a, b in zip(jax.tree.leaves(outs[1]), jax.tree.leaves(outs[4])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-2, rtol=5e-2)


# ---------------- protocol ----------------
def test_parameters_wire_roundtrip():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.asarray([1, 2], jnp.int32)},
    }
    wire = pytree_to_parameters(tree)
    assert wire.num_bytes > 0
    back = parameters_to_pytree(wire, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_parameters_wire_roundtrip_bfloat16_bit_exact():
    """The uint16-view path must preserve bf16 payloads bit for bit."""
    rng = np.random.default_rng(5)
    vals = jnp.asarray(rng.normal(size=(37,)), jnp.float32).astype(jnp.bfloat16)
    tree = {"w": vals}
    back = parameters_to_pytree(pytree_to_parameters(tree), tree)
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(vals.view(jnp.uint16)), np.asarray(back["w"].view(jnp.uint16))
    )


def test_parameters_wire_roundtrip_empty_leaves():
    """Zero-element leaves survive the wire (empty buffers, exact shapes)."""
    tree = {
        "empty": jnp.zeros((0,), jnp.float32),
        "empty2d": jnp.zeros((3, 0), jnp.bfloat16),
        "full": jnp.ones((2,), jnp.float32),
    }
    wire = pytree_to_parameters(tree)
    back = parameters_to_pytree(wire, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_parameters_wire_structure_mismatch_asserts():
    tree = {"a": jnp.ones((2,)), "b": jnp.ones((3,))}
    wire = pytree_to_parameters(tree)
    with pytest.raises(AssertionError, match="structure mismatch"):
        parameters_to_pytree(wire, {"a": jnp.ones((2,))})


@pytest.mark.parametrize("codec,n", [
    (NullCodec(), 300), (Int8Codec(), 300), (Int8Codec(), 512),
    (TopKCodec(frac=0.1), 300),
])
def test_compressed_parameters_wire_roundtrip(codec, n):
    """CompressedParameters serialization: payload bytes == codec.wire_bytes
    (Int8 encoder padding must NOT cross the wire) and the decode against
    the global params reproduces encode->decode exactly."""
    rng = np.random.default_rng(n)
    old = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32)}
    new = {"w": old["w"] + 0.01 * jnp.asarray(rng.normal(size=(n,)), jnp.float32)}
    enc, _ = compress_update(codec, new, old)
    cp = compress_to_wire(codec, enc, n)
    assert isinstance(cp, CompressedParameters)
    assert cp.num_bytes == codec.wire_bytes(n)
    rebuilt = wire_to_pytree(cp, old)
    expected = decompress_update(codec, enc, old)
    np.testing.assert_allclose(
        np.asarray(rebuilt["w"]), np.asarray(expected["w"]), atol=1e-6
    )


# ---------------- cost model ----------------
def test_cost_model_reproduces_paper_cpu_gpu_ratio():
    """Paper Table 3: CPU full training is ~1.27x GPU time."""
    gpu, cpu = PROFILES["jetson-tx2-gpu"], PROFILES["jetson-tx2-cpu"]
    ratio = cpu.step_time_s / gpu.step_time_s
    assert 1.2 < ratio < 1.35


def test_cost_model_energy_scales_with_clients():
    """Paper Table 2b: more clients -> more total energy, ~flat wall time."""
    cm = CostModel(profiles=[PROFILES["pixel-4"]] * 10, update_bytes=10_000_000)
    e, t = {}, {}
    for c in (4, 7, 10):
        costs = cm.round_costs([50] * c)
        e[c] = cm.round_energy(costs)
        t[c] = cm.round_wall_time(costs)
    assert e[4] < e[7] < e[10]
    assert abs(t[4] - t[10]) < 1e-9  # homogeneous fleet: wall flat in C


def test_tau_steps_under_budget():
    cm = CostModel(profiles=[PROFILES["jetson-tx2-gpu"], PROFILES["jetson-tx2-cpu"]],
                   update_bytes=1_000_000)
    tau = cm.tau_for_profile("jetson-tx2-gpu", epochs=10, steps_per_epoch=78)
    assert cm.steps_under_tau(0, tau, 780) == 780       # GPU completes
    assert cm.steps_under_tau(1, tau, 780) < 780        # CPU truncated
    assert cm.steps_under_tau(1, 0.0, 780) == 780       # tau=0 = no cutoff


# ---------------- compression ----------------
@pytest.mark.parametrize("n", [256, 300, 511, 512, 513])
def test_int8_wire_bytes_match_encoded_payload(n):
    """wire_bytes must count ceil(n/block) scales — the encoder pads to a
    block multiple — and match the actual payload (pad int8s excluded:
    the receiver re-pads from n)."""
    codec = Int8Codec()
    vec = jnp.asarray(np.random.default_rng(n).normal(size=(n,)), jnp.float32)
    enc = codec.encode(vec)
    n_scales = enc["scale"].size
    assert n_scales == -(-n // codec.block)
    actual = n * enc["q"].dtype.itemsize + n_scales * enc["scale"].dtype.itemsize
    assert codec.wire_bytes(n) == actual


def test_codec_wire_bytes_ordering():
    """TopK(1%) < Int8 < Null(fp32) for any realistically sized update."""
    n = 100_000
    assert TopKCodec(frac=0.01).wire_bytes(n) < Int8Codec().wire_bytes(n)
    assert Int8Codec().wire_bytes(n) * 3.5 < NullCodec().wire_bytes(n)


@pytest.mark.parametrize("codec", [NullCodec(), Int8Codec(), TopKCodec(frac=0.01)])
def test_codec_wire_bytes_accepts_per_client_vector(codec):
    """Heterogeneous-fleet accounting: a vector of sizes in, a list out,
    elementwise equal to the scalar call."""
    sizes = [300, 511, 4096]
    out = codec.wire_bytes(sizes)
    assert isinstance(out, list) and len(out) == 3
    assert out == [codec.wire_bytes(n) for n in sizes]
    assert codec.wire_bytes(np.asarray(sizes)) == out
    assert isinstance(codec.wire_bytes(300), int)


def test_cost_model_per_client_uplink_vector():
    """round_costs/round_comm_bytes take one wire size per client."""
    cm = CostModel(profiles=[PROFILES["pixel-4"], PROFILES["jetson-tx2-gpu"]],
                   update_bytes=4_000_000)
    ups = [100_000, 2_000_000]
    costs = cm.round_costs([10, 10], uplink_bytes=ups)
    for c, up, p in zip(costs, ups, [PROFILES["pixel-4"], PROFILES["jetson-tx2-gpu"]]):
        expected = up * 8 / (p.uplink_mbps * 1e6) + 4_000_000 * 8 / (p.downlink_mbps * 1e6)
        assert c.t_comm_s == pytest.approx(expected)
    assert cm.round_comm_bytes(2, uplink_bytes=ups) == sum(ups) + 2 * 4_000_000
    with pytest.raises(AssertionError):
        cm.round_costs([10, 10], uplink_bytes=[1])


def test_cost_model_charges_compressed_uplink():
    """uplink_bytes shrinks t_comm/energy; downlink unchanged."""
    cm = CostModel(profiles=[PROFILES["pixel-4"]], update_bytes=4_000_000)
    full = cm.client_round_cost(0, 10)
    comp = cm.client_round_cost(0, 10, uplink_bytes=1_000_000)
    assert comp.t_comm_s < full.t_comm_s
    assert comp.t_compute_s == full.t_compute_s
    p = PROFILES["pixel-4"]
    expected = 1_000_000 * 8 / (p.uplink_mbps * 1e6) + 4_000_000 * 8 / (p.downlink_mbps * 1e6)
    assert comp.t_comm_s == pytest.approx(expected)
    # round totals: up (compressed) + down (full) per client
    assert cm.round_comm_bytes(3, uplink_bytes=1_000_000) == 3 * 5_000_000


def test_round_comm_bytes_honors_payload_override():
    """Regression: round_comm_bytes charged the downlink at update_bytes
    even when round_costs was given a payload_bytes override, so the
    reported byte count disagreed with the time/energy charge."""
    cm = CostModel(profiles=[PROFILES["pixel-4"]], update_bytes=4_000_000)
    # payload override, both directions (legacy callers)
    assert cm.round_comm_bytes(3, payload_bytes=1_000_000) == 3 * 2_000_000
    # ...and it must agree with what client_round_cost charges time for
    cost = cm.client_round_cost(0, 10, payload_bytes=1_000_000)
    p = PROFILES["pixel-4"]
    expected_t = 1_000_000 * 8 / (p.uplink_mbps * 1e6) + 1_000_000 * 8 / (
        p.downlink_mbps * 1e6
    )
    assert cost.t_comm_s == pytest.approx(expected_t)
    # uplink override still narrows only the client->server leg
    assert cm.round_comm_bytes(
        2, payload_bytes=1_000_000, uplink_bytes=500
    ) == 2 * (500 + 1_000_000)
    # no override: unchanged behavior
    assert cm.round_comm_bytes(2) == 2 * 8_000_000


def test_int8_codec_roundtrip_and_wire_size():
    codec = Int8Codec()
    rng = np.random.default_rng(0)
    old = {"w": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    new = {"w": old["w"] + 0.01 * jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    enc, residual = compress_update(codec, new, old)
    rebuilt = decompress_update(codec, enc, old)
    np.testing.assert_allclose(
        np.asarray(rebuilt["w"]), np.asarray(new["w"]), atol=1e-3
    )
    assert codec.wire_bytes(300) < 300 * 4  # smaller than fp32 wire


@pytest.mark.parametrize("codec", [NullCodec(), Int8Codec(), TopKCodec(frac=0.1)])
def test_codec_reduce_zero_weights_yields_zeros(codec):
    """All-zero aggregation weights must produce a zero average on every
    reduce path (kernel and reference oracle alike), never NaNs."""
    deltas = jnp.ones((3, 512), jnp.float32) * 0.01
    avg, _ = codec.aggregate_batch(
        deltas, jnp.zeros(3), codec.init_client_state(3, 512)
    )
    np.testing.assert_array_equal(np.asarray(avg), 0.0)


def test_topk_codec_keeps_largest():
    codec = TopKCodec(frac=0.1)
    delta = jnp.asarray(np.r_[np.zeros(90), np.arange(1, 11)], jnp.float32)
    enc = codec.encode(delta)
    dec = codec.decode(enc)
    np.testing.assert_allclose(np.asarray(dec[-10:]), np.arange(1, 11))
    assert float(jnp.abs(dec[:90]).sum()) == 0.0


def _topk_fit_results(codec, global_params, n_clients, seed=0):
    from repro.core import FitRes
    from repro.utils.pytree import tree_size

    rng = np.random.default_rng(seed)
    n = tree_size(global_params)
    out = []
    for c in range(n_clients):
        newp = jax.tree.map(
            lambda x: x + 0.02 * jnp.asarray(rng.normal(size=x.shape), x.dtype),
            global_params,
        )
        enc, _ = compress_update(codec, newp, global_params)
        out.append((c, FitRes(parameters=compress_to_wire(codec, enc, n),
                              num_examples=10 + 3 * c)))
    return out


@pytest.mark.parametrize("strategy_cls", [FedAvg, FedProx])
def test_aggregate_fit_topk_sparse_path_matches_dense(strategy_cls):
    """A homogeneous-TopK fleet takes the O(C·k) grouped wire path; for the
    linear aggregators it must agree with the per-client densify path."""
    rng = np.random.default_rng(5)
    gp = {"a": jnp.asarray(rng.normal(size=(30, 10)), jnp.float32),
          "b": jnp.asarray(rng.normal(size=(17,)), jnp.float32)}
    results = _topk_fit_results(TopKCodec(frac=0.1), gp, n_clients=4)
    strat = strategy_cls()
    weights = jnp.asarray([float(r.num_examples) for _, r in results])

    grouped = strat._aggregate_fit_wire(0, results, weights, gp,
                                        strat.init_state(gp))
    assert grouped is not None, "all-TopK fleet must select the wire path"
    sparse, _ = grouped
    trees = [strat.fitres_parameters(r, gp) for _, r in results]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    dense, _ = strat.aggregate(stacked, weights, gp, strat.init_state(gp), 0)
    for k in gp:
        np.testing.assert_allclose(
            np.asarray(sparse[k]), np.asarray(dense[k]), atol=1e-5, rtol=1e-5
        )
    # aggregate_fit itself returns the sparse result bit-for-bit
    full = strat.aggregate_fit(0, results, gp)
    for k in gp:
        np.testing.assert_array_equal(np.asarray(full[k]), np.asarray(sparse[k]))


def test_aggregate_fit_topk_sparse_path_fedopt():
    """FedOpt over the sparse path: the pseudo-gradient is EXACTLY zero at
    coordinates no client transmitted, so adam leaves them untouched —
    unlike the dense leafwise mean, whose fp noise (~1e-8) gets amplified
    by adam's sign-like first step into spurious lr-scale movement.  The
    transmitted coordinates agree with the dense path."""
    rng = np.random.default_rng(5)
    gp = {"w": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    results = _topk_fit_results(TopKCodec(frac=0.1), gp, n_clients=4)
    strat = FedAdam()
    weights = jnp.asarray([float(r.num_examples) for _, r in results])
    grouped = strat._aggregate_fit_wire(0, results, weights, gp,
                                        strat.init_state(gp))
    assert grouped is not None
    sparse, _ = grouped

    touched = np.zeros(300, bool)
    for _, res in results:
        cp = res.parameters
        for key, buf, (dtype, shape) in zip(cp.fields, cp.tensors, cp.manifest):
            if key == "idx":
                touched[np.frombuffer(buf, dtype=dtype)] = True
    # untransmitted coordinates: exactly unchanged (g == 0 -> adam no-op)
    np.testing.assert_array_equal(
        np.asarray(sparse["w"])[~touched], np.asarray(gp["w"])[~touched]
    )
    # transmitted coordinates: match the densify path
    trees = [strat.fitres_parameters(r, gp) for _, r in results]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    dense, _ = strat.aggregate(stacked, weights, gp, strat.init_state(gp), 0)
    np.testing.assert_allclose(
        np.asarray(sparse["w"])[touched], np.asarray(dense["w"])[touched],
        atol=1e-3,
    )


def test_aggregate_fit_custom_aggregate_override_falls_back():
    """A Strategy subclass with a custom ``aggregate`` (e.g. robust median
    aggregation) must NOT be silently replaced by the sparse weighted-mean
    fast path — it falls back to the densify path that honors the override."""
    class MedianStrategy(FedAvg):
        def aggregate(self, client_params, weights, global_params, server_state, rnd):
            med = jax.tree.map(lambda x: jnp.median(x, axis=0), client_params)
            return med, server_state

    rng = np.random.default_rng(8)
    gp = {"w": jnp.asarray(rng.normal(size=(200,)), jnp.float32)}
    results = _topk_fit_results(TopKCodec(frac=0.1), gp, n_clients=3)
    strat = MedianStrategy()
    weights = jnp.asarray([float(r.num_examples) for _, r in results])
    assert not strat._grouped_fit_compatible()
    assert strat._aggregate_fit_wire(0, results, weights, gp, ()) is None
    # the full call routes through the override: result == leafwise median
    out = strat.aggregate_fit(0, results, gp)
    trees = [strat.fitres_parameters(r, gp) for _, r in results]
    exp = jnp.median(jnp.stack([t["w"] for t in trees]), axis=0)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(exp), atol=1e-6)
    # while the stock strategies stay eligible
    assert FedAvg()._grouped_fit_compatible()
    assert FedProx()._grouped_fit_compatible()
    assert FedAdam()._grouped_fit_compatible()


def test_aggregate_fit_mixed_codec_fleet_takes_grouped_path():
    """A mixed TopK+Int8 fleet no longer densifies per client: the grouped
    wire reduce aggregates each codec group on its own kernel path and
    matches the stacked densify reference (the PR 3 fallback is deleted)."""
    rng = np.random.default_rng(6)
    gp = {"w": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    results = _topk_fit_results(TopKCodec(frac=0.1), gp, n_clients=3)
    from repro.core import FitRes

    newp = {"w": gp["w"] + 0.02 * jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    enc, _ = compress_update(Int8Codec(), newp, gp)
    results.append((3, FitRes(parameters=compress_to_wire(Int8Codec(), enc, 300),
                              num_examples=10)))
    strat = FedAvg()
    weights = jnp.asarray([float(r.num_examples) for _, r in results])
    grouped = strat._aggregate_fit_wire(0, results, weights, gp,
                                        strat.init_state(gp))
    assert grouped is not None, "mixed stock-codec fleet must take the wire path"
    out, _ = grouped
    # reference: stack the per-client dense decodes, weighted mean
    trees = [strat.fitres_parameters(r, gp) for _, r in results]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    exp, _ = strat.aggregate(stacked, weights, gp, strat.init_state(gp), 0)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(exp["w"]),
                               atol=1e-5, rtol=1e-5)
    # ...and the TopK group is never densified along the way
    from repro.core.compression import ban_topk_densify

    strat.reset_server_state()
    with ban_topk_densify():
        full = strat.aggregate_fit(0, results, gp)
    np.testing.assert_array_equal(np.asarray(full["w"]), np.asarray(out["w"]))


def test_aggregate_fit_foreign_codec_falls_back_to_densify():
    """A codec subclass may redefine the wire format: exact-type checks must
    route it to the per-client dense decode, not the grouped kernel path."""
    class WeirdTopK(TopKCodec):
        pass

    rng = np.random.default_rng(9)
    gp = {"w": jnp.asarray(rng.normal(size=(120,)), jnp.float32)}
    results = _topk_fit_results(WeirdTopK(frac=0.1), gp, n_clients=2)
    strat = FedAvg()
    weights = jnp.asarray([float(r.num_examples) for _, r in results])
    assert strat._aggregate_fit_wire(0, results, weights, gp, ()) is None
    out = strat.aggregate_fit(0, results, gp)  # densify path still works
    assert out["w"].shape == (120,)


# ---------------- data ----------------
def test_dirichlet_partition_covers_all_sizes():
    data = make_classification(n=1000, num_classes=10, shape=(8,), seed=0)
    clients = dirichlet_partition(data, n_clients=7, alpha=0.5, seed=0)
    stats = partition_stats(clients)
    assert stats["n_clients"] == 7
    assert sum(len(c) for c in clients) >= 1000  # floor-padding may duplicate
    assert stats["sizes_min"] >= 8


def test_dirichlet_alpha_controls_heterogeneity():
    data = make_classification(n=4000, num_classes=10, shape=(4,), seed=1)
    ent = {}
    for alpha in (0.1, 100.0):
        clients = dirichlet_partition(data, n_clients=8, alpha=alpha, seed=1)
        ent[alpha] = partition_stats(clients)["mean_label_entropy"]
    assert ent[0.1] < ent[100.0]  # low alpha = more skewed labels


def test_client_dataset_batches_cycle():
    data = make_classification(n=50, num_classes=3, shape=(4,), seed=0)
    c = iid_partition(data, n_clients=2)[0]
    seen = 0
    for _ in range(10):
        b = c.next_batch(16)
        assert b["x"].shape == (16, 4)
        seen += 16
    assert seen > len(c)  # cycled through epochs without error


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_lm_stream_is_learnable_markov(seed):
    toks = make_lm_tokens(n_tokens=2000, vocab_size=17, order=1, noise=0.0, seed=seed)
    # deterministic chain: next token fully determined by previous
    nxt = {}
    ok = True
    for a, b in zip(toks[:-1], toks[1:]):
        if a in nxt and nxt[a] != b:
            ok = False
            break
        nxt[int(a)] = int(b)
    assert ok


# ---------------- checkpoint ----------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(5, dtype=jnp.float32),
        "nest": {"b": jnp.ones((2, 2), jnp.bfloat16)},
    }
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree, extra_meta={"round": 3})
    back = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


# ---------------- optimizers ----------------
@pytest.mark.parametrize("make_opt", [lambda: sgd(0.1, momentum=0.9),
                                      lambda: adam(0.1), lambda: yogi(0.1)])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for i in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state = opt.update(grads, params, state, i)
    assert float(jnp.abs(params["w"]).max()) < 0.1


# ---------------- pytree utils ----------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_flatten_unflatten_inverse(seed):
    rng = np.random.default_rng(seed)
    tree = {
        "a": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32),
        "b": [jnp.asarray(rng.normal(size=(5,)), jnp.bfloat16)],
    }
    vec = tree_flatten_to_vector(tree)
    back = tree_unflatten_from_vector(vec, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), atol=1e-2
        )


# ---------------- History convergence-time accounting ----------------
def _rec(rnd, wall, acc=None):
    from repro.core.server import RoundRecord

    return RoundRecord(rnd=rnd, train_loss=1.0, eval_loss=None, eval_acc=acc,
                       wall_time_s=wall, energy_j=0.0, comm_bytes=0, steps=1)


def test_history_no_eval_rounds():
    """eval_every > num_rounds: no accuracy exists anywhere."""
    from repro.core.server import History

    h = History()
    h.add(_rec(1, 10.0))
    h.add(_rec(2, 5.0))
    assert h.accuracy_series() == []
    assert h.final_accuracy() is None
    assert h.time_to_accuracy(0.1) is None
    assert h.total_time_s == 15.0


def test_history_target_never_reached():
    from repro.core.server import History

    h = History()
    h.add(_rec(1, 10.0, acc=0.2))
    h.add(_rec(2, 5.0, acc=0.4))
    assert h.time_to_accuracy(0.5) is None
    # the crossing round's wall time counts toward the convergence time,
    # and non-eval rounds before it count too
    h.add(_rec(3, 2.0))           # no eval this round
    h.add(_rec(4, 3.0, acc=0.6))
    assert h.time_to_accuracy(0.5) == pytest.approx(20.0)
    assert h.time_to_accuracy(0.4) == pytest.approx(15.0)  # earlier crossing
    assert h.final_accuracy() == 0.6
    assert h.accuracy_series() == [(1, 0.2), (2, 0.4), (4, 0.6)]


def test_history_first_round_hit_and_empty():
    from repro.core.server import History

    h = History()
    h.add(_rec(1, 3.0, acc=0.9))
    assert h.time_to_accuracy(0.5) == pytest.approx(3.0)
    assert h.time_to_accuracy(0.9) == pytest.approx(3.0)  # >= is inclusive
    empty = History()
    assert empty.time_to_accuracy(0.0) is None
    assert empty.final_accuracy() is None
    assert empty.total_time_s == 0.0 and empty.total_energy_j == 0.0

"""Per-architecture smoke tests (required deliverable (f)).

For each assigned architecture: instantiate the REDUCED same-family variant
(2 layers, d_model<=512, <=4 experts), run one forward/train step and one
prefill+decode step on CPU, asserting output shapes and finiteness.  Also
checks decode-vs-prefill logit parity (the cache path equals the full pass).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.models import build_model

ASSIGNED = [
    "mixtral-8x7b",
    "jamba-1.5-large-398b",
    "xlstm-1.3b",
    "stablelm-3b",
    "granite-8b",
    "paligemma-3b",
    "qwen3-0.6b",
    "minicpm3-4b",
    "musicgen-medium",
    "deepseek-moe-16b",
]


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
    }
    if cfg.frontend_tokens:
        fd = cfg.frontend_dim or cfg.d_model
        batch["frontend"] = rng.normal(size=(b, cfg.frontend_tokens, fd)).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg)

    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(p, b)
        new_p = jax.tree.map(lambda x, g: x - 0.01 * g.astype(x.dtype), p, grads)
        return loss, metrics, new_p

    loss, metrics, new_p = jax.jit(step)(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    for leaf in jax.tree.leaves(new_p):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    b, s, ctx = 2, 16, 64
    batch = _batch(cfg, b=b, s=s)
    del batch["labels"]
    logits, cache = jax.jit(lambda p, bt: m.prefill(p, bt, ctx))(params, batch)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(lambda p, bt, c: m.decode_step(p, bt, c, ctx))(
        params, {"tokens": tok}, cache
    )
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache["pos"]) == s + cfg.frontend_tokens + 1


# MoE archs (mixtral/jamba/deepseek) are excluded: capacity-based token
# dropping depends on the prefill length (capacity = ceil(S*k*cf/E)), so
# prefill(S) vs prefill(S-1)+decode legitimately differ on dropped tokens.
# Frontend-stub archs (paligemma/musicgen) are covered by the shape smoke
# tests; strict parity would need the conditioning prefix re-threaded.
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "granite-8b", "minicpm3-4b", "xlstm-1.3b", "stablelm-3b"])
def test_decode_matches_prefill_logits(arch):
    """prefill(t[0:s]) then decode(t[s]) == prefill(t[0:s+1]) last logits."""
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    b, s, ctx = 1, 17, 64
    toks = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)

    logits_full, _ = m.prefill(params, {"tokens": toks}, ctx)
    _, cache = m.prefill(params, {"tokens": toks[:, :-1]}, ctx)
    logits_step, _ = m.decode_step(params, {"tokens": toks[:, -1:]}, cache, ctx)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_step[:, -1], np.float32),
        atol=0.15, rtol=0.15,  # bf16 params + different reduction orders
    )


def test_sliding_window_ring_cache_decode():
    """Decode far beyond the window: ring cache stays finite & bounded."""
    cfg = get_config("mixtral-8x7b").reduced()
    assert cfg.sliding_window is not None
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    ctx = 256  # > window (64)
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (1, 16)).astype(np.int32)}
    logits, cache = m.prefill(params, batch, ctx)
    # cache length is the window, not the context
    k_cache = jax.tree.leaves(cache["layers"])[0]
    decode = jax.jit(lambda p, bt, c: m.decode_step(p, bt, c, ctx))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(80):  # wrap the ring buffer
        logits, cache = decode(params, {"tokens": tok}, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_registry_contains_all_assigned():
    known = list_configs()
    for a in ASSIGNED + ["resnet18-cifar10", "mobilenet-head-office31"]:
        assert a in known

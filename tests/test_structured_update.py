"""Leafwise structured updates (this PR's tentpole): the segment map, the
``StructuredUpdate`` wire, and the bitwise-parity contract with the flat
``(n_params,)`` surface it replaced.

What is pinned here:

- **segment-map round-trip**: leafwise flatten -> split -> unflatten is a
  bitwise inverse of ``tree_flatten_to_vector`` for any tree (hypothesis
  sweep + seeded pins, so the property keeps teeth when hypothesis is
  absent and the shim skips);
- **single-segment == legacy flat, bitwise**: a codec bound to
  ``SegmentMap.flat(n)`` produces byte-identical aggregates, states, wire
  sizes, and whole *rounds* (parallel, sequential, AND rounds-as-scan) as
  the unsegmented codec, for Null / Int8 / TopK — the refactor cannot have
  changed a single bit of the legacy path;
- **CohortState leafwise spill**: per-segment residual rows survive the
  population store (spill -> rehydrate bitwise), eviction still resets,
  and the single-flat-segment store matches the legacy flat store bitwise
  across an eviction;
- **per-segment VMEM dispatch**: the TopK scatter kernel's VMEM gate sees
  ``seg.size`` per call, so segments stay on the Pallas path where the
  monolithic flat vector falls back to the XLA oracle;
- **LoRACodec + mixed fleets**: factor wire beats dense Int8, a rank-r
  update reconstructs near-exactly at rank r, and a LoRA group and an
  Int8 group aggregate in ONE fleet via ``MixedCodec``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    CohortState, FedAvg, Int8Codec, LoRACodec, MixedCodec, NullCodec,
    RoundSpec, Segment, SegmentMap, StructuredUpdate, TopKCodec,
    make_multi_round_step, make_round_step,
)
from repro.core.compression import compress_update, decompress_update
from repro.core.protocol import compress_to_wire, wire_to_enc, wire_to_pytree
from repro.kernels import ops
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import (
    tree_flatten_to_vector, tree_size, tree_sub, tree_unflatten_from_vector,
)

CODECS = {
    "null": NullCodec(),
    "int8": Int8Codec(),
    "topk": TopKCodec(frac=0.25),
}


def _tree(seed, scale=0.01):
    """A param-like pytree with a 1-D bias, 2-D matrices, and a 3-D
    stacked-expert leaf (the MoE shape the matrix fold exists for)."""
    rng = np.random.default_rng(seed)
    return {
        "bias": jnp.asarray(rng.normal(size=(9,)) * scale, jnp.float32),
        "emb": jnp.asarray(rng.normal(size=(12, 8)) * scale, jnp.float32),
        "experts": jnp.asarray(rng.normal(size=(2, 5, 4)) * scale, jnp.float32),
        "w": jnp.asarray(rng.normal(size=(16, 6)) * scale, jnp.float32),
    }


# ---------------- the segment map ----------------
def test_from_tree_tiles_the_flat_vector():
    t = _tree(0)
    segs = SegmentMap.from_tree(t)
    assert segs.n_params == tree_size(t) == 9 + 96 + 40 + 96
    off = 0
    for seg, leaf in zip(segs, jax.tree.leaves(t)):
        assert seg.offset == off and seg.shape == tuple(leaf.shape)
        off += seg.size
    assert segs.matches_leaves(jax.tree.leaves(t))


def test_noncontiguous_segments_rejected():
    with pytest.raises(AssertionError, match="contiguous"):
        SegmentMap((Segment("a", (4,), 0), Segment("b", (4,), 5)))


def test_matrix_shape_folds_leading_axes():
    assert Segment("e", (2, 5, 4), 0).matrix_shape == (10, 4)
    assert Segment("w", (16, 6), 0).matrix_shape == (16, 6)
    with pytest.raises(AssertionError, match="no matrix view"):
        Segment("b", (9,), 0).matrix_shape


def _assert_split_roundtrip(t):
    segs = SegmentMap.from_tree(t)
    vec = tree_flatten_to_vector(t)
    parts = segs.split(vec)
    # split slices are bitwise the leaves, and concat is bitwise the vector
    for part, leaf, seg in zip(parts, jax.tree.leaves(t), segs):
        np.testing.assert_array_equal(
            np.asarray(part), np.asarray(leaf).reshape(-1), err_msg=seg.name
        )
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(parts)), np.asarray(vec)
    )
    back = tree_unflatten_from_vector(vec, t)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_split_roundtrip_pinned(seed):
    _assert_split_roundtrip(_tree(seed, scale=10.0 ** (seed - 1)))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=40), min_size=1,
                max_size=6),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_split_roundtrip_property(sizes, seed):
    rng = np.random.default_rng(seed)
    t = {f"l{i}": jnp.asarray(rng.normal(size=(n,)), jnp.float32)
         for i, n in enumerate(sizes)}
    _assert_split_roundtrip(t)


# ---------------- single flat segment == legacy, surface level ----------------
@pytest.mark.parametrize("name", list(CODECS))
def test_single_segment_aggregate_batch_bitwise(name):
    codec = CODECS[name]
    n = 700
    seg = codec.with_segments(SegmentMap.flat(n))
    rng = np.random.default_rng(5)
    deltas = jnp.asarray(rng.normal(size=(3, n)) * 0.01, jnp.float32)
    w = jnp.asarray(rng.random(3) + 0.1, jnp.float32)
    flat_state = codec.init_client_state(3, n)
    seg_state = seg.init_client_state(3, n)
    out_f, new_f = codec.aggregate_batch(deltas, w, flat_state)
    out_s, new_s = seg.aggregate_batch(deltas, w, seg_state)
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(out_f))
    assert isinstance(new_s, tuple) and len(new_s) == 1
    np.testing.assert_array_equal(
        np.asarray(new_s[0]) if name != "null" else np.zeros(0),
        np.asarray(new_f) if name != "null" else np.zeros(0),
    )
    assert seg.wire_bytes(n) == codec.wire_bytes(n)


@pytest.mark.parametrize("name", list(CODECS))
def test_structured_wire_serialization_exact(name):
    """encode_structured -> CompressedParameters -> wire_to_enc round-trips
    and the serialized payload is EXACTLY the restated per-segment bytes."""
    t = _tree(3)
    segs = SegmentMap.from_tree(t)
    codec = CODECS[name].with_segments(segs)
    n = segs.n_params
    vec = tree_flatten_to_vector(t)
    su = codec.encode_structured(vec)
    assert isinstance(su, StructuredUpdate) and len(su.payloads) == len(segs)
    dec = codec.decode_structured(su)
    cp = compress_to_wire(codec, su, n)
    assert cp.num_bytes == codec.wire_bytes(n)
    back = wire_to_enc(cp)
    np.testing.assert_array_equal(
        np.asarray(codec.decode_structured(back)), np.asarray(dec)
    )
    zeros = jax.tree.map(jnp.zeros_like, t)
    out = wire_to_pytree(cp, zeros)
    np.testing.assert_allclose(
        np.asarray(tree_flatten_to_vector(out)), np.asarray(dec),
        atol=1e-6, rtol=1e-6,
    )


@pytest.mark.parametrize("name", ["null", "int8"])
def test_compress_update_leafwise_matches_flat(name):
    """The client-side surface: segmented compress_update decodes to the
    same update as the flat path (bitwise for null, allclose for int8 —
    per-segment block padding shifts block boundaries).  TopK is excluded
    on purpose: per-segment selection keeps each segment's own top-k,
    which is a different (intended) support than the global flat top-k —
    pinned in test_topk_leafwise_selects_per_segment below."""
    g, p = _tree(7), _tree(8)
    flat_codec = CODECS[name]
    seg_codec = flat_codec.with_segments(SegmentMap.from_tree(g))
    enc_f, res_f = compress_update(flat_codec, p, g)
    enc_s, res_s = compress_update(seg_codec, p, g)
    out_f = decompress_update(flat_codec, enc_f, g)
    out_s = decompress_update(seg_codec, enc_s, g)
    tol = dict(atol=0, rtol=0) if name == "null" else dict(atol=5e-4, rtol=0)
    np.testing.assert_allclose(
        np.asarray(tree_flatten_to_vector(out_s)),
        np.asarray(tree_flatten_to_vector(out_f)), **tol,
    )
    assert isinstance(res_s, tuple)
    # residual rows cover stateful segments only
    for row, seg in zip(res_s, seg_codec.segments):
        if seg_codec.segment_stateful(seg):
            assert row.shape == (seg.size,)
        else:
            assert row == ()


def test_topk_leafwise_selects_per_segment():
    """Leafwise TopK keeps ceil(frac * seg.size) entries of EACH segment —
    a tiny-but-loud layer cannot be starved by a huge noisy one, which is
    the point of structure-aware selection."""
    import math

    g = {"small": jnp.zeros((8,)), "big": jnp.zeros((512,))}
    rng = np.random.default_rng(0)
    p = {"small": jnp.asarray(rng.normal(size=(8,)) * 0.01, jnp.float32),
         "big": jnp.asarray(rng.normal(size=(512,)) * 100.0, jnp.float32)}
    codec = TopKCodec(frac=0.25).with_segments(SegmentMap.from_tree(g))
    su, _ = compress_update(codec, p, g)
    for payload, seg in zip(su.payloads, su.segments):
        k = math.ceil(0.25 * seg.size)
        assert payload["idx"].shape == (k,), seg.name
    out = decompress_update(codec, su, g)
    # the small segment transmitted: its top entries survive the wire even
    # though every one of them is below the big segment's global top-25%
    assert float(jnp.abs(out["small"]).max()) > 0.0


# ---------------- single flat segment == legacy, whole rounds ----------------
C, STEPS, B = 4, 2, 16


def _setup(seed=0):
    m = build_model("mobilenet-head-office31")
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 1.0, size=(m.cfg.num_classes, m.cfg.feature_dim))

    def batch_of(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, m.cfg.num_classes, n)
        x = centers[y] + 0.4 * r.normal(size=(n, m.cfg.feature_dim))
        return x.astype(np.float32), y.astype(np.int32)

    xs, ys = zip(*[batch_of(STEPS * B, 100 + c) for c in range(C)])
    train = {
        "x": jnp.asarray(np.stack(xs).reshape(C, STEPS, B, -1)),
        "y": jnp.asarray(np.stack(ys).reshape(C, STEPS, B)),
    }
    return m, m.init(jax.random.key(seed)), train


def _run_rounds(m, params, train, codec, mode, rounds=3):
    spec = RoundSpec(max_steps=STEPS, execution_mode=mode, codec=codec)
    rs = jax.jit(make_round_step(m.loss_fn, sgd(0.1), FedAvg(), spec))
    w = jnp.ones(C)
    bud = jnp.full((C,), STEPS, jnp.int32)
    p, state = params, ()
    cstate = codec.init_client_state(C, tree_size(params))
    for rnd in range(rounds):
        p, state, cstate, met = rs(p, state, cstate, train, w, bud, rnd)
    return p, cstate, met


def _assert_state_bitwise(seg_state, flat_state):
    seg_rows = [np.asarray(r) for r in jax.tree.leaves(seg_state)]
    flat_rows = [np.asarray(r) for r in jax.tree.leaves(flat_state)]
    assert len(seg_rows) == len(flat_rows)
    for a, b in zip(seg_rows, flat_rows):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("mode", ["parallel", "sequential"])
@pytest.mark.parametrize("name", list(CODECS))
def test_single_segment_round_bitwise_matches_flat(name, mode):
    """The PR's acceptance bar: whole jitted rounds under a single flat
    segment are byte-identical to the pre-refactor flat path."""
    m, params, train = _setup()
    flat_codec = CODECS[name]
    seg_codec = flat_codec.with_segments(SegmentMap.flat(tree_size(params)))
    p_f, cs_f, met_f = _run_rounds(m, params, train, flat_codec, mode)
    p_s, cs_s, met_s = _run_rounds(m, params, train, seg_codec, mode)
    for a, b in zip(jax.tree.leaves(p_s), jax.tree.leaves(p_f)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _assert_state_bitwise(cs_s, cs_f)
    for k in met_f:
        np.testing.assert_array_equal(
            np.asarray(met_s[k]), np.asarray(met_f[k]), err_msg=k
        )


@pytest.mark.parametrize("name", list(CODECS))
def test_single_segment_scan_bitwise_matches_flat(name):
    """Same bar on rounds-as-scan: the whole R-round lax.scan program."""
    m, params, train = _setup()
    R = 3
    outs = {}
    for label, codec in (
        ("flat", CODECS[name]),
        ("seg", CODECS[name].with_segments(SegmentMap.flat(tree_size(params)))),
    ):
        spec = RoundSpec(max_steps=STEPS, execution_mode="parallel",
                         codec=codec)
        multi = make_multi_round_step(
            m.loss_fn, sgd(0.1), FedAvg(), spec, R, stacked_batches=False
        )
        cs = codec.init_client_state(C, tree_size(params))
        sched = (jnp.ones((R, C), jnp.float32),
                 jnp.zeros((R, C), jnp.float32),
                 jnp.zeros((R, C), jnp.float32))
        outs[label] = jax.jit(multi)(
            params, FedAvg().init_state(params), cs, train, jnp.ones(C),
            jnp.full((C,), STEPS, jnp.int32), *sched
        )
    for a, b in zip(jax.tree.leaves(outs["seg"][0]),
                    jax.tree.leaves(outs["flat"][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _assert_state_bitwise(outs["seg"][2], outs["flat"][2])


# ---------------- CohortState: leafwise spill ----------------
def test_cohort_state_leafwise_spill_rehydrates_bitwise():
    t = _tree(11)
    segs = SegmentMap.from_tree(t)
    codec = Int8Codec().with_segments(segs)
    cs = CohortState(codec, segs.n_params, capacity=8)
    rng = np.random.default_rng(0)
    rows = {cid: tuple(rng.normal(size=(seg.size,)).astype(np.float32)
                       for seg in segs) for cid in (3, 7)}
    for cid, row in rows.items():
        cs.put_row(cid, row)
    g = cs.gather([3, 5, 7])
    assert isinstance(g, tuple) and len(g) == len(segs)
    for i, seg in enumerate(segs):
        assert g[i].shape == (3, seg.size)
        np.testing.assert_array_equal(np.asarray(g[i][0]), rows[3][i])
        np.testing.assert_array_equal(np.asarray(g[i][1]), np.zeros(seg.size))
        np.testing.assert_array_equal(np.asarray(g[i][2]), rows[7][i])
    # scatter back and round-trip again: bitwise stable
    cs.scatter([3, 5, 7], g)
    g2 = cs.gather([3, 5, 7])
    for a, b in zip(g, g2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cohort_state_leafwise_eviction_resets_residual():
    segs = SegmentMap.from_tree({"a": jnp.zeros((4,)), "b": jnp.zeros((2, 2))})
    codec = TopKCodec(frac=0.5).with_segments(segs)
    cs = CohortState(codec, 8, capacity=2)
    for cid in (1, 2, 3):  # capacity 2: inserting 3 evicts 1
        cs.put_row(cid, (np.full(4, float(cid), np.float32),
                         np.full(4, float(cid), np.float32)))
    assert cs.evictions == 1
    g = cs.gather([1, 2, 3])
    for i in range(2):
        np.testing.assert_array_equal(np.asarray(g[i][0]), np.zeros(4))
        np.testing.assert_array_equal(np.asarray(g[i][1]), np.full(4, 2.0))
        np.testing.assert_array_equal(np.asarray(g[i][2]), np.full(4, 3.0))


def test_cohort_state_single_segment_matches_flat_across_eviction():
    """The population round loop — gather, aggregate, scatter — under a
    single flat segment is bitwise the legacy flat store, including the
    reset row an eviction leaves behind."""
    n = 96
    flat_codec = Int8Codec()
    seg_codec = flat_codec.with_segments(SegmentMap.flat(n))
    rng = np.random.default_rng(2)
    deltas = jnp.asarray(rng.normal(size=(3, n)) * 0.01, jnp.float32)
    w = jnp.ones(3)

    def run(codec):
        cs = CohortState(codec, n, capacity=2)
        outs = []
        for cohort in ([1, 2, 3], [2, 3, 4], [1, 2, 4]):
            state = cs.gather(cohort)
            out, new_state = codec.aggregate_batch(deltas, w, state)
            cs.scatter(cohort, new_state)
            outs.append(np.asarray(out))
        return cs, outs

    cs_f, outs_f = run(flat_codec)
    cs_s, outs_s = run(seg_codec)
    assert cs_f.evictions == cs_s.evictions > 0
    for a, b in zip(outs_s, outs_f):
        np.testing.assert_array_equal(a, b)
    for cid in (1, 2, 4):
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(r) for r in cs_s.gather([cid])],
                           axis=1)[0],
            np.asarray(cs_f.gather([cid]))[0],
        )


# ---------------- per-segment VMEM-budget kernel dispatch ----------------
def test_topk_pallas_dispatch_is_per_segment(monkeypatch):
    """Segments below the VMEM budget take the Pallas scatter path even
    when the TOTAL model is over budget (where the monolithic flat vector
    falls back to the XLA oracle)."""
    from repro.kernels import scatter_reduce

    monkeypatch.setattr(scatter_reduce, "MAX_N_PARAMS", 300)
    segs = SegmentMap((Segment("a", (256,), 0), Segment("b", (16, 16), 256)))
    n = segs.n_params
    assert n > 300 and all(s.size <= 300 for s in segs)
    rng = np.random.default_rng(4)
    deltas = jnp.asarray(rng.normal(size=(2, n)), jnp.float32)
    w = jnp.ones(2)
    ops.set_impl("pallas")
    try:
        flat = TopKCodec(frac=0.1)
        before = ops.topk_pallas_calls()
        flat.aggregate_batch(deltas, w, flat.init_client_state(2, n))
        assert ops.topk_pallas_calls() == before  # over budget: oracle

        seg = flat.with_segments(segs)
        before = ops.topk_pallas_calls()
        out, _ = seg.aggregate_batch(deltas, w, seg.init_client_state(2, n))
        assert ops.topk_pallas_calls() == before + len(segs)
        assert out.shape == (n,)
    finally:
        ops.set_impl("auto")


# ---------------- LoRA + mixed fleets ----------------
def _llm_tree(seed, scale=0.01):
    """Matrices big enough for rank-4 factors to undercut the dense wire."""
    rng = np.random.default_rng(seed)
    return {
        "bias": jnp.asarray(rng.normal(size=(48,)) * scale, jnp.float32),
        "experts": jnp.asarray(rng.normal(size=(2, 40, 48)) * scale,
                               jnp.float32),
        "w": jnp.asarray(rng.normal(size=(64, 48)) * scale, jnp.float32),
    }


def test_lora_wire_beats_int8_and_reconstructs_low_rank():
    t = _llm_tree(13)
    segs = SegmentMap.from_tree(t)
    lora = LoRACodec(rank=4, factor_codec=NullCodec()).with_segments(segs)
    int8 = Int8Codec().with_segments(segs)
    n = segs.n_params
    assert lora.wire_bytes(n) < int8.wire_bytes(n)
    # a true rank-2 update round-trips the rank-4 factor wire near-exactly
    rng = np.random.default_rng(1)
    u = rng.normal(size=(64, 2)).astype(np.float32)
    v = rng.normal(size=(2, 48)).astype(np.float32)
    low = jnp.asarray(u @ v)
    seg = next(s for s in segs if s.name.endswith("'w']"))
    dec = lora.decode_segment(
        lora.encode_segment(low.reshape(-1), seg), seg
    ).reshape(64, 48)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(low),
                               atol=1e-3, rtol=1e-3)


def test_lora_requires_segments():
    with pytest.raises(TypeError, match="SegmentMap"):
        LoRACodec(rank=2).encode(jnp.zeros((8,)))
    with pytest.raises(TypeError, match="SegmentMap"):
        LoRACodec(rank=2).wire_bytes(8)


def test_lora_residual_telescopes():
    """Error feedback on the factor wire: what rank r cannot carry lands in
    the residual, and round 2 transmits it (residual norm contracts)."""
    t = _llm_tree(17, scale=1.0)
    segs = SegmentMap.from_tree(t)
    lora = LoRACodec(rank=2, factor_codec=NullCodec()).with_segments(segs)
    g = jax.tree.map(jnp.zeros_like, t)
    enc1, res1 = compress_update(lora, t, g)
    enc2, res2 = compress_update(lora, g, g, residual=res1)  # zero new delta
    n1 = sum(float(jnp.sum(r * r)) for r in res1 if not isinstance(r, tuple))
    n2 = sum(float(jnp.sum(r * r)) for r in res2 if not isinstance(r, tuple))
    assert n2 < n1  # the carried error shrinks once retransmitted


def test_mixed_lora_int8_fleet_aggregates():
    """Satellite 6: one fleet, a LoRA group AND an Int8 group, one round."""
    t = _llm_tree(19)
    segs = SegmentMap.from_tree(t)
    mixed = MixedCodec(
        codecs=(LoRACodec(rank=2, fallback=Int8Codec()), Int8Codec()),
        assignment=(0, 0, 1, 1),
    ).with_segments(segs)
    n = segs.n_params
    state = mixed.init_client_state(4, n)
    client_params = jax.tree.map(
        lambda leaf: jnp.stack([leaf * (1 + 0.1 * c) for c in range(4)]), t
    )
    new_global, new_state = mixed.aggregate_updates(
        client_params, t, jnp.ones(4), state
    )
    assert jax.tree.structure(new_global) == jax.tree.structure(t)
    assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(new_global))
    per_client = mixed.wire_bytes([n] * 4)
    lora_wire = LoRACodec(rank=2, fallback=Int8Codec()) \
        .with_segments(segs).wire_bytes(n)
    int8_wire = Int8Codec().with_segments(segs).wire_bytes(n)
    assert per_client == [lora_wire, lora_wire, int8_wire, int8_wire]
    assert lora_wire < int8_wire


def test_mixed_codec_rejects_conflicting_segment_maps():
    segs_a = SegmentMap.from_tree({"a": jnp.zeros((8,))})
    segs_b = SegmentMap.from_tree({"a": jnp.zeros((4,)), "b": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="segment map"):
        MixedCodec(
            codecs=(Int8Codec().with_segments(segs_a),
                    TopKCodec(frac=0.5).with_segments(segs_b)),
            assignment=(0, 1),
        )

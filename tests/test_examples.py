"""Drift coverage for the examples/ drivers.

The examples are the repo's public face; nothing else imports them, so an
API rename silently breaks them until a reader hits the traceback.  These
tests execute both drivers on tiny configs every CI run.

``federated_llm_finetune`` exposes ``main(argv)`` and is driven directly —
including the structured-update path (``--codec lora``) that the ISSUE's
acceptance pins: the LoRA wire must undercut the dense Int8 wire by >= 10x
on the LLM configs.  ``quickstart`` is a straight-line script, so it runs
under ``runpy`` (same module-level execution a reader gets).
"""
import runpy
import sys
from pathlib import Path

import pytest

EXAMPLES = Path(__file__).resolve().parent.parent / "examples"
sys.path.insert(0, str(EXAMPLES))

TINY = ["--rounds", "2", "--layers", "1", "--d-model", "64",
        "--seq", "16", "--batch", "1", "--clients", "2", "--local-steps", "2"]


def _run_finetune(extra):
    import federated_llm_finetune as ex

    params, loss = ex.main(TINY + extra)
    assert loss == loss, "final loss is NaN"  # noqa: PLR0124 (NaN check)
    return params, loss


def test_llm_finetune_fp32_smoke():
    params, _ = _run_finetune(["--codec", "fp32"])
    assert params  # a real pytree came back


def test_llm_finetune_lora_smoke():
    _run_finetune(["--codec", "lora", "--rank", "2"])


def test_llm_finetune_lora_moe_arch():
    """The dormant MoE config: stacked-expert 3-D leaves fold into matrix
    segments and ship low-rank factors inside the jitted round."""
    _run_finetune(["--arch", "mixtral-8x7b", "--codec", "lora", "--rank", "2"])


def test_llm_finetune_lora_wire_beats_int8_10x():
    """ISSUE acceptance: LoRA wire >= 10x under dense Int8 on the LLM arch."""
    import jax

    import federated_llm_finetune as ex
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.utils.pytree import tree_size

    cfg = get_config("qwen3-0.6b").reduced(n_layers=1, d_model=64)
    params = build_model(cfg).init(jax.random.key(0))
    n = tree_size(params)
    lora, int8 = ex.build_codec("lora", params, rank=4)
    assert int8.wire_bytes(n) >= 10 * lora.wire_bytes(n), (
        f"lora wire {lora.wire_bytes(n)} vs int8 {int8.wire_bytes(n)}"
    )


def test_llm_finetune_rejects_unknown_codec():
    import jax

    import federated_llm_finetune as ex
    from repro.configs.base import get_config
    from repro.models import build_model

    cfg = get_config("qwen3-0.6b").reduced(n_layers=1, d_model=64)
    params = build_model(cfg).init(jax.random.key(0))
    with pytest.raises(ValueError, match="unknown codec"):
        ex.build_codec("zstd", params, rank=4)


def test_quickstart_runs(capsys):
    runpy.run_path(str(EXAMPLES / "quickstart.py"), run_name="quickstart")
    out = capsys.readouterr().out
    assert "final accuracy:" in out
    assert "population mode" in out

"""Property-test harness for the codec wire format (ISSUE-3).

The wire format is load-bearing on every execution path (PR 2), so it is
proven here by properties rather than hand-picked examples, for Null / Int8
/ TopK over random shapes, dtypes and scales:

- **round-trip**: ``encode`` -> ``wire_payload`` -> serialization ->
  ``from_wire`` -> ``decode`` reproduces ``decode(encode(.))`` exactly;
- **size**: the serialized payload is EXACTLY ``codec.wire_bytes(n)`` bytes
  (Int8 encoder padding trimmed off the wire);
- **residual contraction**: repeatedly re-encoding a residual shrinks it
  monotonically, and the error-feedback loop on a fixed delta stays within
  its provable bound;
- **TopK determinism**: equal-magnitude ties break toward the lower index,
  payloads are bit-identical under jit vs eager, and indices arrive in
  canonical ascending order (regression for the lax.top_k tie order).

Hypothesis drives the randomized sweeps when installed (the CI ``test``
extra); every property is ALSO pinned by seeded deterministic cases below
so the harness keeps teeth when hypothesis is absent and the shim skips.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import Int8Codec, NullCodec, TopKCodec
from repro.core.protocol import compress_to_wire, wire_to_pytree
from repro.core.compression import compress_update, decompress_update

CODECS = {
    "null": NullCodec(),
    "int8": Int8Codec(),
    "topk": TopKCodec(frac=0.1),
}


def _vec(n, seed, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n,)) * scale, dtype)


# ------------------------------------------------------------------ round-trip
def _assert_roundtrip(codec, vec):
    n = vec.shape[0]
    enc = codec.encode(vec)
    dec = codec.decode(enc)
    # wire_payload -> (serialize) -> from_wire -> decode is the same decode
    wire = codec.wire_payload(enc)
    rebuilt = codec.from_wire(
        {k: (v if isinstance(v, (int, float)) else jnp.asarray(np.asarray(v)))
         for k, v in wire.items()}
    )
    np.testing.assert_array_equal(np.asarray(codec.decode(rebuilt)), np.asarray(dec))
    # and through the full CompressedParameters serialization
    cp = compress_to_wire(codec, enc, n)
    assert cp.num_bytes == codec.wire_bytes(n), (
        f"{type(codec).__name__}: serialized {cp.num_bytes} != "
        f"wire_bytes {codec.wire_bytes(n)}"
    )
    out = wire_to_pytree(cp, {"w": jnp.zeros_like(vec, jnp.float32)})
    np.testing.assert_allclose(
        np.asarray(out["w"]), np.asarray(dec), atol=1e-6, rtol=1e-6
    )


@pytest.mark.parametrize("name", list(CODECS))
@pytest.mark.parametrize("n,seed,scale", [
    (64, 0, 1.0), (300, 1, 1e-3), (511, 2, 1e3), (512, 3, 0.01),
    (513, 4, 10.0), (2048, 5, 1.0), (7, 6, 1.0),
])
def test_wire_roundtrip_and_exact_size(name, n, seed, scale):
    _assert_roundtrip(CODECS[name], _vec(n, seed, scale))


@pytest.mark.parametrize("name", list(CODECS))
def test_wire_roundtrip_bf16_delta(name):
    """bf16 client deltas survive the wire (codecs upcast to fp32)."""
    vec = _vec(300, 9, dtype=jnp.bfloat16).astype(jnp.float32)
    _assert_roundtrip(CODECS[name], vec)


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(sorted(CODECS)),
    n=st.integers(2, 3000),
    seed=st.integers(0, 2**16),
    log_scale=st.floats(-4.0, 4.0),
)
def test_wire_roundtrip_property(name, n, seed, log_scale):
    _assert_roundtrip(CODECS[name], _vec(n, seed, 10.0 ** log_scale))


# ------------------------------------------------------- residual contraction
def _residual_norms(codec, delta, steps=6):
    """‖r_t‖ for r_0 = delta, r_{t+1} = r_t - decode(encode(r_t))."""
    r, norms = delta, []
    for _ in range(steps):
        r = r - codec.decode(codec.encode(r))
        norms.append(float(jnp.linalg.norm(r)))
    return norms


@pytest.mark.parametrize("name", list(CODECS))
@pytest.mark.parametrize("n,seed,scale", [(256, 0, 1.0), (1000, 1, 1e-2), (333, 2, 1e2)])
def test_repeated_encode_residual_nonincreasing(name, n, seed, scale):
    codec = CODECS[name]
    delta = _vec(n, seed, scale)
    norms = [float(jnp.linalg.norm(delta))] + _residual_norms(codec, delta)
    for a, b in zip(norms, norms[1:]):
        assert b <= a + 1e-5 * max(1.0, a), norms
    if isinstance(codec, NullCodec):
        assert norms[1] == 0.0  # identity wire: nothing is ever left behind
    if isinstance(codec, TopKCodec):
        # dropping the k largest of n removes >= k/n of the energy per pass
        rho = float(np.sqrt(1.0 - codec.k_of(n) / n))
        for a, b in zip(norms, norms[1:]):
            assert b <= rho * a + 1e-5 * max(1.0, a)


@settings(max_examples=20, deadline=None)
@given(name=st.sampled_from(sorted(CODECS)), seed=st.integers(0, 2**16))
def test_repeated_encode_residual_nonincreasing_property(name, seed):
    codec = CODECS[name]
    delta = _vec(512, seed, 1.0)
    norms = [float(jnp.linalg.norm(delta))] + _residual_norms(codec, delta)
    for a, b in zip(norms, norms[1:]):
        assert b <= a + 1e-5 * max(1.0, a), norms


@pytest.mark.parametrize("name,n", [("int8", 512), ("topk", 500)])
def test_error_feedback_loop_residual_bounded(name, n):
    """The error-feedback recursion r <- (delta + r) - decode(encode(delta + r))
    on a FIXED delta stays within its provable bound (TopK: rho/(1-rho)·‖d‖
    with rho = sqrt(1 - k/n); Int8: the blockwise half-scale error)."""
    codec = CODECS[name]
    delta = _vec(n, 7, 0.5)
    r = jnp.zeros_like(delta)
    norms = []
    for _ in range(25):
        eff = delta + r
        r = eff - codec.decode(codec.encode(eff))
        norms.append(float(jnp.linalg.norm(r)))
    d = float(jnp.linalg.norm(delta))
    if name == "topk":
        rho = float(np.sqrt(1.0 - codec.k_of(n) / n))
        bound = rho / (1.0 - rho) * d
        assert max(norms) <= bound + 1e-4, (max(norms), bound)
    else:
        # int8 round-to-nearest: every entry's error <= its block half-scale,
        # and scales track |eff| <= |delta| + |r|; boundedness, not blow-up
        assert max(norms[5:]) <= 2.0 * max(norms[:5]) + 1e-6, norms


# ------------------------------------------------------- TopK determinism
def test_topk_tie_break_is_lower_index():
    """All-equal magnitudes: the k lowest indices win, in ascending order."""
    codec = TopKCodec(frac=0.1)
    n = 100
    for sign in (1.0, -1.0):
        enc = codec.encode(jnp.full((n,), 0.5 * sign, jnp.float32))
        np.testing.assert_array_equal(np.asarray(enc["idx"]), np.arange(codec.k_of(n)))


def test_topk_tie_break_mixed_magnitudes():
    """Ties below the clear winners break toward lower indices."""
    codec = TopKCodec(frac=0.03)  # k=3 of n=100
    x = np.zeros(100, np.float32)
    x[77] = 9.0          # unambiguous top-1
    x[[13, 40, 85]] = 2.0  # three-way tie for the remaining two slots
    enc = codec.encode(jnp.asarray(x))
    np.testing.assert_array_equal(np.sort(np.asarray(enc["idx"])), [13, 40, 77])


@pytest.mark.parametrize("n,seed", [(300, 0), (1024, 1), (65, 2)])
def test_topk_encode_jit_eager_bitwise_identical(n, seed):
    """Regression (ISSUE-3): the payload must be reproducible across jit and
    eager — raw lax.top_k tie order is lowering-dependent."""
    codec = TopKCodec(frac=0.1)
    # quantized values force plenty of exact magnitude ties
    vec = jnp.asarray(
        np.round(np.random.default_rng(seed).normal(size=n) * 4) / 4, jnp.float32
    )
    eager = codec.encode(vec)
    jitted = jax.jit(codec.encode)(vec)
    np.testing.assert_array_equal(np.asarray(eager["idx"]), np.asarray(jitted["idx"]))
    np.testing.assert_array_equal(np.asarray(eager["val"]), np.asarray(jitted["val"]))
    # canonical wire order: indices strictly ascending (hence distinct)
    idx = np.asarray(eager["idx"])
    assert (np.diff(idx) > 0).all(), idx
    # batch surface agrees with the vector surface
    enc_b = jax.jit(codec.encode_batch)(jnp.stack([vec, -vec]))
    np.testing.assert_array_equal(np.asarray(enc_b["idx"][0]), idx)
    np.testing.assert_array_equal(np.asarray(enc_b["idx"][1]), idx)


def test_topk_keeps_largest_magnitudes():
    """Determinism must not change WHAT is selected: the decoded vector
    carries exactly the k largest-|.| entries."""
    codec = TopKCodec(frac=0.1)
    vec = _vec(200, 11)
    enc = codec.encode(vec)
    dec = codec.decode(enc)
    top = np.argsort(-np.abs(np.asarray(vec)))[: codec.k_of(200)]
    np.testing.assert_array_equal(np.sort(np.asarray(enc["idx"])), np.sort(top))
    np.testing.assert_allclose(
        np.asarray(dec[enc["idx"]]), np.asarray(vec[enc["idx"]]), atol=0
    )


# ------------------------------------------------------- full client loop
@pytest.mark.parametrize("name", list(CODECS))
def test_compress_update_roundtrip_with_residual(name):
    """The python client loop (compress_update / decompress_update) preserves
    delta + residual telescoping: transmitted + new_residual == delta + old."""
    codec = CODECS[name]
    rng = np.random.default_rng(3)
    old = {"w": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    new = {"w": old["w"] + 0.01 * jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    residual = 0.001 * jnp.asarray(rng.normal(size=(300,)), jnp.float32)
    enc, new_res = compress_update(codec, new, old, residual=residual)
    sent = codec.decode(enc)
    eff = (new["w"] - old["w"]) + residual
    np.testing.assert_allclose(
        np.asarray(sent + new_res), np.asarray(eff), atol=1e-5, rtol=1e-5
    )
    rebuilt = decompress_update(codec, enc, old)
    np.testing.assert_allclose(
        np.asarray(rebuilt["w"]), np.asarray(old["w"] + sent), atol=1e-6
    )

"""Shared test bootstrap.

Force multiple host-platform devices BEFORE jax initializes so the mesh
shard_map round-engine tests can build a real multi-device (even multi-"pod")
CPU mesh in-process.  Single-device tests are unaffected: unsharded
computations stay on device 0.
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()

"""Satellite property test: distinct (seed, rnd) pairs never replay streams.

The defect class this pins down: affine seeding like ``seed * 1000 + rnd``
makes experiment seed s+1's round r replay seed s's round r+1000 — the
"independent" control arm of an ablation quietly reuses the treatment arm's
randomness.  Tuple seeding ``default_rng((seed, rnd))`` feeds both values
to SeedSequence entropy, where no two distinct tuples share a stream.
fedlint's rng-discipline rule bans the affine form statically; this test
proves the runtime contract across a 2-D sweep for every consumer:
AvailabilityTrace (availability + jitter streams) and Strategy client
sampling.
"""
import numpy as np
import pytest

from repro.core import STRATEGIES
from repro.core.cost_model import AvailabilityTrace

# Grid chosen so affine seed maps WOULD collide: under seed*1000 + rnd,
# (seed=0, rnd=1001) and (seed=1, rnd=1) hash to the same stream.
SEEDS = (0, 1, 2)
ROUNDS = (1, 2, 3, 1001, 2001)
N_CLIENTS = 64


def _trace(seed):
    return AvailabilityTrace(
        n_clients=N_CLIENTS, seed=seed,
        dropout=(0.3,) * N_CLIENTS, jitter_std=0.25,
    )


def test_affine_seeding_really_does_collide():
    """Sanity check that the banned pattern is a live hazard, not theory."""
    a = np.random.default_rng(0 * 1000 + 1001).random(16)
    b = np.random.default_rng(1 * 1000 + 1).random(16)
    assert np.array_equal(a, b)  # identical streams: the bug
    c = np.random.default_rng((0, 1001)).random(16)
    d = np.random.default_rng((1, 1)).random(16)
    assert not np.array_equal(c, d)  # tuple seeding: independent


def test_availability_streams_distinct_across_seed_round_grid():
    seen = {}
    for seed in SEEDS:
        trace = _trace(seed)
        for rnd in ROUNDS:
            up = trace.available(rnd)
            jit = trace.step_jitter(rnd)
            assert up.shape == (N_CLIENTS,)
            assert jit.shape == (N_CLIENTS,) and np.all(jit > 0)
            sig = up.tobytes() + jit.tobytes()
            assert sig not in seen, (
                f"(seed={seed}, rnd={rnd}) replays {seen[sig]}"
            )
            seen[sig] = (seed, rnd)


def test_availability_streams_are_replayable():
    for seed in SEEDS:
        for rnd in ROUNDS:
            assert np.array_equal(
                _trace(seed).available(rnd), _trace(seed).available(rnd)
            )
            assert np.array_equal(
                _trace(seed).step_jitter(rnd), _trace(seed).step_jitter(rnd)
            )


def test_availability_and_jitter_streams_independent():
    # stream=0 (availability) and stream=1 (jitter) of the same (seed, rnd)
    # must not be reinterpretations of one another: uniforms driving the
    # dropout draw differ from the normals driving the jitter draw
    trace = _trace(7)
    jit_a = trace.step_jitter(3)
    jit_b = _trace(7).step_jitter(3)
    assert np.array_equal(jit_a, jit_b)
    assert not np.array_equal(
        trace.available(3), _trace(7).available(1001)
    )


@pytest.mark.parametrize("name", ["fedavg", "fedbuff"])
def test_sample_clients_distinct_across_seed_round_grid(name):
    client_ids = list(range(200))
    seen = {}
    for seed in SEEDS:
        strat = STRATEGIES[name](fraction_fit=0.2, seed=seed)
        for rnd in ROUNDS:
            cohort = strat.sample_clients(rnd, client_ids)
            assert cohort == sorted(set(cohort))
            assert len(cohort) == 40
            again = STRATEGIES[name](
                fraction_fit=0.2, seed=seed
            ).sample_clients(rnd, client_ids)
            assert cohort == again  # replayable
            sig = tuple(cohort)
            assert sig not in seen, (
                f"(seed={seed}, rnd={rnd}) replays cohort of {seen[sig]}"
            )
            seen[sig] = (seed, rnd)


def test_sample_clients_empty_pool():
    strat = STRATEGIES["fedavg"](seed=0)
    assert strat.sample_clients(1, []) == []

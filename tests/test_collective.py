"""Compressed collectives + mesh-sharded client state (ISSUE-10).

- ``CompressedPsum`` wire kernels: ref vs Pallas-interpret parity, and the
  exact-summability identity the shared pre-pmax'd scale buys
  (``unpack(sum_d pack(x_d)) == sum_d unpack(pack(x_d))``);
- mesh round engine: ``collective="fp32"`` (the default) takes the exact
  pre-PR psum path; ``collective="int8"`` tracks it within tolerance with
  a bounded (telescoping) per-device error-feedback residual, and a masked
  device's residual row carries bitwise unchanged;
- sharded client state: ``shard_client_state`` / ``CohortState(shardings=)``
  move placement only — gathered values stay bitwise identical to the
  unsharded layout for flat and segmented (Int8/TopK/LoRA) codecs, through
  an eviction round, with per-device addressable bytes ~1/n_devices;
- ``CostModel`` collective accounting: >=3.9x int8-vs-fp32 per-hop byte
  reduction, per-tier sums, and the ``round_comm_bytes`` regression (mesh
  rounds now bill the psum traffic the old accounting silently omitted).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CohortState, CompressedPsum, FedAvg, Int8Codec, LoRACodec, NullCodec,
    RoundSpec, SegmentMap, TopKCodec, init_collective_residual,
    make_round_step,
)
from repro.core.compression import fp32_collective_bytes
from repro.core.cost_model import CostModel, DeviceProfile
from repro.kernels import ops, ref
from repro.launch.mesh import collective_tiers
from repro.models import build_model
from repro.models.sharding import (
    ShardRules, client_state_shardings, shard_client_state,
)
from repro.optim import sgd
from repro.utils.pytree import tree_size

C, STEPS, B = 4, 2, 16


# ---------------- wire kernels ----------------
def _scales(x, block=256):
    am = jnp.max(jnp.abs(x).reshape(-1, block), axis=1)
    return jnp.where(am == 0.0, 1.0, am / 127.0)


def test_collective_pack_unpack_ref_vs_interpret():
    x = jax.random.normal(jax.random.key(0), (8192,), jnp.float32)
    s = _scales(x)
    q_ref = ref.collective_pack(x, s)
    q_pal = ops.collective_pack(x, s, interpret=True)
    np.testing.assert_array_equal(np.asarray(q_ref), np.asarray(q_pal))
    assert q_ref.dtype == jnp.int32
    assert int(jnp.max(jnp.abs(q_ref))) <= 127
    d_ref = ref.collective_unpack(q_ref, s)
    d_pal = ops.collective_unpack(q_ref, s, interpret=True)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_pal))


def test_collective_quant_exactly_summable():
    """Shared scale grid => the accumulation is EXACT in the int domain
    (the int32 psum loses nothing; sum-then-dequant == dequant-then-sum up
    to ONE final fp32 rounding per element, instead of one per hop)."""
    key = jax.random.key(1)
    xs = jax.random.normal(key, (8, 4096), jnp.float32)
    s = _scales(jnp.max(jnp.abs(xs), axis=0).reshape(-1))  # pmax stand-in
    qs = [np.asarray(ref.collective_pack(x, s)) for x in xs]
    q_sum = sum(q.astype(np.int64) for q in qs)
    assert np.abs(q_sum).max() <= 8 * 127  # overflow bound: fan-in * 127
    np.testing.assert_array_equal(  # int32 accumulator == exact int sum
        np.asarray(sum(jnp.asarray(q) for q in qs)), q_sum.astype(np.int32)
    )
    summed_then_unpacked = ref.collective_unpack(jnp.asarray(q_sum), s)
    unpacked_then_summed = sum(ref.collective_unpack(jnp.asarray(q), s)
                               for q in qs)
    np.testing.assert_allclose(  # same value, one fp32 rounding apart
        np.asarray(summed_then_unpacked), np.asarray(unpacked_then_summed),
        rtol=0, atol=float(jnp.max(s)) * 1e-4,
    )


def test_collective_roundtrip_error_bounded_by_scale():
    x = jax.random.normal(jax.random.key(2), (4096,), jnp.float32)
    s = _scales(x)
    back = ref.collective_unpack(ref.collective_pack(x, s), s)
    err = jnp.abs(back - x).reshape(-1, 256)
    assert bool(jnp.all(err <= 0.5 * s[:, None] + 1e-7))


# ---------------- mesh round engine ----------------
def _setup(seed=0):
    m = build_model("mobilenet-head-office31")
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 1.0, size=(m.cfg.num_classes, m.cfg.feature_dim))

    def batch_of(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, m.cfg.num_classes, n)
        x = centers[y] + 0.4 * r.normal(size=(n, m.cfg.feature_dim))
        return x.astype(np.float32), y.astype(np.int32)

    xs, ys = zip(*[batch_of(STEPS * B, 100 + c) for c in range(C)])
    train = {
        "x": jnp.asarray(np.stack(xs).reshape(C, STEPS, B, -1)),
        "y": jnp.asarray(np.stack(ys).reshape(C, STEPS, B)),
    }
    ex, ey = batch_of(512, 999)
    eval_batch = {"x": jnp.asarray(ex), "y": jnp.asarray(ey)}
    params = m.init(jax.random.key(seed))
    return m, params, train, eval_batch


def _client_mesh():
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 host devices (see conftest.py)")
    return jax.make_mesh((2, 2), ("pod", "data")), ("pod", "data")


def _mesh_run(m, params, train, eval_batch, spec, mesh, axes, rounds=12,
              masks=None):
    strat = FedAvg()
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat, spec, mesh=mesh, client_axes=axes,
    ))
    w = jnp.ones(C)
    bud = jnp.full((C,), STEPS, jnp.int32)
    state = strat.init_state(params)
    cstate = spec.codec.init_client_state(C, tree_size(params))
    if spec.collective == "int8":
        cstate = (cstate, init_collective_residual(params, C))
    p = params
    coll_norms = []
    for rnd in range(rounds):
        args = (p, state, cstate, train, w, bud, rnd)
        if masks is not None:
            args = args + (masks[rnd],)
        p, state, cstate, met = rs(*args)
        if "collective_residual_norm_mean" in met:
            coll_norms.append(float(met["collective_residual_norm_mean"]))
    loss, _ = m.loss_fn(p, eval_batch)
    return float(loss), p, cstate, coll_norms


def test_fp32_collective_is_the_default_and_unchanged_contract():
    """Default spec takes the pre-PR path: plain codec state (no residual
    tuple), no collective metrics, bitwise equal to an explicit "fp32"."""
    m, params, train, eval_batch = _setup()
    mesh, axes = _client_mesh()
    codec = Int8Codec()
    assert RoundSpec(max_steps=1, execution_mode="parallel").collective == "fp32"
    sp_def = RoundSpec(max_steps=STEPS, execution_mode="parallel", codec=codec)
    sp_exp = RoundSpec(max_steps=STEPS, execution_mode="parallel", codec=codec,
                       collective="fp32")
    l1, p1, cs1, n1 = _mesh_run(m, params, train, eval_batch, sp_def, mesh,
                                axes, rounds=3)
    l2, p2, cs2, n2 = _mesh_run(m, params, train, eval_batch, sp_exp, mesh,
                                axes, rounds=3)
    assert l1 == l2 and n1 == [] and n2 == []
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cs1.shape == (C, tree_size(params))  # plain block, not a tuple


def test_int8_collective_tracks_fp32_with_bounded_residual():
    m, params, train, eval_batch = _setup()
    mesh, axes = _client_mesh()
    codec = Int8Codec()
    sp_fp = RoundSpec(max_steps=STEPS, execution_mode="parallel", codec=codec)
    sp_i8 = RoundSpec(max_steps=STEPS, execution_mode="parallel", codec=codec,
                      collective="int8")
    l_fp, _, _, _ = _mesh_run(m, params, train, eval_batch, sp_fp, mesh, axes)
    l_i8, _, cstate, norms = _mesh_run(
        m, params, train, eval_batch, sp_i8, mesh, axes
    )
    assert l_i8 == pytest.approx(l_fp, rel=5e-2)
    # collective error feedback telescopes: the residual stays bounded (on
    # the order of one block-scale quantum), never grows with rounds
    assert len(norms) == 12 and norms[-1] <= 3.0 * max(norms[0], 1e-6)
    codec_state, resid = cstate
    assert codec_state.shape == (C, tree_size(params))
    assert {l.shape[0] for l in jax.tree.leaves(resid)} == {C}


def test_int8_collective_null_codec_also_works():
    """The collective composes with an uncompressed uplink (NullCodec)."""
    m, params, train, eval_batch = _setup()
    mesh, axes = _client_mesh()
    sp_fp = RoundSpec(max_steps=STEPS, execution_mode="parallel",
                      codec=NullCodec())
    sp_i8 = RoundSpec(max_steps=STEPS, execution_mode="parallel",
                      codec=NullCodec(), collective="int8")
    l_fp, _, _, _ = _mesh_run(m, params, train, eval_batch, sp_fp, mesh, axes,
                              rounds=6)
    l_i8, _, _, norms = _mesh_run(m, params, train, eval_batch, sp_i8, mesh,
                                  axes, rounds=6)
    assert l_i8 == pytest.approx(l_fp, rel=5e-2)
    assert norms and all(n >= 0.0 for n in norms)


def test_int8_collective_masked_residual_carries_unchanged():
    m, params, train, eval_batch = _setup()
    mesh, axes = _client_mesh()
    spec = RoundSpec(max_steps=STEPS, execution_mode="parallel",
                     codec=Int8Codec(), collective="int8")
    masks = [jnp.ones((C,)), jnp.asarray([0.0, 1.0, 1.0, 1.0])]
    # round 1 (all live) seeds every residual row; round 2 masks client 0
    _, _, cs1, _ = _mesh_run(m, params, train, eval_batch, spec, mesh, axes,
                             rounds=1, masks=masks[:1])
    _, _, cs2, _ = _mesh_run(m, params, train, eval_batch, spec, mesh, axes,
                             rounds=2, masks=masks)
    r1, r2 = jax.tree.leaves(cs1[1]), jax.tree.leaves(cs2[1])
    changed = False
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(  # masked: carried bitwise
            np.asarray(a[0]), np.asarray(b[0])
        )
        changed = changed or not np.array_equal(np.asarray(a[1]),
                                                np.asarray(b[1]))
    assert changed  # live rows DID update


def test_collective_validation_errors():
    m, params, _, _ = _setup()
    with pytest.raises(ValueError, match="fp32 | int8"):
        make_round_step(
            m.loss_fn, sgd(0.1), FedAvg(),
            RoundSpec(max_steps=1, execution_mode="parallel", collective="int4"),
        )
    with pytest.raises(NotImplementedError, match="mesh"):
        make_round_step(  # int8 without a mesh: nothing to compress
            m.loss_fn, sgd(0.1), FedAvg(),
            RoundSpec(max_steps=1, execution_mode="parallel", collective="int8"),
        )


# ---------------- sharded client state ----------------
def _fsdp_mesh_rules():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices (see conftest.py)")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = ShardRules(mode="fsdp", axis_sizes=(("data", 4), ("model", 2)))
    return mesh, rules


def _seg_tree():
    # sizes divisible by 8 shard; the odd bias replicates (spec drops axes)
    return {
        "w1": jnp.zeros((64, 16)),
        "b1": jnp.zeros((9,)),
        "w2": jnp.zeros((16, 8)),
    }


@pytest.mark.parametrize("codec_fn", [
    lambda segs: Int8Codec().with_segments(segs),
    lambda segs: TopKCodec(frac=0.25).with_segments(segs),
    lambda segs: LoRACodec(rank=2).with_segments(segs),
], ids=["int8", "topk", "lora"])
def test_shard_client_state_bitwise_segmented(codec_fn):
    mesh, rules = _fsdp_mesh_rules()
    segs = SegmentMap.from_tree(_seg_tree())
    codec = codec_fn(segs)
    state = codec.init_client_state(C, segs.n_params)
    rng = np.random.default_rng(3)
    state = tuple(
        jnp.asarray(rng.normal(size=x.shape).astype(np.float32))
        if hasattr(x, "shape") else x
        for x in state
    )
    sharded = shard_client_state(state, mesh, rules, segments=segs)
    for a, b, seg in zip(state, sharded, segs):
        if not hasattr(a, "shape"):
            assert b == ()
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        if seg.size % 8 == 0:  # param dim sharded: ~1/n_dev resident bytes
            assert b.addressable_shards[0].data.nbytes == a.nbytes // 8


def test_shard_client_state_flat_block():
    mesh, rules = _fsdp_mesh_rules()
    rng = np.random.default_rng(4)
    block = jnp.asarray(rng.normal(size=(C, 1024)).astype(np.float32))
    sharded = shard_client_state(block, mesh, rules)
    np.testing.assert_array_equal(np.asarray(block), np.asarray(sharded))
    assert sharded.addressable_shards[0].data.nbytes == block.nbytes // 8
    assert sharded.addressable_shards[0].data.shape == (C, 1024 // 8)


def test_cohort_state_sharded_gather_bitwise_with_eviction():
    mesh, rules = _fsdp_mesh_rules()
    segs = SegmentMap.from_tree(_seg_tree())
    codec = Int8Codec().with_segments(segs)
    shardings = client_state_shardings(mesh, rules, segs)
    plain = CohortState(codec, segs.n_params, capacity=2)
    sharded = CohortState(codec, segs.n_params, capacity=2,
                          shardings=shardings)
    rng = np.random.default_rng(5)
    for cid in (1, 2, 3):  # capacity 2: cid 1 evicted (residual reset to 0)
        row = tuple(rng.normal(size=(seg.size,)).astype(np.float32)
                    for seg in segs)
        plain.put_row(cid, row)
        sharded.put_row(cid, row)
    assert plain.evictions == sharded.evictions == 1
    ids = [1, 2, 3]
    g_plain, g_sharded = plain.gather(ids), sharded.gather(ids)
    for a, b, seg in zip(g_plain, g_sharded, segs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.asarray(b)[0].any()  # evicted row zeros, sharded too
        if seg.size % 8 == 0:
            assert b.addressable_shards[0].data.nbytes == a.nbytes // 8
    # scatter accepts the sharded blocks straight back
    sharded.scatter(ids, g_sharded)
    for a, b in zip(plain.gather(ids), sharded.gather(ids)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------- cost model accounting ----------------
def _cm(**kw):
    return CostModel(
        profiles=[DeviceProfile("d", step_time_s=0.1, active_power_w=5.0)],
        update_bytes=4 * 10_000, **kw,
    )


def test_collective_bytes_ratio_and_tiers():
    tiers = (("pod", 2), ("data", 16))
    n = 10_000
    fp = _cm(mesh_tiers=tiers)
    i8 = _cm(mesh_tiers=tiers, collective="int8")
    assert fp.collective_bytes(n) / i8.collective_bytes(n) >= 3.9
    for cm in (fp, i8):
        by = cm.collective_bytes_by_tier(n)
        assert set(by) == {"pod", "data"}
        assert sum(by.values()) == cm.collective_bytes(n)
        # outer tier reduces once over 2 pods; inner runs 2 groups of 16
        per_hop = cm._per_device_hop_bytes(n)
        assert by["pod"] == 2 * (2 - 1) * per_hop
        assert by["data"] == 2 * 2 * (16 - 1) * per_hop
    # the formula the model bills is the codec's own
    assert i8._per_device_hop_bytes(n) == CompressedPsum().collective_bytes(n)
    assert fp._per_device_hop_bytes(n) == fp32_collective_bytes(n)


def test_round_comm_bytes_mesh_vs_vmap_regression():
    """The mesh path's psum traffic is billed; the vmap path is unchanged."""
    n_clients, n = 8, 10_000
    vmap_cm = _cm()  # no mesh: exact pre-PR accounting
    assert vmap_cm.collective_bytes(n) == 0
    assert vmap_cm.round_comm_bytes(n_clients) == n_clients * 2 * 4 * n
    mesh_cm = _cm(mesh_tiers=(("pod", 2), ("data", 4)), collective="int8")
    got = mesh_cm.round_comm_bytes(n_clients, n_elems=n)
    assert got == n_clients * 2 * 4 * n + mesh_cm.collective_bytes(n)
    assert got > vmap_cm.round_comm_bytes(n_clients)  # was silently equal


def test_collective_tiers_from_mesh():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    assert collective_tiers(mesh, ("pod", "data")) == (("pod", 2), ("data", 2))
    with pytest.raises(ValueError, match="not on mesh"):
        collective_tiers(mesh, ("rack",))


def test_compressed_psum_byte_formula():
    cp = CompressedPsum(block=256)
    n = 7050
    assert cp.collective_bytes(n) == n + 4 * ((n + 255) // 256) + 4
    assert fp32_collective_bytes(n) == 4 * n + 4
    assert fp32_collective_bytes(n) / cp.collective_bytes(n) >= 3.9

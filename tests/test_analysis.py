"""fedlint: per-rule fixture tests, CLI contract, and the repo-tree gate.

Every rule gets one fixture proving it fires (with the exact finding set)
and one proving it stays silent on the idiomatic version of the same code.
The fire fixtures double as regressions for the true positives this pass
found in-tree (launch/train.py affine seeding, kernels without a declared
VMEM budget).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import run
from repro.analysis.__main__ import main
from repro.analysis.core import Finding, load_baseline, split_baseline
from repro.analysis.rules import ALL_RULES, RULES_BY_NAME

REPO = Path(__file__).resolve().parents[1]
FIX = "tests/analysis_fixtures"


@pytest.fixture(autouse=True)
def _repo_root(monkeypatch):
    # Finding.path (and so Finding.key) is relative to the cwd; pin it.
    monkeypatch.chdir(REPO)


def _findings(rule, *names):
    out = run([f"{FIX}/{n}" for n in names], rules=[RULES_BY_NAME[rule]])
    assert all(f.rule == rule for f in out)
    return {(f.func, f.code) for f in out}


FIRE = {
    "jit-host-sync": (("jit_bad.py",), {
        ("<module>", "module-scope-device-call"),
        ("helper", "py-cast"),
        ("stats", "np-call"),
        ("make_round_step.round_step", "print"),
        ("make_round_step.round_step", "item"),
        ("make_round_step.round_step", "block-until-ready"),
    }),
    "rng-discipline": (("rng_bad.py",), {
        ("round_batches", "additive-seed"),
        ("round_batches", "round-only-seed"),
        ("batch_call", "additive-seed"),
        ("reuse", "key-reuse"),
    }),
    "recompile-hazard": (("recompile_bad.py",), {
        ("kernel", "unknown-static"),
        ("step", "unhashable-static"),
        ("driver", "py-scalar-arg"),
        ("kernel", "varying-shape"),
        ("driver", "container-arg"),
        ("cohort_step", "varying-shape"),
    }),
    "pallas-vmem-budget": (("vmem_missing.py", "vmem_over.py"), {
        ("<module>", "missing-budget"),
        ("over_budget", "vmem-over-budget"),
        ("unresolved", "unresolved-block-shape"),
    }),
    "mask-nan-safety": (("mask_bad.py",), {
        ("masked_metrics", "unmasked-sum"),
        ("masked_metrics", "unmasked-max"),
    }),
    "wire-accounting": (("wire_bad.py", "collective_bad.py"), {
        ("EveryOtherCodec", "wire-bytes-not-overridden"),
        ("SparseSegmentCodec", "segment-wire-bytes-not-overridden"),
        ("QuantizedAllReduce", "collective-bytes-not-stated"),
    }),
}

SILENT = {
    "jit-host-sync": ("jit_clean.py",),
    "rng-discipline": ("rng_clean.py",),
    "recompile-hazard": ("recompile_clean.py",),
    "pallas-vmem-budget": ("vmem_clean.py",),
    "mask-nan-safety": ("mask_clean.py",),
    "wire-accounting": ("wire_clean.py", "collective_clean.py"),
}


@pytest.mark.parametrize("rule", sorted(FIRE))
def test_rule_fires_with_exact_finding_set(rule):
    names, expected = FIRE[rule]
    assert _findings(rule, *names) == expected


@pytest.mark.parametrize("rule", sorted(SILENT))
def test_rule_silent_on_idiomatic_code(rule):
    assert _findings(rule, *SILENT[rule]) == set()


def test_every_rule_has_fixture_coverage():
    assert {r.NAME for r in ALL_RULES} == set(FIRE) == set(SILENT)


def test_fallback_rule_flags_refless_dispatch():
    got = _findings(
        "pallas-vmem-budget", "vmem_clean.py", "vmem_dispatch_bad.py"
    )
    assert got == {("<module>", "no-oracle-fallback")}


def test_fallback_rule_accepts_ref_covered_dispatch():
    got = _findings(
        "pallas-vmem-budget", "vmem_clean.py", "vmem_dispatch_ok.py"
    )
    assert got == set()


# ---------------------------------------------------------------- baseline


def test_finding_key_is_line_independent():
    a = Finding("r", "p.py", 10, "f", "c", "m")
    b = Finding("r", "p.py", 99, "f", "c", "m")
    assert a.key == b.key == "r:p.py:f:c"


def test_repo_tree_clean_modulo_baseline():
    """The acceptance gate: src/repro has no findings outside the committed
    baseline, the baseline is small, justified, and not stale."""
    findings = run(["src/repro"])
    baseline = load_baseline("fedlint_baseline.json")
    active, suppressed, stale = split_baseline(findings, baseline)
    assert not active, [f.key for f in active]
    assert not stale, stale
    assert len(baseline) <= 5
    for key, reason in baseline.items():
        assert reason and "TODO" not in reason, key


def test_baseline_never_grows():
    """ISSUE-8 re-audit emptied the baseline (all four PR-6 suppressions
    were fixable: static partitions became pure-python index lists,
    np.prod(shape) became math.prod, int() became math.floor).  The
    suppression count is a RATCHET — it only goes down.  Adding an entry
    means either fixing the finding instead, or a reviewed decision that
    raises this pin in the same commit."""
    with open("fedlint_baseline.json") as f:
        raw = json.load(f)
    assert len(raw["suppressions"]) <= 0, (
        "fedlint_baseline.json grew - fix the finding instead of "
        "suppressing it (or raise this ratchet with a reviewed reason)"
    )


# --------------------------------------------------------------------- CLI


def test_cli_exit_codes():
    assert main([f"{FIX}/rng_clean.py", "--no-baseline"]) == 0
    assert main([f"{FIX}/rng_bad.py", "--no-baseline"]) == 1
    assert main(["definitely/not/here.py"]) == 3


def test_cli_rule_filter():
    # mask_bad only trips mask-nan-safety; filtering to another rule is clean
    assert main([f"{FIX}/mask_bad.py", "--no-baseline",
                 "--rule", "wire-accounting"]) == 0
    assert main([f"{FIX}/mask_bad.py", "--no-baseline",
                 "--rule", "mask-nan-safety"]) == 1


def test_cli_stale_baseline_only_fails_under_check(tmp_path):
    bl = tmp_path / "bl.json"
    bl.write_text(json.dumps({"suppressions": [
        {"key": "gone:rule:entry:x", "reason": "stale on purpose"}
    ]}))
    args = [f"{FIX}/rng_clean.py", "--baseline", str(bl)]
    assert main(args) == 0
    assert main(args + ["--check-baseline"]) == 2


def test_cli_json_report(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = main([f"{FIX}/mask_bad.py", "--no-baseline",
               "--format", "json", "--out", str(out)])
    assert rc == 1
    report = json.loads(out.read_text())
    assert report["counts"] == {
        "active": 2, "suppressed": 0, "stale_baseline": 0,
    }
    assert {f["code"] for f in report["findings"]} == {
        "unmasked-sum", "unmasked-max",
    }
    assert json.loads(capsys.readouterr().out) == report


# ------------------------------------------------------------ import hygiene


def _py(code):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", code], cwd=REPO, env=env,
                          capture_output=True, text=True)


def test_analysis_package_never_imports_jax():
    # fedlint must run on boxes (and CI stages) with no accelerator stack
    r = _py("import sys, repro.analysis, repro.analysis.__main__; "
            "assert 'jax' not in sys.modules")
    assert r.returncode == 0, r.stderr


def test_kernels_package_import_is_lazy():
    # pytest collection must not drag Pallas kernels (and thus a backend)
    # in at module scope; submodules load on first attribute access only
    r = _py(
        "import sys, repro.kernels; "
        "assert 'repro.kernels.scatter_reduce' not in sys.modules; "
        "assert 'jax' not in sys.modules, 'kernels __init__ imported jax'; "
        "sr = repro.kernels.scatter_reduce; "
        "assert sr.MAX_N_PARAMS <= sr.VMEM_BUDGET_ELEMS; "
        "import repro.kernels.ops"
    )
    assert r.returncode == 0, r.stderr

"""Unified codec-carrying round engine, end-to-end (core/rounds.py).

Asserts the ISSUE-2 acceptance criteria on the synthetic head-model task:
- ONE round_step signature across parallel / mesh shard_map / sequential:
  (global, server_state, client_state, batches, weights, budgets, rnd)
  -> (global, server_state, client_state, metrics), with the client state
  owned by the codec (empty for NullCodec);
- the Int8 compressed path converges to within rtol=5e-2 of the NullCodec
  baseline on final eval loss over 20 rounds on ALL THREE paths (the mesh
  path runs on a real multi-device host-platform mesh, see conftest.py);
- TopK with error feedback also tracks the baseline (looser tol — it
  transmits a fraction of the mass per round);
- accumulated error-feedback residuals stay bounded (no blow-up);
- batch codec roundtrips agree with the 1-D codec surface.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedAvg, Int8Codec, NullCodec, RoundSpec, TopKCodec, make_round_step,
)
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_size

C, STEPS, B, ROUNDS = 4, 2, 16, 20


def _setup(seed=0):
    m = build_model("mobilenet-head-office31")
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 1.0, size=(m.cfg.num_classes, m.cfg.feature_dim))

    def batch_of(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, m.cfg.num_classes, n)
        x = centers[y] + 0.4 * r.normal(size=(n, m.cfg.feature_dim))
        return x.astype(np.float32), y.astype(np.int32)

    xs, ys = zip(*[batch_of(STEPS * B, 100 + c) for c in range(C)])
    train = {
        "x": jnp.asarray(np.stack(xs).reshape(C, STEPS, B, -1)),
        "y": jnp.asarray(np.stack(ys).reshape(C, STEPS, B)),
    }
    ex, ey = batch_of(512, 999)
    eval_batch = {"x": jnp.asarray(ex), "y": jnp.asarray(ey)}
    params = m.init(jax.random.key(seed))
    return m, params, train, eval_batch


def _client_mesh():
    """A 2x2 ("pod", "data") mesh: 4 clients, hierarchical cross-pod psum."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 host devices (see conftest.py)")
    return jax.make_mesh((2, 2), ("pod", "data")), ("pod", "data")


def _run(m, params, train, eval_batch, codec, mode="parallel", mesh=None,
         client_axes=("data",), rounds=ROUNDS):
    strat = FedAvg()
    spec = RoundSpec(max_steps=STEPS, execution_mode=mode, codec=codec)
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat, spec, mesh=mesh, client_axes=client_axes,
    ))
    w = jnp.ones(C)
    bud = jnp.full((C,), STEPS, jnp.int32)
    state = strat.init_state(params)
    cstate = codec.init_client_state(C, tree_size(params))
    p = params
    res_norms = []
    for rnd in range(rounds):
        p, state, cstate, met = rs(p, state, cstate, train, w, bud, rnd)
        if "residual_norm_mean" in met:
            res_norms.append(float(met["residual_norm_mean"]))
    loss, _ = m.loss_fn(p, eval_batch)
    return float(loss), res_norms


# ---------------- the uniform contract ----------------
def test_client_state_is_codec_owned():
    m, params, _, _ = _setup()
    n = tree_size(params)
    assert NullCodec().init_client_state(C, n) == ()
    res = Int8Codec().init_client_state(C, n)
    assert res.shape == (C, n) and res.dtype == jnp.float32
    assert not np.asarray(res).any()


@pytest.mark.parametrize("mode", ["parallel", "sequential"])
def test_round_step_uniform_signature(mode):
    """Same 7-arg/4-tuple contract whether or not anything is compressed."""
    m, params, train, _ = _setup()
    n = tree_size(params)
    for codec in (NullCodec(), Int8Codec()):
        spec = RoundSpec(max_steps=STEPS, execution_mode=mode, codec=codec)
        rs = jax.jit(make_round_step(m.loss_fn, sgd(0.1), FedAvg(), spec))
        cstate = codec.init_client_state(C, n)
        p, sstate, new_cstate, met = rs(
            params, (), cstate, train, jnp.ones(C),
            jnp.full((C,), STEPS, jnp.int32), 0,
        )
        assert jax.tree.structure(p) == jax.tree.structure(params)
        assert jax.tree.structure(new_cstate) == jax.tree.structure(cstate)
        if jax.tree.leaves(cstate):
            assert new_cstate.shape == (C, n)
            assert float(met["residual_norm_mean"]) >= 0.0
        assert {"client_loss_mean", "client_loss_max", "steps_total"} <= set(met)


def test_default_codec_is_null():
    assert isinstance(RoundSpec(max_steps=1, execution_mode="parallel").codec,
                      NullCodec)


# ---------------- parallel (vmap) path ----------------
def test_int8_round_path_converges_like_uncompressed():
    """ISSUE acceptance: Int8 final eval loss within rtol=5e-2 over 20 rounds."""
    m, params, train, eval_batch = _setup()
    base, base_norms = _run(m, params, train, eval_batch, NullCodec())
    assert base_norms == []  # NullCodec carries no residual state at all
    int8, res_norms = _run(m, params, train, eval_batch, Int8Codec())
    assert int8 == pytest.approx(base, rel=5e-2)
    # error feedback keeps the residual bounded (quantization error scale)
    assert res_norms[-1] < 10 * (res_norms[0] + 1e-9)
    assert max(res_norms) < 1.0


def test_topk_error_feedback_converges_and_residual_bounded():
    m, params, train, eval_batch = _setup()
    base, _ = _run(m, params, train, eval_batch, NullCodec())
    topk, res_norms = _run(m, params, train, eval_batch, TopKCodec(frac=0.25))
    # sparsified wire still reaches the neighborhood of the dense optimum
    assert topk == pytest.approx(base, rel=0.25)
    # residual does not blow up: later rounds stay within a constant factor
    # of the early-round residual scale
    assert res_norms[-1] < 5 * max(res_norms[:5])


# ---------------- mesh shard_map path ----------------
def test_mesh_path_null_codec_matches_vmap_path():
    m, params, train, eval_batch = _setup()
    mesh, axes = _client_mesh()
    base, _ = _run(m, params, train, eval_batch, NullCodec(), rounds=3)
    meshed, _ = _run(m, params, train, eval_batch, NullCodec(),
                     mesh=mesh, client_axes=axes, rounds=3)
    assert meshed == pytest.approx(base, rel=1e-3)


def test_int8_mesh_path_converges_like_uncompressed():
    """ISSUE acceptance: codec on the shard_map path (encode before the
    hierarchical cross-pod psum), within 5% of NullCodec over 20 rounds."""
    m, params, train, eval_batch = _setup()
    mesh, axes = _client_mesh()
    base, _ = _run(m, params, train, eval_batch, NullCodec(),
                   mesh=mesh, client_axes=axes)
    int8, res_norms = _run(m, params, train, eval_batch, Int8Codec(),
                           mesh=mesh, client_axes=axes)
    assert int8 == pytest.approx(base, rel=5e-2)
    assert res_norms and max(res_norms) < 1.0


def test_client_loss_mean_weighted_on_every_mode():
    """Same round, same metric: client_loss_mean is the examples-weighted
    mean on the vmap, mesh shard_map, and sequential paths alike (the vmap
    and mesh paths used to report an unweighted jnp.mean)."""
    m, params, train, _ = _setup()
    mesh, axes = _client_mesh()
    w = jnp.asarray([1.0, 4.0, 0.25, 2.0])  # non-uniform: unweighted differs
    bud = jnp.full((C,), STEPS, jnp.int32)
    means = {}
    for label, kw in (
        ("parallel", {}),
        ("mesh", {"mesh": mesh, "client_axes": axes}),
        ("sequential", {}),
    ):
        mode = "sequential" if label == "sequential" else "parallel"
        spec = RoundSpec(max_steps=STEPS, execution_mode=mode, codec=NullCodec())
        rs = jax.jit(make_round_step(m.loss_fn, sgd(0.1), FedAvg(), spec, **kw))
        _, _, _, met = rs(params, (), (), train, w, bud, 0)
        means[label] = float(met["client_loss_mean"])
    assert means["mesh"] == pytest.approx(means["parallel"], rel=1e-4)
    assert means["sequential"] == pytest.approx(means["parallel"], rel=1e-4)


# ---------------- sequential scan path ----------------
def test_int8_sequential_path_converges_like_uncompressed():
    """ISSUE acceptance: codec through the sequential scan (per-client state
    rows scanned alongside), within 5% of NullCodec over 20 rounds."""
    m, params, train, eval_batch = _setup()
    base, _ = _run(m, params, train, eval_batch, NullCodec(), mode="sequential")
    int8, res_norms = _run(m, params, train, eval_batch, Int8Codec(),
                           mode="sequential")
    assert int8 == pytest.approx(base, rel=5e-2)
    assert res_norms and max(res_norms) < 1.0


def test_sequential_residual_rows_track_clients():
    """The scanned state rows land back in per-client order: round 2 of a
    sequential run equals round 2 of a parallel run (same codec state)."""
    m, params, train, eval_batch = _setup()
    outs = {}
    for mode in ("parallel", "sequential"):
        outs[mode], _ = _run(m, params, train, eval_batch, Int8Codec(),
                             mode=mode, rounds=2)
    assert outs["sequential"] == pytest.approx(outs["parallel"], rel=1e-2)


# ---------------- codec surfaces ----------------
@pytest.mark.parametrize("codec", [Int8Codec(), TopKCodec(frac=0.1), NullCodec()])
def test_batch_codec_agrees_with_vector_codec(codec):
    rng = np.random.default_rng(3)
    deltas = jnp.asarray(rng.normal(size=(3, 700)) * 0.01, jnp.float32)
    enc_b = codec.encode_batch(deltas)
    dec_b = codec.decode_batch(enc_b)
    assert dec_b.shape == deltas.shape
    for i in range(3):
        dec_1 = codec.decode(codec.encode(deltas[i]))
        np.testing.assert_allclose(
            np.asarray(dec_b[i]), np.asarray(dec_1), atol=1e-6, rtol=1e-6
        )
    # reduce == fedavg_reduce over the decoded rows
    w = jnp.asarray(rng.random(3) + 0.1, jnp.float32)
    red = codec.reduce(enc_b, w)
    exp = jnp.einsum("c,cn->n", w, dec_b) / jnp.sum(w)
    np.testing.assert_allclose(np.asarray(red), np.asarray(exp), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("codec", [Int8Codec(), TopKCodec(frac=0.1)])
def test_transmit_tree_matches_encode_decode(codec):
    rng = np.random.default_rng(7)
    delta = {"a": jnp.asarray(rng.normal(size=(40, 8)) * 0.01, jnp.float32),
             "b": jnp.asarray(rng.normal(size=(13,)) * 0.01, jnp.float32)}
    n = 40 * 8 + 13
    state = jnp.zeros((n,), jnp.float32)
    dec_tree, new_state = codec.transmit_tree(delta, state)
    from repro.utils.pytree import tree_flatten_to_vector
    vec = tree_flatten_to_vector(delta)
    dec_vec = codec.decode(codec.encode(vec))
    np.testing.assert_allclose(
        np.asarray(tree_flatten_to_vector(dec_tree)), np.asarray(dec_vec),
        atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(new_state), np.asarray(vec - dec_vec), atol=1e-6
    )


def test_null_transmit_tree_is_identity():
    delta = {"a": jnp.ones((4, 4), jnp.bfloat16)}
    out, state = NullCodec().transmit_tree(delta, ())
    assert out is delta and state == ()

"""Compressed-wire round engine, end-to-end (core/rounds.py + compression).

Asserts the ISSUE-1 acceptance criteria on the synthetic head-model task:
- the Int8 compressed parallel round path converges to within rtol=5e-2 of
  the uncompressed path on final eval loss over 20 rounds;
- TopK with error feedback also tracks the uncompressed path (looser tol —
  it transmits a fraction of the mass per round);
- accumulated error-feedback residuals stay bounded (no blow-up across
  rounds);
- batch codec roundtrips agree with the 1-D codec surface.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedAvg, Int8Codec, NullCodec, RoundSpec, TopKCodec,
    init_residuals, make_round_step,
)
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_size

C, STEPS, B, ROUNDS = 4, 2, 16, 20


def _setup(seed=0):
    m = build_model("mobilenet-head-office31")
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 1.0, size=(m.cfg.num_classes, m.cfg.feature_dim))

    def batch_of(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, m.cfg.num_classes, n)
        x = centers[y] + 0.4 * r.normal(size=(n, m.cfg.feature_dim))
        return x.astype(np.float32), y.astype(np.int32)

    xs, ys = zip(*[batch_of(STEPS * B, 100 + c) for c in range(C)])
    train = {
        "x": jnp.asarray(np.stack(xs).reshape(C, STEPS, B, -1)),
        "y": jnp.asarray(np.stack(ys).reshape(C, STEPS, B)),
    }
    ex, ey = batch_of(512, 999)
    eval_batch = {"x": jnp.asarray(ex), "y": jnp.asarray(ey)}
    params = m.init(jax.random.key(seed))
    return m, params, train, eval_batch


def _run(m, params, train, eval_batch, codec):
    strat = FedAvg()
    spec = RoundSpec(max_steps=STEPS, execution_mode="parallel", codec=codec)
    rs = jax.jit(make_round_step(m.loss_fn, sgd(0.1), strat, spec))
    w = jnp.ones(C)
    bud = jnp.full((C,), STEPS, jnp.int32)
    state = strat.init_state(params)
    res_norms = []
    if codec is None:
        rs_plain = rs
        p = params
        for rnd in range(ROUNDS):
            p, state, _ = rs_plain(p, state, train, w, bud, rnd)
    else:
        p = params
        res = init_residuals(params, C)
        for rnd in range(ROUNDS):
            p, state, res, met = rs(p, state, res, train, w, bud, rnd)
            res_norms.append(float(met["residual_norm_mean"]))
    loss, _ = m.loss_fn(p, eval_batch)
    return float(loss), res_norms


def test_compressed_round_state_shapes():
    m, params, train, _ = _setup()
    res = init_residuals(params, C)
    assert res.shape == (C, tree_size(params))
    spec = RoundSpec(max_steps=STEPS, execution_mode="parallel", codec=Int8Codec())
    rs = jax.jit(make_round_step(m.loss_fn, sgd(0.1), FedAvg(), spec))
    p, _, new_res, met = rs(
        params, (), res, train, jnp.ones(C), jnp.full((C,), STEPS, jnp.int32), 0
    )
    assert new_res.shape == res.shape
    assert jax.tree.structure(p) == jax.tree.structure(params)
    assert float(met["residual_norm_mean"]) >= 0.0


def test_null_codec_matches_uncompressed_path():
    """The identity codec is exactly the uncompressed engine (same reduce)."""
    m, params, train, eval_batch = _setup()
    base, _ = _run(m, params, train, eval_batch, None)
    null, res_norms = _run(m, params, train, eval_batch, NullCodec())
    assert null == pytest.approx(base, rel=1e-3)
    assert max(res_norms) < 1e-4  # nothing is ever left untransmitted


def test_int8_round_path_converges_like_uncompressed():
    """ISSUE-1 acceptance: Int8 final eval loss within rtol=5e-2 over 20 rounds."""
    m, params, train, eval_batch = _setup()
    base, _ = _run(m, params, train, eval_batch, None)
    int8, res_norms = _run(m, params, train, eval_batch, Int8Codec())
    assert int8 == pytest.approx(base, rel=5e-2)
    # error feedback keeps the residual bounded (quantization error scale)
    assert res_norms[-1] < 10 * (res_norms[0] + 1e-9)
    assert max(res_norms) < 1.0


def test_topk_error_feedback_converges_and_residual_bounded():
    m, params, train, eval_batch = _setup()
    base, _ = _run(m, params, train, eval_batch, None)
    topk, res_norms = _run(m, params, train, eval_batch, TopKCodec(frac=0.25))
    # sparsified wire still reaches the neighborhood of the dense optimum
    assert topk == pytest.approx(base, rel=0.25)
    # residual does not blow up: later rounds stay within a constant factor
    # of the early-round residual scale
    assert res_norms[-1] < 5 * max(res_norms[:5])


@pytest.mark.parametrize("codec", [Int8Codec(), TopKCodec(frac=0.1), NullCodec()])
def test_batch_codec_agrees_with_vector_codec(codec):
    rng = np.random.default_rng(3)
    deltas = jnp.asarray(rng.normal(size=(3, 700)) * 0.01, jnp.float32)
    enc_b = codec.encode_batch(deltas)
    dec_b = codec.decode_batch(enc_b)
    assert dec_b.shape == deltas.shape
    for i in range(3):
        dec_1 = codec.decode(codec.encode(deltas[i]))
        np.testing.assert_allclose(
            np.asarray(dec_b[i]), np.asarray(dec_1), atol=1e-6, rtol=1e-6
        )
    # reduce == fedavg_reduce over the decoded rows
    w = jnp.asarray(rng.random(3) + 0.1, jnp.float32)
    red = codec.reduce(enc_b, w)
    exp = jnp.einsum("c,cn->n", w, dec_b) / jnp.sum(w)
    np.testing.assert_allclose(np.asarray(red), np.asarray(exp), atol=1e-5, rtol=1e-5)


def test_codec_rejects_unsupported_modes():
    m, params, _, _ = _setup()
    with pytest.raises(NotImplementedError):
        make_round_step(
            m.loss_fn, sgd(0.1), FedAvg(),
            RoundSpec(max_steps=1, execution_mode="sequential", codec=Int8Codec()),
        )

"""Per-kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.dequant_reduce import dequant_reduce
from repro.kernels.fedavg_reduce import fedavg_reduce
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quantize import dequantize_int8, quantize_int8
from repro.kernels.selective_scan import selective_scan

RNG = np.random.default_rng(0)


def _randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kv,d,window",
    [
        (1, 128, 4, 4, 64, None),      # MHA
        (2, 256, 8, 2, 64, None),      # GQA 4:1
        (1, 256, 4, 1, 128, None),     # MQA
        (2, 256, 4, 4, 64, 64),        # sliding window
        (1, 384, 6, 3, 32, 128),       # non-pow2 heads, window
    ],
)
def test_flash_attention_matches_oracle(b, s, h, kv, d, window, dtype):
    q = _randn((b, s, h, d), dtype)
    k = _randn((b, s, kv, d), dtype)
    v = _randn((b, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    exp = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,d", [(2, 256, 8, 4, 64), (1, 128, 4, 1, 128)])
def test_decode_attention_matches_oracle(b, s, h, kv, d, dtype):
    q = _randn((b, h, d), dtype)
    k = _randn((b, s, kv, d), dtype)
    v = _randn((b, s, kv, d), dtype)
    valid = jnp.asarray(RNG.random((b, s)) > 0.25)
    valid = valid.at[:, 0].set(True)  # at least one valid slot
    out = decode_attention(q, k, v, kv_valid=valid, interpret=True)
    exp = ref.decode_attention(q, k, v, kv_valid=valid)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("b,s,di,n,bd,chunk", [
    (1, 128, 64, 16, 32, 64),
    (2, 256, 128, 8, 128, 128),
])
def test_selective_scan_matches_oracle(b, s, di, n, bd, chunk):
    x = _randn((b, s, di), scale=0.5)
    dt = jax.nn.softplus(_randn((b, s, di)))
    A = -jnp.exp(_randn((di, n), scale=0.3))
    Bm = _randn((b, s, n))
    Cm = _randn((b, s, n))
    D = _randn((di,))
    y1, h1 = selective_scan(x, dt, A, Bm, Cm, D, interpret=True, bd=bd, chunk=chunk)
    y2, h2 = ref.selective_scan(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4, rtol=2e-4)


def test_selective_scan_matches_stepwise_recurrence():
    """The parallel scan equals the literal per-token recurrence."""
    b, s, di, n = 1, 64, 32, 8
    x = _randn((b, s, di), scale=0.5)
    dt = jax.nn.softplus(_randn((b, s, di)))
    A = -jnp.exp(_randn((di, n), scale=0.3))
    Bm, Cm, D = _randn((b, s, n)), _randn((b, s, n)), _randn((di,))
    y_par, h_par = ref.selective_scan(x, dt, A, Bm, Cm, D)
    h = jnp.zeros((b, di, n))
    ys = []
    for t in range(s):
        y, h = ref.selective_scan_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_par), np.stack(ys, 1), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h), atol=2e-5, rtol=2e-5)


def test_selective_scan_init_state_continuation():
    """scan(x[0:s]) == scan(x[0:m]) then scan(x[m:s], init_state)."""
    b, s, m_, di, n = 1, 128, 64, 32, 8
    x = _randn((b, s, di), scale=0.5)
    dt = jax.nn.softplus(_randn((b, s, di)))
    A = -jnp.exp(_randn((di, n), scale=0.3))
    Bm, Cm, D = _randn((b, s, n)), _randn((b, s, n)), _randn((di,))
    y_full, h_full = ref.selective_scan(x, dt, A, Bm, Cm, D)
    _, h1 = ref.selective_scan(x[:, :m_], dt[:, :m_], A, Bm[:, :m_], Cm[:, :m_], D)
    y2, h2 = ref.selective_scan(
        x[:, m_:], dt[:, m_:], A, Bm[:, m_:], Cm[:, m_:], D, init_state=h1
    )
    np.testing.assert_allclose(np.asarray(y_full[:, m_:]), np.asarray(y2), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("c,n,bn", [(4, 8192, 4096), (16, 16384, 8192), (3, 4096, 4096)])
def test_fedavg_reduce_matches_oracle(c, n, bn):
    u = _randn((c, n))
    w = jnp.asarray(RNG.random(c) + 0.1, jnp.float32)
    out = fedavg_reduce(u, w, interpret=True, bn=bn)
    exp = ref.fedavg_reduce(u, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("c,n,bn", [(4, 5000, 4096), (3, 8193, 8192), (2, 100, 64)])
def test_fedavg_reduce_tail_block(c, n, bn):
    """Regression: n % bn != 0 — the tail block must be reduced, not dropped."""
    u = _randn((c, n))
    w = jnp.asarray(RNG.random(c) + 0.1, jnp.float32)
    out = fedavg_reduce(u, w, interpret=True, bn=bn)
    exp = ref.fedavg_reduce(u, w)
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)
    # the tail specifically (the elements past the last full tile)
    np.testing.assert_allclose(
        np.asarray(out[-(n % bn):]), np.asarray(exp[-(n % bn):]), atol=2e-5, rtol=2e-5
    )


@pytest.mark.parametrize("c,n,bn", [(4, 8192, 4096), (3, 5120, 2048), (2, 2048, 2048)])
def test_dequant_reduce_matches_oracle(c, n, bn):
    """Fused dequantize+weighted-reduce == dequantize rows then fedavg_reduce."""
    x = _randn((c, n))
    q, s = ref.quantize_int8(x.reshape(-1))
    q = q.reshape(c, n)
    s = s.reshape(c, n // 256)
    w = jnp.asarray(RNG.random(c) + 0.1, jnp.float32)
    fused = dequant_reduce(q, s, w, interpret=True, bn=bn)
    exp = ref.dequant_reduce(q, s, w)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(exp), atol=2e-5, rtol=2e-5)
    # and the unfused composition agrees
    dense = jnp.stack([ref.dequantize_int8(q[i], s[i]) for i in range(c)])
    unfused = ref.fedavg_reduce(dense, w)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused), atol=2e-5, rtol=2e-5)


# ---------------- TopK scatter-accumulate reduce ----------------
from repro.kernels.scatter_reduce import topk_scatter_reduce


def _sparse_payload(c, k, n, seed, dup=False):
    rng = np.random.default_rng(seed)
    if dup and k > 1:
        # force duplicate indices within each client (they must ACCUMULATE)
        pool = rng.integers(0, n, (c, max(1, k // 2)))
        idx = pool[:, rng.integers(0, pool.shape[1], k)]
    else:
        idx = np.stack([rng.choice(n, size=k, replace=False) for _ in range(c)])
    val = rng.normal(size=(c, k)).astype(np.float32)
    w = (rng.random(c) + 0.1).astype(np.float32)
    return jnp.asarray(idx, jnp.int32), jnp.asarray(val), jnp.asarray(w)


def _dense_of(idx, val, n):
    """Densify a sparse payload with np.add.at (duplicates accumulate)."""
    c = idx.shape[0]
    dense = np.zeros((c, n), np.float32)
    for i in range(c):
        np.add.at(dense[i], np.asarray(idx[i]), np.asarray(val[i]))
    return jnp.asarray(dense)


@pytest.mark.parametrize("c,k,n", [(4, 64, 8192), (8, 10, 1000), (2, 512, 4096)])
def test_topk_scatter_reduce_matches_dense_reference(c, k, n):
    idx, val, w = _sparse_payload(c, k, n, seed=c * 1000 + k)
    out = topk_scatter_reduce(idx, val, w, n, interpret=True)
    exp = ref.fedavg_reduce(_dense_of(idx, val, n), w)
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ref.topk_scatter_reduce(idx, val, w, n)), np.asarray(exp),
        atol=1e-5, rtol=1e-5,
    )


@pytest.mark.parametrize("c,k,n", [(4, 32, 2048), (3, 7, 100)])
def test_topk_scatter_reduce_duplicate_indices_accumulate(c, k, n):
    """Duplicate indices within one client sum, exactly like np.add.at."""
    idx, val, w = _sparse_payload(c, k, n, seed=42, dup=True)
    out = topk_scatter_reduce(idx, val, w, n, interpret=True)
    exp = ref.fedavg_reduce(_dense_of(idx, val, n), w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)


def test_topk_scatter_reduce_k_zero_clients():
    """k == 0 (empty payloads) and zero-value padding rows both yield the
    contribution-free result on kernel and oracle alike."""
    n = 500
    for fn in (lambda i, v, w: topk_scatter_reduce(i, v, w, n, interpret=True),
               lambda i, v, w: ref.topk_scatter_reduce(i, v, w, n)):
        out = fn(jnp.zeros((3, 0), jnp.int32), jnp.zeros((3, 0), jnp.float32),
                 jnp.ones(3))
        assert out.shape == (n,) and not np.asarray(out).any()
    # a client padded with val=0 entries (heterogeneous k) contributes nothing
    idx, val, w = _sparse_payload(4, 16, n, seed=7)
    val = val.at[2].set(0.0)
    out = topk_scatter_reduce(idx, val, w, n, interpret=True)
    exp = ref.fedavg_reduce(_dense_of(idx, val, n), w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)


def test_topk_scatter_reduce_out_of_range_indices_dropped():
    """A corrupt wire payload (idx < 0 or >= N) must be dropped identically
    by kernel and oracle — no negative wrapping, no out-of-block write."""
    n = 256
    idx = jnp.asarray([[0, -1, n, 5, 2**30, 255]], jnp.int32)
    val = jnp.ones((1, 6), jnp.float32)
    w = jnp.ones(1)
    exp = np.zeros(n, np.float32)
    exp[[0, 5, 255]] = 1.0  # only the in-range entries land
    for out in (topk_scatter_reduce(idx, val, w, n, interpret=True),
                ref.topk_scatter_reduce(idx, val, w, n)):
        np.testing.assert_allclose(np.asarray(out), exp, atol=1e-6)


def test_topk_scatter_reduce_zero_weight_vector():
    """safe_weight_sum semantics: all-zero weights -> zeros, never NaNs."""
    idx, val, _ = _sparse_payload(4, 32, 1024, seed=3)
    out = topk_scatter_reduce(idx, val, jnp.zeros(4), 1024, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    out_ref = ref.topk_scatter_reduce(idx, val, jnp.zeros(4), 1024)
    np.testing.assert_array_equal(np.asarray(out_ref), 0.0)


@pytest.mark.parametrize("n", [100, 5000, 8193, 129])
def test_topk_scatter_reduce_tail_indices(n):
    """Regression (fedavg_reduce tail-drop class): indices in the last,
    non-lane-aligned tail of the output must land, not vanish in pad."""
    c, k = 3, 8
    rng = np.random.default_rng(n)
    idx = jnp.asarray(rng.integers(0, n, (c, k)), jnp.int32)
    idx = idx.at[:, -1].set(n - 1).at[:, 0].set(0)  # pin both boundaries
    val = jnp.asarray(rng.normal(size=(c, k)), jnp.float32)
    w = jnp.asarray(rng.random(c) + 0.1, jnp.float32)
    out = topk_scatter_reduce(idx, val, w, n, interpret=True)
    exp = ref.fedavg_reduce(_dense_of(idx, val, n), w)
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)
    assert np.asarray(out)[-1] == pytest.approx(float(exp[-1]), abs=1e-5)


def test_topk_codec_reduce_hits_scatter_kernel():
    """The codec's reduce on a REAL encoded payload == dense decode+reduce,
    on both the interpret-mode kernel and the dispatch path."""
    from repro.core.compression import TopKCodec
    from repro.kernels import ops

    codec = TopKCodec(frac=0.05)
    rng = np.random.default_rng(0)
    deltas = jnp.asarray(rng.normal(size=(6, 3000)) * 0.01, jnp.float32)
    w = jnp.asarray(rng.random(6) + 0.1, jnp.float32)
    enc = codec.encode_batch(deltas)
    exp = ref.fedavg_reduce(codec.decode_batch(enc), w)
    for out in (
        codec.reduce(enc, w),                       # dispatch (ref on CPU)
        codec.reduce(enc, w, interpret=True),       # Pallas interpret body
        ops.topk_scatter_reduce(enc["idx"], enc["val"], w, 3000),
    ):
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(1, 6), k=st.integers(1, 64), seed=st.integers(0, 1000))
def test_topk_scatter_reduce_property(c, k, seed):
    n = 2048
    idx, val, w = _sparse_payload(c, k, n, seed=seed, dup=(seed % 2 == 0))
    out = topk_scatter_reduce(idx, val, w, n, interpret=True)
    exp = ref.fedavg_reduce(_dense_of(idx, val, n), w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(2, 8),
    scale=st.floats(0.1, 10.0),
)
def test_fedavg_reduce_weight_scale_invariance(c, scale):
    """Scaling all weights by a constant must not change the mean."""
    rng = np.random.default_rng(c)
    u = jnp.asarray(rng.normal(size=(c, 2048)), jnp.float32)
    w = jnp.asarray(rng.random(c) + 0.5, jnp.float32)
    a = fedavg_reduce(u, w, interpret=True, bn=2048)
    b = fedavg_reduce(u, w * scale, interpret=True, bn=2048)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_quantize_roundtrip_matches_oracle():
    x = _randn((8192,))
    q, s = quantize_int8(x, interpret=True, bn=4096)
    qr, sr = ref.quantize_int8(x)
    assert bool(jnp.all(q == qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xd = dequantize_int8(q, s, interpret=True, bn=4096)
    np.testing.assert_allclose(np.asarray(xd), np.asarray(ref.dequantize_int8(qr, sr)), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
def test_quantize_error_bound(seed, scale):
    """|x - dequant(quant(x))| <= blockwise scale (= absmax/127) per entry."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1024,)) * scale, jnp.float32)
    q, s = ref.quantize_int8(x, block=256)
    xd = ref.dequantize_int8(q, s, block=256)
    err = np.abs(np.asarray(x - xd)).reshape(-1, 256)
    bound = np.asarray(s)[:, None] * 0.5 + 1e-9
    assert (err <= bound + 1e-6).all()


# ---------------- ops-level group partial sums (normalize=False) ----------------
@pytest.mark.parametrize("interpret", [False, True])
def test_ops_reduces_normalize_false_yield_weighted_sums(interpret):
    """normalize=False turns each FL reduce into the weighted SUM — the
    group-partial form the mixed-codec engine combines under one fleet
    denominator — on both the kernel and reference dispatch paths."""
    from repro.kernels import ops

    rng = np.random.default_rng(11)
    c, n = 4, 1024
    w = jnp.asarray(rng.random(c) + 0.1, jnp.float32)
    wsum = float(jnp.sum(w))

    u = jnp.asarray(rng.normal(size=(c, n)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.fedavg_reduce(u, w, interpret=interpret, normalize=False)),
        np.asarray(ops.fedavg_reduce(u, w, interpret=interpret)) * wsum,
        atol=1e-4, rtol=1e-5,
    )

    q, s = ref.quantize_int8(u.reshape(-1))
    q = q.reshape(c, n)
    s = s.reshape(c, n // 256)
    np.testing.assert_allclose(
        np.asarray(ops.dequant_reduce(q, s, w, interpret=interpret, normalize=False)),
        np.asarray(ops.dequant_reduce(q, s, w, interpret=interpret)) * wsum,
        atol=1e-4, rtol=1e-5,
    )

    idx = jnp.asarray(rng.integers(0, n, (c, 16)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(c, 16)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.topk_scatter_reduce(idx, val, w, n, interpret=interpret,
                                           normalize=False)),
        np.asarray(ops.topk_scatter_reduce(idx, val, w, n, interpret=interpret)) * wsum,
        atol=1e-5, rtol=1e-5,
    )
    # all-zero weights: the weighted sum is exactly zero, never NaN
    z = jnp.zeros(c)
    for out in (
        ops.fedavg_reduce(u, z, interpret=interpret, normalize=False),
        ops.topk_scatter_reduce(idx, val, z, n, interpret=interpret,
                                normalize=False),
    ):
        np.testing.assert_array_equal(np.asarray(out), 0.0)

"""Rounds-as-scan (ISSUE 8): the whole training run as one ``lax.scan``.

Acceptance criteria asserted here:
- ``Server.run_scanned`` is BITWISE equal to R iterations of the per-round
  python driver (``reference=True``) — final global params, every stacked
  device output, and the decoded ``History`` — for NullCodec, Int8, TopK,
  and a Deadline policy whose participation mask is provably non-trivial
  (churn + stragglers actually drop clients);
- on-device cohort sampling (``cohort_dispatch_mask``) matches the same
  seeded priorities drawn host-side;
- carry donation keeps compiled temp memory FLAT in R (peak memory must
  not scale with the number of rounds when batches are reused);
- non-traceable policies (``BufferedAsync``) are rejected at build time.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AvailabilityTrace, BufferedAsync, Deadline, FedAvg, PROFILES, RoundSpec,
    Server, SyncAll, cohort_dispatch_mask, make_multi_round_step,
)
from repro.core.compression import Int8Codec, NullCodec, TopKCodec
from repro.core.cost_model import CostModel
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_size

# a mixed fleet: one fast chip, two mid edge boards, three slow phones —
# under a deadline the phones straggle, under churn the mobiles drop out
FLEET = [
    "tpu-v5e-chip", "jetson-tx2-gpu", "jetson-tx2-gpu",
    "pixel-2", "pixel-2", "pixel-3",
]
C = len(FLEET)


def _fixture(codec, *, R=6, steps=2, B=4, seed=0):
    model = build_model("mobilenet-head-office31")
    params = model.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    batches = {
        "x": jnp.asarray(rng.normal(
            size=(R, C, steps, B, model.cfg.feature_dim)).astype(np.float32)),
        "y": jnp.asarray(rng.integers(
            0, model.cfg.num_classes, (R, C, steps, B)).astype(np.int32)),
    }
    spec = RoundSpec(max_steps=steps, execution_mode="parallel", codec=codec)
    cm = CostModel(
        profiles=[PROFILES[n] for n in FLEET],
        update_bytes=4 * tree_size(params),
    )
    # tau between the fast chip's and the phones' round time: real drops
    tau = 1.25 * cm.client_round_cost(1, steps).t_total_s
    trace = AvailabilityTrace.from_profiles(
        [PROFILES[n] for n in FLEET], seed=seed,
        mobile_dropout=0.3, jitter_std=0.1,
    )
    return model, params, batches, spec, cm, tau, trace


def _run_both(codec, *, cohort_size=None, R=6, seed=0):
    model, params, batches, spec, cm, tau, trace = _fixture(
        codec, R=R, seed=seed
    )
    out = {}
    for ref in (False, True):
        srv = Server(
            strategy=FedAvg(), clients=[], cost_model=cm,
            policy=Deadline(tau=tau), availability=trace,
            cohort_size=cohort_size,
        )
        srv.logger.quiet = True
        out[ref] = srv.run_scanned(
            params, R, loss_fn=model.loss_fn, opt=sgd(0.1), spec=spec,
            batches=batches, reference=ref,
        )
    return out[False], out[True]


def _assert_tree_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_history_equal(ha, hb):
    assert len(ha.rounds) == len(hb.rounds)
    for ra, rb in zip(ha.rounds, hb.rounds):
        assert ra.rnd == rb.rnd
        assert ra.train_loss == rb.train_loss  # bitwise, not approx
        assert ra.wall_time_s == rb.wall_time_s
        assert ra.energy_j == rb.energy_j
        assert ra.comm_bytes == rb.comm_bytes
        assert ra.steps == rb.steps
        assert ra.participants == rb.participants
        assert ra.dropped == rb.dropped


# ---------------- bitwise parity: scan == python driver ----------------
@pytest.mark.parametrize("name,codec,cohort", [
    ("null", NullCodec(), None),
    ("int8", Int8Codec(), None),
    ("topk", TopKCodec(frac=0.05), 4),
], ids=["null", "int8", "topk-cohort"])
def test_scanned_matches_python_driver_bitwise(name, codec, cohort):
    (g_s, h_s, st_s), (g_p, h_p, st_p) = _run_both(codec, cohort_size=cohort)
    _assert_tree_bitwise(g_s, g_p)
    assert set(st_s) == set(st_p)
    for k in st_s:
        np.testing.assert_array_equal(
            np.asarray(st_s[k]), np.asarray(st_p[k]), err_msg=k
        )
    _assert_history_equal(h_s, h_p)


def test_deadline_mask_is_nontrivial():
    """The parity fixture must actually exercise the mask: churn + the
    deadline drop SOME clients in SOME rounds, and keep others."""
    (_, hist, stacked), _ = _run_both(NullCodec())
    dropped = sum(r.dropped for r in hist.rounds)
    participants = sum(r.participants for r in hist.rounds)
    assert dropped > 0, "fixture never dropped a client - mask is trivial"
    assert participants > 0, "fixture dropped everyone - mask is trivial"
    mask = stacked["participation_mask"]
    disp = stacked["dispatch_mask"]
    assert mask.shape == disp.shape == (len(hist.rounds), C)
    assert np.any(mask < disp)  # a dispatched straggler missed tau


def test_cohort_mask_counts_and_availability():
    """On-device cohort sampling picks exactly cohort_size available
    clients (fewer only when churn leaves fewer available)."""
    (_, hist, stacked), _ = _run_both(TopKCodec(frac=0.05), cohort_size=4)
    disp = stacked["dispatch_mask"]
    for r, row in enumerate(disp):
        assert row.sum() <= 4
    assert np.any(disp.sum(axis=1) == 4)  # some full cohorts exist
    # reporters are always a subset of the dispatched cohort
    assert np.all((stacked["participation_mask"] > 0) <= (disp > 0))


def test_cohort_dispatch_mask_unit():
    pri = jnp.asarray([0.3, 0.1, 0.9, 0.2, 0.5])
    avail = jnp.asarray([1.0, 1.0, 1.0, 0.0, 1.0])
    m = np.asarray(cohort_dispatch_mask(pri, avail, 2))
    # two lowest priorities among AVAILABLE clients: ids 1 (0.1) and 0 (0.3)
    np.testing.assert_array_equal(m, [1.0, 1.0, 0.0, 0.0, 0.0])
    # cohort larger than the available fleet: everyone available, nobody else
    m2 = np.asarray(cohort_dispatch_mask(pri, avail, 5))
    np.testing.assert_array_equal(m2, [1.0, 1.0, 1.0, 0.0, 1.0])


# ---------------- pure-array policy verdicts ----------------
def test_plan_arrays_matches_deadline_semantics():
    t = jnp.asarray([1.0, 30.0, 5.0, 2.0])
    disp = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    mask, end = Deadline(tau=10.0).plan_arrays(disp, t, tau=10.0)
    np.testing.assert_array_equal(np.asarray(mask), [1.0, 0.0, 1.0, 0.0])
    assert float(end) == 10.0  # a straggler exists: wait the full cutoff
    # no stragglers: round ends with the last reporter, not the cutoff
    mask2, end2 = Deadline(tau=10.0).plan_arrays(
        disp, jnp.asarray([1.0, 6.0, 5.0, 2.0]), tau=10.0
    )
    np.testing.assert_array_equal(np.asarray(mask2), [1.0, 1.0, 1.0, 0.0])
    assert float(end2) == 6.0
    # infinite tau degrades to SyncAll
    mask3, end3 = Deadline().plan_arrays(disp, t, tau=float("inf"))
    np.testing.assert_array_equal(np.asarray(mask3), np.asarray(disp))
    assert float(end3) == 30.0
    sm, se = SyncAll().plan_arrays(disp, t)
    np.testing.assert_array_equal(np.asarray(sm), np.asarray(disp))
    assert float(se) == 30.0


def test_buffered_async_is_rejected_at_build_time():
    assert not BufferedAsync().traceable
    model = build_model("mobilenet-head-office31")
    spec = RoundSpec(max_steps=2, execution_mode="parallel")
    with pytest.raises(NotImplementedError, match="BufferedAsync"):
        make_multi_round_step(
            model.loss_fn, sgd(0.1), FedAvg(), spec, 4,
            policy=BufferedAsync(),
        )
    with pytest.raises(NotImplementedError):
        BufferedAsync().plan_arrays(jnp.ones((2,)), jnp.ones((2,)))


def test_run_scanned_rejects_population_mode():
    model = build_model("mobilenet-head-office31")
    params = model.init(jax.random.key(0))
    srv = Server(strategy=FedAvg(), clients=[], population=object(),
                 cohort_size=2)
    with pytest.raises(NotImplementedError, match="population"):
        srv.run_scanned(
            params, 2, loss_fn=model.loss_fn, opt=sgd(0.1),
            spec=RoundSpec(max_steps=1, execution_mode="parallel"),
            batches={"x": jnp.zeros((2, 2, 1, 1))},
        )


# ---------------- donation: memory flat in R ----------------
def test_donated_scan_memory_does_not_scale_with_rounds():
    """Compiled temp memory at R=32 must match R=8: the scan carry is
    donated/aliased in place, per-round metrics are the only O(R) device
    output, and reused batches are a closed-over constant."""
    model = build_model("mobilenet-head-office31")
    params = model.init(jax.random.key(0))
    steps, B = 2, 4
    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(
            size=(C, steps, B, model.cfg.feature_dim)).astype(np.float32)),
        "y": jnp.asarray(rng.integers(
            0, model.cfg.num_classes, (C, steps, B)).astype(np.int32)),
    }
    spec = RoundSpec(max_steps=steps, execution_mode="parallel")
    strat = FedAvg()
    w = jnp.ones((C,))
    bud = jnp.full((C,), steps, jnp.int32)
    cs = spec.codec.init_client_state(C, tree_size(params))
    temp = {}
    for R in (8, 32):
        multi = make_multi_round_step(
            model.loss_fn, sgd(0.1), strat, spec, R, stacked_batches=False
        )
        sched = (jnp.ones((R, C), jnp.float32),
                 jnp.zeros((R, C), jnp.float32),
                 jnp.zeros((R, C), jnp.float32))
        compiled = jax.jit(multi, donate_argnums=(0, 1, 2)).lower(
            params, strat.init_state(params), cs, batch, w, bud, *sched
        ).compile()
        ma = compiled.memory_analysis()
        if ma is None:
            pytest.skip("backend does not expose memory_analysis")
        temp[R] = int(ma.temp_size_in_bytes)
    assert temp[32] <= temp[8] * 1.05, (
        f"temp memory scales with R: {temp}"
    )


def test_reused_batches_parity_with_stacked():
    """stacked_batches=False (one batch reused every round) must equal a
    stack of R copies of that batch."""
    model, params, batches, spec, cm, tau, trace = _fixture(
        NullCodec(), R=4
    )
    one = jax.tree.map(lambda x: x[0], batches)
    tiled = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (4,) + x.shape), one
    )
    outs = []
    for b, stacked_flag in ((tiled, True), (one, False)):
        srv = Server(
            strategy=FedAvg(), clients=[], cost_model=cm,
            policy=Deadline(tau=tau), availability=trace,
        )
        srv.logger.quiet = True
        outs.append(srv.run_scanned(
            params, 4, loss_fn=model.loss_fn, opt=sgd(0.1), spec=spec,
            batches=b, stacked_batches=stacked_flag,
        ))
    (g_a, h_a, _), (g_b, h_b, _) = outs
    _assert_tree_bitwise(g_a, g_b)
    _assert_history_equal(h_a, h_b)


def test_donation_keeps_caller_params_valid():
    """run_scanned with donate=True must copy before donating: the
    caller's param arrays stay readable and a second run from the same
    params reproduces the first bitwise."""
    model, params, batches, spec, cm, tau, trace = _fixture(
        NullCodec(), R=3
    )
    srv = Server(strategy=FedAvg(), clients=[], cost_model=cm,
                 policy=Deadline(tau=tau), availability=trace)
    srv.logger.quiet = True
    kw = dict(loss_fn=model.loss_fn, opt=sgd(0.1), spec=spec,
              batches=batches)
    g1, h1, _ = srv.run_scanned(params, 3, **kw)
    # caller buffers survived donation
    _ = [np.asarray(x) for x in jax.tree.leaves(params)]
    g2, h2, _ = srv.run_scanned(params, 3, **kw)
    _assert_tree_bitwise(g1, g2)
    _assert_history_equal(h1, h2)


# ---------------- schedule precompute matrices ----------------
def test_schedule_matrices_match_per_round_draws():
    trace = AvailabilityTrace.from_profiles(
        [PROFILES[n] for n in FLEET], seed=3,
        mobile_dropout=0.4, jitter_std=0.2,
    )
    rounds = range(1, 9)
    am = trace.available_matrix(rounds)
    jm = trace.step_jitter_matrix(rounds)
    assert am.shape == jm.shape == (8, C)
    for i, r in enumerate(rounds):
        np.testing.assert_array_equal(am[i], np.asarray(trace.available(r)))
        np.testing.assert_array_equal(jm[i], np.asarray(trace.step_jitter(r)))
    pm = trace.cohort_priority_matrix(rounds)
    assert pm.shape == (8, C)
    # priorities are fresh draws per round, uniform in [0, 1)
    assert np.all((pm >= 0.0) & (pm < 1.0))
    assert not np.array_equal(pm[0], pm[1])


def test_fleet_time_matrix_matches_client_round_cost():
    cm = CostModel(profiles=[PROFILES[n] for n in FLEET],
                   update_bytes=1 << 20)
    steps = 5
    budgets = np.full((C,), steps, np.int64)
    jitter = np.linspace(0.8, 1.2, 8 * C).reshape(8, C)
    tm = cm.fleet_time_matrix(budgets, jitter)
    for r in (0, 7):
        for cid in range(C):
            ref = cm.client_round_cost(cid, steps, jitter=float(jitter[r, cid]))
            assert tm[r, cid] == ref.t_total_s, (r, cid)

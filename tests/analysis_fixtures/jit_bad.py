"""jit-host-sync positive fixture: every host-sync pattern, plus a
module-scope device call for the import-scan.  Never imported — only
parsed by fedlint in tests."""
import jax
import jax.numpy as jnp
import numpy as np

jax.devices()  # module-scope-device-call: breaks backend-less collection


def helper(x):
    return float(jnp.sum(x))  # py-cast once reachable


def stats(x):
    return np.mean(np.asarray(x))  # np-call once reachable


def make_round_step(loss_fn):
    def round_step(params, batch):
        loss = loss_fn(params, batch)
        print("loss", loss)       # print: runs at trace time only
        loss.item()               # item: host-device sync
        loss.block_until_ready()  # block-until-ready
        return helper(loss) + stats(loss)

    return round_step

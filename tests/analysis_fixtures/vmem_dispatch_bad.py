"""pallas-vmem-budget positive fixture: dispatches a kernel module with no
reference to the ref oracle anywhere — no CPU / over-budget escape hatch."""
from .vmem_clean import accumulate


def reduce_updates(x):
    return accumulate(x)

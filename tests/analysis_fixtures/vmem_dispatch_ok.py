"""pallas-vmem-budget negative fixture: dispatcher keeps the ref oracle as
its escape hatch next to the kernel path."""
from . import ref
from .vmem_clean import BLOCK, accumulate


def reduce_updates(x):
    if x.shape[0] % BLOCK == 0:
        return accumulate(x)
    return ref.accumulate(x)

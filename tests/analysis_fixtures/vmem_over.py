"""pallas-vmem-budget positive fixture: over-budget and unresolved shapes."""
import jax
from jax.experimental import pallas as pl

VMEM_BUDGET_ELEMS = 1 << 10  # 4 KB: far below the blocks declared here
VMEM_ASSUMES = {"c": 1024}


def _sum_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].sum(axis=0, keepdims=True)


def over_budget(x):
    c = 1024
    bn = 8
    # 2 x (1024*8) in + 2 x (1*8) out = 16400 elems >> 1024 budget
    return pl.pallas_call(
        _sum_kernel,
        grid=(x.shape[1] // bn,),
        in_specs=[pl.BlockSpec((c, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, x.shape[1]), x.dtype),
    )(x)


def unresolved(x, bn):
    # bn is a runtime arg with no default and no VMEM_ASSUMES pin: the
    # ceiling cannot be audited, which is itself the defect.
    return pl.pallas_call(
        _sum_kernel,
        grid=(x.shape[1] // bn,),
        in_specs=[pl.BlockSpec((1024, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, x.shape[1]), x.dtype),
    )(x)

"""mask-nan-safety positive fixture: reductions that ignore the mask in
scope.  With an all-dropped cohort these are the NaN/garbage paths."""
import jax.numpy as jnp


def masked_metrics(losses, weights, mask):
    w_eff = weights * mask
    total = jnp.sum(losses * weights)  # ignores mask: counts dropped clients
    worst = jnp.max(losses)            # dropped clients' garbage wins the max
    return total / jnp.maximum(1.0, jnp.sum(w_eff)), worst

"""wire-accounting positive fixture: a codec subclass changes the encoded
payload but inherits the parent's wire_bytes — cost model silently lies."""


class UpdateCodec:
    def wire_bytes(self, sizes):
        return [4 * s for s in sizes]

    def encode(self, delta):
        return delta

    def decode(self, payload):
        return payload


class EveryOtherCodec(UpdateCodec):
    def encode(self, delta):           # halves the payload...
        return delta[::2]

    def decode(self, payload):
        out = list(payload) * 2
        return out[: len(payload) * 2]
    # ...but no wire_bytes override: accounting still bills 4*s

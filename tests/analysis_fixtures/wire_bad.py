"""wire-accounting positive fixture: a codec subclass changes the encoded
payload but inherits the parent's wire_bytes — cost model silently lies."""


class UpdateCodec:
    def wire_bytes(self, sizes):
        return [4 * s for s in sizes]

    def encode(self, delta):
        return delta

    def decode(self, payload):
        return payload


class EveryOtherCodec(UpdateCodec):
    def encode(self, delta):           # halves the payload...
        return delta[::2]

    def decode(self, payload):
        out = list(payload) * 2
        return out[: len(payload) * 2]
    # ...but no wire_bytes override: accounting still bills 4*s


class SparseSegmentCodec(UpdateCodec):
    def encode_segment(self, vec, seg):    # changes one segment's wire...
        return vec[: seg.size // 2]

    def decode_segment(self, enc, seg):
        return list(enc) + [0] * (seg.size - len(enc))

    def wire_bytes(self, sizes):           # flat accounting restated, but the
        return [2 * s for s in sizes]      # segmented billing path never
    # calls it: segment_wire_bytes still costs the parent's flat format

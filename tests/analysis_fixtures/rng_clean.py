"""rng-discipline negative fixture: tuple seeding and split-before-reuse."""
import jax
import numpy as np


def round_batches(seed, rnd):
    # the fixed launch/train.py shape: the (seed, rnd) tuple IS the seed
    return np.random.default_rng((seed, rnd))


def batch_call(args, rnd, lm_round_batch):
    return lm_round_batch(n_clients=4, seed=(args.seed, rnd))


def single_stream(seed):
    return np.random.default_rng(seed)  # one seed, one stream: fine


def no_reuse(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (2,))
    b = jax.random.uniform(k2, (2,))
    return a + b


def resplit(key):
    a = jax.random.normal(key, (2,))
    key = jax.random.split(key, 2)[0]  # reassignment retires the old key
    b = jax.random.normal(key, (2,))
    return a + b

"""wire-accounting negative fixture: overriding subclasses restate their
wire cost; non-codec overrides are out of scope."""


class UpdateCodec:
    def wire_bytes(self, sizes):
        return [4 * s for s in sizes]

    def encode(self, delta):
        return delta

    def decode(self, payload):
        return payload


class HalfCodec(UpdateCodec):
    def encode(self, delta):
        return delta[::2]

    def wire_bytes(self, sizes):       # payload changed, cost restated
        return [4 * (s // 2) for s in sizes]


class ScalarCodec(UpdateCodec):
    def encode(self, delta):
        return delta

    def _wire_bytes_scalar(self, n):   # scalar-form accounting also counts
        return 4 * n


class NamedCodec(UpdateCodec):
    name = "identity"                  # no codec-path override: exempt


class FactorSegmentCodec(UpdateCodec):
    def encode_segment(self, vec, seg):
        return vec[: seg.size // 2]

    def segment_wire_bytes(self, seg):     # per-segment cost restated
        return 4 * (seg.size // 2)

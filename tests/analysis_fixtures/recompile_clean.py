"""recompile-hazard negative fixture: statics declared, shapes stable."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kernel(x, bn: int = 128, interpret: bool = False):
    return x * bn


@jax.jit
def apply(params, x):
    return params["w"] * x


def driver(params, x):
    y = kernel(x, bn=256)              # scalar into a *static* param: fine
    a = kernel(jnp.zeros((8, 8)))      # one literal shape only
    b = kernel(jnp.zeros((8, 8)))
    return apply(params, y) + a + b    # params is a variable, not a literal

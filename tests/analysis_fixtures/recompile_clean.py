"""recompile-hazard negative fixture: statics declared, shapes stable."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kernel(x, bn: int = 128, interpret: bool = False):
    return x * bn


@jax.jit
def apply(params, x):
    return params["w"] * x


@jax.jit
def cohort_step(client_state):
    return client_state + 1.0


def driver(params, x):
    y = kernel(x, bn=256)              # scalar into a *static* param: fine
    a = kernel(jnp.zeros((8, 8)))      # one literal shape only
    b = kernel(jnp.zeros((8, 8)))
    return apply(params, y) + a + b    # params is a variable, not a literal


def population_driver():
    # population mode done right: the dense cohort is always (C, n) for one
    # static C — gather/scatter resamples WHO fills the rows, not the shape
    r1 = cohort_step(jnp.zeros((16, 4)))
    r2 = cohort_step(jnp.zeros((16, 4)))
    return r1, r2

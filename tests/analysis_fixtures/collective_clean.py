"""wire-accounting collective negative fixture: compressed collectives
that state their per-hop wire size, and psums with nothing encoded."""
import jax


class Int8AllReduce:
    def reduce(self, wx, axes):
        q = collective_pack(wx, self.scales(wx))
        for ax in axes:
            q = jax.lax.psum(q, ax)
        return q

    def collective_bytes(self, n):       # per-device per-hop wire restated
        return n + 4 * (n // 256) + 4


class WeightDenominator:
    def reduce(self, w, axes):           # fp32 sidecar psum, no encode:
        for ax in axes:                  # billed default — exempt
            w = jax.lax.psum(w, ax)
        return w


class OfflineEncoder:
    def encode(self, delta):             # encodes, but nothing crosses a
        return delta[::2]                # collective here — exempt

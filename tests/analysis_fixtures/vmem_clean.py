"""pallas-vmem-budget negative fixture: declared budget, blocks well inside
it, grid-invariant accumulator counted single-buffered."""
import jax
from jax.experimental import pallas as pl

VMEM_BUDGET_ELEMS = 1 << 16
VMEM_ASSUMES = {}

BLOCK = 128


def _acc_kernel(x_ref, o_ref):
    o_ref[...] += x_ref[...]


def accumulate(x):
    # 2 x 128 pipelined in + 1 x 128 grid-invariant accumulator = 384 elems
    return pl.pallas_call(
        _acc_kernel,
        grid=(x.shape[0] // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((BLOCK,), x.dtype),
    )(x)

"""pallas-vmem-budget positive fixture: missing-budget.

Regression copy of the pre-PR state of the seven src/repro/kernels modules:
a pallas_call file with no VMEM_BUDGET_ELEMS declaration at all."""
import jax
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def copy(x):
    return pl.pallas_call(
        _copy_kernel,
        grid=(x.shape[0] // 128,),
        in_specs=[pl.BlockSpec((128,), lambda i: (i,))],
        out_specs=pl.BlockSpec((128,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)

"""jit-host-sync negative fixture: pure-jnp traced bodies; host numpy and
casts only in functions NOT reachable from the round step."""
import jax
import jax.numpy as jnp
import numpy as np


def host_side_report(metrics):
    # not reachable from make_round_step: python-side logging is fine
    return {k: float(v) for k, v in metrics.items()}


def tree_size(x):
    # also unreachable here: static host accounting
    return int(np.prod(x.shape))


def make_round_step(loss_fn):
    def round_step(params, batch):
        loss = loss_fn(params, batch)
        return jnp.mean(loss) / jnp.maximum(1.0, jnp.sum(loss * 0 + 1))

    return round_step

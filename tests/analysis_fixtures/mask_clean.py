"""mask-nan-safety negative fixture: every reduction is mask-aware, the
``is None`` arm is exempt, and mask-free functions are out of scope."""
import jax.numpy as jnp


def masked_metrics(losses, weights, mask):
    mf = mask.astype(jnp.float32)
    w_eff = weights * mf
    losses_eff = jnp.where(mf > 0, losses, 0.0)      # sanitized
    total = jnp.sum(losses_eff * w_eff)
    worst = jnp.max(jnp.where(mf > 0, losses, -jnp.inf))
    count = jnp.maximum(1.0, jnp.sum(mf))
    return total / count, worst


def maybe_masked(losses, mask=None):
    if mask is None:
        return jnp.mean(losses)                      # unmasked arm: exempt
    return jnp.mean(losses, where=mask > 0)


def no_mask_here(losses):
    return jnp.mean(losses)                          # no mask in scope

"""wire-accounting collective positive fixture: a class quantizes the psum
payload but never states collective_bytes — the cost model bills fp32 for
wire the class compressed."""
import jax


class QuantizedAllReduce:
    def pack(self, x, scales):
        return collective_pack(x, scales)

    def reduce(self, wx, axes):
        q = self.pack(wx, self.scales(wx))
        for ax in axes:
            q = jax.lax.psum(q, ax)
        return q
    # changes the per-hop wire format, but no collective_bytes: flagged


class PlainAllReduce:
    def reduce(self, wx, axes):          # fp32 psum, nothing encoded:
        for ax in axes:                  # the billed default — NOT flagged
            wx = jax.lax.psum(wx, ax)
        return wx

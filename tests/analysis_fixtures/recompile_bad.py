"""recompile-hazard positive fixture: every hazard class."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("bn", "interpet"))  # typo!
def kernel(x, bn: int = 128, interpret: bool = False):
    return x * bn


@functools.partial(jax.jit, static_argnames=("cfg",))
def step(x, cfg={"lr": 0.1}):  # unhashable static default
    return x * cfg["lr"]


@jax.jit
def apply(params, x):
    return params["w"] * x


@jax.jit
def cohort_step(client_state):
    return client_state + 1.0


def driver():
    y = kernel(0.5)                   # python scalar into non-static x
    a = kernel(jnp.zeros((8, 8)))     # two literal shapes for the same
    b = kernel(jnp.zeros((16, 16)))   # non-static param: compile per shape
    z = apply({"w": 2.0}, y)          # dict of baked-in scalars
    return a, b, z


def population_driver():
    # the gather/scatter hazard: feeding the jitted engine a cohort whose
    # size follows the POPULATION (varying N) instead of a fixed C —
    # every resample would recompile
    small = cohort_step(jnp.zeros((16, 4)))
    big = cohort_step(jnp.zeros((1000, 4)))
    return small, big

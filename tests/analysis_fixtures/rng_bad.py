"""rng-discipline positive fixture — regression copies of the two seeding
bugs this PR fixed in launch/train.py, plus a key-reuse case."""
import jax
import numpy as np


def round_batches(seed, rnd):
    rng = np.random.default_rng(seed * 1000 + rnd)  # additive-seed
    rng2 = np.random.default_rng(rnd)               # round-only-seed
    return rng, rng2


def batch_call(args, rnd, lm_round_batch):
    # the launch/train.py:89 shape: affine seed smuggled through a kwarg
    return lm_round_batch(n_clients=4, seed=args.seed * 1000 + rnd)


def reuse(key):
    a = jax.random.normal(key, (2,))
    b = jax.random.uniform(key, (2,))  # key-reuse: replays a's stream
    return a + b

"""Optional-hypothesis shim: property tests degrade to skips when absent.

``hypothesis`` lives in the ``test`` extra (see pyproject.toml) and is not
part of the runtime deps.  When it is missing, this module substitutes a
``given`` decorator that turns each property test into a single skipped
test instead of a collection error, so the rest of the suite still runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: skip property tests, keep the suite green
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `hypothesis.strategies`: every factory returns None."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed (pip install '.[test]')")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

"""Million-client population layer (core/population.py).

Pins the ISSUE-7 contracts:
- packed struct-of-arrays fleet: ~1 byte/device, O(cohort) queries;
- streamed availability/jitter is pool-composition-independent and agrees
  with the full-vector surface on population-backed traces;
- resident-only-when-sampled codec state: a population-backed round using
  CohortState.gather/scatter is BITWISE the legacy full-cohort round for
  N == C (globals, metrics, residual rows);
- eviction resets the residual to zero and the post-eviction round is
  bitwise the round of a fresh-residual client (error feedback intact);
- Server population mode reproduces the legacy loop at N == cohort_size;
- CostAwareSampling prefers deadline-feasible cohorts;
- LazyClientPool spills/rehydrates client carry through the store.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AvailabilityTrace, CohortState, CostAwareFedAvg, CostModel, FedAvg,
    Int8Codec, JaxClient, LazyClientPool, MixedCodec, NullCodec, Population,
    RoundSpec, Server, TopKCodec, make_round_step,
)
from repro.core.cost_model import PIXEL_2, PIXEL_3, PIXEL_4
from repro.data.federated import ClientDataset
from repro.data.synthetic import make_features
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_size

C, STEPS, B = 4, 2, 16


# ---------------- packed representation ----------------
def test_synthetic_population_is_flat():
    pop = Population.synthetic(100_000, seed=7)
    assert len(pop) == 100_000
    # ~1 byte/device: uint8 codes + per-class columns, never per-device rows
    assert pop.nbytes / len(pop) <= 2.0
    assert pop.profile_codes.dtype == np.uint8
    # profile() and column() answer from the same table
    ids = np.asarray([0, 17, 99_999])
    step = pop.column("step_time_s", ids)
    for i, cid in enumerate(ids):
        assert step[i] == pop.profile(int(cid)).step_time_s


def test_from_profiles_roundtrip():
    profiles = [PIXEL_4, PIXEL_3, PIXEL_4, PIXEL_2, PIXEL_3]
    pop = Population.from_profiles(profiles)
    assert len(pop) == 5 and pop.n_profiles == 3  # deduplicated classes
    for i, p in enumerate(profiles):
        assert pop.profile(i) is p


def test_expected_round_s_matches_scalar_formula():
    pop = Population.from_profiles([PIXEL_4, PIXEL_2])
    t = pop.expected_round_s([0, 1], steps=10, up_bytes=1e6, down_bytes=1e6)
    for i, p in enumerate((PIXEL_4, PIXEL_2)):
        assert t[i] == pytest.approx(10 * p.step_time_s + p.comm_time_s(1e6, 1e6))


# ---------------- streamed availability ----------------
def test_streamed_availability_is_pool_independent():
    pop = Population.synthetic(50_000, seed=3)
    tr = AvailabilityTrace.from_profiles(pop, seed=11)
    ids = np.asarray([5, 123, 4_567, 49_999])
    solo = np.asarray([tr.available_for(4, [int(c)])[0] for c in ids])
    pooled = tr.available_for(4, ids)
    shuffled = tr.available_for(4, ids[::-1])[::-1]
    np.testing.assert_array_equal(solo, pooled)
    np.testing.assert_array_equal(shuffled, pooled)
    # deterministic replay, but a different round is a different draw
    np.testing.assert_array_equal(tr.available_for(4, ids), pooled)
    assert any(
        not np.array_equal(tr.available_for(r, np.arange(2000)),
                           tr.available_for(4, np.arange(2000)))
        for r in (5, 6, 7)
    )


def test_population_trace_full_vector_agrees_with_streamed():
    pop = Population.synthetic(300, seed=2)
    tr = AvailabilityTrace.from_profiles(pop, seed=9, jitter_std=0.1)
    all_ids = np.arange(300)
    np.testing.assert_array_equal(tr.available(6), tr.available_for(6, all_ids))
    np.testing.assert_array_equal(tr.step_jitter(6), tr.step_jitter_for(6, all_ids))
    assert tr.available(6, client_id=42) == bool(tr.available_for(6, [42])[0])


def test_streamed_dropout_rate_tracks_class_rate():
    pop = Population.synthetic(40_000, mix=("pixel-4",), seed=0)
    tr = AvailabilityTrace.from_profiles(pop, seed=1, mobile_dropout=0.15)
    up = tr.available_for(3, np.arange(len(pop)))
    assert 1.0 - up.mean() == pytest.approx(0.15, abs=0.02)


def test_population_trace_guards():
    pop = Population.synthetic(100, seed=0)
    with pytest.raises(ValueError):
        AvailabilityTrace.from_profiles(pop, late_join=3)
    with pytest.raises(AssertionError):
        AvailabilityTrace(n_clients=100, dropout=(0.1,) * 100, population=pop)


# ---------------- CohortState ----------------
def test_cohort_state_eviction_resets_residual():
    cs = CohortState(TopKCodec(frac=0.25), 8, capacity=2)
    cs.put_row(1, np.full(8, 1.0))
    cs.put_row(2, np.full(8, 2.0))
    cs.get_row(1)                      # touch: 2 becomes LRU
    cs.put_row(3, np.full(8, 3.0))     # evicts 2
    assert cs.evictions == 1 and len(cs) == 2
    g = np.asarray(cs.gather([1, 2, 3]))
    assert g.shape == (3, 8)
    np.testing.assert_array_equal(g[0], np.full(8, 1.0, np.float32))
    np.testing.assert_array_equal(g[1], np.zeros(8))  # evicted -> fresh zeros
    np.testing.assert_array_equal(g[2], np.full(8, 3.0, np.float32))


def test_cohort_state_stateless_and_mixed():
    assert CohortState(NullCodec(), 8).gather([1, 2, 3]) == ()
    cs = CohortState(NullCodec(), 8)
    cs.scatter([1, 2], ())  # no-op, not a crash
    assert len(cs) == 0 and cs.nbytes == 0
    with pytest.raises(TypeError):
        CohortState(MixedCodec(codecs=(Int8Codec(),), assignment=(0,)), 8)


# ---------------- jitted-engine bitwise parity ----------------
def _engine_setup(seed=0):
    m = build_model("mobilenet-head-office31")
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 1.0, size=(m.cfg.num_classes, m.cfg.feature_dim))

    def batch_of(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, m.cfg.num_classes, n)
        x = centers[y] + 0.4 * r.normal(size=(n, m.cfg.feature_dim))
        return x.astype(np.float32), y.astype(np.int32)

    xs, ys = zip(*[batch_of(STEPS * B, 100 + c) for c in range(C)])
    train = {
        "x": jnp.asarray(np.stack(xs).reshape(C, STEPS, B, -1)),
        "y": jnp.asarray(np.stack(ys).reshape(C, STEPS, B)),
    }
    params = m.init(jax.random.key(seed))
    return m, params, train


def _jitted_round_step(m, codec):
    spec = RoundSpec(max_steps=STEPS, execution_mode="parallel", codec=codec)
    return jax.jit(make_round_step(m.loss_fn, sgd(0.1), FedAvg(), spec))


@pytest.mark.parametrize("codec", [TopKCodec(frac=0.25), Int8Codec()])
def test_population_round_bitwise_matches_legacy(codec):
    """ISSUE-7 acceptance: cohort gather/scatter == threaded client state,
    bitwise, for N == C — globals, metrics, and residual rows alike."""
    m, params, train = _engine_setup()
    rs = _jitted_round_step(m, codec)
    n = tree_size(params)
    w, bud = jnp.ones(C), jnp.full((C,), STEPS, jnp.int32)
    cohort = list(range(C))

    # legacy: dense (C, n) state threaded through every round
    p_leg, s_leg = params, FedAvg().init_state(params)
    cstate = codec.init_client_state(C, n)
    legacy = []
    for rnd in range(3):
        p_leg, s_leg, cstate, met = rs(p_leg, s_leg, cstate, train, w, bud, rnd)
        legacy.append(met)

    # population: rows resident only for the round, via gather/scatter
    store = CohortState(codec, n, capacity=16)
    p_pop, s_pop = params, FedAvg().init_state(params)
    for rnd in range(3):
        dense = store.gather(cohort)
        p_pop, s_pop, dense, met = rs(p_pop, s_pop, dense, train, w, bud, rnd)
        store.scatter(cohort, dense)
        for k, v in legacy[rnd].items():
            np.testing.assert_array_equal(np.asarray(v), np.asarray(met[k]), err_msg=k)

    for a, b in zip(jax.tree.leaves(p_leg), jax.tree.leaves(p_pop)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(cstate), np.asarray(store.gather(cohort))
    )


def test_eviction_round_bitwise_matches_fresh_residual():
    """The eviction contract end-to-end: after an evicted row, the next
    round is bitwise the round of a client that never compressed anything,
    and error feedback keeps working from the reset."""
    codec = TopKCodec(frac=0.25)
    m, params, train = _engine_setup()
    rs = _jitted_round_step(m, codec)
    n = tree_size(params)
    w, bud = jnp.ones(C), jnp.full((C,), STEPS, jnp.int32)
    cohort = list(range(C))

    def run(store_capacity):
        store = CohortState(codec, n, capacity=store_capacity)
        p, s = params, FedAvg().init_state(params)
        outs = []
        for rnd in range(3):
            dense = store.gather(cohort)
            p, s, dense, met = rs(p, s, dense, train, w, bud, rnd)
            store.scatter(cohort, dense)
            outs.append((p, met))
        return store, outs

    tight, tight_outs = run(store_capacity=1)   # every scatter evicts C-1 rows
    assert tight.evictions > 0 and len(tight) == 1

    # replay with the rows the tight store actually lost zeroed by hand:
    # round r of the tight run must be bitwise round r of this run
    store = CohortState(codec, n, capacity=16)
    p, s = params, FedAvg().init_state(params)
    for rnd in range(3):
        dense = np.array(store.gather(cohort))
        dense[: C - 1] = 0.0  # what eviction reset (only row C-1 survived)
        p, s, new_dense, met = rs(p, s, jnp.asarray(dense), train, w, bud, rnd)
        store.scatter(cohort, new_dense)
        for k, v in tight_outs[rnd][1].items():
            np.testing.assert_array_equal(np.asarray(v), np.asarray(met[k]), err_msg=k)
        assert np.isfinite(float(met["residual_norm_mean"]))
    for a, b in zip(jax.tree.leaves(tight_outs[-1][0]), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------- Server population mode ----------------
def _server_fixture(pop):
    """Fresh model/clients for one Server.run (dataset cursors are stateful)."""
    m = build_model("mobilenet-head-office31")
    data = make_features(
        n=C * 64, num_classes=m.cfg.num_classes, feature_dim=m.cfg.feature_dim,
        seed=5,
    )
    names = [pop.profile(cid).name for cid in range(C)]

    def factory(cid):
        lo = cid * 64
        return JaxClient(
            client_id=cid, loss_fn=m.loss_fn, batch_size=B,
            dataset=ClientDataset(
                client_id=cid, x=data.x[lo:lo + 64], y=data.y[lo:lo + 64]
            ),
            device_profile=names[cid],
        )

    params = m.init(jax.random.key(0))
    return m, params, factory


def test_server_population_mode_matches_legacy():
    """N == cohort_size, no churn: the population-mode Server round is
    bitwise the legacy round (same cohort, costs, metrics, final global)."""
    profiles = [PIXEL_4, PIXEL_3, PIXEL_2, PIXEL_4]
    pop = Population.from_profiles(profiles)
    m, params, factory = _server_fixture(pop)
    strat = FedAvg(local_epochs=1)

    legacy_cm = CostModel(profiles=profiles, update_bytes=40_000)
    srv = Server(
        strategy=strat, clients=[factory(c) for c in range(C)],
        cost_model=legacy_cm,
    )
    g_leg, h_leg = srv.run(params, num_rounds=3)

    pop_cm = CostModel(profiles=[], update_bytes=40_000, population=pop)
    pool = LazyClientPool(pop, factory, capacity=8)
    srv2 = Server(
        strategy=strat, clients=pool, cost_model=pop_cm,
        population=pop, cohort_size=C,
    )
    g_pop, h_pop = srv2.run(params, num_rounds=3)

    for a, b in zip(h_leg.rounds, h_pop.rounds):
        assert (a.train_loss, a.eval_loss, a.eval_acc) == (
            b.train_loss, b.eval_loss, b.eval_acc
        )
        assert a.wall_time_s == b.wall_time_s
        assert a.energy_j == b.energy_j
        assert a.comm_bytes == b.comm_bytes
        assert a.participants == b.participants
    for a, b in zip(jax.tree.leaves(g_leg), jax.tree.leaves(g_pop)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert pool.live <= pool.capacity


def test_server_population_mode_guards():
    pop = Population.synthetic(64, seed=0)
    srv = Server(strategy=FedAvg(), clients=LazyClientPool(pop, lambda c: None),
                 population=pop)
    with pytest.raises(ValueError):
        srv.run({}, num_rounds=1)
    srv = Server(
        strategy=FedAvg(), clients=LazyClientPool(pop, lambda c: None),
        population=pop, cohort_size=4,
        codec=MixedCodec(codecs=(Int8Codec(),), assignment=(0,) * 4),
    )
    with pytest.raises(TypeError):
        srv.run({}, num_rounds=1)


# ---------------- cost-aware sampling ----------------
def test_cost_aware_sampling_prefers_feasible():
    pop = Population.synthetic(
        4_000, mix={"jetson-tx2-gpu": 0.5, "pixel-2": 0.5}, seed=4
    )
    cm = CostModel(profiles=[], update_bytes=4_000_000, population=pop)
    # pixel-2: 20*0.37 + links(4MB) ~ 10.1s; jetson: 20*0.153 + ~0.6s ~ 3.7s
    tau = 6.0
    aware = CostAwareFedAvg(expected_steps=20)
    blind = FedAvg()
    cohort = aware.sample_cohort(2, pop, 16, cost_model=cm, deadline_s=tau)
    t = pop.expected_round_s(cohort, steps=20, up_bytes=4e6, down_bytes=4e6)
    assert len(cohort) == 16 and (t <= tau).all()
    assert all(pop.profile(c).name == "jetson-tx2-gpu" for c in cohort)
    b = blind.sample_cohort(2, pop, 16)
    tb = pop.expected_round_s(b, steps=20, up_bytes=4e6, down_bytes=4e6)
    assert (tb > tau).any()  # the blind draw includes predicted stragglers


def test_cost_aware_fills_from_infeasible_fastest_first():
    pop = Population.synthetic(50, mix=("pixel-2", "pixel-3"), seed=1)
    cm = CostModel(profiles=[], update_bytes=4_000_000, population=pop)
    aware = CostAwareFedAvg(expected_steps=20)
    # impossible deadline: nobody is feasible, so ranking is fastest-first
    cohort = aware.sample_cohort(1, pop, 10, cost_model=cm, deadline_s=1e-6)
    assert len(cohort) == 10
    names = {pop.profile(c).name for c in cohort}
    # pixel-3 is strictly faster; with ~25 of each, the 10 fastest are all pixel-3
    assert names == {"pixel-3"}


def test_sample_clients_population_dispatch():
    pop = Population.synthetic(10_000, seed=0)
    strat = FedAvg(min_fit_clients=8, fraction_fit=0.0)
    chosen = strat.sample_clients(3, pop)
    assert len(chosen) == 8 and chosen == sorted(chosen)
    assert all(0 <= c < 10_000 for c in chosen)
    assert chosen == strat.sample_clients(3, pop)  # deterministic in (seed, rnd)


# ---------------- LazyClientPool ----------------
class _StubClient:
    def __init__(self, cid):
        self.cid = cid
        self.row = None

    def export_state(self):
        return self.row

    def import_state(self, state):
        self.row = np.asarray(state, np.float32)


def test_lazy_pool_spills_and_rehydrates():
    pop = Population.synthetic(100, seed=0)
    store = CohortState(TopKCodec(frac=0.5), 4, capacity=64)
    pool = LazyClientPool(pop, _StubClient, capacity=1, state_store=store)
    c0 = pool[0]
    c0.row = np.asarray([1.0, 2.0, 3.0, 4.0], np.float32)
    pool[1]                       # capacity 1: evicts client 0, spilling its row
    assert pool.live == 1
    assert store.get_row(0) is not None
    c0_again = pool[0]            # fresh object, rehydrated carry
    assert c0_again is not c0
    np.testing.assert_array_equal(c0_again.row, [1.0, 2.0, 3.0, 4.0])
    assert pool.materializations == 3
    assert len(pool) == 100
    pool.reset_state()
    assert pool.live == 0 and len(store) == 0


def test_cost_model_profile_for_population():
    pop = Population.from_profiles([PIXEL_4, PIXEL_2])
    cm = CostModel(profiles=[], update_bytes=1, population=pop)
    assert cm.profile_for(0) is PIXEL_4 and cm.profile_for(1) is PIXEL_2
    legacy = CostModel(profiles=[PIXEL_4, PIXEL_2], update_bytes=1)
    assert legacy.profile_for(2) is PIXEL_4  # round-robin unchanged


# ---------------- forced churn: short / empty cohorts ----------------
def test_forced_churn_short_and_empty_cohorts():
    """ISSUE-8 regression: heavy churn leaving the bounded cohort redraw
    short — or EMPTY — must follow the legacy empty-round path, never
    crash.  Every round is recorded; an empty round dispatches nothing,
    aggregates nothing (NaN train_loss, participants == 0, zero
    energy/comm), and the virtual clock keeps moving."""
    profiles = [PIXEL_4, PIXEL_3, PIXEL_2, PIXEL_4]
    pop = Population.from_profiles(profiles)
    m, params, factory = _server_fixture(pop)
    # every profile is battery-powered: mobile_dropout=1.0 downs the WHOLE
    # fleet every round — the all-empty worst case
    dead = AvailabilityTrace.from_profiles(
        pop, seed=0, mobile_dropout=1.0, plugged_dropout=1.0
    )
    cm = CostModel(profiles=[], update_bytes=40_000, population=pop)
    srv = Server(
        strategy=FedAvg(local_epochs=1),
        clients=LazyClientPool(pop, factory, capacity=8),
        cost_model=cm, population=pop, cohort_size=C, availability=dead,
    )
    srv.logger.quiet = True
    g, hist = srv.run(params, num_rounds=3)
    assert len(hist.rounds) == 3
    for rec in hist.rounds:
        assert rec.participants == 0 and rec.dropped == 0
        assert np.isnan(rec.train_loss)
        assert rec.energy_j == 0.0 and rec.comm_bytes == 0
        assert rec.steps == 0
    # nothing aggregated: the global is bitwise the init
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # moderate churn: SHORT cohorts (0 < k < cohort_size) mix with empty
    # ones; aggregation happens exactly on the rounds with participants
    m2, params2, factory2 = _server_fixture(pop)
    flaky = AvailabilityTrace.from_profiles(
        pop, seed=3, mobile_dropout=0.7, plugged_dropout=0.7
    )
    srv2 = Server(
        strategy=FedAvg(local_epochs=1),
        clients=LazyClientPool(pop, factory2, capacity=8),
        cost_model=cm, population=pop, cohort_size=C, availability=flaky,
    )
    srv2.logger.quiet = True
    g2, hist2 = srv2.run(params2, num_rounds=8)
    parts = [rec.participants for rec in hist2.rounds]
    assert len(parts) == 8
    assert any(0 < p < C for p in parts), f"no short cohort in {parts}"
    for rec in hist2.rounds:
        if rec.participants == 0:
            assert np.isnan(rec.train_loss) and rec.comm_bytes == 0
        else:
            assert np.isfinite(rec.train_loss) and rec.comm_bytes > 0
    # training actually happened on the non-empty rounds
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(g2), jax.tree.leaves(params2))
    )
    assert changed == (sum(parts) > 0)

"""Rounds-as-scan benchmark: one compiled run vs the per-round python loop.

The scan driver's reason to exist, measured: ``Server.run_scanned`` compiles
the WHOLE training run into one ``lax.scan`` over the jitted round step —
no per-round python dispatch, no per-round host sync, metrics pulled from
the device exactly once.  This harness runs the same schedule through both
drivers at R rounds and reports rounds/sec plus the compiled memory story:

- ``scan``   — ``run_scanned(...)``: one ``jax.jit`` entry for R rounds,
  donated carry, stacked metrics decoded post-hoc.
- ``python`` — ``run_scanned(..., reference=True)``: the SAME schedule,
  verdict helpers, and jitted round step, re-entering python (and paying a
  ``device_get``) every round — bitwise-equal results, per-round overhead.

Timings exclude compile (one warmup run each) — the win being measured is
dispatch/sync overhead, not tracing.  ``temp_bytes`` is XLA's compiled
scratch allocation at R=8 vs R=32 with per-round-constant batches: the
donated carry must keep it FLAT in R.

Rows print CSV-style like the other benches; ``--out`` (default
``BENCH_scan.json``) captures the results machine-readably so the perf
trajectory accumulates across PRs.

``--smoke`` is the CI guard (tiny model, R in {8, 32}) and asserts the
ISSUE-8 acceptance criteria:

- scanned rounds/sec >= 2x the python driver at R=32, and
- compiled temp memory at R=32 is flat vs R=8 (within 5%).

  PYTHONPATH=src python -m benchmarks.scan_bench [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import (
    AvailabilityTrace, Deadline, FedAvg, PROFILES, RoundSpec, Server,
    make_multi_round_step,
)
from repro.core.cost_model import CostModel
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_size

# same mixed fleet as straggler_bench: the Deadline mask is non-trivial
FLEET = (
    "tpu-v5e-chip", "jetson-tx2-gpu", "jetson-tx2-gpu",
    "pixel-2", "pixel-2", "pixel-3",
)
C = len(FLEET)


def _model():
    """The REDUCED head (7k params, ~0.4ms/round of XLA compute): what
    this bench measures is per-round driver overhead — at the full head's
    ~140ms/round both drivers are compute-bound and indistinguishable."""
    arch = replace(get_config("mobilenet-head-office31"),
                   name="mobilenet-head-office31-reduced")
    return build_model(arch)


def _setup(R, *, steps=2, batch=8, seed=0):
    m = _model()
    params = m.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    batches = {
        "x": jnp.asarray(rng.normal(
            size=(R, C, steps, batch, m.cfg.feature_dim)).astype(np.float32)),
        "y": jnp.asarray(rng.integers(
            0, m.cfg.num_classes, (R, C, steps, batch)).astype(np.int32)),
    }
    spec = RoundSpec(max_steps=steps, execution_mode="parallel")
    cm = CostModel(profiles=[PROFILES[p] for p in FLEET],
                   update_bytes=4 * tree_size(params))
    tau = 1.25 * cm.client_round_cost(1, steps).t_total_s
    trace = AvailabilityTrace.from_profiles(
        [PROFILES[p] for p in FLEET], seed=seed,
        mobile_dropout=0.3, jitter_std=0.1,
    )
    return m, params, batches, spec, cm, tau, trace


def _server(cm, tau, trace):
    srv = Server(strategy=FedAvg(), clients=[], cost_model=cm,
                 policy=Deadline(tau=tau), availability=trace)
    srv.logger.quiet = True
    return srv


def bench_drivers(R, *, repeats=3, seed=0) -> dict:
    """Wall-clock one full R-round run through each driver (post-warmup
    best of ``repeats``) and return rounds/sec for both."""
    m, params, batches, spec, cm, tau, trace = _setup(R, seed=seed)
    kw = dict(loss_fn=m.loss_fn, opt=sgd(0.1), spec=spec, batches=batches)
    out = {"R": R}
    for name, ref in (("scan", False), ("python", True)):
        # ONE server per driver: its compiled-program memo is what makes
        # the warmup count (run_scanned re-seeds strategy/client state per
        # call, so repeats are bitwise-identical runs)
        srv = _server(cm, tau, trace)
        srv.run_scanned(params, R, reference=ref, **kw)  # warmup/compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            _, hist, _ = srv.run_scanned(params, R, reference=ref, **kw)
            best = min(best, time.perf_counter() - t0)
        out[name] = {
            "wall_s": best,
            "rounds_per_s": R / best,
            "final_loss": hist.rounds[-1].train_loss,
        }
    out["speedup"] = out["scan"]["rounds_per_s"] / out["python"]["rounds_per_s"]
    return out


def temp_bytes_vs_rounds(r_values=(8, 32), *, steps=2, batch=8, seed=0) -> dict:
    """Compiled temp allocation of the donated scan at each R, with
    per-round-constant batches (the O(R) inputs removed): flat == the
    carry really aliases in place."""
    m = _model()
    params = m.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    one = {
        "x": jnp.asarray(rng.normal(
            size=(C, steps, batch, m.cfg.feature_dim)).astype(np.float32)),
        "y": jnp.asarray(rng.integers(
            0, m.cfg.num_classes, (C, steps, batch)).astype(np.int32)),
    }
    spec = RoundSpec(max_steps=steps, execution_mode="parallel")
    strat = FedAvg()
    w = jnp.ones((C,))
    bud = jnp.full((C,), steps, jnp.int32)
    cs = spec.codec.init_client_state(C, tree_size(params))
    out = {}
    for R in r_values:
        multi = make_multi_round_step(
            m.loss_fn, sgd(0.1), strat, spec, R, stacked_batches=False
        )
        sched = (jnp.ones((R, C), jnp.float32),
                 jnp.zeros((R, C), jnp.float32),
                 jnp.zeros((R, C), jnp.float32))
        ma = jax.jit(multi, donate_argnums=(0, 1, 2)).lower(
            params, strat.init_state(params), cs, one, w, bud, *sched
        ).compile().memory_analysis()
        out[str(R)] = None if ma is None else int(ma.temp_size_in_bytes)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, nargs="+", default=[8, 32])
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: R in {8, 32} + acceptance asserts")
    ap.add_argument("--out", default="BENCH_scan.json")
    args = ap.parse_args()
    r_values = [8, 32] if args.smoke else args.rounds
    repeats = 2 if args.smoke else args.repeats

    runs = [bench_drivers(R, repeats=repeats) for R in r_values]
    for r in runs:
        print(
            f"scan[R={r['R']}] "
            f"scan={r['scan']['rounds_per_s']:.2f}r/s "
            f"python={r['python']['rounds_per_s']:.2f}r/s "
            f"speedup={r['speedup']:.2f}x "
            f"loss={r['scan']['final_loss']:.4f}"
        )

    temps = temp_bytes_vs_rounds(tuple(r_values))
    print("scan[temp_bytes] " + " ".join(
        f"R={k}:{v}" for k, v in temps.items()
    ))

    with open(args.out, "w") as f:
        json.dump({
            "bench": "scan", "fleet": FLEET, "r_values": r_values,
            "runs": runs, "temp_bytes": temps,
        }, f, indent=2, default=float)
    print(f"scan[json] wrote {args.out}")

    # acceptance guards (CI runs --smoke): the compiled run amortizes the
    # per-round dispatch, and the donated carry keeps memory flat in R
    big = max(runs, key=lambda r: r["R"])
    assert big["speedup"] >= 2.0, (
        f"scan speedup {big['speedup']:.2f}x < 2x at R={big['R']}"
    )
    vals = [v for v in temps.values() if v is not None]
    if len(vals) >= 2:
        assert max(vals) <= min(vals) * 1.05, (
            f"compiled temp memory scales with R: {temps}"
        )
    print(f"scan[guards] OK: {big['speedup']:.2f}x rounds/sec at "
          f"R={big['R']}; temp bytes flat in R")


if __name__ == "__main__":
    main()

"""Compressed-collective benchmark: int8 vs fp32 mesh psum wire.

The mesh shard_map round moves every device's partial weighted sum across
the interconnect — per psum hop, per device, a full model in fp32.
``RoundSpec(collective="int8")`` (``CompressedPsum``) shrinks that to one
byte per element plus a small scale sidecar.  This harness runs the SAME
schedule through both collectives on a real 8-device host-platform mesh
(2 "pods" x 4 "data", hierarchical cross-pod psum) with the reduced head
model and reports:

- cross-link collective bytes per round, fp32 vs int8, from the
  ``CostModel`` tier accounting (tiers derived from the actual mesh via
  ``launch.mesh.collective_tiers`` — the same formula the round billing
  uses, so the bench cannot drift from the shipped accounting);
- final eval loss of both runs — the byte reduction must come at MATCHED
  accuracy, not by under-training;
- wall time per round for both (CPU psums: directional only);
- the sharded client-state memory story: per-device addressable bytes of
  a ``shard_client_state``-laid-out (C, n) residual block vs unsharded.

Rows print CSV-style like the other benches; ``--out`` (default
``BENCH_mesh.json``) captures everything machine-readably.

``--smoke`` is the CI guard and asserts the ISSUE-10 acceptance criteria:

- int8 collective moves >= 3x fewer cross-link bytes than fp32, and
- int8 final loss within 5% of fp32 (matched accuracy), and
- sharded client state is resident at ~1/n_devices per device.

  PYTHONPATH=src python -m benchmarks.mesh_bench [--smoke] [--out F]
"""
from __future__ import annotations

import os
import sys

if "jax" not in sys.modules:  # must land before jax initializes
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8"
        ).strip()

import argparse
import json
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import (
    FedAvg, PROFILES, RoundSpec, init_collective_residual, make_round_step,
)
from repro.core.cost_model import CostModel
from repro.launch.mesh import collective_tiers
from repro.models import build_model
from repro.models.sharding import ShardRules, shard_client_state
from repro.optim import sgd
from repro.utils.pytree import tree_size

C, STEPS, B, ROUNDS = 8, 2, 16, 15
AXES = ("pod", "data")


def _model():
    """The REDUCED head: the bench measures wire accounting and parity,
    not head-size FLOPs."""
    arch = replace(get_config("mobilenet-head-office31"),
                   name="mobilenet-head-office31-reduced")
    return build_model(arch)


def _mesh():
    if len(jax.devices()) < 8:
        raise SystemExit(
            "mesh_bench needs 8 devices (XLA_FLAGS="
            "--xla_force_host_platform_device_count=8 before jax imports)"
        )
    return jax.make_mesh((2, 4), AXES)


def _setup(seed=0):
    m = _model()
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 1.0, size=(m.cfg.num_classes, m.cfg.feature_dim))

    def batch_of(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, m.cfg.num_classes, n)
        x = centers[y] + 0.4 * r.normal(size=(n, m.cfg.feature_dim))
        return x.astype(np.float32), y.astype(np.int32)

    xs, ys = zip(*[batch_of(STEPS * B, 100 + c) for c in range(C)])
    train = {
        "x": jnp.asarray(np.stack(xs).reshape(C, STEPS, B, -1)),
        "y": jnp.asarray(np.stack(ys).reshape(C, STEPS, B)),
    }
    ex, ey = batch_of(512, 999)
    eval_batch = {"x": jnp.asarray(ex), "y": jnp.asarray(ey)}
    return m, m.init(jax.random.key(seed)), train, eval_batch


def run_collective(collective: str, mesh, *, rounds=ROUNDS, seed=0) -> dict:
    """One full mesh training run under the given collective wire."""
    m, params, train, eval_batch = _setup(seed)
    n = tree_size(params)
    spec = RoundSpec(max_steps=STEPS, execution_mode="parallel",
                     collective=collective)
    strat = FedAvg()
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.1), strat, spec, mesh=mesh, client_axes=AXES,
    ))
    cstate = spec.codec.init_client_state(C, n)
    if collective == "int8":
        cstate = (cstate, init_collective_residual(params, C))
    w = jnp.ones(C)
    bud = jnp.full((C,), STEPS, jnp.int32)
    p, state = params, strat.init_state(params)
    p, state, cstate, _ = rs(p, state, cstate, train, w, bud, 0)  # compile
    p, state = params, strat.init_state(params)
    cstate = spec.codec.init_client_state(C, n)
    if collective == "int8":
        cstate = (cstate, init_collective_residual(params, C))
    t0 = time.perf_counter()
    for rnd in range(rounds):
        p, state, cstate, met = rs(p, state, cstate, train, w, bud, rnd)
    jax.block_until_ready(p)
    wall = time.perf_counter() - t0
    loss, _ = m.loss_fn(p, eval_batch)

    cm = CostModel(
        profiles=[PROFILES["tpu-v5e-chip"]], update_bytes=4 * n,
        mesh_tiers=collective_tiers(mesh, AXES), collective=collective,
    )
    return {
        "collective": collective,
        "n_params": int(n),
        "rounds": rounds,
        "final_loss": float(loss),
        "us_per_round": wall / rounds * 1e6,
        "collective_bytes_per_round": int(cm.collective_bytes(n)),
        "collective_bytes_by_tier": {
            k: int(v) for k, v in cm.collective_bytes_by_tier(n).items()
        },
    }


def sharded_state_memory(mesh, n: int = 1 << 14) -> dict:
    """Per-device resident bytes of a (C, n) client-state block laid out by
    ``shard_client_state`` over all 8 mesh devices (fsdp rules) vs the
    replicated layout."""
    rules = ShardRules(mode="fsdp",
                       axis_sizes=tuple(zip(mesh.axis_names,
                                            mesh.devices.shape)))
    block = jnp.zeros((C, n), jnp.float32)
    sharded = shard_client_state(block, mesh, rules)
    per_dev = int(sharded.addressable_shards[0].data.nbytes)
    return {
        "n_elems": n,
        "total_bytes": int(block.nbytes),
        "per_device_bytes": per_dev,
        "reduction": block.nbytes / per_dev,
        "n_devices": int(mesh.devices.size),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: acceptance asserts")
    ap.add_argument("--out", default="BENCH_mesh.json")
    args = ap.parse_args()

    mesh = _mesh()
    runs = {c: run_collective(c, mesh, rounds=args.rounds)
            for c in ("fp32", "int8")}
    for r in runs.values():
        print(
            f"mesh[collective={r['collective']}],{r['us_per_round']:.0f},"
            f"link_bytes={r['collective_bytes_per_round']};"
            f"loss={r['final_loss']:.4f}"
        )
    ratio = (runs["fp32"]["collective_bytes_per_round"]
             / runs["int8"]["collective_bytes_per_round"])
    print(f"mesh[wire_reduction],0,int8_vs_fp32={ratio:.2f}x")

    # fsdp-style state sharding is orthogonal to the collective axes: use a
    # pure fsdp mesh over the same 8 devices for the memory story
    fsdp_mesh = jax.make_mesh((4, 2), ("data", "model"))
    memory = sharded_state_memory(fsdp_mesh)
    print(
        f"mesh[sharded_state],0,per_device_bytes={memory['per_device_bytes']};"
        f"reduction={memory['reduction']:.1f}x"
    )

    with open(args.out, "w") as f:
        json.dump({
            "bench": "mesh",
            "mesh": {"shape": [2, 4], "axes": list(AXES)},
            "runs": runs,
            "wire_reduction": ratio,
            "sharded_state": memory,
        }, f, indent=2, default=float)
    print(f"mesh[json] wrote {args.out}")

    if args.smoke:
        l_fp, l_i8 = (runs[c]["final_loss"] for c in ("fp32", "int8"))
        assert ratio >= 3.0, (
            f"int8 collective only {ratio:.2f}x below fp32 wire (< 3x)"
        )
        assert abs(l_i8 - l_fp) <= 5e-2 * abs(l_fp), (
            f"int8 loss {l_i8:.4f} not matched to fp32 {l_fp:.4f}"
        )
        assert memory["reduction"] >= 0.9 * memory["n_devices"], (
            f"sharded state resident at 1/{memory['reduction']:.1f}, "
            f"expected ~1/{memory['n_devices']}"
        )
        print(f"mesh[guards] OK: {ratio:.2f}x fewer link bytes at matched "
              f"loss ({l_i8:.4f} vs {l_fp:.4f}); state at "
              f"1/{memory['reduction']:.0f} per device")


if __name__ == "__main__":
    main()

"""Population-scale benchmark: round setup cost must be flat in N.

The million-client engine's claim (core/population.py): with the packed
struct-of-arrays fleet, *per-round* work — cohort sampling with streamed
availability, cost ranking, jitter draws, and the CohortState
gather/scatter of codec residual rows — is O(cohort), never O(N).  This
harness measures exactly that loop at a fixed cohort size C while the
population grows 10^3 -> 10^6, and reports:

- ``build_s`` / ``pop_mb``: the one O(N) cost, paid once at construction
  (~1 byte/device: uint8 profile codes + per-class columns);
- ``round_setup_ms``: median per-round time for sample -> rank -> gather ->
  scatter at C=16;
- ``peak_mb``: tracemalloc peak across the measured rounds (started AFTER
  the population is built, so it captures the per-round working set).

Acceptance guards (ISSUE-7, asserted on every run including ``--smoke``):
the 10^6-population round setup time and peak memory stay within 2x of the
10^3 figures (plus small absolute floors — at these scales the absolute
numbers are milliseconds and megabytes, where timer noise lives), and the
packed fleet costs <= 2 bytes/device.

  PYTHONPATH=src python -m benchmarks.population_bench [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import time
import tracemalloc

import numpy as np

from repro.core import (
    AvailabilityTrace, CohortState, CostAwareFedAvg, CostModel, Population,
    TopKCodec,
)

C = 16                  # fixed cohort size: the knob that MAY scale costs
N_PARAMS = 50_000       # residual row width (a head-model-scale vector)
UPDATE_BYTES = 200_000


def _measure(n: int, *, rounds: int, seed: int = 0) -> dict:
    t0 = time.perf_counter()
    pop = Population.synthetic(n, seed=seed)
    build_s = time.perf_counter() - t0

    trace = AvailabilityTrace.from_profiles(pop, seed=seed, jitter_std=0.1)
    cm = CostModel(profiles=[], update_bytes=UPDATE_BYTES, population=pop)
    strat = CostAwareFedAvg(expected_steps=20)
    store = CohortState(TopKCodec(frac=0.01), N_PARAMS, capacity=64)

    tracemalloc.start()
    times = []
    for rnd in range(1, rounds + 1):
        t0 = time.perf_counter()
        cohort = strat.sample_cohort(
            rnd, pop, C, availability=trace, cost_model=cm, deadline_s=30.0
        )
        trace.step_jitter_for(rnd, cohort)
        dense = store.gather(cohort)
        # stand-in for the jitted round's residual update: any (C, n) result
        store.scatter(cohort, np.asarray(dense) + 1.0)
        times.append(time.perf_counter() - t0)
        assert len(cohort) == C
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    return {
        "n": n,
        "build_s": build_s,
        "pop_mb": pop.nbytes / 1e6,
        "bytes_per_device": pop.nbytes / len(pop),
        "round_setup_ms": float(np.median(times) * 1e3),
        "peak_mb": peak / 1e6,
        "rounds": rounds,
        "cohort": C,
        "store_rows": len(store),
        "store_evictions": store.evictions,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: endpoints only (10^3 and 10^6)")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--out", default="BENCH_population.json")
    args = ap.parse_args()
    ns = (1_000, 1_000_000) if args.smoke else (1_000, 10_000, 100_000, 1_000_000)

    rows = [_measure(n, rounds=args.rounds) for n in ns]
    for r in rows:
        print(
            f"population[n={r['n']}] build={r['build_s'] * 1e3:.1f}ms "
            f"pop={r['pop_mb']:.3f}MB ({r['bytes_per_device']:.2f} B/dev) "
            f"round_setup={r['round_setup_ms']:.2f}ms peak={r['peak_mb']:.1f}MB"
        )

    with open(args.out, "w") as f:
        json.dump({
            "bench": "population", "cohort": C, "n_params": N_PARAMS,
            "rounds": args.rounds, "runs": rows,
        }, f, indent=2, default=float)
    print(f"population[json] wrote {args.out}")

    small, big = rows[0], rows[-1]
    # flat-in-N guards: 2x plus an absolute floor (2 ms / 4 MB) so millisecond
    # timer noise and allocator quantization cannot flake the ratio
    t_small, t_big = small["round_setup_ms"], big["round_setup_ms"]
    assert t_big <= max(2.0 * t_small, t_small + 2.0), (
        f"round setup grew with N: {t_big:.2f}ms at n={big['n']} vs "
        f"{t_small:.2f}ms at n={small['n']}"
    )
    m_small, m_big = small["peak_mb"], big["peak_mb"]
    assert m_big <= max(2.0 * m_small, m_small + 4.0), (
        f"round peak memory grew with N: {m_big:.1f}MB vs {m_small:.1f}MB"
    )
    assert big["bytes_per_device"] <= 2.0, (
        f"packed fleet costs {big['bytes_per_device']:.2f} B/device (> 2)"
    )
    print(
        "population[guards] OK: round setup "
        f"{t_small:.2f}ms -> {t_big:.2f}ms and peak {m_small:.1f}MB -> "
        f"{m_big:.1f}MB across a 1000x population growth at C={C}"
    )


if __name__ == "__main__":
    main()

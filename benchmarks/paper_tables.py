"""Paper-table reproductions (qualitative trends at CPU scale).

Each function mirrors one table of "On-device Federated Learning with
Flower" with synthetic data + the calibrated cost model, and returns rows
[(label, accuracy, sim_minutes, sim_kJ)].  The paper's absolute numbers are
device+dataset specific; the claims under test are the TRENDS (see
EXPERIMENTS.md §Paper-validation).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.resnet18_cifar10 import CNN_CONFIG
from repro.core import FedAvg, FedTau, JaxClient, PROFILES, Server
from repro.core.cost_model import CostModel
from repro.core.server import make_cost_model_for
from repro.data.federated import dirichlet_partition
from repro.data.synthetic import make_classification, make_features
from repro.models import build_model, resnet


# NOTE: the Jetson workload benches use the head model as a fast surrogate
# for ResNet-18/CIFAR-10 — conv compiles take minutes on this 1-core CPU
# container while the system-cost accounting (the thing these tables
# measure) is model-independent.  The full JAX ResNet-18 is exercised by
# tests/ and examples/heterogeneous_cutoff.py.
_HEAD = build_model("mobilenet-head-office31")


def _resnet_setup(n_clients: int, seed=0):
    data = make_features(n=1200, num_classes=31, feature_dim=_HEAD.cfg.feature_dim,
                         seed=seed)
    shards = dirichlet_partition(data, n_clients=n_clients, alpha=1.0, seed=seed)
    params = _HEAD.init(jax.random.key(seed))
    mask = _HEAD.trainable_mask(params)
    clients = [
        JaxClient(client_id=c.client_id, loss_fn=_HEAD.loss_fn, dataset=c,
                  batch_size=32, trainable_mask=mask)
        for c in shards
    ]
    return params, clients


def table2a(rounds: int = 2, epochs_grid=(1, 3, 5)) -> list[tuple]:
    """Vary local epochs E on the Jetson fleet (ResNet/CIFAR-like).

    Paper Table 2a: E up => accuracy up, time up, energy up."""
    rows = []
    for e in epochs_grid:
        params, clients = _resnet_setup(n_clients=4)
        cm = make_cost_model_for(params, [PROFILES["jetson-tx2-gpu"]] * 4)
        server = Server(strategy=FedAvg(local_epochs=e, local_lr=0.05),
                        clients=clients, cost_model=cm)
        server.logger.quiet = True
        _, hist = server.run(params, num_rounds=rounds)
        rows.append((f"E={e}", hist.final_accuracy(),
                     hist.total_time_s / 60, hist.total_energy_j / 1e3))
    return rows


def table2b(rounds: int = 2, clients_grid=(4, 7, 10)) -> list[tuple]:
    """Vary client count C on the Android fleet (head model / Office-31-like).

    Paper Table 2b: C up => accuracy up, energy up, wall ~flat."""
    m = build_model("mobilenet-head-office31")
    rows = []
    for c in clients_grid:
        # each participating device contributes ITS OWN data (the paper's
        # setting): total examples scale with C, per-client size is fixed
        data = make_features(n=250 * c, num_classes=31, feature_dim=m.cfg.feature_dim, seed=1)
        shards = dirichlet_partition(data, n_clients=c, alpha=0.5, seed=1)
        params = m.init(jax.random.key(1))
        mask = m.trainable_mask(params)
        fleet = [PROFILES[name] for name in
                 ("pixel-4", "pixel-3", "pixel-2", "galaxy-tab-s6", "galaxy-tab-s4")]
        clients = [
            JaxClient(client_id=s.client_id, loss_fn=m.loss_fn, dataset=s,
                      batch_size=32, trainable_mask=mask)
            for s in shards
        ]
        cm = make_cost_model_for(params, [fleet[i % len(fleet)] for i in range(c)])
        server = Server(strategy=FedAvg(local_epochs=5, local_lr=0.1),
                        clients=clients, cost_model=cm)
        server.logger.quiet = True
        _, hist = server.run(params, num_rounds=rounds)
        rows.append((f"C={c}", hist.final_accuracy(),
                     hist.total_time_s / 60, hist.total_energy_j / 1e3))
    return rows


def table3(rounds: int = 2, epochs: int = 3) -> list[tuple]:
    """Computational heterogeneity + processor-specific cutoff tau.

    Paper Table 3: CPU(tau=0) ~1.27x GPU time at equal accuracy; setting
    tau = GPU round time equalizes walls at a small accuracy drop."""
    rows = []
    params0, clients0 = _resnet_setup(n_clients=4, seed=2)
    spe = clients0[0].steps_per_epoch()

    def run(profile: str, tau_mult: float | None):
        params, clients = _resnet_setup(n_clients=4, seed=2)
        cm = make_cost_model_for(params, [PROFILES[profile]] * 4)
        if tau_mult is None:
            strat = FedTau(local_epochs=epochs, local_lr=0.05, tau_s=0.0,
                           cost_model=cm, steps_per_epoch=spe)
        else:
            tau = cm.tau_for_profile("jetson-tx2-gpu", epochs=epochs,
                                     steps_per_epoch=spe) * tau_mult
            strat = FedTau(local_epochs=epochs, local_lr=0.05, tau_s=tau,
                           cost_model=cm, steps_per_epoch=spe)
        server = Server(strategy=strat, clients=clients, cost_model=cm)
        server.logger.quiet = True
        _, hist = server.run(params, num_rounds=rounds)
        return hist

    h_gpu = run("jetson-tx2-gpu", None)
    rows.append(("GPU tau=0", h_gpu.final_accuracy(), h_gpu.total_time_s / 60,
                 h_gpu.total_energy_j / 1e3))
    h_cpu = run("jetson-tx2-cpu", None)
    rows.append(("CPU tau=0", h_cpu.final_accuracy(), h_cpu.total_time_s / 60,
                 h_cpu.total_energy_j / 1e3))
    h_tau112 = run("jetson-tx2-cpu", 1.12)   # paper's tau=2.23 ~ 1.12x GPU round
    rows.append(("CPU tau=1.12xGPU", h_tau112.final_accuracy(),
                 h_tau112.total_time_s / 60, h_tau112.total_energy_j / 1e3))
    h_tau = run("jetson-tx2-cpu", 1.0)       # paper's tau=1.99 = GPU round time
    rows.append(("CPU tau=GPU", h_tau.final_accuracy(), h_tau.total_time_s / 60,
                 h_tau.total_energy_j / 1e3))
    return rows

"""Render EXPERIMENTS.md tables from dryrun_results.json.

  PYTHONPATH=src python -m benchmarks.roofline_report dryrun_results.json
"""
from __future__ import annotations

import json
import sys

ARCH_ORDER = (
    "mixtral-8x7b", "jamba-1.5-large-398b", "xlstm-1.3b", "stablelm-3b",
    "granite-8b", "paligemma-3b", "qwen3-0.6b", "minicpm3-4b",
    "musicgen-medium", "deepseek-moe-16b",
)
SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def render(path: str, mesh: str = "16x16") -> str:
    with open(path) as f:
        rows = json.load(f)
    by_key = {(r["arch"], r["shape"], r["mesh"]): r for r in rows}
    out = [
        "| arch | shape | mem/dev (GB) | compute (ms) | memory (ms) | "
        "collective (ms) | dominant | useful-FLOP frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = by_key.get((a, s, mesh))
            if r is None:
                out.append(f"| {a} | {s} | — | — | — | — | (pending) | — |")
                continue
            fit = "" if r["per_device_gb"] <= 16 else " ⚠"
            out.append(
                f"| {a} | {s} | {r['per_device_gb']:.2f}{fit} | "
                f"{r['compute_ms']:.1f} | {r['memory_ms']:.1f} | "
                f"{r['collective_ms']:.1f} | {r['dominant']} | "
                f"{r['useful_flops_frac']:.2f} |"
            )
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "16x16"
    print(render(path, mesh))

"""Straggler benchmark: sync vs Deadline vs BufferedAsync on a mixed fleet.

The virtual-clock layer's reason to exist, measured: on a Pixel/Jetson/TPU
fleet whose slowest device steps ~37x slower than its fastest, lockstep
FedAvg pays the straggler's wall clock every round.  This harness runs the
same task under three round policies and reports the paper's axes —
accuracy, simulated convergence time, energy — plus participation and
staleness:

- ``sync``      — ``SyncAll``: the classic loop; every round waits for the
  slowest pixel.
- ``deadline``  — ``Deadline(tau)``: rounds cut at the Jetson-class round
  time; pixels are dropped (wasted work is charged) but the clock flies.
- ``fedbuff``   — ``FedBuffStrategy`` + ``BufferedAsync(K)``: aggregate the
  first K arrivals, stragglers report late with staleness-discounted
  weight.  Runs 2x the rounds of sync — that is the async story: more
  aggregations in less virtual time.

Rows print CSV-style like the other benches; ``--out`` (default
``BENCH_straggler.json``) captures the full result set machine-readably so
the perf trajectory accumulates across PRs.

``--smoke`` is the CI guard (tiny model, 4 sync rounds) and asserts the
ISSUE-5 acceptance criteria:

- FedBuff reaches the seed FedAvg eval accuracy (within 0.02), and
- both cost-driven policies finish in less virtual wall-clock than
  ``SyncAll`` on the straggler-heavy fleet.

  PYTHONPATH=src python -m benchmarks.straggler_bench [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core import (
    AvailabilityTrace, BufferedAsync, CostAwareFedAvg, CostModel, Deadline,
    FedAvg, FedBuffStrategy, JaxClient, LazyClientPool, PROFILES, Population,
    Server, SyncAll,
)
from repro.core.server import make_cost_model_for
from repro.data.federated import ClientDataset, dirichlet_partition
from repro.data.synthetic import make_features
from repro.models import build_model
from repro.utils.pytree import tree_bytes

# straggler-heavy: one datacenter chip, two edge boards, three phones —
# step times 0.010 / 0.153 / 0.290-0.370 s (a ~37x spread)
FLEET = (
    "tpu-v5e-chip", "jetson-tx2-gpu", "jetson-tx2-gpu",
    "pixel-2", "pixel-2", "pixel-3",
)


def _setup(seed=0, n=1200):
    m = build_model("mobilenet-head-office31")
    data = make_features(n=n, num_classes=31, feature_dim=m.cfg.feature_dim,
                         seed=seed)
    shards = dirichlet_partition(data, n_clients=len(FLEET), alpha=100.0,
                                 seed=seed)
    params = m.init(jax.random.key(seed))
    mask = m.trainable_mask(params)
    clients = [
        JaxClient(client_id=c.client_id, loss_fn=m.loss_fn, dataset=c,
                  batch_size=32, trainable_mask=mask, device_profile=prof)
        for c, prof in zip(shards, FLEET)
    ]
    cm = make_cost_model_for(params, [PROFILES[p] for p in FLEET])
    return m, params, clients, cm


def _run(policy_name, strategy, policy, rounds, *, availability=None, seed=0):
    """One fresh experiment (clients rebuilt: the batch cursor is stateful)."""
    m, params, clients, cm = _setup(seed=seed)
    srv = Server(strategy=strategy, clients=clients, cost_model=cm,
                 policy=policy, availability=availability)
    srv.logger.quiet = True
    _, hist = srv.run(params, num_rounds=rounds)
    return {
        "policy": policy_name,
        "rounds": rounds,
        "final_acc": hist.final_accuracy(),
        "total_time_s": hist.total_time_s,
        "total_energy_kj": hist.total_energy_j / 1e3,
        "comm_mb": sum(r.comm_bytes for r in hist.rounds) / 1e6,
        "mean_participants": float(np.mean([r.participants for r in hist.rounds])),
        "dropped_total": sum(r.dropped for r in hist.rounds),
        "mean_staleness": float(np.mean([r.staleness_mean for r in hist.rounds])),
        # per ROUND (None on eval-less rounds), aligned with wall_series so
        # time-to-accuracy arithmetic stays correct under eval_every > 1
        "acc_series": [r.eval_acc for r in hist.rounds],
        "wall_series": [r.wall_time_s for r in hist.rounds],
    }


POP_N, POP_COHORT, POP_SHARD = 60, 8, 32
POP_MIX = ("jetson-tx2-gpu", "pixel-2", "pixel-3")


def _run_population(policy_name, strategy, rounds, *, seed=0):
    """Population-mode comparison row: blind vs cost-aware sampling at
    EQUAL cohort size under the same Deadline(tau).  The fleet is a packed
    60-device jetson/pixel population served by a LazyClientPool; the only
    difference between the two rows is who gets drawn."""
    m = build_model("mobilenet-head-office31")
    data = make_features(n=POP_N * POP_SHARD, num_classes=31,
                         feature_dim=m.cfg.feature_dim, seed=seed)
    params = m.init(jax.random.key(seed))
    mask = m.trainable_mask(params)
    pop = Population.synthetic(POP_N, mix=POP_MIX, seed=seed)

    def factory(cid):
        lo = cid * POP_SHARD
        return JaxClient(
            client_id=cid, loss_fn=m.loss_fn, batch_size=16,
            dataset=ClientDataset(client_id=cid, x=data.x[lo:lo + POP_SHARD],
                                  y=data.y[lo:lo + POP_SHARD]),
            trainable_mask=mask, device_profile=pop.profile(cid).name,
        )

    cm = CostModel(profiles=[], update_bytes=tree_bytes(params), population=pop)
    spe = POP_SHARD // 16
    jet = PROFILES["jetson-tx2-gpu"]
    tau = 1.25 * (spe * jet.step_time_s
                  + jet.comm_time_s(cm.update_bytes, cm.update_bytes))
    srv = Server(
        strategy=strategy, clients=LazyClientPool(pop, factory, capacity=POP_N),
        cost_model=cm, policy=Deadline(tau=tau),
        population=pop, cohort_size=POP_COHORT,
    )
    srv.logger.quiet = True
    _, hist = srv.run(params, num_rounds=rounds)
    return {
        "policy": policy_name,
        "rounds": rounds,
        "final_acc": hist.final_accuracy(),
        "total_time_s": hist.total_time_s,
        "total_energy_kj": hist.total_energy_j / 1e3,
        "comm_mb": sum(r.comm_bytes for r in hist.rounds) / 1e6,
        "mean_participants": float(np.mean([r.participants for r in hist.rounds])),
        "dropped_total": sum(r.dropped for r in hist.rounds),
        "mean_staleness": float(np.mean([r.staleness_mean for r in hist.rounds])),
        "acc_series": [r.eval_acc for r in hist.rounds],
        "wall_series": [r.wall_time_s for r in hist.rounds],
    }


def time_to_acc(run: dict, target: float) -> float | None:
    """History.time_to_accuracy over the serialized series (same contract:
    cumulative virtual wall time through the first eval round >= target)."""
    t = 0.0
    for wall, acc in zip(run["wall_series"], run["acc_series"]):
        t += wall
        if acc is not None and acc >= target:
            return t
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: tiny run + acceptance asserts")
    ap.add_argument("--out", default="BENCH_straggler.json")
    args = ap.parse_args()
    rounds = 4 if args.smoke else args.rounds

    m, params, clients, cm = _setup()
    spe = clients[0].steps_per_epoch()
    # cut where the Jetson class (client 1) finishes a full round —
    # compute AND comm — with 25% slack: TPUs+Jetsons report, pixels drop
    tau = 1.25 * cm.client_round_cost(1, spe).t_total_s

    fedbuff = FedBuffStrategy(local_epochs=1, local_lr=0.1, buffer_size=3,
                              max_staleness=4, alpha=0.5)
    runs = [
        _run("sync", FedAvg(local_epochs=1, local_lr=0.1), SyncAll(), rounds),
        _run("deadline", FedAvg(local_epochs=1, local_lr=0.1),
             Deadline(tau=tau), rounds),
        # async aggregates K=3 of 6 per round: 2x the rounds in (far) less
        # virtual time is the point
        _run("fedbuff", fedbuff, fedbuff.make_policy(), 2 * rounds),
    ]
    if not args.smoke:
        # churn study: the sync loop under seeded dropout/jitter traces
        trace = AvailabilityTrace.from_profiles(
            [PROFILES[p] for p in FLEET], seed=0, jitter_std=0.1
        )
        runs.append(_run("sync_churn", FedAvg(local_epochs=1, local_lr=0.1),
                         SyncAll(), rounds, availability=trace))

    # population mode, same deadline + cohort size: the only difference is
    # WHO gets sampled — blind uniform vs cost-aware (Oort-lite) ranking
    runs += [
        _run_population("pop_blind", FedAvg(local_epochs=1, local_lr=0.1),
                        rounds),
        _run_population(
            "pop_costaware",
            CostAwareFedAvg(local_epochs=1, local_lr=0.1, expected_steps=2),
            rounds,
        ),
    ]

    by_name = {r["policy"]: r for r in runs}
    target = 0.9 * by_name["sync"]["final_acc"]
    for r in runs:
        r["time_to_target_s"] = time_to_acc(r, target)
        print(
            f"straggler[{r['policy']}] rounds={r['rounds']} "
            f"acc={r['final_acc']:.3f} wall={r['total_time_s']:.1f}s "
            f"tta@{target:.2f}={r['time_to_target_s']} "
            f"energy={r['total_energy_kj']:.2f}kJ comm={r['comm_mb']:.2f}MB "
            f"parts={r['mean_participants']:.1f} "
            f"dropped={r['dropped_total']} stale={r['mean_staleness']:.2f}"
        )

    with open(args.out, "w") as f:
        json.dump({
            "bench": "straggler", "fleet": FLEET, "rounds": rounds,
            "tau_s": tau, "target_acc": target, "runs": runs,
        }, f, indent=2, default=float)
    print(f"straggler[json] wrote {args.out}")

    # acceptance guards (CI runs --smoke): the cost-driven policies beat
    # lockstep wall-clock, and buffered async still reaches FedAvg accuracy
    sync, ddl, buf = by_name["sync"], by_name["deadline"], by_name["fedbuff"]
    assert ddl["total_time_s"] < sync["total_time_s"], (
        f"Deadline wall {ddl['total_time_s']} !< SyncAll {sync['total_time_s']}"
    )
    assert buf["total_time_s"] < sync["total_time_s"], (
        f"BufferedAsync wall {buf['total_time_s']} !< SyncAll "
        f"{sync['total_time_s']} (even at 2x rounds)"
    )
    assert buf["final_acc"] >= sync["final_acc"] - 0.02, (
        f"FedBuff acc {buf['final_acc']} below FedAvg {sync['final_acc']}"
    )
    assert ddl["dropped_total"] > 0 and buf["mean_staleness"] > 0
    # ISSUE-7: cost-aware sampling makes the SAME cohort size lose fewer
    # clients to the SAME deadline than the blind draw
    blind, aware = by_name["pop_blind"], by_name["pop_costaware"]
    assert aware["dropped_total"] < blind["dropped_total"], (
        f"cost-aware drops {aware['dropped_total']} !< blind "
        f"{blind['dropped_total']} at equal cohort size"
    )
    assert aware["mean_participants"] >= blind["mean_participants"]
    print("straggler[guards] OK: deadline+async beat sync wall; "
          "fedbuff holds FedAvg accuracy; cost-aware sampling drops "
          f"{aware['dropped_total']} vs blind {blind['dropped_total']}")


if __name__ == "__main__":
    main()

"""Compression benchmark: accuracy-vs-wire-bytes + fused-kernel bandwidth.

Five sections, CSV rows like benchmarks/run.py:

1. ``wire[...]``    — per-client uplink bytes for the FULL resnet18_cifar10
   and qwen3_0_6b configs under every codec (param counts via
   ``jax.eval_shape``: nothing is allocated), with the reduction ratio vs
   the fp32 wire.  ISSUE-1 acceptance: Int8 >= 3.5x.
2. ``acc[...]``     — the compressed round engine run for ``--rounds`` on
   CPU-reduced variants of both configs: final eval loss per codec next to
   the cumulative uplink bytes it cost (the paper's accuracy-vs-system-cost
   tradeoff, with communication as the cost axis).
3. ``kernel[...]``  — interpret-mode timing of the fused dequant+reduce
   Pallas kernel vs the unfused dequantize-then-fedavg_reduce pair, with
   effective GB/s over the int8 payload.
4. ``topk[...]``    — the O(C·k) scatter-accumulate TopK reduce vs the
   densify-then-fedavg_reduce baseline over a (C, N, k-fraction) sweep:
   per-call time plus peak intermediate bytes (XLA ``memory_analysis``
   temps when the backend reports them, the analytic payload/dense-matrix
   sizes otherwise).  ISSUE-3 acceptance: sparse beats dense at
   k/N <= 0.1 for C >= 8.
5. ``sparse[...]``  — path-selection guard: asserts ``TopKCodec`` routes
   ``aggregate_batch``/``reduce`` through the sparse scatter dispatch and
   NEVER through ``decode_batch`` densification (a regression here fails
   the benchmark, which CI runs with ``--smoke``).
6. ``mixed[...]``   — mixed-fleet sweep: a Pixel→TopK / Jetson→Int8 /
   TPU→Null fleet aggregated by ONE ``MixedCodec.aggregate_batch`` (each
   group on its own kernel path) — fleet wire bytes + reduce time next to
   every single-codec fleet baseline, with a guard that the mixed fleet
   ships strictly less wire than the uncompressed one and that the TopK
   group is never densified.
7. ``lora[...]``    — the structured-update frontier: per-client wire for
   ``LoRACodec`` over a rank sweep at FULL LLM param counts (qwen3-0.6b +
   the MoE mixtral-8x7b, shapes via ``jax.eval_shape``), then the
   accuracy-vs-wire run on the reduced LM: final eval loss under
   fp32/int8/lora next to the uplink each cost.  Results land in
   ``BENCH_lora.json``.  Guards (every mode): LoRA wire < dense Int8 at
   every rank in the sweep; the training run reaches >= 10x less wire
   than Int8 at a final loss within 5% (this PR's acceptance bar).

  PYTHONPATH=src python -m benchmarks.compression_bench [--fast|--smoke]

``--smoke`` is the CI guard: the tiny head model, 2 rounds, small kernel
shapes — it exists so the harness itself cannot silently rot (every section
executes against the live engine API on every push).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import FedAvg, Int8Codec, NullCodec, RoundSpec, TopKCodec, make_round_step
from repro.data.loader import lm_round_batch
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_size

CODECS = {
    "fp32": NullCodec(),
    "int8": Int8Codec(),
    "topk1%": TopKCodec(frac=0.01),
}


def _timeit(fn, *args, n=3):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def _timeit_median(fn, *args, n=9):
    """Median of n individually timed calls — robust to the multi-second
    scheduler stalls of shared CI hosts that a mean-of-batch absorbs."""
    jax.block_until_ready(fn(*args))  # compile
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6  # us


# ---------------------------------------------------------------- section 1
def bench_wire_bytes() -> list[str]:
    rows = []
    for arch in ("resnet18-cifar10", "qwen3-0.6b"):
        m = build_model(arch)
        shapes = jax.eval_shape(m.init, jax.random.key(0))
        n_params = int(sum(np.prod(s.shape) for s in jax.tree.leaves(shapes)))
        fp32 = CODECS["fp32"].wire_bytes(n_params)
        for name, codec in CODECS.items():
            wb = codec.wire_bytes(n_params)
            rows.append(
                f"wire[{arch}/{name}],0,"
                f"bytes={wb};reduction_vs_fp32={fp32 / wb:.2f}x"
            )
    return rows


# ---------------------------------------------------------------- section 2
def _run_rounds(m, params, train, eval_batch, codec, rounds):
    strat = FedAvg()
    C = int(jax.tree.leaves(train)[0].shape[0])
    steps = int(jax.tree.leaves(train)[0].shape[1])
    spec = RoundSpec(max_steps=steps, execution_mode="parallel", codec=codec)
    rs = jax.jit(make_round_step(m.loss_fn, sgd(0.1), strat, spec))
    w = jnp.ones(C)
    bud = jnp.full((C,), steps, jnp.int32)
    p, state = params, strat.init_state(params)
    cstate = codec.init_client_state(C, tree_size(params))
    for rnd in range(rounds):
        p, state, cstate, _ = rs(p, state, cstate, train, w, bud, rnd)
    loss, _ = m.loss_fn(p, eval_batch)
    uplink = codec.wire_bytes(tree_size(params)) * C * rounds
    return float(loss), uplink


def _cnn_setup(seed=0):
    m = build_model(get_config("resnet18-cifar10").reduced())
    cfg = m.cfg
    rng = np.random.default_rng(seed)
    C, steps, B = 3, 1, 8
    shape = (cfg.image_size, cfg.image_size, cfg.channels)
    centers = rng.normal(0.0, 0.8, size=(cfg.num_classes, *shape))
    y = rng.integers(0, cfg.num_classes, (C, steps, B))
    x = centers[y] + 0.5 * rng.normal(size=(C, steps, B, *shape))
    ye = rng.integers(0, cfg.num_classes, 64)
    xe = centers[ye] + 0.5 * rng.normal(size=(64, *shape))
    train = {"x": jnp.asarray(x, jnp.float32), "y": jnp.asarray(y, jnp.int32)}
    eval_batch = {"x": jnp.asarray(xe, jnp.float32), "y": jnp.asarray(ye, jnp.int32)}
    return m, m.init(jax.random.key(seed)), train, eval_batch


def _lm_setup(seed=0):
    cfg = get_config("qwen3-0.6b").reduced()
    m = build_model(cfg)
    C, steps, B, S = 2, 1, 2, 64
    train = lm_round_batch(
        n_clients=C, steps=steps, batch_size=B, seq_len=S,
        vocab_size=cfg.vocab_size, seed=seed,
    )
    train = jax.tree.map(jnp.asarray, train)
    hold = lm_round_batch(
        n_clients=1, steps=1, batch_size=4, seq_len=S,
        vocab_size=cfg.vocab_size, seed=seed + 1,
    )
    eval_batch = {k: jnp.asarray(v[0, 0]) for k, v in hold.items()}
    return m, m.init(jax.random.key(seed)), train, eval_batch


def _head_setup(seed=0):
    """Tiny head model — the --smoke fixture (sub-second per codec)."""
    m = build_model("mobilenet-head-office31")
    rng = np.random.default_rng(seed)
    C, steps, B = 2, 1, 8
    centers = rng.normal(0.0, 1.0, size=(m.cfg.num_classes, m.cfg.feature_dim))
    y = rng.integers(0, m.cfg.num_classes, (C, steps, B))
    x = centers[y] + 0.4 * rng.normal(size=(C, steps, B, m.cfg.feature_dim))
    ye = rng.integers(0, m.cfg.num_classes, 64)
    xe = centers[ye] + 0.4 * rng.normal(size=(64, m.cfg.feature_dim))
    train = {"x": jnp.asarray(x, jnp.float32), "y": jnp.asarray(y, jnp.int32)}
    eval_batch = {"x": jnp.asarray(xe, jnp.float32), "y": jnp.asarray(ye, jnp.int32)}
    return m, m.init(jax.random.key(seed)), train, eval_batch


def bench_accuracy_vs_bytes(rounds: int, smoke: bool = False) -> list[str]:
    rows = []
    setups = (
        (("head_office31", _head_setup),) if smoke
        else (("resnet18_cifar10", _cnn_setup), ("qwen3_0_6b", _lm_setup))
    )
    for label, setup in setups:
        m, params, train, eval_batch = setup()
        for name, codec in CODECS.items():
            t0 = time.perf_counter()
            loss, uplink = _run_rounds(m, params, train, eval_batch, codec, rounds)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(
                f"acc[{label}/{name}],{us:.0f},"
                f"eval_loss={loss:.4f};uplink_bytes={uplink}"
            )
    return rows


# ---------------------------------------------------------------- section 3
def bench_kernel(fast: bool) -> list[str]:
    from repro.kernels import ref
    from repro.kernels.dequant_reduce import dequant_reduce
    from repro.kernels.fedavg_reduce import fedavg_reduce
    from repro.kernels.quantize import dequantize_int8

    rng = np.random.default_rng(0)
    c, n = (4, 1 << 16) if fast else (8, 1 << 18)
    x = jnp.asarray(rng.normal(size=(c * n,)), jnp.float32)
    q, s = ref.quantize_int8(x)
    q = q.reshape(c, n)
    s = s.reshape(c, n // 256)
    w = jnp.asarray(rng.random(c) + 0.1, jnp.float32)

    fused = jax.jit(lambda q, s, w: dequant_reduce(q, s, w, interpret=True))

    def unfused_fn(q, s, w):
        dense = dequantize_int8(
            q.reshape(-1), s.reshape(-1), interpret=True
        ).reshape(c, n)
        return fedavg_reduce(dense, w, interpret=True)

    unfused = jax.jit(unfused_fn)

    us_f = _timeit(fused, q, s, w)
    us_u = _timeit(unfused, q, s, w)
    payload = c * n + 4 * c * (n // 256)  # int8 + scales over the wire
    gbps = payload / (us_f / 1e6) / 1e9
    return [
        f"kernel[dequant_reduce_fused_{c}x{n}],{us_f:.0f},GBps={gbps:.2f}",
        f"kernel[dequant_then_reduce_{c}x{n}],{us_u:.0f},fused_speedup={us_u / us_f:.2f}x",
    ]


# ---------------------------------------------------------------- section 4
def _temp_bytes(fn, *args):
    """Peak XLA temp allocation of jit(fn)(*args), or None if unreported."""
    try:
        mem = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(mem.temp_size_in_bytes)
    except Exception:
        return None


def bench_topk_reduce(fast: bool) -> list[str]:
    """Sparse scatter-accumulate vs densify baseline over (C, N, k/N)."""
    from repro.core import TopKCodec
    from repro.kernels import ops, ref

    sweep = (
        [(8, 1 << 14), (8, 1 << 16)] if fast
        else [(8, 1 << 16), (8, 1 << 18), (32, 1 << 16), (32, 1 << 18)]
    )
    rows = []
    rng = np.random.default_rng(0)
    for c, n in sweep:
        deltas = jnp.asarray(rng.normal(size=(c, n)) * 0.01, jnp.float32)
        w = jnp.asarray(rng.random(c) + 0.1, jnp.float32)
        for frac in (0.01, 0.1):
            codec = TopKCodec(frac=frac)
            k = codec.k_of(n)
            enc = codec.encode_batch(deltas)
            idx, val = enc["idx"], enc["val"]

            def sparse_fn(idx, val, w):
                return ops.topk_scatter_reduce(idx, val, w, n)

            def dense_fn(idx, val, w):
                dense = (
                    jnp.zeros((c, n), val.dtype).at[jnp.arange(c)[:, None], idx]
                    .add(val)
                )  # the pre-ISSUE-3 densify: (C, N) materialized in HBM
                return ref.fedavg_reduce(dense, w)

            us_s = _timeit_median(jax.jit(sparse_fn), idx, val, w)
            us_d = _timeit_median(jax.jit(dense_fn), idx, val, w)
            # peak intermediates: measured temps when available, else the
            # analytic sizes (dense: the (C, N) fp32 matrix; sparse: the
            # payload + the (N,) fp32 accumulator)
            tb_s = _temp_bytes(sparse_fn, idx, val, w)
            tb_d = _temp_bytes(dense_fn, idx, val, w)
            # use measured temps only when BOTH sides report (0 is a valid
            # measurement); otherwise both analytic, never a mixed ratio
            if tb_s is not None and tb_d is not None:
                ib_s, ib_d = tb_s, tb_d
            else:
                ib_s, ib_d = c * k * 8 + n * 4, c * n * 4
            rows.append(
                f"topk[C{c}_N{n}_k{frac}],{us_s:.0f},"
                f"dense_us={us_d:.0f};speedup={us_d / us_s:.2f}x;"
                f"peak_intermediate_bytes={ib_s};dense_intermediate_bytes={ib_d};"
                f"mem_reduction={ib_d / max(ib_s, 1):.1f}x"
            )
    return rows


# ---------------------------------------------------------------- section 5
def check_sparse_path_selected() -> list[str]:
    """Assert TopK aggregation routes through the sparse scatter dispatch
    (ops.topk_scatter_reduce) and never densifies via decode_batch."""
    from repro.core import TopKCodec
    from repro.kernels import ops, ref

    codec = TopKCodec(frac=0.1)
    rng = np.random.default_rng(1)
    c, n = 8, 4096
    deltas = jnp.asarray(rng.normal(size=(c, n)) * 0.01, jnp.float32)
    w = jnp.asarray(rng.random(c) + 0.1, jnp.float32)
    state = codec.init_client_state(c, n)

    from repro.core.compression import ban_topk_densify

    before = ops.topk_sparse_calls()
    with ban_topk_densify():  # any densify on the aggregation path is banned
        avg, new_state = codec.aggregate_batch(deltas, w, state)
    calls = ops.topk_sparse_calls() - before
    assert calls >= 1, "sparse scatter dispatch was never reached"

    # and the sparse result still equals the dense reference within 1e-5
    enc = codec.encode_batch(deltas + state)
    exp = ref.fedavg_reduce(codec.decode_batch(enc), w)
    np.testing.assert_allclose(np.asarray(avg), np.asarray(exp),
                               atol=1e-5, rtol=1e-5)
    err = float(np.max(np.abs(np.asarray(avg) - np.asarray(exp))))
    return [f"sparse[topk_path_selected],0,dispatches={calls};max_err_vs_dense={err:.2e}"]


# ---------------------------------------------------------------- section 6
def bench_mixed_fleet(fast: bool) -> list[str]:
    """Heterogeneous fleet through ONE grouped aggregate vs single-codec
    fleets of the same size: per-fleet wire bytes and reduce time."""
    from repro.core import BandwidthCodecPolicy, MixedCodec
    from repro.core.cost_model import PROFILES

    sweep = [(6, 1 << 14)] if fast else [(6, 1 << 16), (12, 1 << 18)]
    device_cycle = ("pixel-4", "jetson-tx2-gpu", "tpu-v5e-chip")
    rows = []
    rng = np.random.default_rng(0)
    for c, n in sweep:
        fleet = [PROFILES[device_cycle[i % 3]] for i in range(c)]
        mixed = MixedCodec.from_policy(BandwidthCodecPolicy(), fleet)
        deltas = jnp.asarray(rng.normal(size=(c, n)) * 0.01, jnp.float32)
        w = jnp.asarray(rng.random(c) + 0.1, jnp.float32)

        base = {}
        for name, codec in CODECS.items():
            fn = jax.jit(
                lambda d, w, s, codec=codec: codec.aggregate_batch(d, w, s)[0]
            )
            us = _timeit_median(fn, deltas, w, codec.init_client_state(c, n))
            base[name] = (us, codec.wire_bytes(n) * c)

        # the TopK group must stay sparse inside the mixed aggregate too
        from repro.core.compression import ban_topk_densify

        with ban_topk_densify():
            fn_m = jax.jit(lambda d, w, s: mixed.aggregate_batch(d, w, s)[0])
            us_m = _timeit_median(fn_m, deltas, w, mixed.init_client_state(c, n))
        wire_m = sum(mixed.wire_bytes(n))
        assert wire_m < base["fp32"][1], "mixed fleet must ship less than fp32"
        derived = ";".join(
            f"{name}_us={us:.0f};{name}_wire={wb}" for name, (us, wb) in base.items()
        )
        rows.append(
            f"mixed[fleet_C{c}_N{n}],{us_m:.0f},"
            f"fleet_wire_bytes={wire_m};"
            f"wire_vs_fp32={base['fp32'][1] / wire_m:.2f}x;{derived}"
        )
    return rows


# ---------------------------------------------------------------- section 7
def bench_lora_frontier(rounds: int, smoke: bool,
                        out: str = "BENCH_lora.json") -> list[str]:
    """LoRA accuracy-vs-wire frontier + the >= 10x acceptance guard."""
    import json

    from repro.core import LoRACodec, SegmentMap

    rows, frontier = [], []
    # wire at FULL LLM scale: abstract shapes only, nothing allocated
    for arch in ("qwen3-0.6b", "mixtral-8x7b"):
        m = build_model(arch)
        shapes = jax.eval_shape(m.init, jax.random.key(0))
        segs = SegmentMap.from_tree(shapes)
        n = segs.n_params
        int8_w = Int8Codec().with_segments(segs).wire_bytes(n)
        fp32_w = CODECS["fp32"].wire_bytes(n)
        for rank in (1, 4, 16, 64):
            lora_w = LoRACodec(rank=rank, factor_codec=Int8Codec()) \
                .with_segments(segs).wire_bytes(n)
            assert lora_w < int8_w, (
                f"{arch} r{rank}: lora wire {lora_w} >= int8 {int8_w}"
            )
            rows.append(
                f"lora[{arch}/r{rank}],0,bytes={lora_w};"
                f"vs_int8={int8_w / lora_w:.1f}x;vs_fp32={fp32_w / lora_w:.1f}x"
            )
            frontier.append({"arch": arch, "rank": rank, "n_params": n,
                             "lora_bytes": lora_w, "int8_bytes": int8_w,
                             "fp32_bytes": fp32_w})

    # accuracy-vs-wire on the reduced LM: the acceptance run
    m, params, train, eval_batch = _lm_setup()
    segs = SegmentMap.from_tree(params)
    n = tree_size(params)
    runs = {}
    for name, codec in (
        ("fp32", CODECS["fp32"]),
        ("int8", Int8Codec().with_segments(segs)),
        ("lora_r4", LoRACodec(rank=4, factor_codec=Int8Codec())
            .with_segments(segs)),
    ):
        t0 = time.perf_counter()
        loss, uplink = _run_rounds(m, params, train, eval_batch, codec, rounds)
        us = (time.perf_counter() - t0) * 1e6
        wire = codec.wire_bytes(n)
        runs[name] = {"eval_loss": loss, "wire_bytes": wire,
                      "uplink_bytes": uplink}
        rows.append(
            f"lora[qwen3_reduced/{name}],{us:.0f},"
            f"eval_loss={loss:.4f};wire_bytes={wire};uplink_bytes={uplink}"
        )

    with open(out, "w") as f:
        json.dump({"bench": "lora", "rounds": rounds, "smoke": smoke,
                   "frontier": frontier, "runs": runs}, f, indent=2,
                  default=float)
    rows.append(f"lora[json],0,wrote={out}")

    # acceptance: >= 10x less wire than dense Int8 at matched final loss
    ratio = runs["int8"]["wire_bytes"] / runs["lora_r4"]["wire_bytes"]
    assert ratio >= 10.0, f"lora wire only {ratio:.1f}x under int8"
    li, ll = runs["int8"]["eval_loss"], runs["lora_r4"]["eval_loss"]
    assert abs(ll - li) <= 0.05 * abs(li), (
        f"lora loss {ll:.4f} not matched to int8 {li:.4f}"
    )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny model, 2 rounds, small kernel shapes")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()
    rounds = args.rounds if args.rounds is not None else (
        2 if args.smoke else 3 if args.fast else 10
    )

    print("name,us_per_call,derived")
    for row in bench_wire_bytes():
        print(row)
    for row in bench_accuracy_vs_bytes(rounds, smoke=args.smoke):
        print(row)
    for row in bench_kernel(args.fast or args.smoke):
        print(row)
    for row in bench_topk_reduce(args.fast or args.smoke):
        print(row)
    for row in check_sparse_path_selected():
        print(row)
    for row in bench_mixed_fleet(args.fast or args.smoke):
        print(row)
    for row in bench_lora_frontier(rounds, smoke=args.smoke):
        print(row)


if __name__ == "__main__":
    main()

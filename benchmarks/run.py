"""Benchmark harness — one entry per paper table + framework micro-benches.

Prints ``name,us_per_call,derived`` CSV rows (derived = the quantity the
paper's table reports: accuracy / minutes / kJ or bandwidth).

  PYTHONPATH=src python -m benchmarks.run [--fast | --smoke]

``--smoke`` runs only the framework micro-benches (round step, aggregation,
compression) — the CI drift gate that every bench entry point still matches
the library's current signatures; ``--fast`` additionally runs the paper
tables at reduced grids.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_paper_tables(fast: bool) -> list[str]:
    from benchmarks.paper_tables import table2a, table2b, table3

    rounds = 2 if fast else 3
    rows = []
    t0 = time.perf_counter()
    for label, acc, mins, kj in table2a(rounds=rounds, epochs_grid=(1, 3)):
        sys.stdout.flush()
        rows.append(f"table2a[{label}],{(time.perf_counter()-t0)*1e6:.0f},acc={acc:.3f};mins={mins:.2f};kJ={kj:.2f}")
    for label, acc, mins, kj in table2b(rounds=rounds, clients_grid=(4, 7) if fast else (4, 7, 10)):
        rows.append(f"table2b[{label}],{(time.perf_counter()-t0)*1e6:.0f},acc={acc:.3f};mins={mins:.2f};kJ={kj:.2f}")
    for label, acc, mins, kj in table3(rounds=rounds):
        rows.append(f"table3[{label}],{(time.perf_counter()-t0)*1e6:.0f},acc={acc:.3f};mins={mins:.2f};kJ={kj:.2f}")
    return rows


def bench_aggregation_kernel() -> list[str]:
    """fedavg_reduce kernel (interpret) vs jnp oracle vs tree-level mean."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    c, n = 8, 1 << 20
    u = jnp.asarray(rng.normal(size=(c, n)), jnp.float32)
    w = jnp.asarray(rng.random(c) + 0.1, jnp.float32)
    us_ref = _timeit(jax.jit(ref.fedavg_reduce), u, w)
    gbps = (c * n * 4) / (us_ref / 1e6) / 1e9
    return [
        f"fedavg_reduce_oracle_{c}x{n},{us_ref:.0f},GBps={gbps:.1f}",
    ]


def bench_round_step() -> list[str]:
    """Jitted FL round step throughput (reduced LM, parallel mode)."""
    from repro.configs.base import get_config
    from repro.core import FedAvg, RoundSpec, make_round_step
    from repro.data.loader import lm_round_batch
    from repro.models import build_model
    from repro.optim import sgd

    cfg = get_config("qwen3-0.6b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    C, steps, B, S = 2, 1, 2, 64
    rs = jax.jit(make_round_step(
        m.loss_fn, sgd(0.05), FedAvg(), RoundSpec(max_steps=steps, execution_mode="parallel")
    ))
    batch = lm_round_batch(n_clients=C, steps=steps, batch_size=B, seq_len=S,
                           vocab_size=cfg.vocab_size, seed=0)
    w = jnp.ones(C); bud = jnp.full((C,), steps, jnp.int32)

    def run(p):
        new, _, _, met = rs(p, (), (), batch, w, bud, 0)
        return met["client_loss_mean"]

    us = _timeit(run, params, n=3)
    toks = C * steps * B * S
    return [f"fl_round_step_reduced,{us:.0f},tokens_per_s={toks/(us/1e6):.0f}"]


def bench_compression() -> list[str]:
    from repro.core.compression import CompressedPsum, fp32_collective_bytes
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1 << 20,)), jnp.float32)
    q8 = jax.jit(lambda x: ref.quantize_int8(x))
    us = _timeit(q8, x)
    rows = [f"quantize_int8_1M,{us:.0f},GBps={(x.size*4)/(us/1e6)/1e9:.1f}"]
    # collective wire drift gate: shared-scale pack/unpack roundtrip + the
    # per-hop byte reduction the cost model bills (mesh_bench measures the
    # full round; this row just pins the entry points)
    scales = jnp.maximum(
        jnp.max(jnp.abs(x.reshape(-1, 256)), axis=1), 1e-8
    ) / 127.0
    cpk = jax.jit(
        lambda x, s: ref.collective_unpack(ref.collective_pack(x, s), s)
    )
    us_c = _timeit(cpk, x, scales)
    ratio = fp32_collective_bytes(x.size) / CompressedPsum().collective_bytes(
        x.size
    )
    rows.append(f"collective_pack_1M,{us_c:.0f},wire_vs_fp32={ratio:.1f}x")
    return rows


def bench_structured_wire() -> list[str]:
    """Leafwise structured-update drift gate: segment the reduced LM's
    params, push one update through the LoRA factor wire, and report the
    reduction vs dense Int8 (guards the segmented codec entry points)."""
    from repro.configs.base import get_config
    from repro.core import Int8Codec, LoRACodec, SegmentMap
    from repro.models import build_model
    from repro.utils.pytree import tree_flatten_to_vector, tree_size

    cfg = get_config("qwen3-0.6b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    segs = SegmentMap.from_tree(params)
    n = tree_size(params)
    lora = LoRACodec(rank=4, factor_codec=Int8Codec()).with_segments(segs)
    vec = 0.01 * tree_flatten_to_vector(params)
    enc = jax.jit(lora.encode_structured)(vec)
    us = _timeit(lambda v: lora.decode_structured(lora.encode_structured(v)),
                 vec, n=3)
    int8_w = Int8Codec().with_segments(segs).wire_bytes(n)
    lora_w = lora.wire_bytes(n)
    assert lora_w < int8_w and len(enc.payloads) == len(segs)
    return [
        f"structured_lora_roundtrip_{len(segs)}segs,{us:.0f},"
        f"wire_bytes={lora_w};vs_int8={int8_w / lora_w:.1f}x"
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="micro-benches only (skip the paper tables)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for row in bench_round_step():
        print(row)
    for row in bench_aggregation_kernel():
        print(row)
    for row in bench_compression():
        print(row)
    for row in bench_structured_wire():
        print(row)
    if not args.smoke:
        for row in bench_paper_tables(args.fast):
            print(row)


if __name__ == "__main__":
    main()

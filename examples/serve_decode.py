"""Batched serving demo: prefill a prompt batch then decode tokens, on any
assigned architecture (reduced size on CPU; ring-cache SWA, MLA latent cache
and recurrent-state decode all exercised by --arch choice).

  PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
  PYTHONPATH=src python examples/serve_decode.py --arch xlstm-1.3b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main()

"""End-to-end driver: federated fine-tuning of a transformer LM with the
jitted pod-scale round step (parallel client mode) on a learnable synthetic
stream, with a selectable uplink wire format.

``--codec lora`` builds the segment-structured ``LoRACodec`` from the model's
own parameter tree (``SegmentMap.from_tree``): matrix leaves — including the
stacked-expert 3-D tensors of the MoE archs, which fold (E, d_in, d_out) ->
(E*d_in, d_out) — ship rank-``--rank`` factors (int8-quantized), everything
else falls back to plain Int8.  ``--codec int8`` / ``fp32`` run the same
loop on the dense wire for comparison.

Default runs a reduced dense model for a quick demo; ``--arch mixtral-8x7b``
exercises the MoE stack (reduced: 4 experts), and ``--steps-total 300
--d-model 512 --layers 8`` approaches the ~100M-param regime (slow on 1 CPU
core).

  PYTHONPATH=src python examples/federated_llm_finetune.py --rounds 8
  PYTHONPATH=src python examples/federated_llm_finetune.py \
      --arch mixtral-8x7b --codec lora --rank 4
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import (
    FedAvg, Int8Codec, LoRACodec, NullCodec, RoundSpec, SegmentMap,
    make_round_step,
)
from repro.data.loader import lm_round_batch
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_size


def build_codec(name: str, params, rank: int):
    """-> (codec, int8 reference codec) — both on the same segment map so
    the per-round wire comparison is apples-to-apples."""
    segs = SegmentMap.from_tree(params)
    int8 = Int8Codec().with_segments(segs)
    if name == "fp32":
        return NullCodec().with_segments(segs), int8
    if name == "int8":
        return int8, int8
    if name == "lora":
        lora = LoRACodec(
            rank=rank, factor_codec=Int8Codec(), fallback=Int8Codec()
        ).with_segments(segs)
        return lora, int8
    raise ValueError(f"unknown codec {name!r}: expected fp32 | int8 | lora")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--codec", default="fp32", choices=["fp32", "int8", "lora"])
    ap.add_argument("--rank", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced(n_layers=args.layers, d_model=args.d_model)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_params = tree_size(params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    codec, int8 = build_codec(args.codec, params, args.rank)
    wire = codec.wire_bytes(n_params)
    print(f"codec={args.codec} uplink {wire/1e3:.1f} KB/client/round "
          f"({int8.wire_bytes(n_params)/wire:.1f}x vs int8 dense)")

    strategy = FedAvg()
    round_step = jax.jit(make_round_step(
        model.loss_fn, sgd(0.1), strategy,
        RoundSpec(max_steps=args.local_steps, execution_mode="parallel",
                  codec=codec),
    ))

    weights = jnp.ones((args.clients,))
    budgets = jnp.full((args.clients,), args.local_steps, jnp.int32)
    state = strategy.init_state(params)
    client_state = codec.init_client_state(args.clients, n_params)
    for rnd in range(1, args.rounds + 1):
        batch = lm_round_batch(
            n_clients=args.clients, steps=args.local_steps, batch_size=args.batch,
            seq_len=args.seq, vocab_size=cfg.vocab_size, seed=rnd,
        )
        params, state, client_state, metrics = round_step(
            params, state, client_state, batch, weights, budgets, rnd
        )
        print(f"round {rnd:2d}  mean client CE loss: "
              f"{float(metrics['client_loss_mean']):.4f}")
    return params, float(metrics["client_loss_mean"])


if __name__ == "__main__":
    main()

"""End-to-end driver: federated training of a transformer LM with the jitted
pod-scale round step (parallel client mode) on a learnable synthetic stream.

Default runs a reduced model for a quick demo; ``--steps-total 300 --d-model
512 --layers 8`` approaches the ~100M-param regime (slow on 1 CPU core).

  PYTHONPATH=src python examples/federated_llm_finetune.py --rounds 8
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import FedAvg, RoundSpec, make_round_step
from repro.data.loader import lm_round_batch
from repro.models import build_model
from repro.optim import sgd
from repro.utils.pytree import tree_size

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--rounds", type=int, default=8)
ap.add_argument("--clients", type=int, default=4)
ap.add_argument("--local-steps", type=int, default=4)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--d-model", type=int, default=128)
ap.add_argument("--layers", type=int, default=2)
args = ap.parse_args()

cfg = get_config(args.arch).reduced(n_layers=args.layers, d_model=args.d_model)
model = build_model(cfg)
params = model.init(jax.random.key(0))
print(f"arch={cfg.name} params={tree_size(params)/1e6:.1f}M")

strategy = FedAvg()
round_step = jax.jit(make_round_step(
    model.loss_fn, sgd(0.1), strategy,
    RoundSpec(max_steps=args.local_steps, execution_mode="parallel"),
))

weights = jnp.ones((args.clients,))
budgets = jnp.full((args.clients,), args.local_steps, jnp.int32)
state = strategy.init_state(params)
client_state = ()  # NullCodec default: no codec-owned per-client state
for rnd in range(1, args.rounds + 1):
    batch = lm_round_batch(
        n_clients=args.clients, steps=args.local_steps, batch_size=args.batch,
        seq_len=args.seq, vocab_size=cfg.vocab_size, seed=rnd,
    )
    params, state, client_state, metrics = round_step(
        params, state, client_state, batch, weights, budgets, rnd
    )
    print(f"round {rnd:2d}  mean client CE loss: {float(metrics['client_loss_mean']):.4f}")

"""The paper's Table-3 experiment: computational heterogeneity + the
processor-specific cutoff tau.

A mixed GPU/CPU Jetson fleet trains ResNet (reduced) with FedAvg; then we set
tau = the GPU fleet's round time, so CPU clients ship partial updates and the
round wall-clock equalizes — trading a little accuracy for a 1.27x speedup.
The same hardware facts drive per-device codec selection
(``BandwidthCodecPolicy``): every client ships the wire its uplink can
afford, and the History charges each one its actual payload bytes.

  PYTHONPATH=src python examples/heterogeneous_cutoff.py
"""
import jax

from repro.configs.resnet18_cifar10 import CNN_CONFIG
from repro.core import BandwidthCodecPolicy, FedTau, JaxClient, PROFILES, Server
from repro.core.server import make_cost_model_for
from repro.data.federated import dirichlet_partition
from repro.data.synthetic import make_classification
from repro.models import resnet

cfg = CNN_CONFIG.reduced()
data = make_classification(n=1200, num_classes=cfg.num_classes,
                           shape=(cfg.image_size, cfg.image_size, 3), noise=1.2)
shards = dirichlet_partition(data, n_clients=4, alpha=1.0)
loss_fn = lambda p, b: resnet.loss_fn(cfg, p, b)

# half the fleet is GPU, half CPU (the paper's heterogeneity scenario)
profiles = [PROFILES["jetson-tx2-gpu"], PROFILES["jetson-tx2-cpu"]] * 2

params = resnet.init_params(jax.random.key(0), cfg)
clients = [JaxClient(client_id=s.client_id, loss_fn=loss_fn, dataset=s,
                     batch_size=32, device_profile=p.name)
           for s, p in zip(shards, profiles)]
cost_model = make_cost_model_for(params, profiles)
spe = clients[0].steps_per_epoch()
# slow uplinks sparsify, edge boards quantize (Jetson uplink=80Mbps -> Int8)
policy = BandwidthCodecPolicy()

for label, tau in [
    ("no cutoff (tau=0)", 0.0),
    ("tau = GPU round time", cost_model.tau_for_profile(
        "jetson-tx2-gpu", epochs=3, steps_per_epoch=spe)),
]:
    strat = FedTau(local_epochs=3, local_lr=0.05, tau_s=tau,
                   cost_model=cost_model, steps_per_epoch=spe,
                   codec_policy=policy)
    server = Server(strategy=strat, clients=clients, cost_model=cost_model)
    server.logger.quiet = True
    p0 = resnet.init_params(jax.random.key(0), cfg)
    _, hist = server.run(p0, num_rounds=3)
    budgets = strat.client_step_budgets(range(4))
    comm_mb = sum(r.comm_bytes for r in hist.rounds) / 1e6
    print(f"{label:>24}: acc={hist.final_accuracy():.3f} "
          f"wall={hist.total_time_s/60:.2f}min energy={hist.total_energy_j/1e3:.1f}kJ "
          f"comm={comm_mb:.1f}MB step-budgets={budgets}")

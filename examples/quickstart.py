"""Quickstart: federated training of the paper's Android head model in ~30
lines — Server + FedAvg + on-device-style clients + system-cost accounting —
then the same loop at fleet scale: a 16-client cohort sampled per round from
a 100k-device packed population.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (
    CostModel, FedAvg, JaxClient, LazyClientPool, PROFILES, Population, Server,
)
from repro.core.server import make_cost_model_for
from repro.data.federated import ClientDataset, dirichlet_partition
from repro.data.synthetic import make_features
from repro.models import build_model

model = build_model("mobilenet-head-office31")   # frozen base + 2-layer head
data = make_features(n=2000, num_classes=31, feature_dim=model.cfg.feature_dim)
shards = dirichlet_partition(data, n_clients=5, alpha=1.0)

params = model.init(jax.random.key(0))
mask = model.trainable_mask(params)              # FL trains only the head
clients = [
    JaxClient(client_id=s.client_id, loss_fn=model.loss_fn, dataset=s,
              batch_size=32, trainable_mask=mask, device_profile="pixel-4")
    for s in shards
]

cost_model = make_cost_model_for(params, [PROFILES["pixel-4"]] * 5)
server = Server(strategy=FedAvg(local_epochs=2, local_lr=0.1),
                clients=clients, cost_model=cost_model)

final_params, history = server.run(params, num_rounds=5)
print(f"final accuracy: {history.final_accuracy():.3f}")
print(f"simulated fleet time: {history.total_time_s/60:.2f} min, "
      f"energy: {history.total_energy_j/1e3:.2f} kJ")

# ---- population mode: the same loop over a 100k-device fleet ----
# A packed Population stores ~1 byte/device; each round samples a 16-client
# cohort id-first, and the LazyClientPool materializes only those clients.
population = Population.synthetic(100_000, seed=0)


def make_client(cid: int) -> JaxClient:
    shard = shards[cid % len(shards)]          # demo data: reuse the 5 shards
    return JaxClient(client_id=cid, loss_fn=model.loss_fn, batch_size=32,
                     dataset=ClientDataset(client_id=cid, x=shard.x, y=shard.y),
                     trainable_mask=mask,
                     device_profile=population.profile(cid).name)


fleet_server = Server(
    strategy=FedAvg(local_epochs=2, local_lr=0.1),
    clients=LazyClientPool(population, make_client, capacity=64),
    cost_model=CostModel(profiles=[], update_bytes=cost_model.update_bytes,
                         population=population),
    population=population, cohort_size=16,
)
final_params, history = fleet_server.run(params, num_rounds=3)
print(f"population mode ({len(population):,} devices, cohort 16): "
      f"accuracy {history.final_accuracy():.3f}, "
      f"fleet time {history.total_time_s/60:.2f} min")

"""Quickstart: federated training of the paper's Android head model in ~30
lines — Server + FedAvg + on-device-style clients + system-cost accounting.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import FedAvg, JaxClient, PROFILES, Server
from repro.core.server import make_cost_model_for
from repro.data.federated import dirichlet_partition
from repro.data.synthetic import make_features
from repro.models import build_model

model = build_model("mobilenet-head-office31")   # frozen base + 2-layer head
data = make_features(n=2000, num_classes=31, feature_dim=model.cfg.feature_dim)
shards = dirichlet_partition(data, n_clients=5, alpha=1.0)

params = model.init(jax.random.key(0))
mask = model.trainable_mask(params)              # FL trains only the head
clients = [
    JaxClient(client_id=s.client_id, loss_fn=model.loss_fn, dataset=s,
              batch_size=32, trainable_mask=mask, device_profile="pixel-4")
    for s in shards
]

cost_model = make_cost_model_for(params, [PROFILES["pixel-4"]] * 5)
server = Server(strategy=FedAvg(local_epochs=2, local_lr=0.1),
                clients=clients, cost_model=cost_model)

final_params, history = server.run(params, num_rounds=5)
print(f"final accuracy: {history.final_accuracy():.3f}")
print(f"simulated fleet time: {history.total_time_s/60:.2f} min, "
      f"energy: {history.total_energy_j/1e3:.2f} kJ")
